"""Sharding-rule table unit tests: TP/FSDP dims per parameter path, spec
construction, and init/use consistency (the invariants the dry-run relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.dist.collectives import AxisCtx
from repro.dist.sharding import tp_dim, tree_param_specs
from repro.models.common import fsdp_participates, fsdp_shard_dim
from repro.models.model import build_model


class TestTPDim:
    @pytest.mark.parametrize("path,ndim,kv,expect", [
        ("blocks/attn/wq", 2, True, 1),
        ("blocks/attn/wk", 2, True, 1),
        ("blocks/attn/wk", 2, False, None),    # replicated KV
        ("blocks/attn/wo", 2, True, 0),
        ("blocks/mlp/w_up", 2, True, 1),
        ("blocks/mlp/w_gate", 2, True, 1),
        ("blocks/mlp/w_down", 2, True, 0),
        ("blocks/moe/w_up", 3, True, 0),       # expert dim
        ("blocks/moe/w_down", 3, True, 0),
        ("embed/table", 2, True, 0),           # vocab rows
        ("unembed/w", 2, True, 1),             # vocab cols
        ("blocks/ssm/wx", 2, True, 1),
        ("blocks/ssm/w_bc", 2, True, None),    # replicated (single group)
        ("blocks/ssm/conv_x", 2, True, 1),
        ("blocks/ssm/norm", 1, True, 0),       # gated-norm over d_inner_local
        ("blocks/ssm/a_log", 1, True, 0),
        ("blocks/ln1", 1, True, None),
        ("adapter", 2, True, None),
    ])
    def test_table(self, path, ndim, kv, expect):
        assert tp_dim(path, ndim, kv) == expect


class TestFSDPRules:
    def test_shard_dim_defaults_and_exceptions(self):
        assert fsdp_shard_dim("blocks/attn/wq", 2) == 0        # d_model rows
        assert fsdp_shard_dim("blocks/mlp/w_down", 2) == 1     # exception
        assert fsdp_shard_dim("embed/table", 2) == 1           # exception
        assert fsdp_shard_dim("blocks/moe/w_up", 3) == 1       # d dim

    def test_participation_scale_free(self):
        """The decision must be identical on sharded and unsharded shapes."""
        full = (4096, 512)
        sharded = (4096 // 16, 512)   # dim0 is the rule dim for wq
        assert fsdp_participates("blocks/attn/wq", full, 16) == \
            fsdp_participates("blocks/attn/wq", sharded, 16)

    def test_small_and_excluded(self):
        assert not fsdp_participates("blocks/ssm/conv_x", (4, 3072), 16)
        assert not fsdp_participates("blocks/moe/router", (4096, 128), 16)
        assert not fsdp_participates("blocks/ln1", (4096,), 16)
        assert not fsdp_participates("x", (64, 8), 16)  # other dims too small


class TestSpecsCoverAllArchs:
    @pytest.mark.parametrize("arch", ARCH_NAMES)
    def test_specs_consistent_with_storage(self, arch):
        """Every leaf gets a spec whose sharded dims divide the stored shape,
        at both single-pod (fsdp=16) and multi-pod (fsdp=32) sizes."""
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda key: model.init(key, 16),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        for fsdp, batch_axes in ((16, ("data",)), (32, ("pod", "data"))):
            axes = AxisCtx(batch_axes=batch_axes, model_axis="model",
                           fsdp_axes=batch_axes)
            specs = tree_param_specs(shapes, cfg, axes, fsdp)
            flat_l = jax.tree_util.tree_leaves(shapes)
            flat_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: hasattr(x, "index") or x is None)
            assert len(flat_l) == len(flat_s)
            for leaf, spec in zip(flat_l, flat_s):
                if spec is None:
                    continue
                for d, entry in enumerate(tuple(spec)):
                    if entry is None:
                        continue
                    names = entry if isinstance(entry, tuple) else (entry,)
                    factor = 1
                    for nm in names:
                        factor *= {"model": 16, "data": 16 if fsdp == 16 else 16,
                                   "pod": 2}[nm]
                    # spec axes beyond tp were already applied to storage:
                    # only the fsdp factor must still divide the stored dim
                    fs = 1
                    for nm in names:
                        if nm in batch_axes:
                            fs *= {"data": 16, "pod": 2}[nm]
                    assert leaf.shape[d] % fs == 0, (arch, leaf.shape, spec, d)
