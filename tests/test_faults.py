"""repro.faults tests: seeded schedule determinism, retransmission energy
accounting, corruption + aggregation gate, warm GBD re-solve, resilient
orchestrator rounds, and the bitwise kill-and-resume contract under faults.
"""

import jax
import numpy as np
import pytest

from repro.core.energy import heterogeneous_fleet, memory_capacities
from repro.faults import (
    FaultPlan,
    FaultSchedule,
    TransmissionOutcome,
    UpdateFaults,
    gate_mask,
    inject_corruption,
    transmit_update,
)
from repro.fed import FLOrchestrator, OrchestratorConfig

from test_fed_integration import batch_fn_for, make_data, make_sim

PLAN = FaultPlan(dropout_prob=0.15, fade_prob=0.2, packet_loss=0.1,
                 slowdown_prob=0.1, corrupt_prob=0.2)


def _orch(n=6, rounds=8, tmp="", **kw):
    fleet = heterogeneous_fleet(n, seed=0, group_step_mhz=5.0)
    caps = memory_capacities(n, lo_mb=2.0, hi_mb=8.0) * 1e6
    cfg = OrchestratorConfig(n_devices=n, n_rounds=rounds,
                             model_dim_d=1 << 16, ckpt_dir=tmp, **kw)
    return FLOrchestrator(cfg, fleet, caps, grad_bytes=1e6)


class TestFaultPlan:
    def test_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(dropout_prob=1.5)
        with pytest.raises(ValueError):
            FaultPlan(packet_loss=1.0)       # can never deliver
        with pytest.raises(ValueError):
            FaultPlan(chunk_bytes=0)
        with pytest.raises(ValueError):
            FaultPlan(max_retries=-1)

    def test_dict_roundtrip_rejects_unknown_keys(self):
        p = FaultPlan(packet_loss=0.2, max_retries=2)
        assert FaultPlan.from_dict(p.to_dict()) == p
        with pytest.raises(ValueError):
            FaultPlan.from_dict({"packet_los": 0.2})   # typo'd key

    def test_active(self):
        assert not FaultPlan().active
        assert FaultPlan(packet_loss=0.01).active
        assert FaultPlan(dropout_prob=0.01).active


class TestScheduleDeterminism:
    def test_same_seed_same_realizations(self):
        a = FaultSchedule(plan=PLAN, seed=7, n_devices=6)
        b = FaultSchedule(plan=PLAN, seed=7, n_devices=6)
        for r in (0, 3, 11):
            ra, rb = a.round_faults(r), b.round_faults(r)
            np.testing.assert_array_equal(ra.drop, rb.drop)
            np.testing.assert_array_equal(ra.fade_db, rb.fade_db)
            np.testing.assert_array_equal(ra.slow, rb.slow)
            np.testing.assert_array_equal(ra.corrupt_kind, rb.corrupt_kind)

    def test_rounds_and_seeds_differ(self):
        s = FaultSchedule(plan=PLAN, seed=7, n_devices=64)
        other_round = s.round_faults(1)
        other_seed = FaultSchedule(plan=PLAN, seed=8,
                                   n_devices=64).round_faults(0)
        base = s.round_faults(0)
        assert not np.array_equal(base.drop, other_round.drop) \
            or not np.array_equal(base.fade_db, other_round.fade_db)
        assert not np.array_equal(base.drop, other_seed.drop) \
            or not np.array_equal(base.fade_db, other_seed.fade_db)

    def test_chunk_streams_are_per_client(self):
        """Client 0 consuming extra draws (retries) must not perturb what
        client 1's stream produces — the replay-stability property."""
        s = FaultSchedule(plan=PLAN, seed=7, n_devices=2)
        r0 = s.chunk_rng(0, 0)
        _ = r0.random(1000)                 # client 0 retries a lot
        want = np.random.default_rng((7, 0xC4A7, 0, 1)).random(8)
        np.testing.assert_array_equal(s.chunk_rng(0, 1).random(8), want)

    def test_round_faults_independent_of_call_order(self):
        s = FaultSchedule(plan=PLAN, seed=7, n_devices=6)
        forward = [s.round_faults(r).drop for r in range(4)]
        backward = [s.round_faults(r).drop for r in reversed(range(4))]
        for f, b in zip(forward, reversed(backward)):
            np.testing.assert_array_equal(f, b)


class TestTransmitUpdate:
    PLAN = FaultPlan(packet_loss=0.3, chunk_bytes=1e3, max_retries=4,
                     backoff_base_s=0.01)

    def test_lossless_is_the_planned_optimum(self):
        """Zero loss: exactly one attempt per chunk, energy == P * T."""
        out = transmit_update(8e4, rate_bps=1e5, p_comm_w=0.5, loss_prob=0.0,
                              rng=np.random.default_rng(0), plan=self.PLAN)
        assert out.delivered
        assert out.chunks == 10 and out.attempts == 10
        assert out.retransmissions == 0 and out.e_retx_j == 0.0
        assert out.t_comm_s == pytest.approx(8e4 / 1e5)
        assert out.e_comm_j == pytest.approx(0.5 * 8e4 / 1e5)

    def test_every_attempt_is_billed(self):
        out = transmit_update(8e4, rate_bps=1e5, p_comm_w=0.5, loss_prob=0.3,
                              rng=np.random.default_rng(1), plan=self.PLAN)
        e_chunk = 0.5 * (8e4 / 10) / 1e5
        assert out.attempts > out.chunks            # some retries happened
        assert out.e_comm_j == pytest.approx(out.attempts * e_chunk)
        assert out.e_retx_j == pytest.approx(out.retransmissions * e_chunk)
        # backoff waits add latency beyond the on-air time, but no energy
        assert out.t_comm_s > out.attempts * (8e4 / 10) / 1e5 - 1e-12

    def test_deadline_abort_keeps_energy_spent(self):
        out = transmit_update(8e4, rate_bps=1e5, p_comm_w=0.5, loss_prob=0.0,
                              rng=np.random.default_rng(0), plan=self.PLAN,
                              budget_s=0.3)         # fits 3 of 10 chunks
        assert not out.delivered
        assert out.attempts == 3
        assert out.e_comm_j == pytest.approx(3 * 0.5 * (8e4 / 10) / 1e5)

    def test_retry_exhaustion_fails_delivery(self):
        plan = FaultPlan(packet_loss=0.9, chunk_bytes=1e3, max_retries=1)
        out = transmit_update(1e3 * 8, rate_bps=1e5, p_comm_w=0.5,
                              loss_prob=0.9, rng=np.random.default_rng(3),
                              plan=plan)
        assert not out.delivered and out.attempts <= 2
        assert out.e_comm_j > 0                     # the waste stays billed

    def test_zero_rate_cannot_deliver(self):
        out = transmit_update(8e4, rate_bps=0.0, p_comm_w=0.5, loss_prob=0.0,
                              rng=np.random.default_rng(0), plan=self.PLAN)
        assert out == TransmissionOutcome(False, 0, 0, 0, 0.0, 0.0, 0.0)

    def test_deterministic_given_rng_seed(self):
        outs = [transmit_update(8e4, 1e5, 0.5, 0.3,
                                np.random.default_rng((7, 0xC4A7, 0, 1)),
                                self.PLAN) for _ in range(2)]
        assert outs[0] == outs[1]


class TestCorruptionAndGate:
    def test_kind1_nan_kind2_norm_blowup(self):
        flat = np.random.default_rng(0).normal(size=1000).astype(np.float32)
        nan = inject_corruption(flat, 1, np.random.default_rng(1))
        assert np.isnan(nan).sum() == 10            # ~1% of 1000
        flip = inject_corruption(flat, 2, np.random.default_rng(1))
        assert np.isfinite(flip).all()
        assert np.linalg.norm(flip) > 1e6 * np.linalg.norm(flat)
        assert inject_corruption(flat, 0, np.random.default_rng(1)) is flat

    def test_corruption_deterministic(self):
        flat = np.arange(100, dtype=np.float64)
        a = inject_corruption(flat, 1, np.random.default_rng(5))
        b = inject_corruption(flat, 1, np.random.default_rng(5))
        np.testing.assert_array_equal(np.isnan(a), np.isnan(b))

    def test_gate_accepts_clean_rejects_damaged(self):
        norms_sq = np.array([1.0, 1.1, 0.9, 1e30, 4.0])
        finite = np.array([True, True, True, True, False])
        accept = gate_mask(norms_sq, finite, factor=50.0)
        np.testing.assert_array_equal(accept,
                                      [True, True, True, False, False])

    def test_gate_no_finite_survivor_rejects_all(self):
        accept = gate_mask(np.array([1.0, 2.0]), np.array([False, False]),
                           factor=50.0)
        assert not accept.any()

    def test_gate_bound_is_relative(self):
        """The bound self-calibrates: tiny late-training norms still pass."""
        norms_sq = np.full(4, 1e-12)
        accept = gate_mask(norms_sq, np.ones(4, dtype=bool), factor=50.0)
        assert accept.all()


class TestGatedSimulatorRound:
    def test_corrupt_update_rejected_not_aggregated(self):
        """A NaN-poisoned client must be gated out and the server update
        must equal the update computed from the clean clients alone."""
        bits = np.full(6, 32)
        batch = batch_fn_for(make_data(seed=2))(0, np.arange(6))

        sim_clean, *_ = make_sim(seed=2)
        rec_drop = None
        # reference: plain round on the same data with no faults
        ref = sim_clean.run_round(batch, bits)
        assert ref["loss"] == pytest.approx(ref["loss"])

        sim, *_ = make_sim(seed=2)
        kinds = np.array([0, 1, 0, 0, 2, 0])
        upd = UpdateFaults(kinds=kinds,
                           rngs=tuple(np.random.default_rng((9, i))
                                      for i in range(6)),
                           gate_factor=50.0)
        rec_drop = sim.run_round(batch, bits, faults=upd)
        assert rec_drop["n_rejected"] == 2
        assert not rec_drop["gate_skipped"]
        np.testing.assert_array_equal(rec_drop["accepted"],
                                      [True, False, True, True, False, True])
        # the aggregate stayed finite despite NaN/blown-up members
        leaves = jax.tree_util.tree_leaves(sim.params)
        assert all(np.isfinite(np.asarray(p)).all() for p in leaves)

    def test_all_corrupt_skips_server_update(self):
        sim, *_ = make_sim(seed=2)
        before = [np.array(p) for p in jax.tree_util.tree_leaves(sim.params)]
        batch = batch_fn_for(make_data(seed=2))(0, np.arange(6))
        upd = UpdateFaults(kinds=np.ones(6, dtype=int),
                           rngs=tuple(np.random.default_rng((9, i))
                                      for i in range(6)))
        rec = sim.run_round(batch, np.full(6, 32), faults=upd)
        assert rec["gate_skipped"] and rec["n_rejected"] == 6
        after = jax.tree_util.tree_leaves(sim.params)
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, np.asarray(a))

    def test_no_faults_path_matches_legacy(self):
        """faults=None and an all-clean UpdateFaults must not disturb the
        legacy (ungated) round's result."""
        recs = {}
        for name, faults in (
                ("legacy", None),
                ("clean", UpdateFaults(
                    kinds=np.zeros(6, dtype=int),
                    rngs=tuple(np.random.default_rng(i) for i in range(6))))):
            sim, *_ = make_sim(seed=4)
            batch = batch_fn_for(make_data(seed=4))(0, np.arange(6))
            recs[name] = sim.run_round(batch, np.full(6, 8), faults=faults)
        assert recs["legacy"]["loss"] == recs["clean"]["loss"]


class TestWarmResolve:
    def test_warm_start_matches_cold_quality(self):
        """A drift-triggered warm re-solve must stay feasible and not be
        meaningfully worse than a cold solve on the same data."""
        orch = _orch(rounds=4)
        cold = orch.resolve(0)
        gains = orch.channel.gains(0) * 0.5          # 3 dB fade everywhere
        warm = orch.resolve(0, warm=True, gains0=gains)
        assert warm["warm"] and not cold["warm"]
        opts = set(orch.cfg.precision.bit_options)
        assert set(np.unique(warm["q"])).issubset(opts)

        orch2 = _orch(rounds=4)
        orch2.resolve(0)                             # prime the incumbent
        cold2 = orch2.resolve(0, gains0=gains)       # cold on faded gains
        assert float(warm["energy_plan"]) <= float(cold2["energy_plan"]) * 1.05

    def test_drift_triggers_midcadence_resolve(self):
        plan = FaultPlan(fade_prob=1.0, fade_depth_db=20.0)
        orch = _orch(rounds=6, resolve_every=100, resolve_drift_db=6.0,
                     faults=plan)
        orch.plan_round(0)                           # cadence cold solve
        recs = [orch.plan_round(r) for r in range(1, 6)]
        assert any(r["resolved"] and r["warm_resolve"] for r in recs)

    def test_no_drift_no_resolve(self):
        orch = _orch(rounds=6, resolve_every=100, resolve_drift_db=1e9,
                     faults=FaultPlan(packet_loss=0.05))
        orch.plan_round(0)
        recs = [orch.plan_round(r) for r in range(1, 6)]
        assert not any(r["resolved"] for r in recs)


class TestResilientOrchestrator:
    def test_faulty_run_reports_resilience_counters(self):
        orch = _orch(rounds=8, faults=PLAN, resolve_drift_db=6.0)
        sim, *_ = make_sim()
        out = orch.run(sim, batch_fn_for(make_data()))
        assert len(out["history"]) == 8
        assert out["total_energy_j"] > 0
        # the fault intensities above make every counter fire within 8
        # rounds x 6 devices at this seed
        assert out["total_retransmissions"] > 0
        assert out["total_retx_energy_j"] > 0
        assert out["total_rejected"] > 0
        assert out["total_dropped_midround"] > 0
        rec = out["energy_log"][0]
        for k in ("retransmissions", "retx_energy_j", "undelivered",
                  "dropped_midround", "attempts", "e_comm_actual",
                  "drift_db", "forced_cohort"):
            assert k in rec, k
        # actual comm energy >= lossless plan for every delivering client
        for e in out["energy_log"]:
            coh = e["cohort"]
            assert (e["e_comm_actual"][coh]
                    >= e["e_comm"][coh] - 1e-12).all()
        # history rows carry the per-round retransmission accounting
        assert all("retransmissions" in h for h in out["history"])

    def test_retx_energy_is_a_surcharge_over_lossless(self):
        """Same seed, loss on vs off: the lossy run's billed comm energy
        exceeds the lossless run's by at least the retransmission energy of
        the delivered clients."""
        outs = {}
        for name, pl in (("lossless", None),
                         ("lossy", FaultPlan(packet_loss=0.25))):
            orch = _orch(rounds=4, faults=pl)
            sim, *_ = make_sim()
            outs[name] = orch.run(sim, batch_fn_for(make_data()))
        assert outs["lossy"]["total_retransmissions"] > 0
        assert (outs["lossy"]["total_energy_j"]
                > outs["lossless"]["total_energy_j"])

    def test_fault_run_deterministic(self):
        fin = []
        for _ in range(2):
            orch = _orch(rounds=5, faults=PLAN)
            sim, *_ = make_sim(seed=3)
            out = orch.run(sim, batch_fn_for(make_data(seed=3)))
            fin.append((out["history"][-1]["loss"], out["total_energy_j"],
                        out["total_retransmissions"]))
        assert fin[0] == fin[1]


class TestKillAndResume:
    def test_resume_under_faults_is_bitwise(self, tmp_path):
        """Kill after 4 of 8 faulty rounds, resume: the global model, the
        energy log, and the resilience counters all match the uninterrupted
        run exactly (not approximately)."""
        kw = dict(faults=PLAN, resolve_drift_db=6.0, ckpt_every=2)

        orch_a = _orch(rounds=8, tmp=str(tmp_path / "a"), **kw)
        sim_a, *_ = make_sim(seed=5)
        out_a = orch_a.run(sim_a, batch_fn_for(make_data(seed=5)))

        orch_b = _orch(rounds=4, tmp=str(tmp_path / "b"), **kw)
        sim_b, *_ = make_sim(seed=5)
        orch_b.run(sim_b, batch_fn_for(make_data(seed=5)))
        orch_c = _orch(rounds=8, tmp=str(tmp_path / "b"), **kw)
        sim_c, *_ = make_sim(seed=5)
        out_c = orch_c.run(sim_c, batch_fn_for(make_data(seed=5)))

        pa = jax.tree_util.tree_leaves(sim_a.params)
        pc = jax.tree_util.tree_leaves(sim_c.params)
        for a, c in zip(pa, pc):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        assert out_a["total_energy_j"] == out_c["total_energy_j"]
        assert (out_a["total_retransmissions"]
                == out_c["total_retransmissions"])
        assert out_a["total_retx_energy_j"] == out_c["total_retx_energy_j"]
        assert len(out_c["energy_log"]) == 8         # replayed + fresh rounds

    def test_resume_refuses_a_different_fault_plan(self, tmp_path):
        ck = str(tmp_path / "ck")
        orch = _orch(rounds=4, tmp=ck, faults=PLAN, ckpt_every=2)
        sim, *_ = make_sim(seed=5)
        orch.run(sim, batch_fn_for(make_data(seed=5)))

        other = FaultPlan(packet_loss=0.4)
        orch2 = _orch(rounds=8, tmp=ck, faults=other, ckpt_every=2)
        sim2, *_ = make_sim(seed=5)
        with pytest.raises(ValueError, match="different trajectory"):
            orch2.run(sim2, batch_fn_for(make_data(seed=5)))


class TestSessionFaultOptions:
    def test_fl_sim_resume_via_runspec_is_bitwise(self, tmp_path):
        """The RunSpec surface: options.faults + options.ckpt_dir make an
        fl-sim run resumable with identical results."""
        from repro.api import RunSpec
        from repro.api.session import Session

        faults = {"dropout_prob": 0.2, "packet_loss": 0.15,
                  "corrupt_prob": 0.25}

        def spec(rounds, ck):
            return RunSpec(arch="resnet", workload="fl-sim", rounds=rounds,
                           batch=8,
                           options={"scheme": "fwq", "n_clients": 4,
                                    "lr": 0.1, "eval_every": 0,
                                    "faults": faults, "ckpt_dir": ck,
                                    "ckpt_every": 2})

        out_a = Session(spec(6, str(tmp_path / "a"))).run()
        Session(spec(3, str(tmp_path / "b"))).run()
        out_c = Session(spec(6, str(tmp_path / "b"))).run()

        assert out_a["history"][-1]["loss"] == out_c["history"][-1]["loss"]
        assert out_a["total_energy_j"] == out_c["total_energy_j"]
        assert out_a["total_retransmissions"] == out_c["total_retransmissions"]
