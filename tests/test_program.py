"""PrecisionProgram tests: the constant program's bitwise equivalence to the
static path, energy-budget demote/restore dynamics, channel_gbd vs the legacy
drift trigger, per-round comm reporting, envelope proofs, the compiled-step
cache, and serve-side paged-KV demotion."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import PrecisionPolicy, RunSpec, Session
from repro.api.program import (
    ChannelGBDProgram,
    ConstantProgram,
    EnergyBudgetProgram,
    Observation,
    PrecisionProgram,
    build_program,
)
from repro.core.energy import heterogeneous_fleet, memory_capacities
from repro.fed import FLOrchestrator, OrchestratorConfig

from test_fed_integration import batch_fn_for, make_data, make_sim


def _orch(n=6, rounds=8, **kw):
    fleet = heterogeneous_fleet(n, seed=0, group_step_mhz=5.0)
    caps = memory_capacities(n, lo_mb=2.0, hi_mb=8.0) * 1e6
    cfg = OrchestratorConfig(n_devices=n, n_rounds=rounds,
                             model_dim_d=1 << 16, **kw)
    return FLOrchestrator(cfg, fleet, caps, grad_bytes=1e6)


def _run(orch, rounds=None, n=6, seed=0):
    sim, _, _ = make_sim(n_clients=n, seed=seed)
    out = orch.run(sim, batch_fn_for(make_data(n_clients=n, seed=seed)))
    return sim, out


class TestRegistry:
    def test_dict_roundtrip(self):
        for prog in (ConstantProgram(kv_watermark=0.75),
                     EnergyBudgetProgram(50.0, slack=1.1, restore=0.8,
                                         demote_comm=False),
                     ChannelGBDProgram(4.0)):
            back = PrecisionProgram.from_dict(prog.to_dict())
            assert type(back) is type(prog)
            assert back.to_dict() == prog.to_dict()

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="constant"):
            PrecisionProgram.from_dict({"kind": "pid_controller"})

    def test_build_program_forms(self):
        assert isinstance(build_program(None), ConstantProgram)
        assert isinstance(build_program("constant"), ConstantProgram)
        eb = build_program({"kind": "energy_budget", "budget_j": 9.0})
        assert isinstance(eb, EnergyBudgetProgram) and eb.budget_j == 9.0
        assert build_program(eb) is eb
        with pytest.raises(TypeError):
            build_program(42)

    def test_validation(self):
        with pytest.raises(ValueError):
            EnergyBudgetProgram(0.0)
        with pytest.raises(ValueError):
            EnergyBudgetProgram(10.0, slack=1.0, restore=1.2)
        with pytest.raises(ValueError):
            ChannelGBDProgram(0.0)


class TestConstantBitwise:
    def test_constant_program_reproduces_static_run(self):
        """The acceptance contract: params + history + energy_log of a
        constant-program run are bitwise equal to the pre-program static
        path (identity fast path all the way down)."""
        sim_a, out_a = _run(_orch(rounds=4))
        sim_b, out_b = _run(_orch(rounds=4, program="constant"))

        for la, lb in zip(jax.tree_util.tree_leaves(sim_a.params),
                          jax.tree_util.tree_leaves(sim_b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert len(out_a["history"]) == len(out_b["history"]) == 4
        for ha, hb in zip(out_a["history"], out_b["history"]):
            assert ha["loss"] == hb["loss"]
            np.testing.assert_array_equal(ha["bits"], hb["bits"])
            assert ha["comm_bits"] == hb["comm_bits"]
        for ea, eb in zip(out_a["energy_log"], out_b["energy_log"]):
            assert ea["energy_round"] == eb["energy_round"]
            np.testing.assert_array_equal(ea["q"], eb["q"])
        assert out_a["total_energy_j"] == out_b["total_energy_j"]
        # constant programs stay out of the output summary
        assert "program" not in out_a and "program" not in out_b

    def test_constant_identity_object(self):
        prog = ConstantProgram()
        pol = PrecisionPolicy.uniform(8)
        assert prog.policy_for_round(0, pol, Observation(round=0)) is pol


class TestEnergyBudget:
    def test_demotes_then_restores_around_spike(self):
        """Synthetic spend trace: a mid-run energy spike pushes cumulative
        spend over pace (demote, twice), then flat spend falls back under
        the restore fraction (restore back up the lattice)."""
        prog = EnergyBudgetProgram(100.0)     # 10 rounds -> pace 10 J/round
        pol = PrecisionPolicy.uniform(32, comm=32)

        def step(r, cum):
            return prog.policy_for_round(
                r, pol, Observation(round=r, rounds_total=10,
                                    energy_cum_j=cum))

        assert step(1, 10.0) is pol                   # on pace: identity
        p3 = step(3, 45.0)                            # 45 > 1.05*30: demote
        assert p3.weights == 16 and p3.comm == 16
        p4 = step(4, 52.0)                            # 52 > 1.05*40: again
        assert p4.weights == 8 and p4.comm == 8
        p8 = step(8, 60.0)                            # 60 < 0.9*80: restore
        assert p8.weights == 16 and p8.comm == 16
        p9 = step(9, 61.0)                            # 61 < 0.9*90: restore
        assert p9.weights == 32 and p9.comm == 32
        assert step(9, 61.0) is pol                   # back at cap: identity
        s = prog.summary()
        assert s["demotions"] == 2 and s["restores"] == 2

    def test_clamp_is_elementwise_min(self):
        prog = EnergyBudgetProgram(1.0)
        het = PrecisionPolicy(weights=(8, 16, 32), comm=32)
        # round 5 of 10 with the full budget spent: cap walks down to 16
        out = prog.policy_for_round(5, het, Observation(
            round=5, rounds_total=10, energy_cum_j=1.0))
        assert out.weights == (8, 16, 16)
        assert out.comm == 16

    def test_orchestrated_demotion_saves_energy(self):
        """Seeded end-to-end: a budget at half the static total forces
        demotions and the measured total drops."""
        _, base = _run(_orch(rounds=4))
        tight = {"kind": "energy_budget",
                 "budget_j": base["total_energy_j"] / 2}
        _, out = _run(_orch(rounds=4, program=tight))
        prog = out["program"]
        assert prog["kind"] == "energy_budget"
        assert prog["demotions"] >= 1
        assert out["total_energy_j"] < base["total_energy_j"]
        # history rows record the demoted widths round by round
        assert any(h["comm_bits"] < 32 for h in out["history"])

    def test_comm_only_demotion(self):
        prog = EnergyBudgetProgram(1.0, demote_weights=False)
        pol = PrecisionPolicy(weights=(8, 32), comm=32)
        out = prog.policy_for_round(5, pol, Observation(
            round=5, rounds_total=10, energy_cum_j=1.0))
        assert out.weights == (8, 32)
        assert out.comm == 16


class TestChannelGBD:
    def test_matches_legacy_drift_trigger(self):
        """channel_gbd generalizes resolve_drift_db: same threshold, same
        re-solve rounds, bitwise-equal trajectories."""
        faults = {"fade_prob": 0.4, "fade_depth_db": 12.0}
        _, legacy = _run(_orch(rounds=6, faults=faults,
                               resolve_drift_db=3.0))
        _, prog = _run(_orch(rounds=6, faults=faults,
                             program={"kind": "channel_gbd",
                                      "drift_db": 3.0}))
        la = [bool(e["resolved"]) for e in legacy["energy_log"]]
        lb = [bool(e["resolved"]) for e in prog["energy_log"]]
        assert la == lb
        for ha, hb in zip(legacy["history"], prog["history"]):
            assert ha["loss"] == hb["loss"]
        assert legacy["total_energy_j"] == prog["total_energy_j"]
        # every drift-triggered re-solve went through the program (cadence
        # re-solves bypass it, so the counter is a lower bound on resolved)
        assert 1 <= prog["program"]["resolves"] <= sum(lb[1:])

    def test_resolve_counter_counts_triggers(self):
        p = ChannelGBDProgram(5.0)
        assert not p.wants_resolve(Observation(round=1, gain_drift_db=4.0))
        assert p.wants_resolve(Observation(round=2, gain_drift_db=6.0))
        assert p.resolves == 1


class TestCommReporting:
    def test_comm_report_has_per_round_rows(self):
        spec = RunSpec(arch="yi-6b", workload="train", mesh="1x1", smoke=True,
                       batch=1, seq=16, rounds=3,
                       precision=PrecisionPolicy.uniform(8, comm=8),
                       options={"lr": 0.05, "quiet": True})
        sess = Session(spec)
        rep0 = sess.comm_report()            # before any round: schedule
        assert [r["round"] for r in rep0["rounds"]] == [0, 1, 2]
        assert all(r["comm_bits"] == 8 for r in rep0["rounds"])
        hist = sess.run()
        rep = sess.comm_report()             # after: executed bits
        assert [r["comm_bits"] for r in rep["rounds"]] \
            == [h["comm_bits"] for h in hist]
        # the flat single-round contract the analyzer checks is unchanged
        for k in ("wire_dtype", "comm_bits", "replicated_elems",
                  "replicated_bytes_wire", "wire_ratio"):
            assert rep[k] == rep0[k]
        assert rep["program"]["comm_envelope"] == [8]

    def test_grad_wire_rounds_caches_by_bits(self):
        from repro.dist.wire import grad_wire_rounds

        tree = {"w": jax.ShapeDtypeStruct((64, 64), np.float32)}
        rows = grad_wire_rounds(tree, fsdp=1, n_clients=4,
                                comm_bits_seq=[32, 8, 8, 32, 8])
        assert [r["comm_bits"] for r in rows] == [32, 8, 8, 32, 8]
        assert rows[1]["wire_dtype"] == "int16"   # 4 * 255 > int8 max
        assert rows[0]["wire_dtype"] == "float32"
        assert rows[1]["replicated_bytes_wire"] < rows[0][
            "replicated_bytes_wire"]

    def test_wire_scale_identity_at_full_precision(self):
        from repro.dist.wire import wire_scale

        assert wire_scale(32, 6) == 1.0
        assert wire_scale(8, 6) == 0.5            # int16 / f32
        assert wire_scale(4, 2) == 0.25           # int8 / f32

    def test_envelope_wire_dtype(self):
        import jax.numpy as jnp

        from repro.dist.collectives import envelope_wire_dtype

        assert envelope_wire_dtype((32,), 8) is None
        assert envelope_wire_dtype((8, 16, 32), 8) == jnp.int32
        assert envelope_wire_dtype((4,), 2) == jnp.int8


class TestEnvelopeProofs:
    def test_program_widens_proof_cells(self):
        from repro.analyze.static_proofs import prove_spec

        base = RunSpec(arch="resnet", workload="fl-sim", rounds=2, batch=8,
                       options={"n_clients": 4})
        recs, fs = prove_spec(base, rules=("overflow",))
        keys = {r["key"] for r in recs}
        assert keys == {"policy.comm", "policy.bit_options[8]",
                        "policy.bit_options[16]", "policy.bit_options[32]"}

        adaptive = dataclasses.replace(base, options={
            "n_clients": 4,
            "precision_program": {"kind": "energy_budget", "budget_j": 10.0}})
        recs2, fs2 = prove_spec(adaptive, rules=("overflow",))
        # fl-sim already proves every lattice member (8/16/32), which
        # subsumes the program's comm envelope — dedupe by bits value means
        # no extra cells, and the whole adaptive schedule is still covered
        keys2 = {r["key"] for r in recs2}
        assert keys2 == keys
        assert not fs and not fs2

    def test_train_workload_gets_comm_envelope(self):
        from repro.analyze.static_proofs import prove_spec

        spec = RunSpec(
            arch="yi-6b", workload="train", mesh="4x1", smoke=True,
            batch=1, seq=16, rounds=2,
            precision=PrecisionPolicy.uniform(8, comm=16),
            options={"precision_program": {"kind": "energy_budget",
                                           "budget_j": 5.0}})
        recs, _ = prove_spec(spec, rules=("overflow",))
        keys = {r["key"] for r in recs}
        assert "policy.comm" in keys
        assert "program.comm[8]" in keys          # 8 < base comm 16


class TestStepCache:
    def test_k_policies_k_steps(self):
        spec = RunSpec(arch="yi-6b", workload="train", mesh="1x1", smoke=True,
                       batch=1, seq=16, rounds=1,
                       precision=PrecisionPolicy.uniform(8, comm=8),
                       options={"lr": 0.05, "quiet": True})
        sess = Session(spec)
        st = sess._ensure_train_state()
        base = sess._train_step_for(sess.policy)
        assert base is st["step"]                 # seeded: zero extra builds
        same_key = PrecisionPolicy.uniform(16, comm=8)
        assert sess._train_step_for(same_key) is base   # weight bits: traced
        other = PrecisionPolicy.uniform(8, comm=4)
        s2 = sess._train_step_for(other)
        assert s2 is not base
        assert sess._train_step_for(other) is s2        # cached thereafter
        assert len(st["step_cache"]) == 2


class TestServeKVDemotion:
    def test_watermark_demotes_f32_pool(self):
        spec = RunSpec(
            arch="yi-6b", workload="serve", smoke=True, batch=2, seq=32,
            precision=PrecisionPolicy.lazy_int8(7),   # kv_cache=32 -> f32
            options=dict(steps=10, s_max=32, prompt_len=8, requests=4,
                         max_new=4, attn_impl="ref", quiet=True,
                         kv_layout="paged",
                         precision_program={"kind": "constant",
                                            "kv_watermark": 0.5}))
        st = Session(spec).serve()
        assert st.kv_demotions == 1
        assert st.kv_bits_final == 16
        assert st.decoded_tokens > 0

    def test_no_watermark_no_demotion(self):
        spec = RunSpec(
            arch="yi-6b", workload="serve", smoke=True, batch=2, seq=32,
            precision=PrecisionPolicy.lazy_int8(7),
            options=dict(steps=6, s_max=32, prompt_len=8, requests=2,
                         max_new=2, attn_impl="ref", quiet=True,
                         kv_layout="paged"))
        st = Session(spec).serve()
        assert st.kv_demotions == 0
        assert st.kv_bits_final == 32

    def test_demote_kv_cache_preserves_tables(self):
        import jax.numpy as jnp

        from repro.models.attention import (KVCache, PagedKVCache,
                                            demote_kv_cache)

        paged = PagedKVCache(jnp.ones((4, 2, 1, 8), jnp.float32),
                             jnp.ones((4, 2, 1, 8), jnp.float32),
                             jnp.array([[0, 1], [2, -1]], jnp.int32),
                             jnp.array([3, 2], jnp.int32))
        contig = KVCache(jnp.ones((2, 8, 1, 8), jnp.float32),
                         jnp.ones((2, 8, 1, 8), jnp.float32),
                         jnp.zeros((2,), jnp.int32))
        out = demote_kv_cache({"a": paged, "b": contig}, jnp.bfloat16)
        assert out["a"].k_pages.dtype == jnp.bfloat16
        assert out["a"].page_table.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out["a"].page_table),
                                      np.asarray(paged.page_table))
        assert out["b"].v.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["b"].length),
                                      np.asarray(contig.length))

    def test_pool_pressure_property(self):
        from repro.launch.paging import PagePool

        pool = PagePool(4)
        assert pool.pressure == 0.0
        pool.alloc(3)
        assert pool.pressure == 0.75
