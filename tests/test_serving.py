"""Serving fast-path tests: lazy-quant kernel dispatch numerics, real
prefill correctness, per-sequence cache lengths, and the continuous-batching
driver end to end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PrecisionPolicy
from repro.configs import get_config, smoke_variant
from repro.core.quantization import default_exempt, storage_dtype
from repro.kernels import ops
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.steps import (
    build_cached_prefill, build_decode_step, build_init_fn,
    init_global_caches)
from repro.models.common import (
    ParamCtx, QTensor, dequant, pack_params_for_serving)
from repro.models.model import build_model

MESH = make_test_mesh((1, 1), ("data", "model"))


def _pack2d(w, bits, key):
    """Deterministic nearest-rounding pack, mirroring pack_params_for_serving."""
    del key
    delta = 1.0 / (2.0**bits - 1.0)
    lim = 2**bits - 1
    wf = w.astype(jnp.float32)
    s = jnp.maximum(jnp.max(jnp.abs(wf)), 1e-12)
    scale = (s * delta).astype(jnp.float32)
    codes = jnp.clip(jnp.round(wf / scale), -lim, lim).astype(storage_dtype(bits))
    return QTensor(codes=codes, scale=scale)


class TestLazyQuantDense:
    @pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 7, 8])
    def test_matches_eager_dequant(self, bits):
        """Kernel-dispatched x @ QTensor == x @ dequant(QTensor) in fp32."""
        w = jax.random.normal(jax.random.PRNGKey(bits), (96, 72), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(100 + bits), (5, 96), jnp.float32)
        q = _pack2d(w, bits, None)
        assert q.codes.dtype == (jnp.int8 if bits <= 7 else jnp.int16)
        lazy = ops.dense_dispatch(x, q)
        eager = x @ dequant(q, jnp.float32)
        np.testing.assert_allclose(np.asarray(lazy), np.asarray(eager),
                                   rtol=1e-5, atol=1e-5)

    def test_leading_dims_and_bf16(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64)).astype(jnp.bfloat16)
        q = _pack2d(w, 7, None)
        lazy = ops.dense_dispatch(x, q)
        assert lazy.shape == (2, 3, 48)
        assert lazy.dtype == jnp.bfloat16
        eager = (x @ dequant(q, jnp.bfloat16)).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(lazy, np.float32),
                                   np.asarray(eager), rtol=3e-2, atol=3e-2)

    def test_paramctx_lazy_returns_qtensor(self):
        axes = axis_ctx_for(MESH)
        q = _pack2d(jnp.ones((8, 8)), 7, None)
        pc_eager = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
        pc_lazy = ParamCtx.from_policy(axes, PrecisionPolicy.lazy_int8(),
                                       compute_dtype=jnp.float32)
        assert isinstance(pc_lazy.use("blocks/attn/wq", q), QTensor)
        assert isinstance(pc_eager.use("blocks/attn/wq", q), jnp.ndarray)


class TestDecodeLazyVsEager:
    def test_packed_decode_matches_eager_dequant(self):
        """One decode step, lazy kernel path vs eager dequant: same token."""
        cfg = smoke_variant(get_config("yi-6b"))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        qparams = pack_params_for_serving(params, 7, jax.random.PRNGKey(1),
                                          exempt=default_exempt)
        B, S = 2, 16
        ptree = jax.eval_shape(lambda: qparams)
        caches = model.init_caches(B, S, tp=1, dtype=jnp.float32)
        toks = {}
        for lazy in (False, True):
            policy = PrecisionPolicy(weights=7, lazy=lazy)
            ss = build_decode_step(model, MESH, axes, params_tree=ptree,
                                   s_max=S, batch_global=B, policy=policy)
            tok, _ = ss.fn(qparams, {"token": jnp.ones((B, 1), jnp.int32)},
                           caches)
            toks[lazy] = np.asarray(tok)
        np.testing.assert_array_equal(toks[False], toks[True])

    def test_packed_moe_decode_matches_eager_dequant(self):
        """MoE arch: the per-expert quant_matmul dispatch (expert_dispatch)
        produces the same greedy token as eagerly dequantizing the stacks."""
        cfg = smoke_variant(get_config("qwen3-moe-235b-a22b"))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        qparams = pack_params_for_serving(params, 7, jax.random.PRNGKey(1),
                                          exempt=default_exempt)
        B, S = 2, 16
        ptree = jax.eval_shape(lambda: qparams)
        caches = model.init_caches(B, S, tp=1, dtype=jnp.float32)
        toks = {}
        for lazy in (False, True):
            policy = PrecisionPolicy(weights=7, lazy=lazy)
            ss = build_decode_step(model, MESH, axes, params_tree=ptree,
                                   s_max=S, batch_global=B, policy=policy)
            tok, _ = ss.fn(qparams, {"token": jnp.ones((B, 1), jnp.int32)},
                           caches)
            toks[lazy] = np.asarray(tok)
        np.testing.assert_array_equal(toks[False], toks[True])


class TestPrefill:
    @pytest.mark.parametrize("arch", ["yi-6b", "qwen3-moe-235b-a22b",
                                      "mamba2-780m", "jamba-1.5-large-398b",
                                      "llama-3.2-vision-90b",
                                      "seamless-m4t-large-v2"])
    def test_prefill_then_decode_all_families(self, arch):
        """Prefill fills the caches and decode continues from them for every
        cache topology (KV, SSM state, hybrid, cross-attention)."""
        cfg = smoke_variant(get_config(arch))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        B, S_max, S_p = 2, 32, 8
        ss = build_decode_step(model, MESH, axes, s_max=S_max, batch_global=B)
        pf = build_cached_prefill(model, MESH, axes, s_max=S_max, s_prompt=S_p,
                                  batch_global=B)
        caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B)
        batch = _prefill_batch(model, cfg, B, S_p, S_max)
        tok, caches = pf.fn(params, batch, caches,
                            jnp.ones((B,), jnp.bool_))
        assert tok.shape == (B, 1)
        for _ in range(3):
            tok, caches = ss.fn(params, {"token": tok}, caches)
            assert np.all(np.isfinite(np.asarray(tok)))

    def test_prefill_matches_full_forward_greedy(self):
        """Dense arch: prefill+decode greedy == re-running the full forward
        over the growing sequence (the teacher-forcing oracle)."""
        cfg = smoke_variant(get_config("yi-6b"))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        B, S_max, S_p, n_new = 2, 32, 8, 4
        prompt = jax.random.randint(jax.random.PRNGKey(7), (B, S_p), 2,
                                    cfg.vocab_size)

        # oracle: full forward over the sequence so far, greedy argmax
        from repro.models.transformer import forward as tf_forward

        def oracle_next(tokens):
            def local(p, t):
                pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
                lg = tf_forward(cfg, pc, p, t)
                return jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
            from jax.sharding import PartitionSpec as P
            sm = jax.shard_map(local, mesh=MESH, in_specs=(P(), P()),
                               out_specs=P(), check_vma=False)
            return np.asarray(sm(params, tokens))

        seq = np.array(prompt)
        want = []
        for _ in range(n_new + 1):
            nxt = oracle_next(jnp.asarray(seq))
            want.append(nxt)
            seq = np.concatenate([seq, nxt[:, None]], axis=1)

        ss = build_decode_step(model, MESH, axes, s_max=S_max, batch_global=B)
        pf = build_cached_prefill(model, MESH, axes, s_max=S_max, s_prompt=S_p,
                                  batch_global=B)
        caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B)
        tok, caches = pf.fn(params, {"tokens": prompt}, caches,
                            jnp.ones((B,), jnp.bool_))
        got = [np.asarray(tok)[:, 0]]
        for _ in range(n_new):
            tok, caches = ss.fn(params, {"token": tok}, caches)
            got.append(np.asarray(tok)[:, 0])
        np.testing.assert_array_equal(np.stack(got), np.stack(want))

    def test_flash_prefill_matches_ref_prefill(self):
        cfg = smoke_variant(get_config("yi-6b"))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        B, S_max, S_p = 2, 32, 8
        prompt = jax.random.randint(jax.random.PRNGKey(3), (B, S_p), 2,
                                    cfg.vocab_size)
        caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B)
        toks = {}
        for impl in ("auto", "flash"):
            pf = build_cached_prefill(model, MESH, axes, s_max=S_max,
                                      s_prompt=S_p, batch_global=B,
                                      attn_impl=impl)
            tok, _ = pf.fn(params, {"tokens": prompt}, caches,
                           jnp.ones((B,), jnp.bool_))
            toks[impl] = np.asarray(tok)
        np.testing.assert_array_equal(toks["auto"], toks["flash"])


def _prefill_batch(model, cfg, B, S_p, S_max):
    spec = model.prefill_batch_spec(B, S_p, S_max)
    batch = {}
    for name, sds in spec.items():
        if sds.dtype == jnp.int32:
            batch[name] = jax.random.randint(jax.random.PRNGKey(11), sds.shape,
                                             2, cfg.vocab_size)
        else:
            batch[name] = jax.random.normal(jax.random.PRNGKey(12), sds.shape,
                                            dtype=sds.dtype)
    return batch


class TestContinuousBatching:
    def test_staggered_admission_is_isolated(self):
        """Admitting B into slot 1 mid-flight must not disturb slot 0, and
        both slots must decode exactly what a solo run decodes (per-sequence
        cache lengths)."""
        cfg = smoke_variant(get_config("yi-6b"))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        B, S_max, S_p = 2, 32, 8
        pa = jax.random.randint(jax.random.PRNGKey(21), (S_p,), 2, cfg.vocab_size)
        pb = jax.random.randint(jax.random.PRNGKey(22), (S_p,), 2, cfg.vocab_size)

        ss = build_decode_step(model, MESH, axes, s_max=S_max, batch_global=B)
        pf = build_cached_prefill(model, MESH, axes, s_max=S_max, s_prompt=S_p,
                                  batch_global=B)

        def solo(prompt, n):
            """Both slots carry the same prompt; read slot 0."""
            caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B)
            toks = jnp.broadcast_to(prompt[None], (B, S_p))
            tok, caches = pf.fn(params, {"tokens": toks}, caches,
                                jnp.ones((B,), jnp.bool_))
            out = [int(np.asarray(tok)[0, 0])]
            for _ in range(n):
                tok, caches = ss.fn(params, {"token": tok}, caches)
                out.append(int(np.asarray(tok)[0, 0]))
            return out

        want_a, want_b = solo(pa, 6), solo(pb, 3)

        # staggered: A at t=0 in slot 0; B at t=3 in slot 1
        caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B)
        toks = jnp.stack([pa, pa])
        tok, caches = pf.fn(params, {"tokens": toks}, caches,
                            jnp.asarray([True, False]))
        got_a = [int(np.asarray(tok)[0, 0])]
        cur = np.array(tok)
        for _ in range(3):
            tok, caches = ss.fn(params, {"token": jnp.asarray(cur)}, caches)
            cur = np.array(tok)
            got_a.append(int(cur[0, 0]))
        toks = jnp.stack([pb, pb])           # slot 0's entry is ignored (mask)
        tok2, caches = pf.fn(params, {"tokens": toks}, caches,
                             jnp.asarray([False, True]))
        cur[1] = np.asarray(tok2)[1]
        got_b = [int(cur[1, 0])]
        for _ in range(3):
            tok, caches = ss.fn(params, {"token": jnp.asarray(cur)}, caches)
            cur = np.array(tok)
            got_a.append(int(cur[0, 0]))
            got_b.append(int(cur[1, 0]))
        assert got_a == want_a
        assert got_b == want_b

    def test_seqpar_kv_cache_tp4_matches_uncached_oracle(self):
        """Replicated-KV arch under tp=4 uses the sequence-parallel cache;
        per-sequence lengths must cross the shard-ownership boundary
        (S_max/tp) and still reproduce the non-cached full-forward greedy
        decode on the same mesh/params exactly.

        Subprocess so XLA gets fake host devices before jax initializes
        (same pattern as test_distributed)."""
        import os
        import subprocess
        import sys

        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.steps import (
    _greedy_pick, build_cached_prefill, build_decode_step, build_init_fn,
    init_global_caches)
from repro.models.common import ParamCtx
from repro.models.model import build_model
from repro.models.attention import kv_cache_seq_parallel
from repro.models.transformer import attn_dims, forward, padded_vocab_local

TP = 4
cfg = smoke_variant(get_config("glm4-9b"))   # smoke n_kv=2: tp=4 -> seqpar
assert kv_cache_seq_parallel(attn_dims(cfg, TP)), "must hit the seqpar path"
model = build_model(cfg)
B, S_max, S_p, n_new = 2, 32, 6, 4           # lengths cross S_max/tp = 8
prompt = jax.random.randint(jax.random.PRNGKey(5), (B, S_p), 2, cfg.vocab_size)

mesh = make_test_mesh((1, TP), ("data", "model"))
axes = axis_ctx_for(mesh)
init_fn, param_specs = build_init_fn(model, mesh, axes)
params = init_fn(jax.random.PRNGKey(0))
# init draws replicated leaves (wk/wv here) independently per TP rank; the
# oracle and the cached path consume them through different shards, so
# canonicalize: round-trip through the host makes every replica identical.
params = jax.tree_util.tree_map(
    lambda x: jax.device_put(np.asarray(x), x.sharding), params)
vl = padded_vocab_local(cfg, TP)

# oracle: full (non-cached) forward over the growing sequence, same mesh
def local_oracle(p, t):
    pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
    lg = forward(cfg, pc, p, t)[:, -1:, :]
    return _greedy_pick(axes, TP, vl, lg)

oracle = jax.jit(jax.shard_map(local_oracle, mesh=mesh,
                               in_specs=(param_specs, P()), out_specs=P(),
                               check_vma=False))
seq = np.array(prompt)
want = []
for _ in range(n_new + 1):
    nxt = np.asarray(oracle(params, jnp.asarray(seq)))
    want.append(nxt[:, 0])
    seq = np.concatenate([seq, nxt], axis=1)

# cached path: prefill + seqpar decode with per-sequence lengths
pf = build_cached_prefill(model, mesh, axes, s_max=S_max, s_prompt=S_p,
                          batch_global=B)
ss = build_decode_step(model, mesh, axes, s_max=S_max, batch_global=B)
caches = init_global_caches(model, mesh, axes, s_max=S_max, batch_global=B)
tok, caches = pf.fn(params, {"tokens": prompt}, caches, jnp.ones((B,), jnp.bool_))
got = [np.asarray(tok)[:, 0]]
for _ in range(n_new):
    tok, caches = ss.fn(params, {"token": tok}, caches)
    got.append(np.asarray(tok)[:, 0])
np.testing.assert_array_equal(np.stack(got), np.stack(want))
print("SEQPAR_OK")
"""
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        out = subprocess.run([sys.executable, "-c", script % {"src": src}],
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "SEQPAR_OK" in out.stdout

    def test_driver_end_to_end_packed(self):
        from repro.launch.serve import run_serve

        stats = run_serve("yi-6b", smoke=True, steps=24, batch=2, s_max=32,
                          prompt_len=8, serve_bits=7, attn_impl="ref",
                          requests=4, max_new=6, quiet=True)
        assert stats.admitted == 4          # mid-flight admissions happened
        assert stats.completed >= 3
        assert stats.decoded_tokens > 0
        assert stats.packed_vs_f32 < 1 / 3  # int8 path streams < 1/3 the bytes
