"""Per-architecture smoke tests (deliverable f).

Each assigned arch gets a REDUCED same-family config and runs one forward /
train step and one decode step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (AOT, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, all_configs, get_config, smoke_variant
from repro.configs.base import TrainConfig
from repro.core.fwq import delta_for_clients
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.steps import build_decode_step, build_init_fn, build_train_step
from repro.models.model import build_model
from repro.optim import build_optimizer

MESH = make_test_mesh((1, 1), ("data", "model"))


def _train_batch(model, b, s, key):
    cfg = model.cfg
    spec = model.train_batch_spec(b, s)
    batch = {}
    for name, sds in spec.items():
        if sds.dtype == jnp.int32:
            batch[name] = jax.random.randint(jax.random.fold_in(key, hash(name) % 97),
                                             sds.shape, 0, cfg.vocab_size)
        else:
            batch[name] = jax.random.normal(jax.random.fold_in(key, hash(name) % 89),
                                            sds.shape, dtype=sds.dtype)
    return batch


def _decode_batch(model, b, s, key):
    spec = model.decode_batch_spec(b, s)
    batch = {}
    for name, sds in spec.items():
        if sds.dtype == jnp.int32:
            batch[name] = jnp.ones(sds.shape, jnp.int32)
        else:
            batch[name] = jax.random.normal(key, sds.shape).astype(sds.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_exact_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.source
    n = cfg.param_count()
    assert n > 1e8  # every assigned arch is at least ~0.1B params


def test_param_counts_match_published_scale():
    counts = {n: c.param_count() for n, c in all_configs().items()}
    # spot-check the headline parameter counts (±25%: embeddings/norms vary)
    expect = {
        "qwen3-moe-235b-a22b": 235e9,
        "olmoe-1b-7b": 6.9e9,
        "gemma-7b": 8.5e9,
        "glm4-9b": 9e9,
        "yi-6b": 6e9,
        "starcoder2-15b": 15e9,
        "jamba-1.5-large-398b": 398e9,
        "mamba2-780m": 0.78e9,
    }
    for name, target in expect.items():
        assert counts[name] == pytest.approx(target, rel=0.3), (name, counts[name])


def test_active_params_moe():
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert active == pytest.approx(22e9, rel=0.35)
    assert active < cfg.param_count() / 5


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    axes = axis_ctx_for(MESH)
    init_fn, _ = build_init_fn(model, MESH, axes)
    params = init_fn(jax.random.PRNGKey(0))
    opt = build_optimizer("sgd", 0.05)
    ts = build_train_step(model, MESH, axes, opt, TrainConfig(), donate=False)
    B, S = 2, 16
    batch = _train_batch(model, B, S, jax.random.PRNGKey(1))
    step = ts.fn(model.train_batch_spec(B, S))
    opt_state = opt.init(params)
    delta = delta_for_clients([8])
    p2, o2, m = step(params, opt_state, batch, delta, jax.random.PRNGKey(2))
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["loss"]) > 0
    # one more step on the same batch must reduce the loss
    p3, o3, m2 = step(p2, o2, batch, delta, jax.random.PRNGKey(3))
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m["loss"]) * 1.05, arch
    # shapes preserved
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)):
        assert a.shape == b.shape
        assert bool(jnp.isfinite(b).all())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    axes = axis_ctx_for(MESH)
    init_fn, _ = build_init_fn(model, MESH, axes)
    params = init_fn(jax.random.PRNGKey(0))
    B, S = 2, 16
    ss = build_decode_step(model, MESH, axes, s_max=S, batch_global=B)
    caches = model.init_caches(B, S, tp=1, dtype=jnp.float32)
    batch = _decode_batch(model, B, S, jax.random.PRNGKey(5))
    tok, new_caches = ss.fn(params, batch, caches)
    assert tok.shape == (B, 1)
    assert int(tok.min()) >= 0
    assert int(tok.max()) < cfg.vocab_size + 64  # padded vocab headroom
    # run a few more steps: tokens stay valid, caches advance
    for i in range(3):
        tok, new_caches = ss.fn(params, {**batch, "token": tok}, new_caches)
        assert np.all(np.isfinite(np.asarray(tok)))


def test_full_configs_param_specs_build():
    """The sharding-rule table must cover every leaf of every FULL arch."""
    from repro.dist.sharding import tree_param_specs
    from repro.launch.mesh import axis_ctx_for

    axes = axis_ctx_for(MESH)
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda key: model.init(key, 16),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        specs = tree_param_specs(shapes, cfg, axes, fsdp=16)
        n_leaves = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: x is None or hasattr(x, "index")))
        assert n_leaves > 0 and n_specs > 0
