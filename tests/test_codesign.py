"""Tests for the energy models, primal solver, master MILP, and GBD loop."""

import numpy as np
import pytest

from repro.core import baselines
from repro.core.channel import ChannelModel
from repro.core.convergence import (
    ProblemConstants,
    corollary1_bound,
    corollary1_lr,
    corollary2_rounds,
    error_budget_bound,
    quant_noise,
)
from repro.core.energy import (
    CommParams,
    DeviceProfile,
    alpha_coefficients,
    heterogeneous_fleet,
    memory_capacities,
    round_energy,
)
from repro.core.gbd import exhaustive_best, run_gbd
from repro.core.master import Cut, MasterSpec, solve_master, solve_master_greedy
from repro.core.primal import (
    PrimalData,
    feasibility_cut,
    optimality_cut,
    solve_primal,
    solve_primal_slsqp,
)


def make_instance(n=4, rounds=3, seed=0, b_max=20e6, t_factor=1.5, grad_mb=5.0,
                  budget_factor=1.5):
    from repro.core.primal import _round_tmin

    fleet = heterogeneous_fleet(n, seed=seed, group_step_mhz=5.0)
    ch = ChannelModel(n_devices=n, seed=seed)
    comm = CommParams(b_max_hz=b_max, grad_bytes=grad_mb * 1e6)
    gains = ch.gain_matrix(rounds)
    p_comm = np.array([d.p_comm for d in fleet])
    a1 = np.zeros((rounds, n))
    a2 = np.zeros((rounds, n))
    for r in range(rounds):
        a1[r], a2[r] = alpha_coefficients(gains[r], p_comm, comm)
    beta1 = np.array([d.beta1 for d in fleet])
    beta2 = np.array([d.beta2 for d in fleet])
    p_comp = np.array([d.runtime_power() for d in fleet])
    # Deadline that BINDS but stays feasible for every q (q=32 is worst case).
    tmin32 = _round_tmin(a2, beta1 + 32 * beta2, b_max)
    t_max = float(t_factor * tmin32.sum())
    data = PrimalData(alpha1=a1, alpha2=a2, beta1=beta1, beta2=beta2,
                      p_comp=p_comp, b_max=b_max, t_max=t_max)
    caps = memory_capacities(n, lo_mb=grad_mb * 0.3, hi_mb=grad_mb * 1.5) * 1e6
    spec = MasterSpec(
        bits_options=(8, 16, 32),
        n_devices=n,
        error_budget=1.0,  # placeholder, set below from memory feasibility
        mem_capacity_bytes=caps,
        model_bytes_fp=grad_mb * 1e6,
    )
    # Budget compatible with memory-forced minimum bit-widths (constraint 25
    # can force 8 bits on small devices; the budget must admit at least that).
    allowed = spec.allowed()
    bits = np.asarray(spec.bits_options)
    forced = np.array([bits[np.flatnonzero(allowed[i])[0]] for i in range(n)])
    floor = float(np.sum(quant_noise(np.maximum(forced, 8)) ** 2))
    spec.error_budget = max(floor * budget_factor,
                            float(np.sum(quant_noise([16] * n) ** 2) * 1.5))
    return data, spec, fleet, gains, comm


class TestEnergyModels:
    def test_power_positive_and_monotone_in_clock(self):
        d = DeviceProfile()
        d_fast = DeviceProfile(f_core=2 * d.f_core)
        assert d_fast.runtime_power() > d.runtime_power() > 0

    def test_exec_time_linear_in_bits(self):
        d = DeviceProfile()
        t8, t16, t32 = (float(d.exec_time(b)) for b in (8, 16, 32))
        assert t8 < t16 < t32
        assert (t32 - t16) == pytest.approx(2 * (t16 - t8), rel=1e-9)
        assert float(d.exec_time(16)) == pytest.approx(d.beta1 + 16 * d.beta2)

    def test_alpha_reformulation_matches_eq21(self):
        comm = CommParams(b_max_hz=20e6, grad_bytes=1e6)
        gains = np.array([1e-9, 3e-9])
        p = np.array([0.1, 0.2])
        a1, a2 = alpha_coefficients(gains, p, comm)
        B = np.array([5e6, 7e6])
        sigma2 = comm.noise_power(comm.b_max_hz)
        rate = B * np.log1p(gains * p / sigma2)
        np.testing.assert_allclose(a1 / B, p * 8 * comm.grad_bytes / rate, rtol=1e-12)
        np.testing.assert_allclose(a2 / B, 8 * comm.grad_bytes / rate, rtol=1e-12)

    def test_round_energy_breakdown(self):
        data, spec, fleet, gains, comm = make_instance()
        out = round_energy(np.full(4, 16), np.full(4, 5e6), fleet, gains[0], comm)
        assert out["energy_total"] > 0
        assert out["t_round"] >= np.max(out["t_comp"])

    def test_channel_groups_ordered(self):
        ch = ChannelModel(n_devices=16, seed=3)
        g = ch.path_gain()
        groups = ch.group_of()
        means = [np.mean(np.log10(g[groups == k])) for k in range(4)]
        # inner rings (higher k) should have better average gain
        assert means[-1] > means[0]

    def test_gains_vary_by_round(self):
        ch = ChannelModel(n_devices=4, seed=0)
        assert not np.allclose(ch.gains(0), ch.gains(1))


class TestConvergenceTheory:
    C = ProblemConstants(L=1.0, tau_sq=4.0, phi=0.5, M=32, N=8, d=1000,
                         F0_minus_Fstar=2.0)

    def test_bound_decreases_in_R(self):
        delta = quant_noise([16] * 8)
        b1 = corollary1_bound(self.C, 100, delta)
        b2 = corollary1_bound(self.C, 10000, delta)
        assert b2 < b1

    def test_quant_floor_irreducible(self):
        delta = quant_noise([8] * 8)
        floor = 9 * self.C.d * self.C.L**2 / self.C.N * np.sum(delta**2)
        b = corollary1_bound(self.C, 10**9, delta)
        assert b == pytest.approx(floor, rel=1e-2)

    def test_more_bits_tighter_bound(self):
        b8 = corollary1_bound(self.C, 1000, quant_noise([8] * 8))
        b16 = corollary1_bound(self.C, 1000, quant_noise([16] * 8))
        b32 = corollary1_bound(self.C, 1000, quant_noise([32] * 8))
        assert b32 < b16 < b8

    def test_lr_positive_and_small(self):
        eta = corollary1_lr(self.C, 1000)
        assert 0 < eta < 1 / (4 * self.C.L)

    def test_corollary2_rounds_scale(self):
        r1 = corollary2_rounds(self.C, 0.5)
        r2 = corollary2_rounds(self.C, 0.25)
        assert r2 > r1 > 0
        # eps^-2 scaling of the dominant term
        assert r2 / r1 > 2.0

    def test_error_budget(self):
        b = error_budget_bound(0.1, 9.0, 1000, 8)
        assert b == pytest.approx(0.1 * 8 / (9.0 * 1000))


class TestPrimal:
    def test_feasible_and_bandwidth_sums(self):
        data, spec, *_ = make_instance()
        sol = solve_primal(data, np.full(4, 16))
        assert sol.feasible
        np.testing.assert_allclose(sol.bandwidth.sum(axis=1), data.b_max, rtol=1e-6)
        assert sol.t_rounds.sum() <= data.t_max * (1 + 1e-9)
        # latency constraints hold
        a = data.comp_times(np.full(4, 16))
        t_needed = a[None, :] + data.alpha2 / sol.bandwidth
        assert np.all(t_needed <= sol.t_rounds[:, None] * (1 + 1e-6))

    def test_optimality(self):
        """Three-way optimality check of the dual-bisection solver:
        (1) value >= unconstrained water-filling floor,
        (2) SLSQP polish started AT our solution cannot improve it >0.5%,
        (3) random feasible perturbations never decrease the objective.
        """
        from repro.core.primal import _waterfill

        data, spec, *_ = make_instance(n=3, rounds=2)
        rng = np.random.default_rng(0)
        for q in ([8, 16, 32], [32, 32, 32], [8, 8, 8]):
            q = np.array(q)
            sol = solve_primal(data, q)
            assert sol.feasible
            # (1) floor: ignore latency constraints entirely
            Bf, _ = _waterfill(data.alpha1, np.full_like(data.alpha1, 1.0),
                               data.b_max)
            floor = np.sum(data.alpha1 / Bf) + data.comp_energy(q)
            assert sol.value >= floor - 1e-9
            # (2) polish
            x0 = np.concatenate([sol.bandwidth.ravel(), sol.t_rounds])
            v_polish = solve_primal_slsqp(data, q, x0=x0)
            assert sol.value <= v_polish * 1.005 + 1e-9
            # (3) feasible perturbations of the bandwidth split
            a = data.comp_times(q)
            for _ in range(20):
                d = rng.normal(size=sol.bandwidth.shape)
                d -= d.mean(axis=1, keepdims=True)  # keep sum_i B = B_max
                B2 = sol.bandwidth + 1e-4 * data.b_max * d
                if np.any(B2 <= 0):
                    continue
                t_need = (a[None, :] + data.alpha2 / B2).max(axis=1)
                if t_need.sum() > data.t_max:
                    continue  # infeasible direction
                v2 = np.sum(data.alpha1 / B2) + data.comp_energy(q)
                assert v2 >= sol.value - 1e-6 * abs(sol.value)

    def test_infeasible_when_deadline_tiny(self):
        data, spec, *_ = make_instance()
        tight = PrimalData(**{**data.__dict__, "t_max": 1e-6})
        sol = solve_primal(tight, np.full(4, 32))
        assert not sol.feasible
        assert np.isfinite(sol.tmin_total)
        assert sol.tmin_grad_q.shape == (4,)
        assert np.all(sol.tmin_grad_q >= 0)  # more bits => more time

    def test_energy_decreases_with_more_time(self):
        # t_factor=1.05: deadline genuinely binds, so relaxing it must help.
        data, spec, *_ = make_instance(t_factor=1.05)
        loose = PrimalData(**{**data.__dict__, "t_max": data.t_max * 4})
        q = np.full(4, 16)
        assert solve_primal(loose, q).value < solve_primal(data, q).value

    def test_optimality_cut_tight_at_incumbent(self):
        data, spec, *_ = make_instance()
        q = np.array([8, 16, 16, 32])
        sol = solve_primal(data, q)
        c0, grad = optimality_cut(data, q, sol)
        assert c0 + grad @ q == pytest.approx(sol.value, rel=1e-9)

    def test_feasibility_cut_separates(self):
        data, spec, *_ = make_instance()
        tight = PrimalData(**{**data.__dict__, "t_max": 1e-6})
        q = np.full(4, 32)
        sol = solve_primal(tight, q)
        g, rhs = feasibility_cut(tight, q, sol)
        assert g @ q > rhs  # the infeasible point is cut off


class TestMasterAndGBD:
    def test_master_one_hot_and_budget(self):
        data, spec, *_ = make_instance()
        sol = solve_master(spec, [])
        assert sol.status == "ok"
        dsq = quant_noise(sol.q) ** 2
        assert float(np.sum(dsq)) <= spec.error_budget + 1e-12

    def test_master_respects_memory(self):
        data, spec, *_ = make_instance()
        # device capacities in bytes; c3(q) U <= C must hold
        sol = solve_master(spec, [])
        need = sol.q / 32.0 * spec.model_bytes_fp
        assert np.all(need <= spec.mem_capacity_bytes + 1e-9)

    def test_master_greedy_agrees_direction(self):
        data, spec, *_ = make_instance()
        cuts = [Cut(kind="opt", c0=1.0, grad=np.ones(4) * 0.1)]
        milp = solve_master(spec, cuts, use_milp=True)
        greedy = solve_master_greedy(spec, cuts)
        assert milp.status == greedy.status == "ok"
        # both one-hot-feasible w.r.t. budget
        for s in (milp, greedy):
            assert float(np.sum(quant_noise(s.q) ** 2)) <= spec.error_budget + 1e-12

    def test_gbd_converges_and_beats_baselines(self):
        data, spec, *_ = make_instance(n=5, rounds=3, seed=2)
        res = run_gbd(data, spec, max_rounds=25)
        assert res.converged
        assert res.gap <= max(1e-3, 1e-4 * abs(res.energy)) + 1e-9
        fp = baselines.full_precision(data, spec)
        uq = baselines.unified_q(data, spec, bits=16)
        assert res.energy <= fp.energy * (1 + 1e-9)
        assert res.energy <= uq.energy * (1 + 1e-9)

    def test_gbd_matches_exhaustive_small(self):
        data, spec, *_ = make_instance(n=3, rounds=2, seed=1)
        res = run_gbd(data, spec, max_rounds=30)
        q_star, v_star = exhaustive_best(data, spec)
        assert res.energy == pytest.approx(v_star, rel=5e-3)

    def test_rand_q_reproducible(self):
        data, spec, *_ = make_instance()
        a = baselines.rand_q(data, spec, seed=7)
        b = baselines.rand_q(data, spec, seed=7)
        np.testing.assert_array_equal(a.q, b.q)

    def test_ub_nonincreasing_lb_nondecreasing(self):
        data, spec, *_ = make_instance(n=5, rounds=3, seed=4)
        res = run_gbd(data, spec, max_rounds=25)
        ubs = [t["ub"] for t in res.trace]
        lbs = [t["lb"] for t in res.trace]
        assert all(u2 <= u1 + 1e-9 for u1, u2 in zip(ubs, ubs[1:]))
        assert all(l2 >= l1 - 1e-9 for l1, l2 in zip(lbs, lbs[1:]))
