"""Minimal stand-in for the ``hypothesis`` API surface these tests use.

The container has no ``hypothesis`` wheel and the suite must stay
dependency-light, so ``conftest.py`` installs this module into
``sys.modules['hypothesis']`` only when the real package is unavailable.
It covers exactly what the tests use — ``@given`` with keyword strategies,
``@settings(max_examples=..., deadline=...)``, ``st.integers`` and
``st.sampled_from`` — by running each test on a fixed number of
deterministically drawn examples (seeded per test name, so failures
reproduce).  No shrinking, no database: a bounded random sweep, which is
the property being relied on here.
"""

from __future__ import annotations

import functools
import inspect
import random

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_for(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        seq = list(options)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.example_for(rng) for s in strats))

    @staticmethod
    def lists(strat, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            strat.example_for(rng)
            for _ in range(rng.randint(min_size, max_size))])


def given(**kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"shim:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = {k: s.example_for(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs, **drawn)

        # pytest introspects the signature for fixtures: hide the drawn
        # params (and the __wrapped__ chain functools.wraps leaves behind).
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        wrapper._shim_given = True
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


st = strategies
__all__ = ["given", "settings", "strategies", "st"]
