"""Distributed correctness: the sharded step must agree numerically with the
single-device run (TP and DP equivalences), and the dry-run cell must lower.

These launch subprocesses so XLA can be given fake host devices before jax
initializes (the main pytest process keeps its single CPU device).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, smoke_variant
from repro.configs.base import TrainConfig
from repro.core.fwq import delta_for_clients
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.steps import build_decode_step, build_init_fn, build_train_step
from repro.models.model import build_model
from repro.optim import build_optimizer

arch = %(arch)r
cfg = smoke_variant(get_config(arch))
model = build_model(cfg)
B, S = 4, 16
key = jax.random.PRNGKey(0)
batch = {}
for name, sds in model.train_batch_spec(B, S).items():
    if sds.dtype == jnp.int32:
        batch[name] = jax.random.randint(jax.random.fold_in(key, hash(name) %% 97),
                                         sds.shape, 0, cfg.vocab_size)
    else:
        batch[name] = jax.random.normal(jax.random.fold_in(key, 3), sds.shape,
                                        dtype=sds.dtype)

def loss_for(mesh_shape, n_clients, bits):
    mesh = make_test_mesh(mesh_shape, ("data", "model"))
    axes = axis_ctx_for(mesh)
    init_fn, _ = build_init_fn(model, mesh, axes)
    params = init_fn(jax.random.PRNGKey(7))
    opt = build_optimizer("sgd", 0.05)
    ts = build_train_step(model, mesh, axes, opt, TrainConfig(), donate=False)
    step = ts.fn(model.train_batch_spec(B, S))
    delta = delta_for_clients([bits] * n_clients)
    p2, o2, m = step(params, opt.init(params), batch, delta, jax.random.PRNGKey(9))
    return float(m["loss"])

# FULL PRECISION so client-id-dependent SR noise cannot differ
base = loss_for((1, 1), 1, 32)
tp4 = loss_for((1, 4), 1, 32)
dp2 = loss_for((2, 1), 2, 32)
dp2tp2 = loss_for((2, 2), 2, 32)
print(json.dumps({"base": base, "tp4": tp4, "dp2": dp2, "dp2tp2": dp2tp2}))
"""


@pytest.mark.parametrize("arch", ["yi-6b", "olmoe-1b-7b", "mamba2-780m",
                                  "jamba-1.5-large-398b"])
def test_sharded_equals_single_device(arch):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT % {"arch": arch}],
                         capture_output=True, text=True, env=env, timeout=1500)
    assert out.returncode == 0, out.stderr[-3000:]
    vals = json.loads(out.stdout.strip().splitlines()[-1])
    base = vals["base"]
    # init differs per tp rank (different local slices are different draws),
    # so TP runs are *statistically* equal but not bitwise: compare DP (same
    # init) tightly and TP loosely (same scale, finite).
    assert abs(vals["dp2"] - base) < 5e-2 * max(abs(base), 1.0), vals
    for k in ("tp4", "dp2tp2"):
        assert vals[k] == pytest.approx(base, rel=0.5), (k, vals)
        assert vals[k] > 0


def test_multipod_mesh_builds():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh, axis_ctx_for
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert m1.devices.size == 256 and m2.devices.size == 512
assert tuple(m2.axis_names) == ("pod", "data", "model")
ctx = axis_ctx_for(m2)
assert ctx.batch_axes == ("pod", "data")
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
