"""Roofline machinery: structural HLO parsing (loop-aware) + term math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_parse import parse_module
from repro.roofline.hw import TPU_V5E


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


class TestHloParse:
    def test_plain_dot_flops_exact(self):
        m, k, n = 128, 256, 64
        co = _compile(lambda a, b: a @ b,
                      jax.ShapeDtypeStruct((m, k), jnp.float32),
                      jax.ShapeDtypeStruct((k, n), jnp.float32))
        mc = parse_module(co.as_text())
        assert mc.flops == pytest.approx(2 * m * k * n, rel=1e-6)
        assert mc.dot_bytes == pytest.approx(4 * (m * k + k * n + m * n), rel=1e-6)

    def test_scan_multiplies_by_trip_count(self):
        L, d = 7, 64

        def f(w, x):
            def body(h, wl):
                return jnp.tanh(h @ wl), ()
            h, _ = jax.lax.scan(body, x, w)
            return h

        co = _compile(f, jax.ShapeDtypeStruct((L, d, d), jnp.float32),
                      jax.ShapeDtypeStruct((8, d), jnp.float32))
        mc = parse_module(co.as_text())
        # XLA cost_analysis counts the body once; the parser must count L times
        ca = co.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0]
        assert mc.flops == pytest.approx(L * 2 * 8 * d * d, rel=0.05)
        assert mc.flops > float(ca.get("flops", 0)) * 2  # cost_analysis understates
        assert mc.n_while >= 1

    def test_batched_dot(self):
        co = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                      jax.ShapeDtypeStruct((4, 32, 16), jnp.float32),
                      jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
        mc = parse_module(co.as_text())
        assert mc.flops == pytest.approx(2 * 4 * 32 * 16 * 8, rel=1e-6)

    def test_no_dots_no_flops(self):
        co = _compile(lambda x: jnp.sin(x) + 1,
                      jax.ShapeDtypeStruct((128,), jnp.float32))
        mc = parse_module(co.as_text())
        assert mc.flops == 0.0
        assert mc.collective_bytes == 0.0

    def test_bf16_equiv_rescale(self):
        co = _compile(lambda a, b: a @ b,
                      jax.ShapeDtypeStruct((64, 64), jnp.float32),
                      jax.ShapeDtypeStruct((64, 64), jnp.float32))
        txt = co.as_text()
        full = parse_module(txt)
        half = parse_module(txt.replace("f32[", "bf16["))
        assert half.dot_bytes == pytest.approx(full.dot_bytes / 2, rel=1e-6)
        assert half.flops == pytest.approx(full.flops, rel=1e-6)


class TestTerms:
    def test_chip_constants(self):
        assert TPU_V5E.peak_flops_bf16 == pytest.approx(197e12)
        assert TPU_V5E.hbm_bw == pytest.approx(819e9)
        assert TPU_V5E.ici_link_bw == pytest.approx(50e9)

    def test_model_flops(self):
        from repro.configs import get_config
        from repro.roofline.analysis import model_flops
        cfg = get_config("yi-6b")
        n = cfg.active_param_count()
        assert model_flops(cfg, "train", 4096, 256) == pytest.approx(
            6 * n * 4096 * 256)
        assert model_flops(cfg, "decode", 32768, 128) == pytest.approx(
            2 * n * 128)


class TestCollectiveParse:
    def test_collectives_counted_with_wire_model(self):
        import os
        import subprocess
        import sys
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.roofline.hlo_parse import parse_module
mesh = jax.make_mesh((4,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
def f(a):
    g = jax.lax.all_gather(a, "x", axis=0, tiled=True)   # (64, 32) f32
    return jax.lax.psum(jnp.sum(g), "x")
sm = jax.shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P(), check_vma=False)
co = jax.jit(sm).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
mc = parse_module(co.as_text())
ag = mc.collective_by_kind.get("all-gather", 0)
expect = (4 - 1) / 4 * 64 * 32 * 4
assert abs(ag - expect) / expect < 1e-6, (ag, expect)
assert mc.collective_counts.get("all-reduce", 0) >= 1
print("OK")
"""
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
