"""FWQ round-function semantics (Algorithm 1) + optimizers + schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fwq import (
    FWQConfig, delta_for_clients, make_fwq_round, make_inline_quantizer,
    make_tree_quant_loss,
)
from repro.optim import adamw, build_optimizer, sgd
from repro.optim.schedules import constant, cosine_decay, warmup_cosine


def quadratic_loss(params, batch, rng):
    """f(w) = ||w - target||^2 per client batch (analytically tractable)."""
    diff = params["w"] - batch["target"]
    return jnp.mean(diff**2), {}


def make_round(n_clients=4, lr=0.1):
    opt = sgd(lr)
    rf = make_fwq_round(make_tree_quant_loss(quadratic_loss), opt.update,
                        FWQConfig(n_clients=n_clients))
    return jax.jit(rf), opt


class TestRoundSemantics:
    def test_full_precision_matches_plain_sgd(self):
        """With q=32 everywhere, a round IS one plain SGD step on the mean
        gradient — verifies lines 6/10/11 wiring exactly."""
        rf, opt = make_round()
        params = {"w": jnp.array([1.0, -2.0, 0.5])}
        targets = jnp.stack([jnp.full(3, t) for t in (0.0, 1.0, 2.0, 3.0)])
        batch = {"target": targets[:, None, :]}  # (clients, M=1, d)
        delta = delta_for_clients([32, 32, 32, 32])
        p2, _, m = rf(params, opt.init(params), batch, delta, jax.random.PRNGKey(0))
        # gradient of mean over clients of (w - t)^2 is 2(w - mean_t)/d... per
        # client: 2(w-t)/3; server mean over clients
        g = np.mean([2 * (np.array([1.0, -2.0, 0.5]) - t) / 3
                     for t in (0.0, 1.0, 2.0, 3.0)], axis=0)
        np.testing.assert_allclose(np.asarray(p2["w"]),
                                   np.array([1.0, -2.0, 0.5]) - 0.1 * g,
                                   rtol=1e-5)

    def test_gradient_evaluated_at_quantized_weights(self):
        """For the quadratic, grad = 2(Q(w) - t)/d exactly — recover Q(w)."""
        opt = sgd(1.0)

        def loss(params, batch, rng):
            return jnp.mean((params["w"] - batch["target"]) ** 2), {}

        rf = jax.jit(make_fwq_round(make_tree_quant_loss(loss), opt.update,
                                    FWQConfig(n_clients=1)))
        w0 = jnp.array([[0.3, -0.7, 0.11, 0.9]])  # 2D => quantized
        params = {"w": w0}
        batch = {"target": jnp.zeros((1, 1, 1, 4))}
        delta = delta_for_clients([2])
        p2, _, m = rf(params, opt.init(params), batch, delta, jax.random.PRNGKey(3))
        # p2 = w0 - 2*Q(w0)/4  =>  Q(w0) = 2*(w0 - p2)
        qw = 2 * (np.asarray(w0) - np.asarray(p2["w"]))
        s = float(np.max(np.abs(np.asarray(w0))))
        codes = qw / (s / 3.0)  # delta(2 bits) = 1/3
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)

    def test_heterogeneous_bits_diverge_clients(self):
        rf, opt = make_round(n_clients=2)
        params = {"w": jax.random.normal(jax.random.PRNGKey(4), (2, 4)) * 0.4}
        batch = {"target": jnp.zeros((2, 1, 2, 4))}
        delta = delta_for_clients([2, 32])
        _, _, m = rf(params, opt.init(params), batch, delta, jax.random.PRNGKey(1))
        # client 1 (fp) has the exact quadratic loss; client 0 sees Q noise
        assert not np.isclose(float(m.client_loss[0]), float(m.client_loss[1]))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_round_deterministic(self, seed):
        rf, opt = make_round(n_clients=2)
        params = {"w": jnp.ones((2, 4)) * 0.3}
        batch = {"target": jnp.zeros((2, 1, 2, 4))}
        delta = delta_for_clients([4, 8])
        outs = [rf(params, opt.init(params), batch, delta,
                   jax.random.PRNGKey(seed))[2].loss for _ in range(2)]
        assert float(outs[0]) == float(outs[1])


class TestInlineQuantizer:
    def test_exempt_paths_passthrough(self):
        t = make_inline_quantizer(jnp.float32(1 / 3), jax.random.PRNGKey(0))
        w = jax.random.normal(jax.random.PRNGKey(8), (8, 8)) * 0.4
        norm = jnp.ones((8,))
        assert np.array_equal(np.asarray(t("blocks/ln1", norm)), np.asarray(norm))
        assert not np.array_equal(np.asarray(t("blocks/mlp/w_up", w)), np.asarray(w))

    def test_site_keys_differ(self):
        t = make_inline_quantizer(jnp.float32(1 / 3), jax.random.PRNGKey(0))
        w = jax.random.normal(jax.random.PRNGKey(9), (8, 8)) * 0.4
        a = np.asarray(t("a/w_up", w))
        b = np.asarray(t("b/w_up", w))
        assert not np.array_equal(a, b)  # independent SR noise per site


class TestOptim:
    def test_sgd_momentum(self):
        opt = sgd(0.1, momentum=0.9)
        p = {"w": jnp.ones(3)}
        s = opt.init(p)
        g = {"w": jnp.ones(3)}
        u1, s = opt.update(g, s, p)
        u2, s = opt.update(g, s, p)
        # second step: mu = 0.9*1 + 1 = 1.9
        np.testing.assert_allclose(np.asarray(u2["w"]), -0.1 * 1.9, rtol=1e-6)

    def test_adamw_direction_and_decay(self):
        opt = adamw(0.01, weight_decay=0.1)
        p = {"w": jnp.full(3, 2.0)}
        s = opt.init(p)
        u, s = opt.update({"w": jnp.ones(3)}, s, p)
        assert np.all(np.asarray(u["w"]) < 0)  # descends

    def test_build_optimizer_rejects_unknown(self):
        with pytest.raises(ValueError):
            build_optimizer("lion", 0.1)

    def test_schedules(self):
        assert float(constant(0.5)(100)) == 0.5
        cd = cosine_decay(1.0, 100, final_frac=0.1)
        assert float(cd(0)) == pytest.approx(1.0)
        assert float(cd(100)) == pytest.approx(0.1, abs=1e-6)
        wc = warmup_cosine(1.0, warmup=10, total_steps=100)
        assert float(wc(0)) == 0.0
        assert float(wc(10)) == pytest.approx(1.0, abs=0.05)
