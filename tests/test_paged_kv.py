"""Paged KV cache + batched flash-decode tests.

Pins the ISSUE-5 contracts: the paged reference decode is BITWISE-equal to
the contiguous cache (tp=1 and tp=4, both KV-sharded and sequence-parallel
layouts, ragged per-slot lengths, staggered admission reusing reclaimed
pages); the flash-decode Pallas kernel matches the gathered-softmax oracle;
a request that outruns its cache capacity terminates cleanly (counted, not
silently clipped); and over-long prompts raise instead of truncating.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.kernels import ops
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.paging import (
    PagePool, SlotPager, plan_admissions, set_page_tables)
from repro.launch.steps import (
    build_cached_prefill, build_decode_step, build_init_fn,
    init_global_caches)
from repro.models.attention import PagedKVCache
from repro.models.common import ParamCtx
from repro.models.model import build_model

MESH = make_test_mesh((1, 1), ("data", "model"))


def _contig_table(batch: int, n_pmax: int) -> np.ndarray:
    """Slot b owns pool rows [b*n_pmax, (b+1)*n_pmax) — capacity == s_max."""
    return np.arange(batch * n_pmax, dtype=np.int32).reshape(batch, n_pmax)


def _setup(arch="yi-6b", B=2, S_max=32, S_p=8, page=8):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    axes = axis_ctx_for(MESH)
    init_fn, param_specs = build_init_fn(model, MESH, axes)
    params = init_fn(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(7), (B, S_p), 2,
                                cfg.vocab_size)
    return cfg, model, axes, params, param_specs, prompt


def _logit_fns(model, axes, param_specs, c_specs, *, with_plens=False,
               attn_impl="auto"):
    """shard_map'd (prefill, decode) returning LOCAL LOGITS, not tokens —
    the bitwise paged-vs-contiguous comparisons need the raw distribution
    (greedy argmax would mask softmax-normalization bugs)."""
    from jax.sharding import PartitionSpec as P

    def dec(p, tok, c):
        pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
        return model.decode_step(pc, p, {"token": tok}, c,
                                 attn_impl=attn_impl)

    sm_dec = jax.jit(jax.shard_map(
        dec, mesh=MESH, in_specs=(param_specs, P(), c_specs),
        out_specs=(P(None, None, "model"), c_specs), check_vma=False))

    if with_plens:
        def pre(p, toks, c, plens):
            pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
            return model.prefill(pc, p, {"tokens": toks}, c,
                                 prompt_lens=plens)

        sm_pre = jax.jit(jax.shard_map(
            pre, mesh=MESH, in_specs=(param_specs, P(), c_specs, P()),
            out_specs=(P(None, None, "model"), c_specs), check_vma=False))
    else:
        def pre(p, toks, c):
            pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
            return model.prefill(pc, p, {"tokens": toks}, c)

        sm_pre = jax.jit(jax.shard_map(
            pre, mesh=MESH, in_specs=(param_specs, P(), c_specs),
            out_specs=(P(None, None, "model"), c_specs), check_vma=False))
    return sm_pre, sm_dec


def _paged_caches(model, B, S_max, page, **kw):
    caches = model.init_caches(B, S_max, tp=1, dtype=jnp.float32,
                               page_size=page, **kw)
    return set_page_tables(caches, _contig_table(B, S_max // page))


class TestPagedVsContiguous:
    def test_bitwise_logits_tp1(self):
        """Paged ref decode produces BITWISE-identical logits to the
        contiguous slab, at the model.decode_step level."""
        cfg, model, axes, params, pspecs, prompt = _setup()
        B, S_max, page = 2, 32, 8

        def run(paged: bool):
            from repro.dist.sharding import cache_specs
            if paged:
                caches = _paged_caches(model, B, S_max, page)
            else:
                caches = model.init_caches(B, S_max, tp=1, dtype=jnp.float32)
            pre, dec = _logit_fns(model, axes, pspecs,
                                  cache_specs(caches, axes, cfg))
            _, caches = pre(params, prompt, caches)
            outs = []
            tok = jnp.ones((B, 1), jnp.int32)
            for t in range(5):
                lg, caches = dec(params, tok + t, caches)
                outs.append(np.asarray(lg))
            return np.stack(outs)

        np.testing.assert_array_equal(run(False), run(True))

    def test_bitwise_logits_ragged_lengths(self):
        """Per-slot prompt lengths (bucketed right-padded prompts): paged and
        contiguous caches stamp/mask identically -> bitwise-equal logits."""
        cfg, model, axes, params, pspecs, prompt = _setup()
        B, S_max, page = 2, 32, 8
        plens = jnp.asarray([5, 8], jnp.int32)

        def run(paged: bool):
            from repro.dist.sharding import cache_specs
            if paged:
                caches = _paged_caches(model, B, S_max, page)
            else:
                caches = model.init_caches(B, S_max, tp=1, dtype=jnp.float32)
            pre, dec = _logit_fns(model, axes, pspecs,
                                  cache_specs(caches, axes, cfg),
                                  with_plens=True)
            lg, caches = pre(params, prompt, caches, plens)
            outs = [np.asarray(lg)]
            tok = jnp.ones((B, 1), jnp.int32)
            for t in range(4):
                lg, caches = dec(params, tok + t, caches)
                outs.append(np.asarray(lg))
            return np.stack(outs)

        np.testing.assert_array_equal(run(False), run(True))

    def test_ragged_prefill_matches_solo_short_prompt(self):
        """A right-padded slot decodes exactly what an unpadded prefill of
        its true prompt decodes (padding never enters cache or logits)."""
        cfg, model, axes, params, pspecs, _ = _setup()
        from repro.dist.sharding import cache_specs
        B, S_max, page = 2, 32, 8
        short = jax.random.randint(jax.random.PRNGKey(3), (B, 5), 2,
                                   cfg.vocab_size)

        def greedy(lg):
            return jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

        def run(tokens, plens):
            caches = _paged_caches(model, B, S_max, page)
            cs = cache_specs(caches, axes, cfg)
            pre, dec = _logit_fns(model, axes, pspecs, cs,
                                  with_plens=plens is not None)
            args = (params, tokens, caches) + (
                (plens,) if plens is not None else ())
            lg, caches = pre(*args)
            tok = greedy(lg)
            toks = [np.asarray(tok)]
            for _ in range(4):
                lg, caches = dec(params, tok, caches)
                tok = greedy(lg)
                toks.append(np.asarray(tok))
            return np.stack(toks)

        padded = jnp.concatenate(
            [short, jnp.ones((B, 3), jnp.int32)], axis=1)   # pad to 8
        np.testing.assert_array_equal(
            run(short, None), run(padded, jnp.full((B,), 5, jnp.int32)))

    def test_staggered_admission_reuses_reclaimed_pages(self):
        """Evicting B and admitting C onto B's reclaimed pages must not
        disturb A (still decoding), and C must decode exactly its solo run."""
        cfg, model, axes, params, _pspecs, _ = _setup()
        B, S_max, S_p, page = 2, 32, 8, 8
        pa, pb, pc_prompt = (jax.random.randint(jax.random.PRNGKey(k), (S_p,),
                                                2, cfg.vocab_size)
                             for k in (21, 22, 23))
        n_pmax = S_max // page
        # pool holds exactly two live requests: C MUST reuse B's pages
        pager = SlotPager.build(B, S_max, page, pool_pages=2 * n_pmax)

        ss = build_decode_step(model, MESH, axes, s_max=S_max, batch_global=B,
                               page_size=page, pool_pages=2 * n_pmax)
        pf = build_cached_prefill(model, MESH, axes, s_max=S_max,
                                  s_prompt=S_p, batch_global=B,
                                  page_size=page, pool_pages=2 * n_pmax)

        def fresh():
            return init_global_caches(model, MESH, axes, s_max=S_max,
                                      batch_global=B, page_size=page,
                                      pool_pages=2 * n_pmax)

        def solo(prompt, n):
            sp = SlotPager.build(B, S_max, page, pool_pages=2 * n_pmax)
            sp.admit(0, S_max), sp.admit(1, S_max)
            caches = set_page_tables(fresh(), sp.table)
            toks = jnp.broadcast_to(prompt[None], (B, S_p))
            tok, caches = pf.fn(params, {"tokens": toks}, caches,
                                jnp.ones((B,), jnp.bool_))
            out = [int(np.asarray(tok)[0, 0])]
            for _ in range(n):
                tok, caches = ss.fn(params, {"token": tok}, caches)
                out.append(int(np.asarray(tok)[0, 0]))
            return out

        want_a, want_b, want_c = solo(pa, 7), solo(pb, 2), solo(pc_prompt, 3)

        pager.admit(0, S_max), pager.admit(1, S_max)
        caches = set_page_tables(fresh(), pager.table)
        tok, caches = pf.fn(params, {"tokens": jnp.stack([pa, pb])}, caches,
                            jnp.ones((B,), jnp.bool_))
        cur = np.array(tok)
        got_a, got_b = [int(cur[0, 0])], [int(cur[1, 0])]
        for _ in range(2):
            tok, caches = ss.fn(params, {"token": jnp.asarray(cur)}, caches)
            cur = np.array(tok)
            got_a.append(int(cur[0, 0]))
            got_b.append(int(cur[1, 0]))
        # B done: evict, then admit C onto the very pages B just freed
        freed = pager.evict(1)
        assert freed == n_pmax
        assert pager.admit(1, S_max)
        caches = set_page_tables(caches, pager.table)
        tok2, caches = pf.fn(params,
                             {"tokens": jnp.stack([pc_prompt, pc_prompt])},
                             caches, jnp.asarray([False, True]))
        cur[1] = np.asarray(tok2)[1]
        got_c = [int(cur[1, 0])]
        for _ in range(3):
            tok, caches = ss.fn(params, {"token": jnp.asarray(cur)}, caches)
            cur = np.array(tok)
            got_a.append(int(cur[0, 0]))
            got_c.append(int(cur[1, 0]))
        # A: 2 pre-eviction + 3 post-eviction decodes; all must match solo
        assert got_a == want_a[:6]
        assert got_b == want_b
        assert got_c == want_c

    @pytest.mark.parametrize("arch,layout", [
        ("yi-6b", "kv-sharded"),          # smoke n_kv=4, tp=4 -> kv heads split
        ("glm4-9b", "seq-parallel"),      # smoke n_kv=2, tp=4 -> seq sharded
    ])
    def test_tp4_bitwise_logits(self, arch, layout):
        """tp=4, both cache shardings: paged decode logits are bitwise-equal
        to the contiguous cache on the same mesh/params.

        Subprocess so XLA gets fake host devices before jax initializes."""
        script = _TP4_SCRIPT % {
            "src": os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src"),
            "arch": arch, "layout": layout}
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "PAGED_TP4_OK" in out.stdout


_TP4_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.launch.mesh import axis_ctx_for, make_test_mesh
from repro.launch.paging import set_page_tables
from repro.launch.steps import (build_cached_prefill, build_decode_step,
                                build_init_fn, init_global_caches)
from repro.models.common import ParamCtx
from repro.models.model import build_model
from repro.models.attention import kv_cache_seq_parallel
from repro.models.transformer import attn_dims

TP, B, S_MAX, S_P, PAGE = 4, 2, 32, 6, 4
cfg = smoke_variant(get_config(%(arch)r))
ad = attn_dims(cfg, TP)
seqpar = kv_cache_seq_parallel(ad)
assert seqpar == (%(layout)r == "seq-parallel"), (seqpar, %(layout)r)
model = build_model(cfg)
mesh = make_test_mesh((1, TP), ("data", "model"))
axes = axis_ctx_for(mesh)
init_fn, param_specs = build_init_fn(model, mesh, axes)
params = init_fn(jax.random.PRNGKey(0))
params = jax.tree_util.tree_map(
    lambda x: jax.device_put(np.asarray(x), x.sharding), params)
prompt = jax.random.randint(jax.random.PRNGKey(5), (B, S_P), 2, cfg.vocab_size)

def decode_logits(paged):
    kw = {"page_size": PAGE} if paged else {}
    caches = init_global_caches(model, mesh, axes, s_max=S_MAX,
                                batch_global=B, **kw)
    if paged:
        if seqpar:
            # shard t owns positions [t*8, (t+1)*8) -> 2 local pages; slot b
            # gets local rows [2b, 2b+1] of every shard's private pool
            n_loc = (S_MAX // TP) // PAGE
            table = np.zeros((B, TP * n_loc), np.int32)
            for b in range(B):
                for t in range(TP):
                    table[b, t * n_loc:(t + 1) * n_loc] = np.arange(
                        b * n_loc, (b + 1) * n_loc)
        else:
            n_pmax = S_MAX // PAGE
            table = np.arange(B * n_pmax, dtype=np.int32).reshape(B, n_pmax)
        caches = set_page_tables(caches, table)
    pf = build_cached_prefill(model, mesh, axes, s_max=S_MAX, s_prompt=S_P,
                              batch_global=B, **kw)
    ss_specs = build_decode_step(model, mesh, axes, s_max=S_MAX,
                                 batch_global=B, **kw)

    def local(p, tok, c):
        pc = ParamCtx(ctx=axes, compute_dtype=jnp.float32)
        lg, nc = model.decode_step(pc, p, {"token": tok}, c)
        return lg, nc

    sm = jax.jit(jax.shard_map(
        local, mesh=mesh,
        in_specs=(param_specs, P(), ss_specs.cache_specs),
        out_specs=(P(None, None, "model"), ss_specs.cache_specs),
        check_vma=False))
    tok, caches = pf.fn(params, {"tokens": prompt}, caches,
                        jnp.ones((B,), jnp.bool_))
    outs = []
    for t in range(5):
        # fixed token stream so both layouts see identical inputs even if a
        # greedy tie ever flipped
        lg, caches = sm(params, jnp.full((B, 1), 2 + t, jnp.int32), caches)
        outs.append(np.asarray(lg))
    return np.stack(outs)

np.testing.assert_array_equal(decode_logits(False), decode_logits(True))
print("PAGED_TP4_OK")
"""


class TestPagedFamilies:
    @pytest.mark.parametrize("arch", ["jamba-1.5-large-398b",
                                      "llama-3.2-vision-90b",
                                      "seamless-m4t-large-v2"])
    def test_paged_matches_contiguous_greedy(self, arch):
        """Hybrid (paged attn sublayers + SSM states), VLM (paged self +
        contiguous cross slabs), enc-dec (paged decoder self): the paged
        cache emits the same greedy tokens as the contiguous reference."""
        cfg = smoke_variant(get_config(arch))
        model = build_model(cfg)
        axes = axis_ctx_for(MESH)
        init_fn, _ = build_init_fn(model, MESH, axes)
        params = init_fn(jax.random.PRNGKey(0))
        B, S_max, S_p, page = 2, 32, 8, 8
        spec = model.prefill_batch_spec(B, S_p, S_max)
        batch = {}
        for name, sds in spec.items():
            if sds.dtype == jnp.int32:
                batch[name] = jax.random.randint(jax.random.PRNGKey(11),
                                                 sds.shape, 2, cfg.vocab_size)
            else:
                batch[name] = jax.random.normal(jax.random.PRNGKey(12),
                                                sds.shape, dtype=sds.dtype)

        def run(paged: bool):
            kw = {"page_size": page} if paged else {}
            pf = build_cached_prefill(model, MESH, axes, s_max=S_max,
                                      s_prompt=S_p, batch_global=B, **kw)
            ss = build_decode_step(model, MESH, axes, s_max=S_max,
                                   batch_global=B, **kw)
            caches = init_global_caches(model, MESH, axes, s_max=S_max,
                                        batch_global=B, **kw)
            if paged:
                caches = set_page_tables(caches,
                                         _contig_table(B, S_max // page))
            tok, caches = pf.fn(params, batch, caches,
                                jnp.ones((B,), jnp.bool_))
            out = [np.asarray(tok)]
            for _ in range(4):
                tok, caches = ss.fn(params, {"token": tok}, caches)
                out.append(np.asarray(tok))
            return np.stack(out)

        np.testing.assert_array_equal(run(False), run(True))


class TestFlashDecodeKernel:
    def _reference(self, q, kp, vp, pt, lens, page):
        B, KV, G, hd = q.shape
        n_pmax = pt.shape[1]
        kv = np.asarray(kp)[np.maximum(pt, 0)].reshape(B, n_pmax * page, KV, hd)
        vv = np.asarray(vp)[np.maximum(pt, 0)].reshape(B, n_pmax * page, KV, hd)
        alloc = np.repeat(pt >= 0, page, axis=1)
        out = np.zeros((B, KV, G, hd), np.float32)
        for b in range(B):
            for h in range(KV):
                s = (np.asarray(q)[b, h].astype(np.float32)
                     @ kv[b, :, h].astype(np.float32).T) * hd ** -0.5
                mask = (np.arange(n_pmax * page) < lens[b]) & alloc[b]
                s = np.where(mask[None, :], s, -1e30)
                w = np.exp(s - s.max(-1, keepdims=True))
                w /= w.sum(-1, keepdims=True)
                out[b, h] = w @ vv[b, :, h].astype(np.float32)
        return out

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_gathered_softmax(self, dtype):
        """Kernel output == gathered-contiguous softmax oracle, for both
        KV storage dtypes (PrecisionPolicy.kv_cache 32 and 16)."""
        rng = np.random.RandomState(1)
        B, KV, G, hd, page, n_pmax, N = 3, 2, 2, 16, 8, 4, 10
        q = jnp.asarray(rng.randn(B, KV, G, hd).astype(np.float32))
        kp = jnp.asarray(rng.randn(N, page, KV, hd).astype(np.float32))
        vp = jnp.asarray(rng.randn(N, page, KV, hd).astype(np.float32))
        pt = np.full((B, n_pmax), -1, np.int32)
        pt[0, :2] = [3, 7]
        pt[1, :4] = [0, 1, 2, 9]
        pt[2, :1] = [5]
        lens = np.array([13, 30, 4], np.int32)
        acc, m, l = ops.flash_paged_decode(q, kp.astype(dtype),
                                           vp.astype(dtype),
                                           jnp.asarray(pt), jnp.asarray(lens))
        got = np.asarray(acc / np.maximum(np.asarray(l), 1e-30))
        want = self._reference(q, kp.astype(dtype), vp.astype(dtype),
                               pt, lens, page)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_flash_decode_logits_match_ref_paged(self):
        """Flash-decode LOGITS match the paged reference to fp32 tolerance
        (per-token greedy equality alone would hide a softmax-normalization
        bug — e.g. masking one extra unwritten position deflates every
        logit but rarely flips the argmax)."""
        cfg, model, axes, params, pspecs, prompt = _setup()
        from repro.dist.sharding import cache_specs
        B, S_max, page = 2, 32, 8

        def run(attn_impl):
            caches = _paged_caches(model, B, S_max, page)
            pre, dec = _logit_fns(model, axes, pspecs,
                                  cache_specs(caches, axes, cfg),
                                  attn_impl=attn_impl)
            _, caches = pre(params, prompt, caches)
            outs = []
            tok = jnp.ones((B, 1), jnp.int32)
            for t in range(5):
                lg, caches = dec(params, tok + t, caches)
                outs.append(np.asarray(lg))
            return np.stack(outs)

        np.testing.assert_allclose(run("flash"), run("ref"),
                                   rtol=2e-5, atol=2e-5)

    def test_flash_decode_greedy_matches_ref_paged(self):
        """End-to-end: flash-decode step emits the same greedy tokens as the
        paged reference (and therefore as the contiguous cache)."""
        cfg, model, axes, params, _pspecs, prompt = _setup()
        B, S_max, S_p, page = 2, 32, 8, 8
        table = _contig_table(B, S_max // page)

        def run(attn_impl):
            ss = build_decode_step(model, MESH, axes, s_max=S_max,
                                   batch_global=B, page_size=page,
                                   attn_impl=attn_impl)
            pf = build_cached_prefill(model, MESH, axes, s_max=S_max,
                                      s_prompt=S_p, batch_global=B,
                                      page_size=page)
            caches = set_page_tables(
                init_global_caches(model, MESH, axes, s_max=S_max,
                                   batch_global=B, page_size=page), table)
            tok, caches = pf.fn(params, {"tokens": prompt}, caches,
                                jnp.ones((B,), jnp.bool_))
            out = [np.asarray(tok)]
            for _ in range(5):
                tok, caches = ss.fn(params, {"token": tok}, caches)
                out.append(np.asarray(tok))
            return np.stack(out)

        np.testing.assert_array_equal(run("ref"), run("flash"))


class TestCapacityGuard:
    def test_capacity_exceeding_request_terminates_cleanly(self):
        """ISSUE-5 headline regression: max_new far past the cache capacity
        must stop AT capacity with exactly (s_max - prompt + 1) tokens per
        sequence, counted in capacity_stops — never silently clipped."""
        from repro.launch.serve import run_serve

        B, S_MAX, S_P = 2, 32, 8
        for layout in ("paged", "contiguous"):
            stats = run_serve("yi-6b", smoke=True, steps=64, batch=B,
                              s_max=S_MAX, prompt_len=S_P, serve_bits=7,
                              requests=B, max_new=100, kv_layout=layout,
                              quiet=True)
            assert stats.capacity_stops == B, (layout, stats)
            assert stats.completed == B
            # each slot: 1 prefill token + (s_max - prompt) decodes
            assert stats.decoded_tokens == B * (S_MAX - S_P), (layout, stats)
            assert stats.decode_steps == S_MAX - S_P

    def test_pool_exhaustion_defers_admission(self):
        """A pool too small for the whole queue defers admissions until
        reclaim — every request still completes."""
        from repro.launch.serve import run_serve

        stats = run_serve("yi-6b", smoke=True, steps=40, batch=4, s_max=64,
                          prompt_len=8, serve_bits=7, requests=6, max_new=6,
                          page_size=8, pool_pages=4, quiet=True)
        assert stats.deferred_admissions > 0
        assert stats.completed == 6
        assert stats.kv_bytes < stats.kv_bytes_contiguous

    def test_impossible_request_raises(self):
        pool = SlotPager.build(2, 32, 8, pool_pages=1)
        with pytest.raises(ValueError, match="can never fit"):
            pool.admit(0, 32)

    def test_page_pool_free_list(self):
        pool = PagePool(4)
        a = pool.alloc(3)
        assert pool.free_pages == 1
        assert pool.alloc(2) is None        # all-or-nothing
        pool.free(a)
        assert pool.free_pages == 4
        with pytest.raises(ValueError):
            pool.free([99])


class TestAdmissionFairness:
    def test_fifo_within_slot_limit(self):
        admit, blocked = plan_admissions(4, 2, [1, 2, 1])
        assert admit == [0, 1]
        assert blocked == []                # third hit the slot limit, not pages

    def test_blocked_head_reserves_everything(self):
        """An oversized head request reserves all free pages: younger small
        requests see zero surplus and must wait behind it."""
        admit, blocked = plan_admissions(3, 4, [4, 1, 1])
        assert admit == [] and blocked == [0, 1, 2]

    def test_no_leapfrogging_past_a_blocked_request(self):
        """A page-blocked request reserves every usable page, so younger
        requests cannot leapfrog it — strict FIFO on the page resource."""
        admit, blocked = plan_admissions(5, 4, [4, 1, 6, 1])
        assert admit == [0, 1]             # fits before anything blocks
        assert blocked == [2, 3]           # and nothing passes index 2

    def test_big_request_admits_under_sustained_small_load(self):
        """Starvation regression: with one page reclaimed per cycle and a
        fresh small request arriving every cycle, the big head-of-queue
        request must still admit (freed pages accrue to it via reservation;
        a grab-what-fits policy would hand every page to the newcomers)."""
        queue = [5]                        # big request waiting, pool drained
        free = 0
        admitted = []
        for _ in range(20):
            free += 1                      # one completion reclaims a page
            queue.append(1)                # sustained small-request load
            take, _blocked = plan_admissions(free, 8, queue)
            for qi in reversed(take):
                need = queue.pop(qi)
                free -= need
                admitted.append(need)
            if 5 in admitted:
                break
        assert 5 in admitted
        # and it got there in exactly the 5 cycles its demand requires
        assert len([a for a in admitted if a == 1]) == 0

    def test_serve_rejects_request_that_can_never_fit(self):
        """A request whose page demand exceeds the whole pool must raise at
        admission planning (waiting would deadlock the queue forever)."""
        from repro.launch.serve import run_serve

        with pytest.raises(ValueError, match="can never fit"):
            run_serve("yi-6b", smoke=True, steps=8, batch=2, s_max=64,
                      prompt_len=8, serve_bits=7, requests=2, max_new=40,
                      page_size=8, pool_pages=2, quiet=True)

    def test_mixed_load_completes_with_tight_pool(self):
        """Ragged prompts + staggered caps against a pool sized for barely
        more than the largest single request: every request completes, with
        deferrals along the way."""
        from repro.launch.serve import run_serve

        stats = run_serve("yi-6b", smoke=True, steps=64, batch=4, s_max=64,
                          prompt_len=8, serve_bits=7, requests=8, max_new=12,
                          page_size=8, pool_pages=4, vary_prompt=True,
                          quiet=True)
        assert stats.completed == 8
        assert stats.deferred_admissions > 0


class TestPrefillBounds:
    def test_prompt_at_exact_capacity_works(self):
        """S_p == s_max boundary: prefill fills every position and decode
        still runs (its K/V write drops; attention sees the full window)."""
        cfg, model, axes, params, _pspecs, _ = _setup()
        B = 2
        S = 16
        prompt = jax.random.randint(jax.random.PRNGKey(9), (B, S), 2,
                                    cfg.vocab_size)
        for kw in ({}, {"page_size": 8}):
            pf = build_cached_prefill(model, MESH, axes, s_max=S, s_prompt=S,
                                      batch_global=B, **kw)
            ss = build_decode_step(model, MESH, axes, s_max=S, batch_global=B,
                                   **kw)
            caches = init_global_caches(model, MESH, axes, s_max=S,
                                        batch_global=B, **kw)
            if kw:
                caches = set_page_tables(caches, _contig_table(B, S // 8))
            tok, caches = pf.fn(params, {"tokens": prompt}, caches,
                                jnp.ones((B,), jnp.bool_))
            assert np.all(np.isfinite(np.asarray(tok)))
            tok, caches = ss.fn(params, {"token": tok}, caches)
            assert np.all(np.isfinite(np.asarray(tok)))

    def test_prompt_past_capacity_raises(self):
        """S_p > s_max must raise (the old path silently jnp.clip-truncated
        the prompt), for both cache layouts."""
        cfg, model, axes, params, _pspecs, _ = _setup()
        B, S = 2, 16
        prompt = jax.random.randint(jax.random.PRNGKey(9), (B, S + 1), 2,
                                    cfg.vocab_size)
        for kw in ({}, {"page_size": 8}):
            caches = init_global_caches(model, MESH, axes, s_max=S,
                                        batch_global=B, **kw)
            if kw:
                caches = set_page_tables(caches, _contig_table(B, S // 8))
            pf = build_cached_prefill(model, MESH, axes, s_max=S,
                                      s_prompt=S + 1, batch_global=B, **kw)
            with pytest.raises(ValueError, match="exceeds the KV-cache"):
                pf.fn(params, {"tokens": prompt}, caches,
                      jnp.ones((B,), jnp.bool_))
