"""Collective-layer tests: AxisCtx degenerate behavior and the SR-quantized
gradient all-reduce (unbiasedness, high-bit exactness, 1-device no-op).

Multi-device cases launch subprocesses so XLA can be given fake host devices
before jax initializes (mirrors tests/test_distributed.py)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import AxisCtx, quantized_psum_batch, wire_dtype
from repro.dist.wire import grad_wire_report

LOCAL = AxisCtx(batch_axes=(), model_axis=None, fsdp_axes=())


class TestWireDtype:
    def test_narrowest_exact_accumulator(self):
        # n * (2^bits - 1) must fit: 4 clients at 4 bits -> 60 -> int8
        assert wire_dtype(4, 4) == jnp.int8
        # 16 clients at 8 bits -> 4080 -> int16 (the 16x16 pod case)
        assert wire_dtype(8, 16) == jnp.int16
        # 16-bit codes always overflow int16 sums -> int32
        assert wire_dtype(16, 2) == jnp.int32
        assert wire_dtype(8, 200) == jnp.int32   # 200 * 255 > 32767
        # beyond int32 there is no exact accumulator (int64 would silently
        # downcast without x64) -> refuse instead of wrapping
        with pytest.raises(ValueError):
            wire_dtype(31, 2)

    def test_noop_outside_mesh_preserves_dtype(self):
        # outside a mesh the collective is a no-op; the on-wire dtype
        # contract is pinned by the multi-device subprocess test below
        axes = AxisCtx(batch_axes=("data",), model_axis=None,
                       fsdp_axes=("data",))
        g = jnp.ones((8, 8), jnp.float32)
        out = quantized_psum_batch(axes, g, jax.random.PRNGKey(0), 8)
        assert out.dtype == g.dtype

    def test_grad_wire_report_replicated_vs_fsdp(self):
        shapes = {
            "norm": {"scale": jax.ShapeDtypeStruct((64,), jnp.float32)},
            "mlp": {"w_up": jax.ShapeDtypeStruct((64, 256), jnp.float32)},
        }
        rep = grad_wire_report(shapes, fsdp=1, n_clients=16, comm_bits=8)
        n_elems = 64 + 64 * 256
        assert rep["replicated_elems"] == n_elems
        assert rep["fsdp_elems"] == 0
        assert rep["wire_dtype"] == "int16"          # 16 * 255 > int8
        assert rep["replicated_bytes_f32"] == n_elems * 4
        # int16 codes + one f32 scale scalar per leaf
        assert rep["replicated_bytes_wire"] == n_elems * 2 + 2 * 4
        assert rep["wire_ratio"] < 0.51

        # uncompressed: wire == f32, ratio 1
        fp = grad_wire_report(shapes, fsdp=1, n_clients=16, comm_bits=32)
        assert fp["replicated_bytes_wire"] == fp["replicated_bytes_f32"]
        assert fp["wire_ratio"] == 1.0
        assert fp["wire_dtype"] == "float32"

        # single client: every reduction is a no-op -> zero wire traffic
        solo = grad_wire_report(shapes, fsdp=1, n_clients=1, comm_bits=8)
        assert solo["replicated_bytes_wire"] == 0
        assert solo["replicated_bytes_f32"] == 0
        assert solo["wire_dtype"] == "none"


class TestAxisCtxLocal:
    def test_sizes_and_indices_outside_mesh(self):
        assert LOCAL.dp == 1 and LOCAL.tp == 1 and LOCAL.fsdp == 1
        assert LOCAL.dp_index() == 0 and LOCAL.tp_index() == 0
        ctx = AxisCtx(batch_axes=("data",), model_axis="model",
                      fsdp_axes=("data",))
        # unbound axes (no shard_map in scope) degrade to the local view
        assert ctx.dp == 1 and ctx.tp == 1 and ctx.fsdp == 1

    def test_collectives_are_identity_without_model_axis(self):
        x = jnp.arange(8.0).reshape(2, 4)
        assert LOCAL.psum_model(x) is x
        assert LOCAL.all_gather_model(x, axis=0) is x
        assert LOCAL.gather_fsdp(x, axis=0) is x


class TestQuantizedPsumSingleDevice:
    def test_one_client_noop(self):
        """dp == 1: the collective must return the gradient untouched, for
        quantized and full-precision bit-widths alike."""
        g = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        for bits in (4, 8, 32):
            out = quantized_psum_batch(LOCAL, g, jax.random.PRNGKey(1), bits)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


_MULTI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import AxisCtx, quantized_psum_batch

mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
axes = AxisCtx(batch_axes=("data",), model_axis=None, fsdp_axes=("data",))
N, SHAPE, R = 4, (8, 16), 256

key = jax.random.PRNGKey(0)
g = jax.random.normal(key, (N,) + SHAPE) * jnp.array(
    [0.1, 1.0, 3.0, 0.5])[:, None, None]          # heterogeneous magnitudes
exact_mean = jnp.mean(g, axis=0)

def run(bits):
    def local(gi, seeds):
        out = jax.vmap(lambda s: quantized_psum_batch(
            axes, gi[0], jax.random.PRNGKey(s), bits))(seeds)
        return out                                   # (R,) + SHAPE, replicated
    sm = jax.shard_map(local, mesh=mesh,
                       in_specs=(P("data"), P()), out_specs=P(),
                       check_vma=False)
    return jax.jit(sm)(g, jnp.arange(R, dtype=jnp.uint32))

# --- exactness at full precision (bits >= 32 bypasses quantization) -------
fp = run(32)
err_fp = float(jnp.max(jnp.abs(fp - exact_mean[None])))

# --- unbiasedness at low bits: E over SR seeds approaches the exact mean --
q8 = run(8)
emp_mean = jnp.mean(q8, axis=0)
bias = float(jnp.max(jnp.abs(emp_mean - exact_mean)))
step = float(jnp.max(jnp.abs(g)) / (2.0**8 - 1.0))
# per-draw noise std <= step/2 per client; mean of N clients, R draws
tol = 5.0 * step / (2.0 * (N * R) ** 0.5) + 1e-6
# every draw lies on the shared grid scaled by 1/N
per_draw_err = float(jnp.max(jnp.abs(q8 - exact_mean[None])))

# --- comm bits reach the wire: the all-reduce operand dtype narrows -------
def lower_text(bits):
    def local(gi, s):
        return quantized_psum_batch(axes, gi[0], jax.random.PRNGKey(s[0]), bits)
    sm = jax.shard_map(local, mesh=mesh, in_specs=(P("data"), P()),
                       out_specs=P(), check_vma=False)
    return jax.jit(sm).lower(g, jnp.zeros((1,), jnp.uint32)).as_text()

t8, t4 = lower_text(8), lower_text(4)
wire = {"i16_at_8bits": "xi16>" in t8,        # 4 * 255 -> int16 accumulator
        "i8_at_4bits": "xi8>" in t4}          # 4 * 15  -> int8 accumulator

print(json.dumps({"err_fp": err_fp, "bias": bias, "tol": tol,
                  "step": step, "per_draw_err": per_draw_err, **wire}))
"""


_NONFINITE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import AxisCtx, quantized_psum_batch

mesh = jax.make_mesh((4,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
axes = AxisCtx(batch_axes=("data",), model_axis=None, fsdp_axes=("data",))
g = jnp.ones((4, 8))
g = g.at[1, 3].set(jnp.nan).at[2, 5].set(jnp.inf)

def run(mode, grad):
    def local(gi):
        return quantized_psum_batch(axes, gi[0], jax.random.PRNGKey(0), 8,
                                    on_nonfinite=mode)
    sm = jax.shard_map(local, mesh=mesh, in_specs=(P("data"),),
                       out_specs=P(), check_vma=False)
    return np.asarray(jax.jit(sm)(grad))

out = {}
# raise on clean input: the guard must be transparent
clean = run("raise", jnp.ones((4, 8)))
out["clean_ok"] = bool(np.allclose(clean, 1.0, atol=1e-2))
# saturate: NaN -> 0, Inf -> the client's largest finite magnitude (1.0)
sat = run("saturate", g)
out["sat_finite"] = bool(np.isfinite(sat).all())
out["sat_mean"] = float(sat.mean())
# raise: NaN/Inf reaching the quantizer must be a loud runtime error.
# Checked LAST: the raising callback leaves the CPU runtime's token state
# poisoned, so any later dispatch in this process would fail spuriously.
try:
    run("raise", g)
    out["raised"] = False
except Exception as e:
    out["raised"] = True
    out["msg"] = f"{type(e).__name__}: {e}"[-800:]
print(json.dumps(out))
"""


class TestNonfiniteGuard:
    def test_invalid_mode_rejected(self):
        axes = AxisCtx(batch_axes=("data",), model_axis=None,
                       fsdp_axes=("data",))
        # outside a mesh dp == 1, so use the guard directly
        from repro.dist.collectives import _nonfinite_guard
        with pytest.raises(ValueError, match="raise.*saturate"):
            _nonfinite_guard(jnp.ones(4), "clamp")

    def test_raise_and_saturate_paths(self):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", _NONFINITE],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        v = json.loads(out.stdout.strip().splitlines()[-1])
        assert v["raised"], v
        assert "non-finite gradient" in v["msg"], v["msg"]
        assert v["clean_ok"], v            # guard is a no-op on finite input
        assert v["sat_finite"], v
        # 30 of 32 entries are exactly 1; NaN becomes 0, Inf clamps to 1 —
        # the mean stays near 1 instead of poisoning the whole reduction
        assert abs(v["sat_mean"] - 1.0) < 0.25, v


class TestQuantizedPsumMultiDevice:
    def test_unbiased_and_exact_high_bits(self):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", _MULTI],
                             capture_output=True, text=True, env=env,
                             timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        v = json.loads(out.stdout.strip().splitlines()[-1])
        # bits=32: bit-exact mean (pmean path)
        assert v["err_fp"] <= 1e-6, v
        # bits=8: unbiased across SR seeds (5-sigma bound on the bias)
        assert v["bias"] <= v["tol"], v
        # and each single draw is within one grid step of the true mean
        assert v["per_draw_err"] <= v["step"] + 1e-6, v
        # the codes cross the wire at the narrow accumulator dtype
        assert v["i16_at_8bits"] and v["i8_at_4bits"], v
