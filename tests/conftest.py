"""Test-session bootstrap.

* Ensures ``src`` is importable even when the suite is invoked without
  ``PYTHONPATH=src`` (e.g. straight ``pytest`` from the repo root) and the
  package is not pip-installed.
* Provides a deterministic fallback for ``hypothesis`` (not shipped in the
  hermetic container): the property tests then run a bounded seeded sweep
  via :mod:`tests._hypothesis_shim` instead of erroring at collection.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
