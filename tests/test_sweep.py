"""repro.sweep tests: grid construction, content-hash keys, resumability
(kill mid-grid, resume, completed cells skipped), and EXPERIMENTS.md table
determinism (interrupted-then-resumed == uninterrupted, byte for byte).
"""

import json

import pytest

from repro.api import PrecisionPolicy, RunSpec
from repro.sweep import (
    Axis,
    PRESETS,
    ResultsStore,
    Sweep,
    SweepRunner,
    cell_key,
    get_preset,
    render_tables,
    update_markers,
    write_experiments,
)
from repro.sweep.grid import set_field


def tiny_fl_sweep(name="tiny", rounds=1):
    """3-cell fl-sim grid, seconds on CPU (the resumability fixture)."""
    return Sweep(
        name=name,
        base={"arch": "mobilenet", "workload": "fl-sim", "rounds": rounds,
              "batch": 8,
              "options": {"n_clients": 4, "lr": 0.1, "eval_every": 0}},
        axes=(Axis("options.scheme",
                   ("fwq", "full_precision", "unified_q")),))


class TestCellKey:
    def test_key_is_order_independent_and_content_addressed(self):
        a = {"arch": "yi-6b", "options": {"x": 1, "y": 2}, "seed": 0}
        b = {"seed": 0, "options": {"y": 2, "x": 1}, "arch": "yi-6b"}
        assert cell_key(a) == cell_key(b)
        assert cell_key(a) != cell_key({**a, "seed": 1})

    def test_key_hashes_resolved_spec_not_spelling(self):
        """Defaults made explicit and omitted must hash identically."""
        sparse = Sweep(name="s", base={"arch": "mobilenet",
                                       "workload": "fl-sim"})
        dense = Sweep(name="s", base=RunSpec(
            arch="mobilenet", workload="fl-sim").to_dict())
        assert sparse.cells()[0].key == dense.cells()[0].key

    def test_precision_changes_key(self):
        base = {"arch": "yi-6b", "workload": "serve"}
        k32 = Sweep(name="s", base=base).cells()[0].key
        k7 = Sweep(name="s", base={
            **base, "precision": {"weights": 7, "lazy": True}}).cells()[0].key
        assert k32 != k7


class TestGrid:
    def test_cross_product_and_dotted_fields(self):
        sw = Sweep(name="g",
                   base={"arch": "yi-6b", "workload": "serve",
                         "options": {"steps": 4}},
                   axes=(Axis("precision.kv_cache", (32, 16)),
                         Axis("options.attn_impl", ("ref", "flash"))))
        cells = sw.cells()
        assert len(cells) == 4
        combos = {(c.spec.precision.kv_cache, c.spec.options["attn_impl"])
                  for c in cells}
        assert combos == {(32, "ref"), (32, "flash"), (16, "ref"),
                          (16, "flash")}
        assert len({c.key for c in cells}) == 4

    def test_dict_axis_values_merge(self):
        d = {"precision": {"kv_cache": 16}}
        set_field(d, "precision", {"weights": 7, "lazy": True})
        assert d["precision"] == {"kv_cache": 16, "weights": 7, "lazy": True}

    def test_presets_build_valid_runspecs(self):
        for name in PRESETS:
            cells = get_preset(name).cells()
            assert cells, name
            for c in cells:
                assert isinstance(c.spec, RunSpec)
                assert isinstance(c.spec.precision, PrecisionPolicy)

    def test_roofline_preset_covers_all_archs_plus_multipod(self):
        from repro.configs import ARCH_NAMES

        cells = get_preset("roofline-all-archs").cells()
        assert len(cells) >= len(ARCH_NAMES) + 1
        assert {c.spec.arch for c in cells} == set(ARCH_NAMES)
        assert any(c.spec.mesh == "2x16x16" for c in cells)
        assert all(c.spec.workload == "dryrun" for c in cells)

    def test_ci_tiny_dryrun_cells_alias_roofline_cells(self):
        """CI's dryrun cells must be content-identical to the grid's."""
        roof = {c.key for c in get_preset("roofline-all-archs").cells()}
        tiny = get_preset("ci-tiny").cells()
        dry = [c for c in tiny if c.spec.workload == "dryrun"]
        assert len(dry) == 2 and all(c.key in roof for c in dry)
        assert any(c.spec.workload == "fl-sim" for c in tiny)


class TestStore:
    def test_append_reload_last_wins(self, tmp_path):
        p = str(tmp_path / "s.jsonl")
        st = ResultsStore(p)
        st.append({"key": "k1", "status": "error", "metrics": {}})
        st.append({"key": "k1", "status": "ok", "metrics": {"v": 1}})
        st2 = ResultsStore(p)
        assert st2.has_ok("k1") and st2.get("k1")["metrics"] == {"v": 1}

    def test_torn_tail_line_is_dropped(self, tmp_path):
        p = str(tmp_path / "s.jsonl")
        st = ResultsStore(p)
        st.append({"key": "k1", "status": "ok", "metrics": {}})
        with open(p, "a") as f:
            f.write('{"key": "k2", "status": "o')     # crash mid-write
        st2 = ResultsStore(p)
        assert st2.has_ok("k1") and st2.get("k2") is None


class TestResumability:
    def test_interrupt_resume_skips_completed_and_tables_identical(
            self, tmp_path):
        """The satellite contract: kill a sweep mid-grid, rerun, completed
        cells are skipped (stored rows untouched, keys stable), and the final
        rendered tables are byte-identical to an uninterrupted run."""
        sweep = tiny_fl_sweep()

        # uninterrupted reference run
        ref_store = ResultsStore(str(tmp_path / "ref.jsonl"))
        SweepRunner(sweep, ref_store, quiet=True).run()

        # interrupted run: 2 cells, then "killed"
        store = ResultsStore(str(tmp_path / "cut.jsonl"))
        first = SweepRunner(sweep, store, quiet=True).run(max_cells=2)
        assert len(first["ran"]) == 2 and len(first["skipped"]) == 0
        frozen = {k: json.dumps(store.get(k), sort_keys=True)
                  for k in first["ran"]}

        # resume in a fresh store object (fresh process semantics)
        store2 = ResultsStore(str(tmp_path / "cut.jsonl"))
        second = SweepRunner(sweep, store2, quiet=True).run()
        assert sorted(second["skipped"]) == sorted(first["ran"])
        assert len(second["ran"]) == 1
        for k, blob in frozen.items():      # completed rows were not redone
            assert json.dumps(store2.get(k), sort_keys=True) == blob

        # byte-identical tables (wall-clock fields never reach the table)
        assert render_tables(sweep, store2) == render_tables(sweep, ref_store)

        exp_a, exp_b = str(tmp_path / "a.md"), str(tmp_path / "b.md")
        write_experiments(exp_a, sweep, store2)
        write_experiments(exp_b, sweep, ref_store)
        assert open(exp_a, "rb").read() == open(exp_b, "rb").read()

    def test_force_reruns_completed_cells(self, tmp_path):
        """Benchmark mode: force ignores the store but still records."""
        sweep = tiny_fl_sweep()
        store = ResultsStore(str(tmp_path / "f.jsonl"))
        SweepRunner(sweep, store, quiet=True).run()
        again = SweepRunner(sweep, store, quiet=True).run(force=True)
        assert len(again["ran"]) == len(sweep.cells())
        assert not again["skipped"]

    def test_error_cells_recorded_and_retried(self, tmp_path):
        bad = Sweep(name="bad",
                    base={"arch": "no-such-arch", "workload": "fl-sim",
                          "rounds": 1, "options": {"n_clients": 2}})
        store = ResultsStore(str(tmp_path / "bad.jsonl"))
        out = SweepRunner(bad, store, quiet=True).run()
        assert len(out["failed"]) == 1
        key = out["failed"][0]
        rec = store.get(key)
        assert rec["status"] == "error"
        # a crash row must carry enough to diagnose without re-running
        assert "error" in rec["metrics"]
        assert "Traceback" in rec["metrics"]["traceback"]
        # default: errors re-run; --keep-failed semantics: skipped
        out2 = SweepRunner(bad, store, quiet=True).run(rerun_failed=False)
        assert out2["skipped"] == [key] and not out2["failed"]

    def test_subprocess_crash_is_a_failed_row_with_stderr(self, tmp_path):
        """A cell whose subprocess exits nonzero becomes an explicit error
        row (returncode + stderr tail) and the grid keeps going — a dead
        cell must never abort the sweep."""
        sweep = Sweep(
            name="crashy",
            base={"arch": "yi-6b", "workload": "serve", "smoke": True,
                  "batch": 2, "seq": 32,
                  "options": {"steps": 4, "quiet": True}},
            axes=(Axis("options.attn_impl", ("bogus", "ref")),))
        store = ResultsStore(str(tmp_path / "c.jsonl"))
        out = SweepRunner(sweep, store, timeout_s=900, quiet=True).run()
        assert len(out["failed"]) == 1 and len(out["ran"]) == 1
        rec = store.get(out["failed"][0])
        assert rec["status"] == "error"
        assert rec["metrics"]["returncode"] != 0
        assert "attn_impl" in rec["metrics"]["stderr"]   # the actual raise
        # the healthy sibling cell still ran to completion
        assert store.get(out["ran"][0])["status"] == "ok"

    def test_subprocess_timeout_is_a_failed_row(self, tmp_path):
        sweep = Sweep(
            name="slow",
            base={"arch": "yi-6b", "workload": "serve", "smoke": True,
                  "batch": 2, "seq": 32,
                  "options": {"steps": 4, "quiet": True}})
        store = ResultsStore(str(tmp_path / "t.jsonl"))
        out = SweepRunner(sweep, store, timeout_s=3, quiet=True).run()
        assert out["failed"] and not out["ran"]
        rec = store.get(out["failed"][0])
        assert rec["status"] == "timeout"
        assert rec["metrics"]["timeout_s"] == 3
        assert "stderr" in rec["metrics"]    # tail captured (may be empty)


class TestMarkers:
    def test_insert_then_replace_idempotent(self, tmp_path):
        text = "# EXPERIMENTS\n\n## §Roofline\n\nprose stays\n"
        t1 = update_markers(text, "x", "TABLE v1")
        assert "TABLE v1" in t1 and "prose stays" in t1
        t2 = update_markers(t1, "x", "TABLE v2")
        assert "TABLE v2" in t2 and "TABLE v1" not in t2
        assert t2 == update_markers(t2, "x", "TABLE v2")

    def test_inline_markers_replace_in_place(self):
        text = ("head\n<!-- sweep:x:begin -->\nold\n<!-- sweep:x:end -->\n"
                "tail\n")
        out = update_markers(text, "x", "new")
        assert out == ("head\n<!-- sweep:x:begin -->\nnew\n"
                       "<!-- sweep:x:end -->\ntail\n")

    def test_dangling_marker_refused(self):
        """A half-present marker pair must raise, not splice over prose."""
        no_end = "head\n<!-- sweep:x:begin -->\nold\nprose\n"
        with pytest.raises(ValueError):
            update_markers(no_end, "x", "new")
        swapped = ("<!-- sweep:x:end -->\nmid\n<!-- sweep:x:begin -->\n")
        with pytest.raises(ValueError):
            update_markers(swapped, "x", "new")

    def test_partial_store_reads_as_partial(self, tmp_path):
        sweep = tiny_fl_sweep()
        store = ResultsStore(str(tmp_path / "p.jsonl"))
        SweepRunner(sweep, store, quiet=True).run(max_cells=1)
        body = render_tables(sweep, store)
        assert "Incomplete cells" in body and "pending" in body


class TestSubprocessCell:
    def test_train_cell_runs_in_subprocess_with_wire_metrics(self, tmp_path):
        """train cells run out-of-process (the runner provisions the 2 fake
        host devices the 2x1 mesh needs) and report the grad wire bytes."""
        sweep = Sweep(
            name="sub",
            base={"arch": "yi-6b", "workload": "train", "mesh": "2x1",
                  "smoke": True, "batch": 1, "seq": 8, "rounds": 1,
                  "precision": {"comm": 8},
                  "options": {"lr": 0.05, "quiet": True}})
        store = ResultsStore(str(tmp_path / "sub.jsonl"))
        out = SweepRunner(sweep, store, timeout_s=900, quiet=True).run()
        assert not out["failed"], store.rows()
        rec = store.get(out["ran"][0])
        assert rec["status"] == "ok"
        wire = rec["metrics"]["wire"]
        assert wire["comm_bits"] == 8 and wire["wire_dtype"] == "int16"
        assert (rec["metrics"]["wire"]["replicated_bytes_wire"]
                < wire["replicated_bytes_f32"])
