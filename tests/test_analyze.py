"""Static analysis (repro.analyze): precision flow, wire lint, kernel checker.

Seeded-regression contract: each rule family has a test that plants exactly
one defect and asserts exactly ONE finding with file/op provenance — and a
matching test that the shipped code produces none.
"""

import dataclasses
import os

import numpy as np
import pytest

import repro  # noqa: F401  (installs the jax compat shims)
import jax
import jax.numpy as jnp

from repro.analyze.allowlist import AllowEntry, apply_allowlist, load_allowlist
from repro.analyze.findings import Finding, at_or_above, worst_severity
from repro.analyze.kernel_check import check_kernel_spec, shipped_kernel_specs
from repro.analyze.precision_flow import lint_jaxpr
from repro.analyze.wire_lint import (WireContext, check_comm_report,
                                     expected_gathers, lint_module)
from repro.api.precision import PrecisionPolicy
from repro.kernels.spec import BlockOperand, KernelSpec, ScratchSpec
from repro.roofline.hlo_parse import CollectiveOp, ModuleCosts, parse_module

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "hlo")


def _fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# hlo_parse hardening: CollectiveOp records from checked-in HLO text
# ---------------------------------------------------------------------------


class TestHloCollectiveRecords:
    def test_f32_allreduce_record(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        r = recs[0]
        assert r.dtype == "f32"
        assert r.elems == 1024 * 256
        assert r.group_size == 4
        assert r.name == "%all-reduce.1"
        assert r.wire_bytes == pytest.approx(2 * 3 / 4 * 1024 * 256 * 4)

    def test_start_done_pair_counted_once(self):
        mc = parse_module(_fixture("allreduce_start_done.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1, "the -done half must not double-count"
        assert recs[0].elems == 512 * 128
        assert mc.collective_counts.get("all-reduce") == 1

    def test_tuple_parts_summed(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        assert recs[0].parts == (("s32", 100), ("s32", 156))
        assert recs[0].elems == 256

    def test_degenerate_group_moves_nothing(self):
        mc = parse_module(_fixture("degenerate_group.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        assert recs[0].group_size == 1
        assert recs[0].wire_bytes == 0.0
        assert mc.collective_bytes == 0.0


# ---------------------------------------------------------------------------
# wire lint
# ---------------------------------------------------------------------------


def _ctx(**kw):
    kw.setdefault("policy", PrecisionPolicy(comm=8))
    kw.setdefault("kind", "train")
    kw.setdefault("n_clients", 4)
    return WireContext(**kw)


def _mc(*records):
    return ModuleCosts(flops=0, dot_bytes=0, collective_bytes=0,
                       collective_by_kind={}, collective_counts={},
                       n_while=0, collectives=list(records))


def _rec(kind, dtype, elems, group=4, **kw):
    kw.setdefault("bytes", 0.0)
    kw.setdefault("wire_bytes", 0.0)
    kw.setdefault("mult", 1.0)
    kw.setdefault("name", f"%{kind}.0")
    kw.setdefault("computation", "%main.0")
    return CollectiveOp(kind=kind, dtype=dtype, elems=elems,
                        group_size=group, **kw)


class TestWireLint:
    def test_f32_allreduce_under_low_bit_comm_exactly_one(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        found = lint_module(mc, _ctx(), cell="t")
        assert len(found) == 1
        f = found[0]
        assert f.rule == "wire.f32_allreduce"
        assert f.severity == "error"
        assert "%all-reduce.1" in f.where

    def test_uncompressed_context_not_flagged(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        assert lint_module(mc, _ctx(kind="decode")) == []
        assert lint_module(mc, _ctx(n_clients=1)) == []
        assert lint_module(
            mc, _ctx(policy=PrecisionPolicy())) == []   # comm=32

    def test_degenerate_group_never_flagged(self):
        mc = parse_module(_fixture("degenerate_group.txt"))
        assert lint_module(mc, _ctx()) == []

    def test_narrow_allreduce(self):
        # wire_dtype(comm=8, n=4) = int16; s8 accumulator overflows
        found = lint_module(_mc(_rec("all-reduce", "s8", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.narrow_allreduce"]
        assert found[0].severity == "error"

    def test_wide_allreduce_warns(self):
        found = lint_module(_mc(_rec("all-reduce", "s32", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.wide_allreduce"]
        assert found[0].severity == "warn"

    def test_matching_width_clean(self):
        found = lint_module(_mc(_rec("all-reduce", "s16", 4096)), _ctx())
        assert found == []

    def test_unexpected_allgather(self):
        ctx = _ctx(kind="decode", fsdp=2,
                   expected_gather_dtypes=expected_gathers(
                       fsdp=2, tp=1, packed=True))
        ok = lint_module(_mc(_rec("all-gather", "s8", 4096, group=2)), ctx)
        assert ok == []
        bad = lint_module(_mc(_rec("all-gather", "f16", 4096, group=2)), ctx)
        assert [f.rule for f in bad] == ["wire.unexpected_allgather"]

    def test_pure_dp_mesh_expects_no_gathers(self):
        assert expected_gathers(fsdp=1, tp=1, packed=False) == frozenset()
        ctx = _ctx(expected_gather_dtypes=frozenset())
        bad = lint_module(_mc(_rec("all-gather", "f32", 4096)), ctx)
        assert [f.rule for f in bad] == ["wire.unexpected_allgather"]


class TestCommReportConsistency:
    def test_matching_report_clean(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        report = {"wire_dtype": "int32", "replicated_elems": 256}
        assert check_comm_report(mc, report) == []

    def test_doctored_report_flagged_once(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        report = {"wire_dtype": "int32", "replicated_elems": 300}
        found = check_comm_report(mc, report, cell="t")
        assert len(found) == 1
        assert found[0].rule == "wire.comm_report_mismatch"
        assert found[0].severity == "error"

    def test_uncompressed_report_noop(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        assert check_comm_report(mc, {"wire_dtype": "none"}) == []
        assert check_comm_report(mc, {"wire_dtype": "float32"}) == []


# ---------------------------------------------------------------------------
# precision-flow lint (taint walk over traced jaxprs)
# ---------------------------------------------------------------------------


LAZY = PrecisionPolicy.lazy_int8()


class TestPrecisionFlow:
    def test_eager_dequant_matmul_exactly_one(self):
        def step(x, codes, scale):
            w = codes.astype(jnp.float32) * scale     # eager dequant
            return x @ w

        traced = jax.jit(step).trace(
            _sds((4, 64), jnp.float32), _sds((64, 64), jnp.int8),
            _sds((), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.severity == "error"]
        assert len(found) == 1
        f = found[0]
        assert f.rule == "precision.eager_dequant"
        assert "test_analyze.py" in f.key            # file provenance
        assert "rhs" in f.message

    def test_scan_body_dequant_reported_once(self):
        def step(x, codes, scale):
            def body(h, c):
                return h @ (c.astype(jnp.float32) * scale), ()
            h, _ = jax.lax.scan(body, x, codes)
            return h

        traced = jax.jit(step).trace(
            _sds((4, 64), jnp.float32), _sds((3, 64, 64), jnp.int8),
            _sds((), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.rule == "precision.eager_dequant"]
        assert len(found) == 1, "loop fixpoint must dedupe per-layer reports"

    def test_quant_matmul_fast_path_clean(self):
        from repro.kernels.ops import quant_matmul

        traced = jax.jit(quant_matmul).trace(
            _sds((8, 128), jnp.float32), _sds((128, 128), jnp.int8),
            _sds((), jnp.float32))
        found = lint_jaxpr(traced.jaxpr, policy=LAZY, expect_fastpath=True)
        assert found == []

    def test_no_fastpath_warning(self):
        traced = jax.jit(lambda x, w: x @ w).trace(
            _sds((4, 64), jnp.float32), _sds((64, 64), jnp.float32))
        found = lint_jaxpr(traced.jaxpr, policy=LAZY, expect_fastpath=True)
        assert [f.rule for f in found] == ["precision.no_fastpath"]
        assert found[0].severity == "warn"
        # not expected (e.g. prefill): no warning
        assert lint_jaxpr(traced.jaxpr, policy=LAZY,
                          expect_fastpath=False) == []

    def test_int32_token_ids_do_not_taint(self):
        def step(tokens, table, w):
            x = jnp.take(table, tokens, axis=0)       # embedding gather
            return x @ w

        traced = jax.jit(step).trace(
            _sds((4,), jnp.int32), _sds((100, 64), jnp.float32),
            _sds((64, 64), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.rule == "precision.eager_dequant"]
        assert found == []

    def test_narrow_psum_accumulator_exactly_one(self):
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
        fn = jax.shard_map(lambda c: jax.lax.psum(c, "x"), mesh=mesh,
                           in_specs=P(), out_specs=P())
        traced = jax.jit(fn).trace(_sds((4, 64), jnp.int8))
        # lint as if the axis had 4 participants: 4*(2^8-1) needs int16
        found = lint_jaxpr(traced.jaxpr,
                           policy=PrecisionPolicy(comm=8),
                           axis_sizes={"x": 4})
        assert [f.rule for f in found] == ["precision.narrow_accumulator"]
        assert found[0].severity == "error"
        assert "test_analyze.py" in found[0].key
        # a wide-enough accumulator is clean
        fn32 = jax.shard_map(lambda c: jax.lax.psum(c, "x"), mesh=mesh,
                             in_specs=P(), out_specs=P())
        traced32 = jax.jit(fn32).trace(_sds((4, 64), jnp.int32))
        assert lint_jaxpr(traced32.jaxpr, policy=PrecisionPolicy(comm=8),
                          axis_sizes={"x": 4}) == []


# ---------------------------------------------------------------------------
# kernel checker
# ---------------------------------------------------------------------------


class TestKernelChecker:
    def test_shipped_kernels_clean(self):
        for spec in shipped_kernel_specs():
            assert check_kernel_spec(spec) == [], spec.name

    def test_index_map_skipping_last_k_step(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 1024, 256)              # grid k-extent 2
        assert spec.grid[2] == 2
        x = spec.inputs[0]
        broken = dataclasses.replace(
            spec, inputs=(dataclasses.replace(
                x, index_map=lambda i, j, k: (i, 0)),) + spec.inputs[1:])
        found = check_kernel_spec(broken, cell="seeded")
        assert len(found) == 1
        f = found[0]
        assert f.rule == "kernel.coverage_gap"
        assert f.key == "quant_matmul:x"
        assert "quant_matmul.py" in f.where

    def test_block_overrunning_unaligned_k(self):
        from repro.kernels.quant_matmul import (_out_map, _scale_map,
                                                _w_map, _x_map)

        # K=130 NOT padded to the 128 block: the second k step overruns
        spec = KernelSpec(
            name="quant_matmul", source="quant_matmul.py:seeded",
            grid=(1, 1, 2),
            inputs=(BlockOperand("x", (8, 130), (8, 128), _x_map),
                    BlockOperand("codes", (256, 128), (128, 128), _w_map),
                    BlockOperand("scale", (1, 1), (1, 1), _scale_map,
                                 coverage="any")),
            outputs=(BlockOperand("out", (8, 128), (8, 128), _out_map),))
        found = check_kernel_spec(spec, cell="seeded")
        assert len(found) == 1
        assert found[0].rule == "kernel.oob_dma"
        assert found[0].key == "quant_matmul:x"

    def test_scratch_dtype_rule(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 512, 256)
        broken = dataclasses.replace(
            spec, scratch=(ScratchSpec("acc", spec.scratch[0].shape,
                                       "bfloat16", binds="out"),))
        found = check_kernel_spec(broken)
        assert [f.rule for f in found] == ["kernel.scratch_dtype"]

    def test_scratch_shape_rule(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 512, 256)
        broken = dataclasses.replace(
            spec, scratch=(ScratchSpec("acc", (8, 8), "float32",
                                       binds="out"),))
        found = check_kernel_spec(broken)
        assert [f.rule for f in found] == ["kernel.scratch_shape"]

    def test_wrapper_padding_matches_choose_blocks(self):
        from repro.kernels.quant_matmul import choose_blocks, kernel_spec

        # ragged decode shapes: the spec must mirror ops.quant_matmul's pad
        for m, k, n in [(1, 64, 64), (3, 513, 2048), (7, 130, 384)]:
            spec = kernel_spec(m, k, n)
            bm, bn, bk = choose_blocks(m, k, n)
            assert spec.inputs[0].shape[0] % bm == 0
            assert spec.inputs[0].shape[1] % bk == 0
            assert check_kernel_spec(spec) == [], (m, k, n)


# ---------------------------------------------------------------------------
# allowlist + severity plumbing
# ---------------------------------------------------------------------------


class TestAllowlist:
    def _finding(self, **kw):
        kw.setdefault("rule", "precision.eager_dequant")
        kw.setdefault("severity", "error")
        kw.setdefault("message", "m")
        kw.setdefault("key", "ops.py:expert_dispatch")
        return Finding(**kw)

    def test_apply_and_gate(self):
        entries = [AllowEntry(rule="precision.*", key="ops.py:*",
                              reason="per-channel scale ABI")]
        f = self._finding()
        out = apply_allowlist([f], entries)
        assert out[0].allowed and out[0].allow_reason
        assert at_or_above(out, "error") == []
        # non-matching key stays gating
        other = apply_allowlist([self._finding(key="layers.py:mlp")], entries)
        assert not other[0].allowed
        assert len(at_or_above(other, "error")) == 1

    def test_worst_severity_skips_allowed(self):
        allowed = dataclasses.replace(self._finding(), allowed=True)
        assert worst_severity([allowed]) is None
        assert worst_severity([allowed], include_allowed=True) == "error"

    def test_load_rejects_reasonless_entries(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text('[[allow]]\nrule = "wire.*"\nkey = "*"\n')
        with pytest.raises(ValueError, match="reason"):
            load_allowlist(str(p))

    def test_load_roundtrip(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text('[[allow]]\nrule = "wire.*"\nkey = "train:*"\n'
                     'reason = "because"\n')
        entries = load_allowlist(str(p))
        assert entries == [AllowEntry("wire.*", "train:*", "because")]
        assert load_allowlist(str(tmp_path / "missing.toml")) == []

    def test_repo_allowlist_parses(self):
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        entries = load_allowlist(os.path.join(repo, "analyze.toml"))
        assert entries, "the checked-in analyze.toml must have entries"
        assert all(e.reason for e in entries)


# ---------------------------------------------------------------------------
# Session.analyze end-to-end (trace-only: no XLA compile)
# ---------------------------------------------------------------------------


class TestSessionAnalyze:
    @pytest.fixture(scope="class")
    def serve_findings(self):
        from repro.api.session import Session
        from repro.api.spec import RunSpec

        spec = RunSpec.from_dict({
            "arch": "yi-6b", "workload": "serve", "mesh": "1x1",
            "smoke": True, "batch": 2, "seq": 32,
            "precision": {"weights": 7, "lazy": True}})
        return Session(spec).analyze(compile=False)

    def test_serve_path_has_no_unallowlisted_errors(self, serve_findings):
        errors = at_or_above(serve_findings, "error")
        assert errors == [], [f.format() for f in errors]

    def test_packed_decode_keeps_fast_path(self, serve_findings):
        # the seeded regression this suite guards: building the decode step
        # without the session policy silently dequantizes every weight
        assert all(f.rule != "precision.no_fastpath"
                   for f in serve_findings)


# ---------------------------------------------------------------------------
# reduce-scatter accumulator contract + unknown collectives (wire lint v2)
# ---------------------------------------------------------------------------


class TestReduceScatterLint:
    def test_narrow_integer_reduce_scatter(self):
        # wire_dtype(comm=8, n=4) = int16; s8 scattered sums overflow
        found = lint_module(_mc(_rec("reduce-scatter", "s8", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.narrow_reduce_scatter"]
        assert found[0].severity == "error"

    def test_wide_integer_reduce_scatter_warns(self):
        found = lint_module(_mc(_rec("reduce-scatter", "s32", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.wide_reduce_scatter"]
        assert found[0].severity == "warn"

    def test_matching_width_clean(self):
        assert lint_module(
            _mc(_rec("reduce-scatter", "s16", 4096)), _ctx()) == []

    def test_float_reduce_scatter_is_the_fsdp_path(self):
        # FSDP gradients reduce-scatter in f32 by design: never flagged
        assert lint_module(
            _mc(_rec("reduce-scatter", "f32", 4096)), _ctx()) == []


class TestUnknownCollective:
    def test_parser_emits_conservative_record(self):
        mc = parse_module(_fixture("unknown_collective.txt"))
        recs = [r for r in mc.collectives if r.kind.startswith("unknown:")]
        assert len(recs) == 1
        r = recs[0]
        assert r.kind == "unknown:collective-broadcast"
        assert r.dtype == "f32" and r.elems == 64 * 32
        assert r.group_size == 4
        # wire bytes = full result bytes: an over- but never under-count
        assert r.wire_bytes == 64 * 32 * 4

    def test_lint_flags_unknown_kind(self):
        mc = parse_module(_fixture("unknown_collective.txt"))
        found = [f for f in lint_module(mc, _ctx())
                 if f.rule == "wire.unknown_collective"]
        assert len(found) == 1
        assert found[0].severity == "warn"
        assert "collective-broadcast" in found[0].message

    def test_known_fixture_has_no_unknown_records(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        assert not any(r.kind.startswith("unknown:")
                       for r in mc.collectives)


# ---------------------------------------------------------------------------
# analytic overflow / error-budget proofs (static_proofs)
# ---------------------------------------------------------------------------


class TestStaticProofs:
    def test_every_comm_cell_in_both_presets_proves(self):
        from repro.analyze.static_proofs import prove_spec
        from repro.sweep.grid import get_preset

        for name in ("grad-comm-wire", "fl-codesign-grid"):
            for cell in get_preset(name).cells():
                records, findings = prove_spec(cell.spec,
                                               rules=("overflow",))
                assert findings == [], (name, cell.label,
                                        [f.format() for f in findings])
                assert all(r["ok"] for r in records), (name, cell.label)

    def test_seeded_negative_one_tier_too_narrow(self):
        from repro.analyze.static_proofs import prove_wire_accumulator

        # comm=8, n=4 needs int16; forcing int8 must fail the proof
        proof, findings = prove_wire_accumulator(8, 4, force_dtype="int8")
        assert not proof["ok"]
        assert [f.rule for f in findings] == ["overflow.wire_accumulator"]
        assert findings[0].severity == "error"
        assert "int8" in findings[0].message

    def test_headroom_matches_code_bound(self):
        from repro.analyze.static_proofs import prove_wire_accumulator
        from repro.dist.collectives import code_bound

        proof, findings = prove_wire_accumulator(8, 4)
        assert findings == [] and proof["ok"]
        assert proof["worst_sum"] == 4 * code_bound(8) == 1020
        assert proof["dtype"] == "int16"
        # int16 capacity 32767 over 1020: 5 doublings fit
        assert proof["headroom_bits"] == 5

    def test_uncompressed_comm_is_trivially_exact(self):
        from repro.analyze.static_proofs import prove_wire_accumulator

        proof, findings = prove_wire_accumulator(32, 8)
        assert findings == [] and proof["ok"]
        assert proof["kind"] == "uncompressed"

    def test_error_budget_accepts_default_policy(self):
        from repro.analyze.static_proofs import check_error_budget
        from repro.api.precision import PrecisionPolicy

        rec, findings = check_error_budget(PrecisionPolicy(), 8)
        assert findings == [], [f.format() for f in findings]
        assert rec["ok"]

    def test_error_budget_rejects_impossible_tolerance(self):
        from repro.analyze.static_proofs import check_error_budget
        from repro.api.precision import PrecisionPolicy

        # a quantized policy (full precision has zero error by definition)
        rec, findings = check_error_budget(PrecisionPolicy(weights=8), 8,
                                           lam=1e-30)
        assert not rec["ok"]
        assert findings and all(f.rule == "precision.error_budget"
                                for f in findings)
        assert all(f.severity == "error" for f in findings)

    def test_overflow_margin_table_renders(self):
        from repro.analyze.static_proofs import overflow_margin_table

        table = overflow_margin_table()
        lines = table.splitlines()
        assert lines[0].startswith("| sweep |")
        assert len(lines) > 2
        assert "**NO**" not in table      # every shipped cell proves
        assert "grad-comm-wire" in table and "fl-codesign-grid" in table


# ---------------------------------------------------------------------------
# scalar-prefetch range checks (kernel.scalar_oob)
# ---------------------------------------------------------------------------


class TestScalarOperandCheck:
    def _spec_with_scalar(self, values, lo, hi):
        from repro.kernels.spec import ScalarOperand

        op = BlockOperand("x", (8,), (8,), lambda i: (0,))
        return KernelSpec(
            name="k", source="test.py:k", grid=(1,),
            inputs=(op,), outputs=(op,),
            scalars=(ScalarOperand("page_table", np.asarray(values),
                                   lo, hi, note="pool rows"),))

    def test_in_range_values_clean(self):
        spec = self._spec_with_scalar([0, 1, 2, -1], -1, 3)
        assert [f for f in check_kernel_spec(spec)
                if f.rule == "kernel.scalar_oob"] == []

    def test_out_of_range_value_flagged(self):
        spec = self._spec_with_scalar([0, 1, 7, -1], -1, 3)
        found = [f for f in check_kernel_spec(spec)
                 if f.rule == "kernel.scalar_oob"]
        assert len(found) == 1
        assert found[0].severity == "error"
        assert "page_table" in found[0].message

    def test_shipped_decode_spec_scalars_in_range(self):
        specs = [s for s in shipped_kernel_specs() if s.scalars]
        assert specs, "the paged decode spec must export scalar operands"
        for spec in specs:
            oob = [f for f in check_kernel_spec(spec)
                   if f.rule == "kernel.scalar_oob"]
            assert oob == [], [f.format() for f in oob]


# ---------------------------------------------------------------------------
# dead-allowlist detection + differential baseline gate
# ---------------------------------------------------------------------------


class TestDeadAllowlist:
    def _f(self, rule="numerics.unguarded", key="ssm.py:ssm_block"):
        return Finding(rule=rule, severity="warn", message="m", key=key)

    def test_live_entry_not_flagged(self):
        from repro.analyze.allowlist import dead_allowlist_findings

        entries = [AllowEntry("numerics.*", "ssm.py:*", "why")]
        assert dead_allowlist_findings([self._f()], entries) == []

    def test_dead_entry_flagged_once(self):
        from repro.analyze.allowlist import (dead_allowlist_findings,
                                             dead_entries)

        entries = [AllowEntry("numerics.*", "ssm.py:*", "why"),
                   AllowEntry("precision.*", "gone.py:*", "stale")]
        findings = [self._f()]
        assert dead_entries(findings, entries) == [entries[1]]
        out = dead_allowlist_findings(findings, entries, path="analyze.toml")
        assert [f.rule for f in out] == ["meta.dead_allowlist"]
        assert out[0].severity == "warn"
        assert "gone.py:*" in out[0].message
        assert out[0].where == "analyze.toml"

    def test_no_entries_no_findings(self):
        from repro.analyze.allowlist import dead_allowlist_findings

        assert dead_allowlist_findings([self._f()], []) == []


class TestBaselineGate:
    def _f(self, rule="wire.f32_allreduce", key="train:step",
           cell="dryrun:train_4k", where="a.py:10"):
        return Finding(rule=rule, severity="error", message="m",
                       key=key, cell=cell, where=where)

    def test_identity_is_line_number_free(self):
        from repro.analyze.baseline import finding_identity

        a = self._f(where="a.py:10")
        b = self._f(where="a.py:999")
        assert finding_identity(a) == finding_identity(b)

    def test_roundtrip_and_diff(self, tmp_path):
        from repro.analyze.baseline import (diff_against_baseline,
                                            load_baseline, write_baseline)

        p = str(tmp_path / "base.json")
        write_baseline([self._f()], p)
        base = load_baseline(p)
        # known finding filtered even if its line number moved
        assert diff_against_baseline([self._f(where="a.py:999")], base) == []
        new = self._f(key="train:other")
        assert diff_against_baseline([new], base) == [new]

    def test_write_merges_extra_identities(self, tmp_path):
        from repro.analyze.baseline import load_baseline, write_baseline

        p = str(tmp_path / "base.json")
        write_baseline([self._f()], p)
        first = load_baseline(p)
        write_baseline([self._f(key="train:other")], p,
                       extra_identities=first)
        merged = load_baseline(p)
        assert first < merged and len(merged) == 2

    def test_committed_baseline_parses(self):
        from repro.analyze.baseline import load_baseline

        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        path = os.path.join(repo, "results", "analyze_baseline.json")
        idents = load_baseline(path)
        assert idents, "the committed baseline must not be empty"
        assert all(len(i) == 3 for i in idents)


class TestRuleSelection:
    def test_normalize_accepts_iterables_and_strings(self):
        from repro.analyze.runner import ALL_RULE_FAMILIES, normalize_rules

        assert normalize_rules(None) is None     # None = every family
        assert set(ALL_RULE_FAMILIES) == {"precision", "wire", "kernel",
                                          "overflow", "numerics"}
        assert normalize_rules("overflow,numerics") == frozenset(
            {"overflow", "numerics"})
        assert normalize_rules(("wire",)) == frozenset({"wire"})

    def test_normalize_rejects_unknown_family(self):
        from repro.analyze.runner import normalize_rules

        with pytest.raises(ValueError, match="unknown rule"):
            normalize_rules("overflow,typo")
