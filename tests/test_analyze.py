"""Static analysis (repro.analyze): precision flow, wire lint, kernel checker.

Seeded-regression contract: each rule family has a test that plants exactly
one defect and asserts exactly ONE finding with file/op provenance — and a
matching test that the shipped code produces none.
"""

import dataclasses
import os

import numpy as np
import pytest

import repro  # noqa: F401  (installs the jax compat shims)
import jax
import jax.numpy as jnp

from repro.analyze.allowlist import AllowEntry, apply_allowlist, load_allowlist
from repro.analyze.findings import Finding, at_or_above, worst_severity
from repro.analyze.kernel_check import check_kernel_spec, shipped_kernel_specs
from repro.analyze.precision_flow import lint_jaxpr
from repro.analyze.wire_lint import (WireContext, check_comm_report,
                                     expected_gathers, lint_module)
from repro.api.precision import PrecisionPolicy
from repro.kernels.spec import BlockOperand, KernelSpec, ScratchSpec
from repro.roofline.hlo_parse import CollectiveOp, ModuleCosts, parse_module

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "hlo")


def _fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# hlo_parse hardening: CollectiveOp records from checked-in HLO text
# ---------------------------------------------------------------------------


class TestHloCollectiveRecords:
    def test_f32_allreduce_record(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        r = recs[0]
        assert r.dtype == "f32"
        assert r.elems == 1024 * 256
        assert r.group_size == 4
        assert r.name == "%all-reduce.1"
        assert r.wire_bytes == pytest.approx(2 * 3 / 4 * 1024 * 256 * 4)

    def test_start_done_pair_counted_once(self):
        mc = parse_module(_fixture("allreduce_start_done.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1, "the -done half must not double-count"
        assert recs[0].elems == 512 * 128
        assert mc.collective_counts.get("all-reduce") == 1

    def test_tuple_parts_summed(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        assert recs[0].parts == (("s32", 100), ("s32", 156))
        assert recs[0].elems == 256

    def test_degenerate_group_moves_nothing(self):
        mc = parse_module(_fixture("degenerate_group.txt"))
        recs = [r for r in mc.collectives if r.kind == "all-reduce"]
        assert len(recs) == 1
        assert recs[0].group_size == 1
        assert recs[0].wire_bytes == 0.0
        assert mc.collective_bytes == 0.0


# ---------------------------------------------------------------------------
# wire lint
# ---------------------------------------------------------------------------


def _ctx(**kw):
    kw.setdefault("policy", PrecisionPolicy(comm=8))
    kw.setdefault("kind", "train")
    kw.setdefault("n_clients", 4)
    return WireContext(**kw)


def _mc(*records):
    return ModuleCosts(flops=0, dot_bytes=0, collective_bytes=0,
                       collective_by_kind={}, collective_counts={},
                       n_while=0, collectives=list(records))


def _rec(kind, dtype, elems, group=4, **kw):
    kw.setdefault("bytes", 0.0)
    kw.setdefault("wire_bytes", 0.0)
    kw.setdefault("mult", 1.0)
    kw.setdefault("name", f"%{kind}.0")
    kw.setdefault("computation", "%main.0")
    return CollectiveOp(kind=kind, dtype=dtype, elems=elems,
                        group_size=group, **kw)


class TestWireLint:
    def test_f32_allreduce_under_low_bit_comm_exactly_one(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        found = lint_module(mc, _ctx(), cell="t")
        assert len(found) == 1
        f = found[0]
        assert f.rule == "wire.f32_allreduce"
        assert f.severity == "error"
        assert "%all-reduce.1" in f.where

    def test_uncompressed_context_not_flagged(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        assert lint_module(mc, _ctx(kind="decode")) == []
        assert lint_module(mc, _ctx(n_clients=1)) == []
        assert lint_module(
            mc, _ctx(policy=PrecisionPolicy())) == []   # comm=32

    def test_degenerate_group_never_flagged(self):
        mc = parse_module(_fixture("degenerate_group.txt"))
        assert lint_module(mc, _ctx()) == []

    def test_narrow_allreduce(self):
        # wire_dtype(comm=8, n=4) = int16; s8 accumulator overflows
        found = lint_module(_mc(_rec("all-reduce", "s8", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.narrow_allreduce"]
        assert found[0].severity == "error"

    def test_wide_allreduce_warns(self):
        found = lint_module(_mc(_rec("all-reduce", "s32", 4096)), _ctx())
        assert [f.rule for f in found] == ["wire.wide_allreduce"]
        assert found[0].severity == "warn"

    def test_matching_width_clean(self):
        found = lint_module(_mc(_rec("all-reduce", "s16", 4096)), _ctx())
        assert found == []

    def test_unexpected_allgather(self):
        ctx = _ctx(kind="decode", fsdp=2,
                   expected_gather_dtypes=expected_gathers(
                       fsdp=2, tp=1, packed=True))
        ok = lint_module(_mc(_rec("all-gather", "s8", 4096, group=2)), ctx)
        assert ok == []
        bad = lint_module(_mc(_rec("all-gather", "f16", 4096, group=2)), ctx)
        assert [f.rule for f in bad] == ["wire.unexpected_allgather"]

    def test_pure_dp_mesh_expects_no_gathers(self):
        assert expected_gathers(fsdp=1, tp=1, packed=False) == frozenset()
        ctx = _ctx(expected_gather_dtypes=frozenset())
        bad = lint_module(_mc(_rec("all-gather", "f32", 4096)), ctx)
        assert [f.rule for f in bad] == ["wire.unexpected_allgather"]


class TestCommReportConsistency:
    def test_matching_report_clean(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        report = {"wire_dtype": "int32", "replicated_elems": 256}
        assert check_comm_report(mc, report) == []

    def test_doctored_report_flagged_once(self):
        mc = parse_module(_fixture("allreduce_tuple.txt"))
        report = {"wire_dtype": "int32", "replicated_elems": 300}
        found = check_comm_report(mc, report, cell="t")
        assert len(found) == 1
        assert found[0].rule == "wire.comm_report_mismatch"
        assert found[0].severity == "error"

    def test_uncompressed_report_noop(self):
        mc = parse_module(_fixture("allreduce_f32.txt"))
        assert check_comm_report(mc, {"wire_dtype": "none"}) == []
        assert check_comm_report(mc, {"wire_dtype": "float32"}) == []


# ---------------------------------------------------------------------------
# precision-flow lint (taint walk over traced jaxprs)
# ---------------------------------------------------------------------------


LAZY = PrecisionPolicy.lazy_int8()


class TestPrecisionFlow:
    def test_eager_dequant_matmul_exactly_one(self):
        def step(x, codes, scale):
            w = codes.astype(jnp.float32) * scale     # eager dequant
            return x @ w

        traced = jax.jit(step).trace(
            _sds((4, 64), jnp.float32), _sds((64, 64), jnp.int8),
            _sds((), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.severity == "error"]
        assert len(found) == 1
        f = found[0]
        assert f.rule == "precision.eager_dequant"
        assert "test_analyze.py" in f.key            # file provenance
        assert "rhs" in f.message

    def test_scan_body_dequant_reported_once(self):
        def step(x, codes, scale):
            def body(h, c):
                return h @ (c.astype(jnp.float32) * scale), ()
            h, _ = jax.lax.scan(body, x, codes)
            return h

        traced = jax.jit(step).trace(
            _sds((4, 64), jnp.float32), _sds((3, 64, 64), jnp.int8),
            _sds((), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.rule == "precision.eager_dequant"]
        assert len(found) == 1, "loop fixpoint must dedupe per-layer reports"

    def test_quant_matmul_fast_path_clean(self):
        from repro.kernels.ops import quant_matmul

        traced = jax.jit(quant_matmul).trace(
            _sds((8, 128), jnp.float32), _sds((128, 128), jnp.int8),
            _sds((), jnp.float32))
        found = lint_jaxpr(traced.jaxpr, policy=LAZY, expect_fastpath=True)
        assert found == []

    def test_no_fastpath_warning(self):
        traced = jax.jit(lambda x, w: x @ w).trace(
            _sds((4, 64), jnp.float32), _sds((64, 64), jnp.float32))
        found = lint_jaxpr(traced.jaxpr, policy=LAZY, expect_fastpath=True)
        assert [f.rule for f in found] == ["precision.no_fastpath"]
        assert found[0].severity == "warn"
        # not expected (e.g. prefill): no warning
        assert lint_jaxpr(traced.jaxpr, policy=LAZY,
                          expect_fastpath=False) == []

    def test_int32_token_ids_do_not_taint(self):
        def step(tokens, table, w):
            x = jnp.take(table, tokens, axis=0)       # embedding gather
            return x @ w

        traced = jax.jit(step).trace(
            _sds((4,), jnp.int32), _sds((100, 64), jnp.float32),
            _sds((64, 64), jnp.float32))
        found = [f for f in lint_jaxpr(traced.jaxpr, policy=LAZY)
                 if f.rule == "precision.eager_dequant"]
        assert found == []

    def test_narrow_psum_accumulator_exactly_one(self):
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("x",))
        fn = jax.shard_map(lambda c: jax.lax.psum(c, "x"), mesh=mesh,
                           in_specs=P(), out_specs=P())
        traced = jax.jit(fn).trace(_sds((4, 64), jnp.int8))
        # lint as if the axis had 4 participants: 4*(2^8-1) needs int16
        found = lint_jaxpr(traced.jaxpr,
                           policy=PrecisionPolicy(comm=8),
                           axis_sizes={"x": 4})
        assert [f.rule for f in found] == ["precision.narrow_accumulator"]
        assert found[0].severity == "error"
        assert "test_analyze.py" in found[0].key
        # a wide-enough accumulator is clean
        fn32 = jax.shard_map(lambda c: jax.lax.psum(c, "x"), mesh=mesh,
                             in_specs=P(), out_specs=P())
        traced32 = jax.jit(fn32).trace(_sds((4, 64), jnp.int32))
        assert lint_jaxpr(traced32.jaxpr, policy=PrecisionPolicy(comm=8),
                          axis_sizes={"x": 4}) == []


# ---------------------------------------------------------------------------
# kernel checker
# ---------------------------------------------------------------------------


class TestKernelChecker:
    def test_shipped_kernels_clean(self):
        for spec in shipped_kernel_specs():
            assert check_kernel_spec(spec) == [], spec.name

    def test_index_map_skipping_last_k_step(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 1024, 256)              # grid k-extent 2
        assert spec.grid[2] == 2
        x = spec.inputs[0]
        broken = dataclasses.replace(
            spec, inputs=(dataclasses.replace(
                x, index_map=lambda i, j, k: (i, 0)),) + spec.inputs[1:])
        found = check_kernel_spec(broken, cell="seeded")
        assert len(found) == 1
        f = found[0]
        assert f.rule == "kernel.coverage_gap"
        assert f.key == "quant_matmul:x"
        assert "quant_matmul.py" in f.where

    def test_block_overrunning_unaligned_k(self):
        from repro.kernels.quant_matmul import (_out_map, _scale_map,
                                                _w_map, _x_map)

        # K=130 NOT padded to the 128 block: the second k step overruns
        spec = KernelSpec(
            name="quant_matmul", source="quant_matmul.py:seeded",
            grid=(1, 1, 2),
            inputs=(BlockOperand("x", (8, 130), (8, 128), _x_map),
                    BlockOperand("codes", (256, 128), (128, 128), _w_map),
                    BlockOperand("scale", (1, 1), (1, 1), _scale_map,
                                 coverage="any")),
            outputs=(BlockOperand("out", (8, 128), (8, 128), _out_map),))
        found = check_kernel_spec(spec, cell="seeded")
        assert len(found) == 1
        assert found[0].rule == "kernel.oob_dma"
        assert found[0].key == "quant_matmul:x"

    def test_scratch_dtype_rule(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 512, 256)
        broken = dataclasses.replace(
            spec, scratch=(ScratchSpec("acc", spec.scratch[0].shape,
                                       "bfloat16", binds="out"),))
        found = check_kernel_spec(broken)
        assert [f.rule for f in found] == ["kernel.scratch_dtype"]

    def test_scratch_shape_rule(self):
        from repro.kernels.quant_matmul import kernel_spec

        spec = kernel_spec(8, 512, 256)
        broken = dataclasses.replace(
            spec, scratch=(ScratchSpec("acc", (8, 8), "float32",
                                       binds="out"),))
        found = check_kernel_spec(broken)
        assert [f.rule for f in found] == ["kernel.scratch_shape"]

    def test_wrapper_padding_matches_choose_blocks(self):
        from repro.kernels.quant_matmul import choose_blocks, kernel_spec

        # ragged decode shapes: the spec must mirror ops.quant_matmul's pad
        for m, k, n in [(1, 64, 64), (3, 513, 2048), (7, 130, 384)]:
            spec = kernel_spec(m, k, n)
            bm, bn, bk = choose_blocks(m, k, n)
            assert spec.inputs[0].shape[0] % bm == 0
            assert spec.inputs[0].shape[1] % bk == 0
            assert check_kernel_spec(spec) == [], (m, k, n)


# ---------------------------------------------------------------------------
# allowlist + severity plumbing
# ---------------------------------------------------------------------------


class TestAllowlist:
    def _finding(self, **kw):
        kw.setdefault("rule", "precision.eager_dequant")
        kw.setdefault("severity", "error")
        kw.setdefault("message", "m")
        kw.setdefault("key", "ops.py:expert_dispatch")
        return Finding(**kw)

    def test_apply_and_gate(self):
        entries = [AllowEntry(rule="precision.*", key="ops.py:*",
                              reason="per-channel scale ABI")]
        f = self._finding()
        out = apply_allowlist([f], entries)
        assert out[0].allowed and out[0].allow_reason
        assert at_or_above(out, "error") == []
        # non-matching key stays gating
        other = apply_allowlist([self._finding(key="layers.py:mlp")], entries)
        assert not other[0].allowed
        assert len(at_or_above(other, "error")) == 1

    def test_worst_severity_skips_allowed(self):
        allowed = dataclasses.replace(self._finding(), allowed=True)
        assert worst_severity([allowed]) is None
        assert worst_severity([allowed], include_allowed=True) == "error"

    def test_load_rejects_reasonless_entries(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text('[[allow]]\nrule = "wire.*"\nkey = "*"\n')
        with pytest.raises(ValueError, match="reason"):
            load_allowlist(str(p))

    def test_load_roundtrip(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text('[[allow]]\nrule = "wire.*"\nkey = "train:*"\n'
                     'reason = "because"\n')
        entries = load_allowlist(str(p))
        assert entries == [AllowEntry("wire.*", "train:*", "because")]
        assert load_allowlist(str(tmp_path / "missing.toml")) == []

    def test_repo_allowlist_parses(self):
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        entries = load_allowlist(os.path.join(repo, "analyze.toml"))
        assert entries, "the checked-in analyze.toml must have entries"
        assert all(e.reason for e in entries)


# ---------------------------------------------------------------------------
# Session.analyze end-to-end (trace-only: no XLA compile)
# ---------------------------------------------------------------------------


class TestSessionAnalyze:
    @pytest.fixture(scope="class")
    def serve_findings(self):
        from repro.api.session import Session
        from repro.api.spec import RunSpec

        spec = RunSpec.from_dict({
            "arch": "yi-6b", "workload": "serve", "mesh": "1x1",
            "smoke": True, "batch": 2, "seq": 32,
            "precision": {"weights": 7, "lazy": True}})
        return Session(spec).analyze(compile=False)

    def test_serve_path_has_no_unallowlisted_errors(self, serve_findings):
        errors = at_or_above(serve_findings, "error")
        assert errors == [], [f.format() for f in errors]

    def test_packed_decode_keeps_fast_path(self, serve_findings):
        # the seeded regression this suite guards: building the decode step
        # without the session policy silently dequantizes every weight
        assert all(f.rule != "precision.no_fastpath"
                   for f in serve_findings)
