"""End-to-end federated system tests: FWQ simulator + orchestrator +
checkpoint/restart + straggler/dropout handling + data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import heterogeneous_fleet, memory_capacities
from repro.data import ClientBatcher, SyntheticImages, dirichlet_partition
from repro.data.partition import heterogeneity_phi
from repro.fed import FLOrchestrator, FLSimulation, OrchestratorConfig, SimConfig
from repro.models.cnn import mobilenet, resnet, xent_loss


def make_sim(n_clients=6, seed=0, lr=0.2, kind="resnet"):
    model = (resnet(depth_blocks=(1, 1), width=8) if kind == "resnet"
             else mobilenet(width=8, n_stages=2))
    loss = xent_loss(model)
    sim = FLSimulation(loss, model.init, SimConfig(n_clients=n_clients,
                                                   lr=lr, seed=seed))
    return sim, model, loss


def make_data(n=512, n_clients=6, seed=0):
    imgs, labels = SyntheticImages(n=n, hw=16, seed=seed).generate()
    parts = dirichlet_partition(labels, n_clients, alpha=0.5, seed=seed)
    return ClientBatcher(imgs, labels, parts, batch=16, seed=seed)


def batch_fn_for(batcher):
    def fn(round_idx, cohort):
        x, y = batcher.sample_round(round_idx, cohort)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    return fn


class TestSimulation:
    def test_fwq_rounds_reduce_loss(self):
        sim, model, loss = make_sim()
        batcher = make_data()
        fn = batch_fn_for(batcher)
        bits = np.array([8, 8, 16, 16, 32, 32])
        losses = []
        for r in range(30):
            rec = sim.run_round(fn(r, np.arange(6)), bits)
            losses.append(rec["loss"])
        assert np.isfinite(losses[-1])
        # robust improvement check: best-of-last-5 clearly below round 0
        assert min(losses[-5:]) < losses[0] - 0.02, losses[::6]

    def test_quantized_worse_or_equal_than_full(self):
        """Discretization error (Cor. 1): aggressive quantization shouldn't
        beat full precision on the same data/seeds (paper Fig. 2 trend)."""
        losses = {}
        for name, bits in [("fp", [32] * 6), ("q2", [2] * 6)]:
            sim, *_ = make_sim(seed=3)
            batcher = make_data(seed=3)
            fn = batch_fn_for(batcher)
            for r in range(20):
                rec = sim.run_round(fn(r, np.arange(6)), np.array(bits))
            losses[name] = rec["loss"]
        assert losses["fp"] <= losses["q2"] + 0.05

    def test_elastic_cohort_sizes(self):
        sim, *_ = make_sim()
        batcher = make_data()
        fn = batch_fn_for(batcher)
        sim.run_round(fn(0, np.arange(6)), np.full(6, 16))
        sim.run_round(fn(1, np.arange(4)), np.full(4, 16))   # shrink
        rec = sim.run_round(fn(2, np.arange(6)), np.full(6, 16))
        assert np.isfinite(rec["loss"])

    def test_deterministic_given_seed(self):
        outs = []
        for _ in range(2):
            sim, *_ = make_sim(seed=11)
            batcher = make_data(seed=11)
            fn = batch_fn_for(batcher)
            for r in range(3):
                rec = sim.run_round(fn(r, np.arange(6)), np.full(6, 8))
            outs.append(rec["loss"])
        assert outs[0] == outs[1]


class TestOrchestrator:
    def _orch(self, n=6, rounds=8, tmp="", **kw):
        fleet = heterogeneous_fleet(n, seed=0, group_step_mhz=5.0)
        caps = memory_capacities(n, lo_mb=2.0, hi_mb=8.0) * 1e6
        cfg = OrchestratorConfig(n_devices=n, n_rounds=rounds,
                                 model_dim_d=1 << 16, ckpt_dir=tmp, **kw)
        return FLOrchestrator(cfg, fleet, caps, grad_bytes=1e6)

    def test_full_run_with_energy_accounting(self):
        orch = self._orch()
        sim, *_ = make_sim()
        out = orch.run(sim, batch_fn_for(make_data()))
        assert out["total_energy_j"] > 0
        assert out["total_time_s"] > 0
        assert len(out["history"]) == 8
        q = out["energy_log"][0]["q"]
        assert set(np.unique(q)).issubset({8, 16, 32})

    def test_fwq_beats_baselines_on_energy(self):
        energies = {}
        for scheme in ("fwq", "full_precision", "unified_q", "rand_q"):
            orch = self._orch(scheme=scheme, rounds=4)
            sim, *_ = make_sim()
            out = orch.run(sim, batch_fn_for(make_data()))
            energies[scheme] = out["total_energy_j"]
        assert energies["fwq"] <= energies["full_precision"] * (1 + 1e-6)
        assert energies["fwq"] <= energies["unified_q"] * (1 + 1e-6)

    def test_straggler_and_dropout_handling(self):
        orch = self._orch(dropout_prob=0.3, straggler_slack=1.0, rounds=6)
        sim, *_ = make_sim()
        out = orch.run(sim, batch_fn_for(make_data()))
        assert len(out["history"]) == 6
        sizes = [r["cohort_size"] for r in out["history"]]
        assert min(sizes) >= 1
        assert any(s < 6 for s in sizes)  # some rounds lost clients

    def test_checkpoint_restart_bit_identical(self, tmp_path):
        ck = str(tmp_path / "ck")
        # run 1: all 8 rounds straight through
        orch = self._orch(rounds=8, tmp=ck + "_a", ckpt_every=2)
        sim, *_ = make_sim(seed=5)
        out_a = orch.run(sim, batch_fn_for(make_data(seed=5)))
        # run 2: crash after 4 rounds, then resume
        orch_b = self._orch(rounds=4, tmp=ck + "_b", ckpt_every=2)
        sim_b, *_ = make_sim(seed=5)
        orch_b.run(sim_b, batch_fn_for(make_data(seed=5)))
        orch_c = self._orch(rounds=8, tmp=ck + "_b", ckpt_every=2)
        sim_c, *_ = make_sim(seed=5)
        out_c = orch_c.run(sim_c, batch_fn_for(make_data(seed=5)))
        assert out_a["history"][-1]["loss"] == pytest.approx(
            out_c["history"][-1]["loss"], abs=1e-6)


class TestData:
    def test_dirichlet_partition_covers(self):
        _, labels = SyntheticImages(n=1000, hw=8).generate()
        parts = dirichlet_partition(labels, 10, alpha=0.3)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(labels)
        assert len(np.unique(allidx)) == len(labels)

    def test_lower_alpha_more_heterogeneous(self):
        _, labels = SyntheticImages(n=4000, hw=8).generate()
        phi_lo = heterogeneity_phi(labels, dirichlet_partition(labels, 8, alpha=0.1, seed=1))
        phi_hi = heterogeneity_phi(labels, dirichlet_partition(labels, 8, alpha=100.0, seed=1))
        assert phi_lo > phi_hi

    def test_batcher_deterministic(self):
        b = make_data()
        x1, y1 = b.sample_round(3, np.array([0, 1]))
        x2, y2 = b.sample_round(3, np.array([0, 1]))
        np.testing.assert_array_equal(x1, x2)


class TestCheckpoint:
    def test_roundtrip_and_verify(self, tmp_path):
        from repro.ckpt import load_checkpoint, save_checkpoint
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 7, tree)
        out, manifest = load_checkpoint(str(tmp_path), tree)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))

    def test_corruption_detected(self, tmp_path):
        from repro.ckpt import load_checkpoint, save_checkpoint
        import numpy as np
        tree = {"a": jnp.arange(4.0)}
        path = save_checkpoint(str(tmp_path), 1, tree)
        data = dict(np.load(path))
        data["a"] = data["a"] + 1
        np.savez(path, **data)
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), tree)

    def test_gc_keeps_latest(self, tmp_path):
        from repro.ckpt import save_checkpoint, latest_step
        from repro.ckpt.checkpoint import latest_step as ls
        tree = {"a": jnp.zeros(2)}
        for s in range(6):
            save_checkpoint(str(tmp_path), s, tree, keep=2)
        assert ls(str(tmp_path)) == 5
        npz = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert len(npz) == 2
