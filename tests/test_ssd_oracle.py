"""Mamba2 SSD correctness: the chunked scan must equal the naive recurrence,
and one-token decode must track the training-path state exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import AxisCtx
from repro.models.common import ParamCtx
from repro.models.ssm import (
    SSMCache, SSMDims, _causal_depthwise_conv, _ssd_scan, init_ssm,
    init_ssm_cache, ssm_block, ssm_decode_step,
)

LOCAL = AxisCtx(batch_axes=(), model_axis=None, fsdp_axes=())


def naive_ssd(xdt, la, Bm, Cm):
    """Direct recurrence: s_t = exp(la_t) s_{t-1} + B_t (x dt)_t ; y = C_t s_t."""
    Bsz, S, H, P = xdt.shape
    N = Bm.shape[-1]
    s = np.zeros((Bsz, H, N, P))
    ys = np.zeros((Bsz, S, H, P))
    xdt, la, Bm, Cm = map(np.asarray, (xdt, la, Bm, Cm))
    for t in range(S):
        decay = np.exp(la[:, t])                      # (B,H)
        s = s * decay[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", Bm[:, t], xdt[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cm[:, t], s)
    return ys, s


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("shape", [(2, 16, 3, 4, 8), (1, 32, 2, 8, 4)])
def test_chunked_ssd_matches_naive_recurrence(chunk, shape):
    Bsz, S, H, P, N = shape
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (Bsz, S, H, P)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))  # <= 0
    Bm = jax.random.normal(ks[2], (Bsz, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (Bsz, S, N)) * 0.5
    y, state = _ssd_scan(xdt, la, Bm, Cm, chunk)
    y_ref, state_ref = naive_ssd(xdt, la, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4, atol=2e-4)


def test_conv_causal():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 4))
    k = jax.random.normal(jax.random.PRNGKey(2), (4, 4))
    y = _causal_depthwise_conv(x, k)
    # output at t must not change if future inputs change
    x2 = x.at[:, 7:].set(99.0)
    y2 = _causal_depthwise_conv(x2, k)
    np.testing.assert_allclose(np.asarray(y[:, :7]), np.asarray(y2[:, :7]),
                               rtol=1e-6)


def test_decode_tracks_block_outputs():
    """Running ssm_block over a sequence must equal step-by-step decode."""
    dims = SSMDims(d_model=16, d_state=8, head_dim=8, expand=2, conv_width=4,
                   chunk=4, tp=1)
    from repro.models.common import key_iter
    p = init_ssm(key_iter(jax.random.PRNGKey(3)), dims)
    pc = ParamCtx(ctx=LOCAL, compute_dtype=jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, 16)) * 0.5

    y_block = ssm_block(pc, "ssm", p, x, dims)

    cache = init_ssm_cache(B, dims, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = ssm_decode_step(pc, "ssm", p, x[:, t:t+1], cache, dims)
        ys.append(yt)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_block), np.asarray(y_steps),
                               rtol=5e-3, atol=5e-3)
