"""Abstract interpreter (repro.analyze.absint / ranges): value-range and
quantization-error propagation over traced jaxprs.

Three layers:

* lattice units — interval arithmetic edge cases (inf endpoints, the
  0 * inf cleanup, widening convergence) on :mod:`repro.analyze.ranges`;
* seeded-regression graph tests — plant one defect (unclamped psum into a
  narrow accumulator, unguarded exp) and assert exactly that finding,
  plus the mirror test that the guarded idiom produces none;
* soundness properties — concrete evaluation of a traced function must
  land inside the interval the interpreter propagated for it, across scan
  carries, cond joins, and the quantize/dequantize idiom (hypothesis, or
  the bundled shim when the wheel is absent).
"""

import math

import numpy as np
import pytest

import repro  # noqa: F401  (installs the jax compat shims)
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analyze import ranges as R
from repro.analyze.absint import abstract_eval, interpret_jaxpr
from repro.analyze.ranges import INF, AbsVal


def _trace(fn, *args):
    return jax.jit(fn).trace(*args).jaxpr


def _findings(fn, *args, rules=("overflow", "numerics"), in_vals=None,
              axis_sizes=None):
    res = interpret_jaxpr(_trace(fn, *args), in_vals=in_vals,
                          axis_sizes=axis_sizes, rules=rules)
    return res


# ---------------------------------------------------------------------------
# Lattice units
# ---------------------------------------------------------------------------


class TestLattice:
    def test_join_hull(self):
        j = R.join(AbsVal(0, 1), AbsVal(3, 5))
        assert (j.lo, j.hi) == (0, 5)

    def test_join_loses_exactness_only_when_either_inexact(self):
        assert R.join(AbsVal(0, 1, exact=True), AbsVal(2, 3, exact=True)).exact
        assert not R.join(AbsVal(0, 1, exact=True), AbsVal(2, 3)).exact

    def test_widen_jumps_to_infinity(self):
        w = R.widen(AbsVal(0, 1), AbsVal(0, 2))
        assert w.hi == INF and w.lo == 0
        w = R.widen(AbsVal(0, 1), AbsVal(-1, 1))
        assert w.lo == -INF and w.hi == 1

    def test_widen_fixpoint_is_stable(self):
        w = R.widen(AbsVal(0, INF), AbsVal(0, INF))
        assert w == AbsVal(0, INF)

    def test_mul_zero_times_inf_is_conservative(self):
        m = R.mul(AbsVal(0, 0), AbsVal(-INF, INF))
        assert m.contains(0.0)

    def test_nan_endpoints_normalized(self):
        v = AbsVal(math.nan, math.nan)
        assert (v.lo, v.hi) == (-INF, INF)

    def test_empty_interval_normalized_to_top(self):
        v = AbsVal(3, 1)
        assert (v.lo, v.hi) == (-INF, INF)

    def test_sub_of_intervals(self):
        s = R.sub(AbsVal(0, 1), AbsVal(2, 3))
        assert (s.lo, s.hi) == (-3, -1)

    def test_div_through_zero_is_unbounded(self):
        d = R.div(AbsVal(1, 1), AbsVal(-1, 1))
        assert d.hi == INF and d.lo == -INF

    def test_scale_by_count(self):
        s = R.scale_by_count(AbsVal(-3, 7, exact=True), 4)
        assert (s.lo, s.hi) == (-12, 28)
        assert s.exact

    def test_clamp_meets_bounds(self):
        c = R.clamp(AbsVal(0, 0), AbsVal(-INF, INF), AbsVal(255, 255))
        assert (c.lo, c.hi) == (0, 255)

    def test_exp_of_nonpositive_bounded_by_one(self):
        e = R.exp(AbsVal(-INF, 0))
        assert e.lo == 0 and e.hi <= 1.0 + 1e-12

    def test_qerr_scales_through_mul(self):
        q = R.mul(AbsVal(-1, 1, qerr=0.5), AbsVal(2, 2))
        assert q.qerr == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Seeded graph regressions: one defect -> one finding; idiom -> none
# ---------------------------------------------------------------------------


class TestOverflowRule:
    def _quant_allreduce(self, wire_dtype):
        def step(g):
            codes = jnp.clip(jnp.round(g * 255.0), 0, 255)
            return jax.lax.psum(codes.astype(wire_dtype), "clients")

        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(4), ("clients",))
        P = jax.sharding.PartitionSpec

        def run(g):
            return jax.shard_map(step, mesh=mesh, in_specs=(P(),),
                                 out_specs=P())(g)

        x = jax.ShapeDtypeStruct((16,), jnp.float32)
        return jax.jit(run).trace(x).jaxpr

    def test_clipped_codes_into_wide_accumulator_prove(self):
        jaxpr = self._quant_allreduce(jnp.int32)
        res = interpret_jaxpr(jaxpr, axis_sizes={"clients": 4},
                              rules=("overflow",))
        assert not res.findings
        ps = [p for p in res.proofs if p["kind"] == "psum"]
        assert ps and all(p["ok"] for p in ps)
        # 4 * 255 = 1020 against int32: > 20 bits of headroom
        assert ps[0]["worst_sum"] == pytest.approx(1020)
        assert ps[0]["headroom_bits"] >= 20

    def test_seeded_negative_narrow_accumulator(self):
        jaxpr = self._quant_allreduce(jnp.int8)
        res = interpret_jaxpr(jaxpr, axis_sizes={"clients": 4},
                              rules=("overflow",))
        errs = [f for f in res.findings
                if f.rule == "overflow.wire_accumulator"]
        assert len(errs) == 1
        assert errs[0].severity == "error"
        assert "int8" in errs[0].message

    def test_unclamped_int_sum_flagged(self):
        def step(x):
            return jax.lax.psum(x, "clients")

        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(4), ("clients",))
        P = jax.sharding.PartitionSpec

        def run(x):
            return jax.shard_map(step, mesh=mesh, in_specs=(P(),),
                                 out_specs=P())(x)

        jaxpr = jax.jit(run).trace(
            jax.ShapeDtypeStruct((8,), jnp.int32)).jaxpr
        res = interpret_jaxpr(jaxpr, axis_sizes={"clients": 4},
                              rules=("overflow",))
        errs = [f for f in res.findings
                if f.rule == "overflow.wire_accumulator"]
        assert len(errs) == 1
        assert "no provable bound" in errs[0].message


class TestNumericsRule:
    def test_unguarded_exp_flagged(self):
        res = _findings(lambda x: jnp.exp(x).sum(), jnp.zeros((8,)))
        assert [f.rule for f in res.findings] == ["numerics.unguarded"]

    def test_softmax_idiom_proven(self):
        res = _findings(lambda x: jax.nn.softmax(x, axis=-1),
                        jnp.zeros((4, 8)))
        assert not res.findings

    def test_online_softmax_scan_carry_proven(self):
        """m_new = max(m, rowmax(s)) needs the two-var max branch."""

        def online(s_all):
            def body(carry, s):
                m, acc = carry
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                return (m_new, acc * corr[..., None] + p.sum(-1,
                        keepdims=True)), ()

            m0 = jnp.full((4,), -jnp.inf, jnp.float32)
            a0 = jnp.zeros((4, 1), jnp.float32)
            (m, acc), _ = jax.lax.scan(body, (m0, a0), s_all)
            return acc

        res = _findings(online, jnp.zeros((3, 4, 8)))
        assert not res.findings

    def test_guarded_log_clean_unguarded_flagged(self):
        clean = _findings(lambda x: jnp.log(jnp.maximum(x, 1e-9)),
                          jnp.ones((4,)))
        assert not clean.findings
        dirty = _findings(lambda x: jnp.log(x), jnp.ones((4,)))
        assert [f.rule for f in dirty.findings] == ["numerics.unguarded"]

    def test_div_by_eps_guarded_clean(self):
        clean = _findings(lambda x: x / (jnp.abs(x) + 1e-6), jnp.ones((4,)))
        assert not clean.findings


# ---------------------------------------------------------------------------
# Soundness properties: concrete eval lands inside the propagated interval
# ---------------------------------------------------------------------------


def _out_intervals(fn, *tmpl, in_vals=None):
    return abstract_eval(jax.jit(fn).trace(*tmpl).jaxpr, in_vals)


def _assert_inside(val, iv: AbsVal, slack=1e-6):
    arr = np.asarray(val, dtype=np.float64)
    assert np.all(arr >= iv.lo - slack), (arr.min(), iv)
    assert np.all(arr <= iv.hi + slack), (arr.max(), iv)


class TestSoundness:
    @settings(max_examples=20, deadline=None)
    @given(x=st.floats(-50.0, 50.0), bits=st.sampled_from([2, 4, 8]))
    def test_dequant_idiom(self, x, bits):
        """round(x/step)*step stays in the interval AND within qerr."""
        step = 2.0 / (2 ** bits - 1)

        def deq(v):
            codes = jnp.round(v / step)
            return codes * step

        tmpl = jax.ShapeDtypeStruct((4,), jnp.float32)
        (iv,) = _out_intervals(deq, tmpl,
                               in_vals=[AbsVal(-abs(x), abs(x))])
        v = np.clip(np.array([x, -x, x / 3, 0.0], np.float32),
                    -abs(x), abs(x))
        out = jax.jit(deq)(v)
        _assert_inside(out, iv, slack=step)
        assert iv.qerr >= step * 0.5 - 1e-12

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 6), x0=st.floats(-2.0, 2.0))
    def test_scan_carry(self, n, x0):
        """Decaying scan carry stays inside the widened fixpoint."""

        def run(x):
            def body(c, _):
                return 0.5 * c + jnp.clip(x.sum(), -1.0, 1.0), ()

            c, _ = jax.lax.scan(body, 0.0, jnp.arange(n))
            return c

        tmpl = jax.ShapeDtypeStruct((2,), jnp.float32)
        (iv,) = _out_intervals(run, tmpl,
                               in_vals=[AbsVal(-abs(x0), abs(x0))])
        out = jax.jit(run)(jnp.array([x0 / 2, x0 / 2], jnp.float32))
        _assert_inside(out, iv)

    @settings(max_examples=15, deadline=None)
    @given(x=st.floats(-10.0, 10.0), flag=st.booleans())
    def test_cond_join(self, x, flag):
        """cond output lands inside the join of both branch intervals."""

        def run(p, v):
            return jax.lax.cond(p, lambda v: jnp.tanh(v),
                                lambda v: jnp.clip(v, -2.0, 2.0), v)

        tmpl_p = jax.ShapeDtypeStruct((), jnp.bool_)
        tmpl_v = jax.ShapeDtypeStruct((), jnp.float32)
        (iv,) = _out_intervals(run, tmpl_p, tmpl_v,
                               in_vals=[None, AbsVal(-abs(x), abs(x))])
        out = jax.jit(run)(jnp.asarray(flag), jnp.float32(x))
        _assert_inside(out, iv)

    @settings(max_examples=10, deadline=None)
    @given(x=st.floats(0.1, 100.0))
    def test_rsqrt_monotone(self, x):
        def run(v):
            return jax.lax.rsqrt(v + 1e-6)

        tmpl = jax.ShapeDtypeStruct((), jnp.float32)
        (iv,) = _out_intervals(run, tmpl, in_vals=[AbsVal(0.1, 100.0)])
        out = jax.jit(run)(jnp.float32(x))
        _assert_inside(out, iv)


# ---------------------------------------------------------------------------
# Fixpoint behavior
# ---------------------------------------------------------------------------


class TestFixpoints:
    def test_growing_carry_widens_not_diverges(self):
        def run(x):
            def body(c, _):
                return c + x.sum(), ()

            c, _ = jax.lax.scan(body, 0.0, jnp.arange(1000))
            return c

        tmpl = jax.ShapeDtypeStruct((2,), jnp.float32)
        (iv,) = _out_intervals(run, tmpl, in_vals=[AbsVal(0.0, 1.0)])
        # must terminate (widening) and stay sound: sum of positives
        assert iv.lo >= 0.0 and iv.hi == INF

    def test_while_loop_counter_bounded_below(self):
        def run(x):
            def cond(c):
                return c[0] < 10.0

            def body(c):
                return (c[0] + 1.0, jnp.minimum(c[1], 0.0))

            return jax.lax.while_loop(cond, body, (x, x))[1]

        tmpl = jax.ShapeDtypeStruct((), jnp.float32)
        (iv,) = _out_intervals(run, tmpl, in_vals=[AbsVal(0.0, 1.0)])
        assert iv.hi <= 0.0 + 1e-12 or iv.hi <= 1.0  # min() keeps hi <= 1
