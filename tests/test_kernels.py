"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py oracles.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on a real TPU the same tests exercise the compiled lowering.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.quant_matmul import quant_matmul_kernel
from repro.kernels.sr_quant import sr_quant_fake_kernel, sr_quant_pack_kernel

INTERP = True  # CPU container: interpret mode everywhere


def key(i):
    return jax.random.PRNGKey(i)


class TestSRQuantKernel:
    @pytest.mark.parametrize("shape", [(256, 512), (512, 1024), (300, 700),
                                       (8, 128), (1024, 128)])
    @pytest.mark.parametrize("bits", [2, 4, 7])
    def test_fake_matches_ref_exactly(self, shape, bits):
        w = jax.random.normal(key(0), shape, jnp.float32)
        u = jax.random.uniform(key(1), shape, jnp.float32)
        s = float(jnp.max(jnp.abs(w)))
        step = jnp.full((1, 1), s / (2**bits - 1), jnp.float32)
        # pad to block multiples like ops.py does
        bm, bn = 256, 512
        pm, pn = (-shape[0]) % bm, (-shape[1]) % bn
        wp = jnp.pad(w, ((0, pm), (0, pn)))
        up = jnp.pad(u, ((0, pm), (0, pn)))
        out = sr_quant_fake_kernel(wp, up, step, interpret=INTERP)[: shape[0], : shape[1]]
        want = ref.sr_quant_fake_ref(w, u, step[0, 0])
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=0, atol=0)

    def test_zero_step_bypasses(self):
        w = jax.random.normal(key(2), (256, 512), jnp.float32)
        u = jax.random.uniform(key(3), (256, 512), jnp.float32)
        out = sr_quant_fake_kernel(w, u, jnp.zeros((1, 1), jnp.float32),
                                   interpret=INTERP)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(w))

    @pytest.mark.parametrize("bits", [4, 7])
    def test_pack_matches_ref(self, bits):
        w = jax.random.normal(key(4), (256, 512), jnp.float32)
        u = jax.random.uniform(key(5), (256, 512), jnp.float32)
        s = float(jnp.max(jnp.abs(w)))
        step = jnp.full((1, 1), s / (2**bits - 1), jnp.float32)
        out = sr_quant_pack_kernel(w, u, step, bits=bits, interpret=INTERP)
        want = ref.sr_quant_pack_ref(w, u, step[0, 0], 2**bits - 1)
        assert out.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_ops_wrapper_unbiased(self):
        w = jax.random.normal(key(6), (64, 256), jnp.float32) * 0.3
        outs = jnp.stack([ops.sr_quantize_fused(w, key(100 + i), 3)
                          for i in range(200)])
        np.testing.assert_allclose(np.asarray(outs.mean(0)), np.asarray(w),
                                   atol=4 * float(jnp.max(jnp.abs(w))) / 7 / np.sqrt(200) + 1e-3)

    @settings(max_examples=10, deadline=None)
    @given(m=st.integers(1, 300), n=st.integers(1, 600), bits=st.sampled_from([3, 7]))
    def test_property_wrapper_on_grid(self, m, n, bits):
        w = jax.random.normal(key(m * 7 + n), (m, n), jnp.float32)
        out = ops.sr_quantize_fused(w, key(0), bits)
        s = float(jnp.max(jnp.abs(w)))
        codes = np.asarray(out) / (s / (2**bits - 1))
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-2)
        assert float(jnp.max(jnp.abs(out))) <= s * (1 + 1e-6)


class TestQuantMatmulKernel:
    @pytest.mark.parametrize("mnk", [(256, 256, 512), (128, 384, 1024),
                                     (300, 200, 700), (8, 128, 256)])
    @pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, mnk, xdtype):
        m, n, k = mnk
        x = jax.random.normal(key(7), (m, k)).astype(xdtype)
        codes = jax.random.randint(key(8), (k, n), -127, 128, jnp.int8)
        scale = jnp.float32(0.013)
        out = ops.quant_matmul(x, codes, scale)
        want = ref.quant_matmul_ref(x, codes, scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-2 if xdtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-2)

    @pytest.mark.parametrize("mnk", [(5, 7, 130),        # tiny + non-aligned
                                     (33, 65, 100),      # nothing 128-aligned
                                     (257, 129, 513),    # just past block edges
                                     (1, 640, 64),       # single decode row
                                     (4, 96, 2048)])     # decode batch, K > bk
    def test_ragged_non_aligned(self, mnk):
        """M, N, K off the 128/256/512 block grid: padding + adaptive blocks."""
        m, n, k = mnk
        x = jax.random.normal(key(9), (m, k), jnp.float32)
        codes = jax.random.randint(key(10), (k, n), -127, 128, jnp.int8)
        scale = jnp.float32(0.02)
        out = ops.quant_matmul(x, codes, scale)
        assert out.shape == (m, n)
        want = ref.quant_matmul_ref(x, codes, scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_int16_codes(self):
        """bits in 8..15 store int16 codes; the kernel streams them the same."""
        x = jax.random.normal(key(22), (32, 256), jnp.float32)
        codes = jax.random.randint(key(23), (256, 128), -(2**15 - 1), 2**15 - 1,
                                   jnp.int16)
        scale = jnp.float32(1e-4)
        out = ops.quant_matmul(x, codes, scale)
        want = ref.quant_matmul_ref(x, codes, scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)

    def test_padding_edge(self):
        x = jax.random.normal(key(9), (5, 130), jnp.float32)
        codes = jax.random.randint(key(10), (130, 7), -20, 20, jnp.int8)
        out = ops.quant_matmul(x, codes, jnp.float32(0.1))
        want = ref.quant_matmul_ref(x, codes, jnp.float32(0.1))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("shape", [(1, 2, 512, 64), (2, 1, 256, 128),
                                       (1, 1, 1024, 64)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, shape, causal):
        B, H, S, D = shape
        q = jax.random.normal(key(11), shape, jnp.float32)
        k = jax.random.normal(key(12), shape, jnp.float32)
        v = jax.random.normal(key(13), shape, jnp.float32)
        out = ops.flash_attention(q, k, v, causal=causal)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        shape = (1, 2, 512, 64)
        q = jax.random.normal(key(14), shape).astype(jnp.bfloat16)
        k = jax.random.normal(key(15), shape).astype(jnp.bfloat16)
        v = jax.random.normal(key(16), shape).astype(jnp.bfloat16)
        out = ops.flash_attention(q, k, v)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    @pytest.mark.parametrize("S", [100, 300, 513])
    @pytest.mark.parametrize("causal", [True, False])
    def test_ragged_seq_len(self, S, causal):
        """Non-128-aligned S: the wrapper pads and the kernel masks the
        padded keys via s_valid."""
        shape = (1, 2, S, 64)
        q = jax.random.normal(key(30), shape, jnp.float32)
        k = jax.random.normal(key(31), shape, jnp.float32)
        v = jax.random.normal(key(32), shape, jnp.float32)
        out = ops.flash_attention(q, k, v, causal=causal)
        assert out.shape == shape
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_model_chunked_path(self):
        """The jnp chunked attention in models/ mirrors the kernel."""
        from repro.models.attention import _chunked_attention
        B, H, S, D = 1, 2, 512, 64
        q = jax.random.normal(key(17), (B, S, H, D), jnp.float32)
        k = jax.random.normal(key(18), (B, S, H, D), jnp.float32)
        v = jax.random.normal(key(19), (B, S, H, D), jnp.float32)
        y_model = _chunked_attention(q, k, v, causal=True, chunk_kv=128)
        y_kernel = ops.flash_attention(
            jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(k, (0, 2, 1, 3)),
            jnp.transpose(v, (0, 2, 1, 3)), causal=True)
        np.testing.assert_allclose(
            np.asarray(jnp.transpose(y_kernel, (0, 2, 1, 3))),
            np.asarray(y_model), rtol=2e-4, atol=2e-4)


class TestExpertDispatch:
    """Per-expert quant_matmul dispatch for MoE stacks (ref-vs-kernel)."""

    def _pack_stack(self, w, bits):
        from repro.core.quantization import storage_dtype
        from repro.models.common import QTensor

        delta = 1.0 / (2.0**bits - 1.0)
        lim = 2**bits - 1
        s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)
        scale = (s * delta).astype(jnp.float32)          # scalar (per-layer)
        codes = jnp.clip(jnp.round(w / scale), -lim, lim).astype(
            storage_dtype(bits))
        return QTensor(codes=codes, scale=scale)

    @pytest.mark.parametrize("bits", [4, 7, 12])
    @pytest.mark.parametrize("shape", [(4, 8, 32, 48), (3, 5, 40, 24)])
    def test_matches_eager_dequant_einsum(self, bits, shape):
        E, C, D, F = shape
        w = jax.random.normal(key(bits), (E, D, F), jnp.float32)
        x = jax.random.normal(key(100 + bits), (E, C, D), jnp.float32)
        q = self._pack_stack(w, bits)
        assert q.codes.dtype == (jnp.int8 if bits <= 7 else jnp.int16)
        got = ops.expert_dispatch(x, q)
        want = jnp.einsum("ecd,edf->ecf", x, ops.as_array(q, jnp.float32))
        assert got.shape == (E, C, F)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_plain_array_keeps_einsum(self):
        w = jax.random.normal(key(5), (2, 16, 24), jnp.float32)
        x = jax.random.normal(key(6), (2, 3, 16), jnp.float32)
        got = ops.expert_dispatch(x, w)
        want = jnp.einsum("ecd,edf->ecf", x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_per_channel_scale_falls_back(self):
        """Non-scalar scales take the eager-dequant einsum fallback."""
        from repro.models.common import QTensor

        w = jax.random.normal(key(7), (2, 16, 24), jnp.float32)
        q = self._pack_stack(w, 7)
        q = QTensor(codes=q.codes, scale=jnp.full((2,), float(q.scale)))
        x = jax.random.normal(key(8), (2, 3, 16), jnp.float32)
        got = ops.expert_dispatch(x, q)
        want = jnp.einsum("ecd,edf->ecf", x,
                          q.codes.astype(jnp.float32)
                          * q.scale[:, None, None])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
