"""Facade tests: RunSpec round-trip, PrecisionPolicy -> kernel bit-widths,
Session-vs-legacy serve equivalence, workload launches, deprecation shims."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PrecisionPolicy, RunSpec, Session, WORKLOADS
from repro.configs.base import ShapeSpec
from repro.core.gbd import GBDResult
from repro.core.quantization import default_exempt, storage_dtype
from repro.kernels import ops
from repro.models.common import QTensor, pack_params_for_policy


def _gbd_result(q):
    q = np.asarray(q)
    return GBDResult(q=q, bandwidth=np.ones((2, q.size)),
                     t_rounds=np.ones((2,)), energy=1.0, lower_bound=0.9,
                     gap=0.1, iterations=3, converged=True, trace=[])


class TestRunSpecRoundTrip:
    def test_to_from_dict_json(self):
        spec = RunSpec(
            arch="yi-6b", workload="serve", mesh="2x4x2", smoke=True, seed=3,
            batch=2, seq=64,
            precision=PrecisionPolicy.from_gbd(_gbd_result([8, 16, 32]),
                                               comm=4),
            options={"steps": 4, "attn_impl": "flash"})
        d = spec.to_dict()
        d2 = json.loads(json.dumps(d))         # survives JSON
        back = RunSpec.from_dict(d2)
        assert back == spec
        assert back.precision.weights == (8, 16, 32)
        assert back.precision.grad_compression_bits == 4
        assert back.options["attn_impl"] == "flash"

    def test_workload_validated(self):
        with pytest.raises(ValueError):
            RunSpec(arch="yi-6b", workload="nope")
        assert set(WORKLOADS) == {"train", "serve", "dryrun", "fl-sim",
                                  "fl-orchestrate"}


class TestPrecisionPolicy:
    def test_from_gbd_per_device_bits(self):
        pol = PrecisionPolicy.from_gbd(_gbd_result([8, 8, 16, 32]))
        np.testing.assert_array_equal(pol.bits_vector(4), [8, 8, 16, 32])
        # delta matches the trainer's resolution mapping
        from repro.core.quantization import delta_from_bits

        np.testing.assert_allclose(
            np.asarray(pol.delta(4)),
            np.asarray(delta_from_bits(jnp.asarray([8, 8, 16, 32]))))

    @pytest.mark.parametrize("bits", [5, 7, 12])
    def test_gbd_bits_reach_dense_dispatch(self, bits):
        """from_gbd -> pack_params_for_policy -> the exact QTensor bit-width
        dense_dispatch streams through quant_matmul."""
        pol = PrecisionPolicy.uniform(bits, lazy=True)
        # the co-design result carries the same bits per device
        pol_gbd = PrecisionPolicy.from_gbd(_gbd_result([bits, bits]))
        assert pol_gbd.bits_vector(2).tolist() == [bits, bits]
        params = {"mlp": {"w_up": jax.random.normal(
            jax.random.PRNGKey(bits), (64, 48), jnp.float32)}}
        packed = pack_params_for_policy(params, pol, jax.random.PRNGKey(1),
                                        exempt=default_exempt)
        q = packed["mlp"]["w_up"]
        assert isinstance(q, QTensor)
        assert q.codes.dtype == storage_dtype(bits)
        assert int(jnp.max(jnp.abs(q.codes))) <= 2**bits - 1
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 64), jnp.float32)
        got = ops.dense_dispatch(x, q)
        want = x @ (q.codes.astype(jnp.float32) * q.scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_full_precision_policy_is_identity(self):
        pol = PrecisionPolicy.full_precision()
        params = {"w": jnp.ones((16, 16))}
        assert pack_params_for_policy(params, pol, jax.random.PRNGKey(0)) \
            is params
        assert not pol.packed

    def test_role_validation(self):
        with pytest.raises(ValueError):
            PrecisionPolicy(grads=16)          # paper: f32 aggregation only
        with pytest.raises(ValueError):
            PrecisionPolicy(weights=32, lazy=True)
        with pytest.raises(ValueError):
            PrecisionPolicy(weights=(8, 16), lazy=True)
        with pytest.raises(ValueError):
            PrecisionPolicy(weights=(8, 16)).serve_bits
        with pytest.raises(ValueError):
            PrecisionPolicy(weights=0)         # 1/(2^0 - 1) would div-zero
        with pytest.raises(ValueError):
            PrecisionPolicy(kv_cache=8)        # int KV cache: not implemented
        assert PrecisionPolicy(kv_cache=16).kv_cache_dtype() == jnp.bfloat16


class TestSessionServe:
    def test_session_serve_bitwise_matches_run_serve(self):
        """The facade serve path decodes exactly what the legacy run_serve
        entry point (PR 2) decodes for the same spec."""
        from repro.launch.serve import run_serve

        kw = dict(steps=10, batch=2, s_max=32, prompt_len=8,
                  requests=2, max_new=4)
        legacy = run_serve("yi-6b", smoke=True, serve_bits=7,
                           attn_impl="ref", quiet=True, **kw)
        spec = RunSpec(arch="yi-6b", workload="serve", smoke=True, batch=2,
                       seq=32, precision=PrecisionPolicy.lazy_int8(7),
                       options=dict(steps=10, s_max=32, prompt_len=8,
                                    requests=2, max_new=4, attn_impl="ref",
                                    quiet=True))
        facade = Session(spec).serve()
        assert facade.sample == legacy.sample
        assert facade.decoded_tokens == legacy.decoded_tokens
        assert facade.decode_steps == legacy.decode_steps
        assert facade.bytes_per_step_packed == legacy.bytes_per_step_packed


class TestSessionWorkloads:
    def test_train_fixed_policy(self):
        """workload=train runs rounds at the spec's fixed policy (no GBD)."""
        spec = RunSpec(arch="yi-6b", workload="train", mesh="1x1", smoke=True,
                       batch=1, seq=16, rounds=2,
                       precision=PrecisionPolicy.uniform(8),
                       options={"lr": 0.05, "quiet": True})
        history = Session(spec).run()
        assert len(history) == 2
        assert history[0]["bits"] == [8]
        assert np.isfinite(history[-1]["loss"])

    def test_fl_orchestrate_gbd_policy(self):
        """workload=fl-orchestrate: per-round bits come from the co-design
        (PrecisionPolicy.from_gbd inside the orchestrator)."""
        spec = RunSpec(arch="yi-6b", workload="fl-orchestrate", mesh="1x1",
                       smoke=True, batch=1, seq=16, rounds=2,
                       options={"scheme": "fwq", "lr": 0.05, "quiet": True})
        sess = Session(spec)
        history = sess.run()
        assert len(history) == 2
        st = sess._ensure_train_state()
        plan = st["orch"].plan_round(0)
        assert isinstance(plan["policy"], PrecisionPolicy)
        assert set(history[0]["bits"]) <= set(plan["policy"].bit_options)

    def test_fl_sim(self):
        spec = RunSpec(arch="mobilenet", workload="fl-sim", rounds=2, batch=8,
                       options={"scheme": "fwq", "n_clients": 4, "lr": 0.1})
        out = Session(spec).run()
        assert len(out["history"]) == 2
        assert out["total_energy_j"] > 0

    def test_dryrun_lower_tiny_cell(self):
        """workload=dryrun AOT-lowers and compiles a cell via Session.lower."""
        spec = RunSpec(arch="yi-6b", workload="dryrun", mesh="1x1", smoke=True)
        cell = ShapeSpec("tiny_train", seq_len=16, global_batch=2,
                         kind="train")
        d = Session(spec).run_dryrun(shape=cell, verbose=False)
        assert d["status"] == "ok"
        assert d["kind"] == "train" and d["n_devices"] == 1


class TestRemovedShims:
    """The PR-3 deprecation shims are gone: the policy forms are the only
    spellings, and the old keywords fail loudly instead of warning."""

    def test_paramctx_lazy_quant_removed(self):
        from repro.launch.mesh import axis_ctx_for, make_test_mesh
        from repro.models.common import ParamCtx

        axes = axis_ctx_for(make_test_mesh((1, 1), ("data", "model")))
        with pytest.raises(TypeError):
            ParamCtx(ctx=axes, compute_dtype=jnp.float32, lazy_quant=True)
        pc = ParamCtx.from_policy(axes, PrecisionPolicy.lazy_int8(),
                                  compute_dtype=jnp.float32)
        assert pc.lazy
        assert not ParamCtx(ctx=axes).lazy

    def test_build_decode_step_lazy_quant_removed(self):
        from repro.configs import get_config, smoke_variant
        from repro.launch.mesh import axis_ctx_for, make_test_mesh
        from repro.launch.steps import build_decode_step
        from repro.models.model import build_model

        mesh = make_test_mesh((1, 1), ("data", "model"))
        model = build_model(smoke_variant(get_config("yi-6b")))
        with pytest.raises(TypeError):
            build_decode_step(model, mesh, axis_ctx_for(mesh),
                              s_max=16, batch_global=2, lazy_quant=False)
        ss = build_decode_step(model, mesh, axis_ctx_for(mesh),
                               s_max=16, batch_global=2)
        assert ss.fn is not None

    def test_orchestrator_bits_options_removed(self):
        from repro.fed.orchestrator import OrchestratorConfig

        with pytest.raises(TypeError):
            OrchestratorConfig(n_devices=4, n_rounds=2, bits_options=(8, 32))
        cfg = OrchestratorConfig(
            n_devices=4, n_rounds=2,
            precision=PrecisionPolicy(bit_options=(8, 32)))
        assert cfg.precision.bit_options == (8, 32)
