"""Unit + property tests for SR quantization (paper Eq. 1 / Lemma 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantization as q


def key(i=0):
    return jax.random.PRNGKey(i)


class TestDelta:
    def test_delta_values(self):
        assert float(q.delta_from_bits(8)) == pytest.approx(1 / 255)
        assert float(q.delta_from_bits(16)) == pytest.approx(1 / 65535)
        assert float(q.delta_from_bits(32)) == 0.0

    def test_delta_vector(self):
        d = q.delta_from_bits(jnp.array([8, 16, 32]))
        np.testing.assert_allclose(
            np.asarray(d), [1 / 255, 1 / 65535, 0.0], rtol=1e-6
        )


class TestSRQuantize:
    def test_full_precision_bypass(self):
        w = jax.random.normal(key(1), (64, 64))
        out = q.sr_quantize(w, 0.0, key(2))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(w))

    def test_values_on_grid(self):
        w = jax.random.normal(key(3), (256,))
        delta = float(q.delta_from_bits(8))
        out = np.asarray(q.sr_quantize(w, delta, key(4)), np.float64)
        s = float(np.max(np.abs(np.asarray(w))))
        codes = out / (s * delta)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)

    def test_unbiased(self):
        """SR property: E[Q(w)] = w (paper §2.1)."""
        w = jnp.array([0.3, -0.7, 0.123, 0.999])
        delta = float(q.delta_from_bits(4))
        reps = 4096
        outs = jax.vmap(lambda k: q.sr_quantize(w, delta, k))(
            jax.random.split(key(5), reps)
        )
        mean = np.asarray(outs).mean(axis=0)
        s = float(jnp.max(jnp.abs(w)))
        tol = 3 * s * delta / np.sqrt(reps) + 1e-4
        np.testing.assert_allclose(mean, np.asarray(w), atol=tol * 4)

    def test_error_bound_lemma3(self):
        """E||Q(w)-w||^2 <= (d/4) * delta^2 (per-tensor, real units)."""
        w = jax.random.normal(key(6), (512,))
        for bits in (4, 8):
            delta = float(q.delta_from_bits(bits))
            s = float(jnp.max(jnp.abs(w)))
            outs = jax.vmap(lambda k: q.sr_quantize(w, delta, k))(
                jax.random.split(key(7), 256)
            )
            err = np.mean(np.sum((np.asarray(outs) - np.asarray(w)[None]) ** 2, -1))
            bound = w.size / 4 * (s * delta) ** 2
            assert err <= bound * 1.05

    def test_max_magnitude_preserved(self):
        w = jax.random.normal(key(8), (128,))
        out = q.sr_quantize(w, float(q.delta_from_bits(8)), key(9))
        s = float(jnp.max(jnp.abs(w)))
        assert float(jnp.max(jnp.abs(out))) <= s + 1e-6

    def test_traced_delta_jit(self):
        """delta can be a traced scalar — one program for all bit-widths."""
        w = jax.random.normal(key(10), (64,))

        @jax.jit
        def f(delta):
            return q.sr_quantize(w, delta, key(11))

        out8 = f(q.delta_from_bits(8))
        out_fp = f(jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(out_fp), np.asarray(w))
        assert not np.array_equal(np.asarray(out8), np.asarray(w))

    @settings(max_examples=30, deadline=None)
    @given(
        bits=st.sampled_from([2, 4, 8, 12]),
        seed=st.integers(0, 2**16),
        n=st.integers(2, 300),
    )
    def test_property_grid_and_range(self, bits, seed, n):
        w = jax.random.normal(key(seed), (n,))
        delta = float(q.delta_from_bits(bits))
        out = np.asarray(q.sr_quantize(w, delta, key(seed + 1)), np.float64)
        s = float(np.max(np.abs(np.asarray(w))))
        assert np.all(np.abs(out) <= s * (1 + 1e-5))
        codes = out / (s * delta)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-2)


class TestPacked:
    @pytest.mark.parametrize("bits", [2, 4, 7, 8, 12, 15])
    def test_roundtrip_error(self, bits):
        w = jax.random.normal(key(20), (64, 128))
        p = q.pack_quantize(w, bits, key(21))
        deq = np.asarray(q.dequantize(p))
        s = float(jnp.max(jnp.abs(w)))
        step = s / (2**bits - 1)
        assert np.max(np.abs(deq - np.asarray(w))) <= step * 1.01

    def test_storage_dtype(self):
        assert q.pack_quantize(jnp.ones((4, 4)), 7, key(0)).codes.dtype == jnp.int8
        assert q.pack_quantize(jnp.ones((4, 4)), 8, key(0)).codes.dtype == jnp.int16

    def test_per_channel(self):
        w = jnp.concatenate([jnp.ones((8, 4)) * 10.0, jnp.ones((8, 4)) * 0.1], 1)
        p = q.pack_quantize(w, 8, key(1), per_channel=True, axis=0)
        deq = np.asarray(q.dequantize(p))
        np.testing.assert_allclose(deq, np.asarray(w), rtol=2e-2)

    def test_memory_savings(self):
        w = jnp.zeros((256, 256)) + 0.5
        p = q.pack_quantize(w, 7, key(2))
        assert p.nbytes() < w.size * 4 / 3.9


class TestTree:
    def _params(self):
        return {
            "dense": {"kernel": jax.random.normal(key(30), (32, 32)),
                      "bias": jnp.zeros((32,))},
            "norm": {"scale": jnp.ones((32,))},
        }

    def test_exemptions(self):
        p = self._params()
        out = q.quantize_tree(p, float(q.delta_from_bits(4)), key(31))
        np.testing.assert_array_equal(np.asarray(out["norm"]["scale"]),
                                      np.asarray(p["norm"]["scale"]))
        np.testing.assert_array_equal(np.asarray(out["dense"]["bias"]),
                                      np.asarray(p["dense"]["bias"]))
        assert not np.array_equal(np.asarray(out["dense"]["kernel"]),
                                  np.asarray(p["dense"]["kernel"]))

    def test_quantizable_size(self):
        p = self._params()
        quant, total = q.quantizable_size(p)
        assert quant == 32 * 32
        assert total == 32 * 32 + 2 * 32

    def test_no_exempt(self):
        p = self._params()
        # off-grid values so quantization must move them
        p["norm"]["scale"] = p["norm"]["scale"] * 0.737
        p["norm"]["scale"] = p["norm"]["scale"].at[0].set(1.0)  # sets s = 1
        out = q.quantize_tree(p, float(q.delta_from_bits(2)), key(32), exempt=None)
        assert not np.array_equal(np.asarray(out["norm"]["scale"]),
                                  np.asarray(p["norm"]["scale"]))
