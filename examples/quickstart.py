"""Quickstart: 10 rounds of FWQ federated learning in ~a minute on CPU —
through the `repro.api` front door.

One RunSpec + Session stands up the paper's core loop end to end:
  * heterogeneous clients quantize the global model with their own bit-widths
    (stochastic rounding, Eq. 1),
  * gradients are computed AT the quantized weights (Algorithm 1),
  * the server aggregates and updates in full precision,
  * the GBD co-design picks the bit-widths/bandwidth from the simulated 5G
    channel + device energy models each round, and hands them to the trainer
    as a per-device PrecisionPolicy (PrecisionPolicy.from_gbd).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import RunSpec, Session


def main():
    spec = RunSpec(
        arch="mobilenet",            # the paper's CIFAR-class CNN
        workload="fl-sim",           # vmap simulator of Algorithm 1
        rounds=10,
        batch=16,
        options={"scheme": "fwq", "n_clients": 8, "lr": 0.08},
    )
    out = Session(spec).run()

    print(f"\n{'round':>5} {'loss':>8} {'energy(J)':>10} {'bits chosen':>16}")
    for h, e in zip(out["history"], out["energy_log"]):
        print(f"{h['round']:>5} {h['loss']:>8.4f} {e['energy_round']:>10.3f} "
              f"{str(sorted(set(h['bits'].tolist()))):>16}")
    print(f"\ntotal energy: {out['total_energy_j']:.2f} J over "
          f"{out['total_time_s']:.1f} s (simulated wall time)")


if __name__ == "__main__":
    main()
