"""Quickstart: 10 rounds of FWQ federated learning in ~a minute on CPU.

Demonstrates the paper's core loop end to end:
  * heterogeneous clients quantize the global model with their own bit-widths
    (stochastic rounding, Eq. 1),
  * gradients are computed AT the quantized weights (Algorithm 1),
  * the server aggregates and updates in full precision,
  * the GBD co-design picks the bit-widths/bandwidth from the simulated 5G
    channel + device energy models.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.energy import heterogeneous_fleet, memory_capacities
from repro.data import ClientBatcher, SyntheticImages, dirichlet_partition
from repro.fed import FLOrchestrator, FLSimulation, OrchestratorConfig, SimConfig
from repro.models.cnn import mobilenet, xent_loss


def main():
    n_clients, rounds = 8, 10

    # 1. model + loss (a MobileNet-style CIFAR net, as in the paper's eval)
    model = mobilenet(width=8, n_stages=2)
    loss = xent_loss(model)

    # 2. non-iid client data
    imgs, labels = SyntheticImages(n=2048, hw=16).generate()
    parts = dirichlet_partition(labels, n_clients, alpha=0.5)
    batcher = ClientBatcher(imgs, labels, parts, batch=16)

    # 3. FL simulator (Algorithm 1) + co-design orchestrator (GBD, §4)
    sim = FLSimulation(loss, model.init, SimConfig(n_clients=n_clients, lr=0.08))
    fleet = heterogeneous_fleet(n_clients, group_step_mhz=5.0)
    caps = memory_capacities(n_clients, lo_mb=2.0, hi_mb=8.0) * 1e6
    orch = FLOrchestrator(
        OrchestratorConfig(n_devices=n_clients, n_rounds=rounds,
                           scheme="fwq", model_dim_d=1 << 16,
                           error_tolerance=4.5),
        fleet, caps, grad_bytes=1e6)

    def batch_fn(r, cohort):
        x, y = batcher.sample_round(r, cohort)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    out = orch.run(sim, batch_fn)

    print(f"\n{'round':>5} {'loss':>8} {'energy(J)':>10} {'bits chosen':>16}")
    for h, e in zip(out["history"], out["energy_log"]):
        print(f"{h['round']:>5} {h['loss']:>8.4f} {e['energy_round']:>10.3f} "
              f"{str(sorted(set(h['bits'].tolist()))):>16}")
    print(f"\ntotal energy: {out['total_energy_j']:.2f} J over "
          f"{out['total_time_s']:.1f} s (simulated wall time)")


if __name__ == "__main__":
    main()
