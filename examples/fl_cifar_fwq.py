"""Paper reproduction driver (Fig. 2): FWQ vs Full-Precision / Unified-Q /
Rand-Q on the CIFAR-class CNN, with accuracy + energy reporting.  The shared
recipe (`benchmarks.bench_convergence.run_scheme`) is one fl-sim RunSpec per
scheme through the `repro.api` facade.

Run:  PYTHONPATH=src python examples/fl_cifar_fwq.py [--rounds 60]
"""

import argparse
import json

from benchmarks.bench_convergence import run_scheme


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--model", default="mobilenet", choices=["mobilenet", "resnet"])
    ap.add_argument("--out", default="results/fig2_repro.json")
    args = ap.parse_args()

    results = []
    for scheme in ("fwq", "full_precision", "unified_q", "rand_q"):
        r = run_scheme(scheme, rounds=args.rounds, model_kind=args.model)
        results.append(r)
        print(f"{scheme:>16}: final_loss={r['losses'][-1]:.4f} "
              f"acc={r['final_acc']:.3f} energy={r['total_energy_j']:.2f}J")

    fwq = results[0]["total_energy_j"]
    print("\nenergy vs FWQ (paper Fig. 2b/d trend — FWQ should be smallest):")
    for r in results:
        print(f"  {r['scheme']:>16}: {r['total_energy_j']/fwq:.2f}x")
    try:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"\nwrote {args.out}")
    except OSError:
        pass


if __name__ == "__main__":
    main()
