"""Paper reproduction driver (Fig. 2): FWQ vs Full-Precision / Unified-Q /
Rand-Q on the CIFAR-class CNN, with accuracy + energy reporting.  The grid
is the ``fl-codesign-grid`` sweep preset run through
`benchmarks.bench_convergence.run_grid` (one fl-sim RunSpec per scheme);
completed schemes resume from the results store, so re-running is free.

Run:  PYTHONPATH=src python examples/fl_cifar_fwq.py [--rounds 60]
"""

import argparse
import json
import os
import sys

# run_grid lives in the benchmarks package at the repo root, which isn't on
# sys.path when this file is executed as a script
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_convergence import run_grid  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--model", default="mobilenet", choices=["mobilenet", "resnet"])
    ap.add_argument("--out", default="results/fig2_repro.json")
    args = ap.parse_args()

    results = run_grid(rounds=args.rounds, arch=args.model)
    for r in results:
        acc = r["final_acc"]
        print(f"{r['scheme']:>16}: final_loss={r['losses'][-1]:.4f} "
              f"acc={'-' if acc is None else f'{acc:.3f}'} "
              f"energy={r['total_energy_j']:.2f}J")

    fwq = results[0]["total_energy_j"]
    print("\nenergy vs FWQ (paper Fig. 2b/d trend — FWQ should be smallest):")
    for r in results:
        print(f"  {r['scheme']:>16}: {r['total_energy_j']/fwq:.2f}x")
    try:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"\nwrote {args.out}")
    except OSError:
        pass


if __name__ == "__main__":
    main()
