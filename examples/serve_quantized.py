"""Quantized serving example via the `repro.api` facade: pack a model to int8
(QTensor, lazy kernel-path dequant) and decode a batch of requests — the
storage/bandwidth side of the paper's co-design.

Run:  PYTHONPATH=src python examples/serve_quantized.py --arch yi-6b
"""

import argparse

from repro.api import PrecisionPolicy, RunSpec, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--bits", type=int, default=7)
    args = ap.parse_args()

    spec = RunSpec(
        arch=args.arch, workload="serve", smoke=True,
        batch=args.batch, seq=64,
        precision=PrecisionPolicy.lazy_int8(args.bits),
        options={"steps": args.steps, "prompt_len": 8},
    )
    stats = Session(spec).serve()
    print(f"\npacked/f32 weight-byte ratio: {stats.packed_vs_f32:.3f}")


if __name__ == "__main__":
    main()
