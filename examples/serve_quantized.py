"""Quantized serving example: pack a model to int8 (QTensor) and decode a
batch of requests — the storage/bandwidth side of the paper's co-design.

Run:  PYTHONPATH=src python examples/serve_quantized.py --arch yi-6b
"""

import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    serve_mod.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--s-max", "64",
    ])


if __name__ == "__main__":
    main()
