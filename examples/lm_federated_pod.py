"""FWQ federated training of an assigned LM architecture on the pod-style
trainer (shard_map path) via the `repro.api` facade — smoke-sized for CPU.

This is the same code path the 16x16 dry-run compiles at production scale:
per-client quantization happens inline in the layers (transient, FSDP-aware),
and each round's per-client bit-widths arrive as a PrecisionPolicy from the
GBD co-design.

Run:  PYTHONPATH=src python examples/lm_federated_pod.py --arch glm4-9b
"""

import argparse

from repro.api import RunSpec, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--scheme", default="fwq")
    args = ap.parse_args()

    spec = RunSpec(
        arch=args.arch, workload="fl-orchestrate", mesh="1x1", smoke=True,
        batch=2, seq=32, rounds=args.rounds,
        options={"scheme": args.scheme, "lr": 0.05},
    )
    history = Session(spec).run()
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} rounds")


if __name__ == "__main__":
    main()
