"""FWQ federated training of an assigned LM architecture on the pod-style
trainer (shard_map path) — smoke-scale so it runs on CPU.

This is the same code path the 16x16 dry-run compiles at production scale:
per-client quantization happens inline in the layers (transient, FSDP-aware).

Run:  PYTHONPATH=src python examples/lm_federated_pod.py --arch glm4-9b
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--scheme", default="fwq")
    args = ap.parse_args()

    history = train_mod.main([
        "--arch", args.arch, "--smoke",
        "--rounds", str(args.rounds),
        "--mesh", "1x1",
        "--batch", "2", "--seq", "32",
        "--scheme", args.scheme,
    ])
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} rounds")


if __name__ == "__main__":
    main()
