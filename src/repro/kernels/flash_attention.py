"""Pallas TPU kernel: causal flash attention forward (online softmax).

Lowering target for the 32k-prefill shapes: no S x S materialization; running
(max, sum, acc) live in VMEM scratch across the KV grid dimension (TPU grids
execute the last axis sequentially, so scratch carries state between k-steps).
Fully-masked (k-block above the diagonal) tiles are skipped with ``pl.when``
— for causal attention that halves the work.

Matches :func:`repro.kernels.ref.flash_attention_ref` to fp32 tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (256, 256)  # (block_q, block_k)

_NEG_INF = -1e30


def _body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
          *, scale: float, block_q: int, block_k: int, n_k: int, causal: bool,
          s_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal skip: this k-block starts after the last query of the q-block.
    # Blocks entirely past the valid (unpadded) key range are skipped too.
    run = ik * block_k < s_valid
    if causal:
        run = jnp.logical_and(run, ik * block_k <= iq * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            s = jnp.where(cols <= rows, s, _NEG_INF)
        if s_valid % block_k:
            # ragged sequence: mask the zero-padded tail keys
            s = jnp.where(cols < s_valid, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           blocks=DEFAULT_BLOCKS, interpret=False,
                           s_valid: int | None = None):
    """q, k, v: (BH, S, D) — batch*heads flattened.  Returns (BH, S, D).

    ``s_valid``: true sequence length when the inputs were zero-padded to a
    block multiple; padded keys are masked inside the kernel (padded query
    rows produce garbage the caller slices off).
    """
    BH, S, D = q.shape
    bq, bk = blocks
    bq, bk = min(bq, S), min(bk, S)
    grid = (BH, pl.cdiv(S, bq), pl.cdiv(S, bk))
    scale = D ** -0.5
    return pl.pallas_call(
        functools.partial(_body, scale=scale, block_q=bq, block_k=bk,
                          n_k=grid[2], causal=causal,
                          s_valid=S if s_valid is None else s_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
