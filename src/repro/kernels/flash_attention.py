"""Pallas TPU kernels: flash attention forward + batched paged flash-decode.

``flash_attention_kernel`` is the prefill path: causal online softmax with no
S x S materialization; running (max, sum, acc) live in VMEM scratch across
the KV grid dimension (TPU grids execute the last axis sequentially, so
scratch carries state between k-steps).  Fully-masked (k-block above the
diagonal) tiles are skipped with ``pl.when`` — for causal attention that
halves the work.

``flash_decode_kernel`` is the long-context decode path: one query token per
slot against a PAGED KV cache.  The per-slot page table rides in as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec
index map dereferences it to DMA exactly the pages each slot owns — K/V
stream page-by-page from HBM in logical order, honoring per-sequence lengths,
with the same online softmax carried in scratch.  It returns unnormalized
``(acc, m, l)`` partials so sequence-parallel launches can merge shards with
a distributed online softmax.

Both match their jnp references to fp32 tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spec import (
    BlockOperand,
    KernelSpec,
    ScalarOperand,
    ScratchSpec,
)

DEFAULT_BLOCKS = (256, 256)  # (block_q, block_k)

_NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# Index maps are module-level so the pallas_call and the static-checker
# metadata (attention_spec / decode_spec) share one definition.


def _q_map(b, i, j):
    return (b, i, 0)


def _kv_map(b, i, j):
    return (b, j, 0)


def _body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
          *, scale: float, block_q: int, block_k: int, n_k: int, causal: bool,
          s_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal skip: this k-block starts after the last query of the q-block.
    # Blocks entirely past the valid (unpadded) key range are skipped too.
    run = ik * block_k < s_valid
    if causal:
        run = jnp.logical_and(run, ik * block_k <= iq * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            s = jnp.where(cols <= rows, s, _NEG_INF)
        if s_valid % block_k:
            # ragged sequence: mask the zero-padded tail keys
            s = jnp.where(cols < s_valid, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           blocks=DEFAULT_BLOCKS, interpret=False,
                           s_valid: int | None = None):
    """q, k, v: (BH, S, D) — batch*heads flattened.  Returns (BH, S, D).

    ``s_valid``: true sequence length when the inputs were zero-padded to a
    block multiple; padded keys are masked inside the kernel (padded query
    rows produce garbage the caller slices off).
    """
    BH, S, D = q.shape
    bq, bk = blocks
    bq, bk = min(bq, S), min(bk, S)
    grid = (BH, pl.cdiv(S, bq), pl.cdiv(S, bk))
    scale = D ** -0.5
    return pl.pallas_call(
        functools.partial(_body, scale=scale, block_q=bq, block_k=bk,
                          n_k=grid[2], causal=causal,
                          s_valid=S if s_valid is None else s_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), _q_map),
            pl.BlockSpec((1, bk, D), _kv_map),
            pl.BlockSpec((1, bk, D), _kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), _q_map),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def attention_spec(BH: int, S: int, D: int, *,
                   blocks=DEFAULT_BLOCKS) -> KernelSpec:
    """Static BlockSpec metadata for the wrapper-level flash-attention call.

    ``S`` is the RAW sequence length; the spec mirrors
    :func:`repro.kernels.ops.flash_attention`'s padding to the 128-aligned
    block multiple.
    """
    bq = bk = min(blocks[0], _round_up(S, 128))
    Sp = _round_up(S, bq)
    grid = (BH, Sp // bq, Sp // bk)
    return KernelSpec(
        name="flash_attention",
        source="flash_attention.py:flash_attention_kernel",
        grid=grid,
        inputs=(
            BlockOperand("q", (BH, Sp, D), (1, bq, D), _q_map),
            BlockOperand("k", (BH, Sp, D), (1, bk, D), _kv_map),
            BlockOperand("v", (BH, Sp, D), (1, bk, D), _kv_map),
        ),
        outputs=(BlockOperand("out", (BH, Sp, D), (1, bq, D), _q_map),),
        scratch=(
            ScratchSpec("m", (bq, 1), "float32"),
            ScratchSpec("l", (bq, 1), "float32"),
            ScratchSpec("acc", (bq, D), "float32", binds="out"),
        ),
    )


# ---------------------------------------------------------------------------
# Batched paged flash-decode
# ---------------------------------------------------------------------------


def _decode_body(pt_ref, len_ref, q_ref, k_ref, v_ref, acc_out, m_out, l_out,
                 m_ref, l_ref, acc_ref, *, scale: float, page: int,
                 n_pmax: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip pages past the slot's length and unallocated (-1) table entries;
    # the index map clamps -1 to page 0 for the DMA, but the compute guard
    # means that page's contents are never read into the softmax.
    pid = pt_ref[b * n_pmax + j]
    valid = jnp.logical_and(pid >= 0, j * page < len_ref[b])

    @pl.when(valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, page)
        cols = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < len_ref[b], s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_pmax - 1)
    def _finish():
        acc_out[0, 0] = acc_ref[...]
        m_out[0, 0] = m_ref[...]
        l_out[0, 0] = l_ref[...]


def _decode_maps(n_pmax: int):
    """The decode grid's index maps, closed over the page-table stride.

    Shared by the ``pallas_call`` (which passes the prefetched scalars
    ``pt``/``ln``) and :func:`decode_spec` (which binds a concrete table).
    ``kv_map`` clamps unallocated (-1) entries to page 0; the kernel body's
    validity guard keeps that page's contents out of the softmax.
    """

    def q_map(b, h, j, pt, ln):
        return (b, h, 0, 0)

    def kv_map(b, h, j, pt, ln):
        return (jnp.maximum(pt[b * n_pmax + j], 0), 0, h, 0)

    return q_map, kv_map


def flash_decode_kernel(q, k_pages, v_pages, page_table, lengths, *,
                        interpret=False):
    """One decode token per slot against a paged KV cache.

    ``q``: (B, KV, G, hd) — q heads grouped under their KV head (GQA).
    ``k_pages``/``v_pages``: (N_pool, page, KV, hd) shared page pool (f32 or
    bf16 — the ``PrecisionPolicy.kv_cache`` storage dtype).
    ``page_table``: (B, n_pmax) int32, -1 = unallocated.
    ``lengths``: (B,) int32 — valid tokens per slot in local coordinates.

    Grid is (B, KV, n_pmax) with the page axis innermost (sequential on TPU,
    so the online-softmax scratch carries across a slot's pages); the page
    table and lengths are scalar-prefetched so each k/v BlockSpec can DMA the
    pool row the table names.  Returns UNNORMALIZED fp32 partials
    ``(acc (B,KV,G,hd), m (B,KV,G,1), l (B,KV,G,1))`` — normalize with
    ``acc / max(l, eps)``, or pmax/psum-merge across sequence-parallel shards
    first.
    """
    B, KV, G, hd = q.shape
    page = k_pages.shape[1]
    n_pmax = page_table.shape[1]
    scale = hd ** -0.5
    q_map, kv_map = _decode_maps(n_pmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pmax),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), q_map),
            pl.BlockSpec((1, 1, G, 1), q_map),
            pl.BlockSpec((1, 1, G, 1), q_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # running max
            pltpu.VMEM((G, 1), jnp.float32),    # running sum
            pltpu.VMEM((G, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_body, scale=scale, page=page,
                          n_pmax=n_pmax),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32)],
        interpret=interpret,
    )(page_table.reshape(-1), lengths, q, k_pages, v_pages)


def decode_spec(B: int, KV: int, G: int, hd: int, *, page: int, n_pool: int,
                page_table, lengths) -> KernelSpec:
    """Static BlockSpec metadata for one flash-decode launch.

    ``page_table`` (B, n_pmax) / ``lengths`` (B,) are CONCRETE int arrays
    (numpy is fine): the checker enumerates the same table-dereferencing
    index maps the scalar-prefetch machinery would, so an index pointing
    outside the page pool is a static finding, not a silent DMA.  The G
    axis must already be padded to the fp32 sublane minimum (8), as
    :func:`repro.kernels.ops.flash_paged_decode` does.
    """
    import numpy as np

    pt = np.asarray(page_table, dtype=np.int64)
    ln = np.asarray(lengths, dtype=np.int64)
    n_pmax = pt.shape[1]
    pt_flat = pt.reshape(-1)
    q_map, kv_map = _decode_maps(n_pmax)

    def _bind(m):
        return lambda b, h, j: m(b, h, j, pt_flat, ln)

    grid = (B, KV, n_pmax)
    # pool rows are addressed through the table: repeated / skipped rows are
    # legal, so the k/v pools check OOB only ("any" coverage)
    return KernelSpec(
        name="flash_decode",
        source="flash_attention.py:flash_decode_kernel",
        grid=grid,
        inputs=(
            BlockOperand("q", (B, KV, G, hd), (1, 1, G, hd), _bind(q_map)),
            BlockOperand("k_pages", (n_pool, page, KV, hd),
                         (1, page, 1, hd), _bind(kv_map), coverage="any"),
            BlockOperand("v_pages", (n_pool, page, KV, hd),
                         (1, page, 1, hd), _bind(kv_map), coverage="any"),
        ),
        outputs=(
            BlockOperand("acc", (B, KV, G, hd), (1, 1, G, hd), _bind(q_map)),
            BlockOperand("m", (B, KV, G, 1), (1, 1, G, 1), _bind(q_map)),
            BlockOperand("l", (B, KV, G, 1), (1, 1, G, 1), _bind(q_map)),
        ),
        scratch=(
            ScratchSpec("m_run", (G, 1), "float32"),
            ScratchSpec("l_run", (G, 1), "float32"),
            ScratchSpec("acc_run", (G, hd), "float32", binds="acc"),
        ),
        # the scalar-prefetch contract: kv_map clamps -1 to page 0 and the
        # compute guard masks it, so -1 is legal; anything >= n_pool would
        # DMA outside the page pool regardless of masking.  Lengths bound
        # the compute guard: at most every owned page fully used.
        scalars=(
            ScalarOperand("page_table", pt_flat, -1, n_pool - 1,
                          note="-1 = unallocated (masked); valid pool rows "
                               f"are [0, {n_pool})"),
            ScalarOperand("lengths", ln, 0, n_pmax * page,
                          note=f"{n_pmax} pages x {page} slots owned at "
                               "most"),
        ),
    )
