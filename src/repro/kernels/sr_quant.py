"""Pallas TPU kernel: fused stochastic-rounding quantization (paper Eq. 1).

This is the FWQ hot spot: every client quantizes every weight every round.
The kernel fuses scale-divide + floor + Bernoulli(frac) + snap in one VMEM
pass (vs. ~5 HBM round-trips when left to op-by-op jnp), streaming
``(block_m, block_n)`` tiles HBM->VMEM->HBM.

Randomness is supplied as a pre-generated uniform tensor so the kernel is
bit-exact against :func:`repro.kernels.ref.sr_quant_fake_ref` and portable to
``interpret=True`` on CPU (pltpu PRNG primitives would pin it to real TPUs).

Two variants:
* ``sr_quant_fake_kernel``  — fp values snapped to the grid (training path)
* ``sr_quant_pack_kernel``  — int8 codes (serving path, 4x HBM saving)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)   # f32 tile: 512 lanes = 4 * 128, 256 sublanes


def _fake_body(w_ref, u_ref, step_ref, o_ref):
    w = w_ref[...]
    u = u_ref[...]
    step = step_ref[0, 0]
    safe = jnp.where(step > 0, step, 1.0)
    t = w / safe
    lower = jnp.floor(t)
    q = (lower + (u < (t - lower)).astype(w.dtype)) * safe
    o_ref[...] = jnp.where(step > 0, q, w)


def _pack_body(w_ref, u_ref, step_ref, o_ref, *, lim: int):
    w = w_ref[...]
    u = u_ref[...]
    step = step_ref[0, 0]
    safe = jnp.where(step > 0, step, 1.0)
    t = w / safe
    lower = jnp.floor(t)
    codes = lower + (u < (t - lower)).astype(w.dtype)
    o_ref[...] = jnp.clip(codes, -lim, lim).astype(jnp.int8)


def _grid_specs(shape, block):
    bm, bn = block
    m, n = shape
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return grid, tile, scalar


def sr_quant_fake_kernel(w, u, step, *, block=DEFAULT_BLOCK, interpret=False):
    """w, u: (M, N) f32; step: (1,1) f32.  Returns grid-snapped f32."""
    grid, tile, scalar = _grid_specs(w.shape, block)
    return pl.pallas_call(
        _fake_body,
        grid=grid,
        in_specs=[tile, tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(w, u, step)


def sr_quant_pack_kernel(w, u, step, *, bits: int = 7, block=DEFAULT_BLOCK,
                         interpret=False):
    """Same, but emits int8 codes in [-(2^bits - 1), 2^bits - 1]."""
    lim = 2**bits - 1
    grid, tile, scalar = _grid_specs(w.shape, block)
    return pl.pallas_call(
        functools.partial(_pack_body, lim=lim),
        grid=grid,
        in_specs=[tile, tile, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.int8),
        interpret=interpret,
    )(w, u, step)
