"""Pallas TPU kernel: int8-weight dequantize-matmul (serving hot spot).

Computes ``x @ (codes * scale)`` streaming the weight as int8: the HBM
traffic on the weight stream is 1/4 of f32 (1/2 of bf16) — exactly the
memory-roofline win the paper's storage argument becomes on a TPU serving
path (decode is weight-bandwidth-bound).

Tiling: grid (M/bm, N/bn, K/bk), K innermost; an f32 VMEM scratch accumulates
partial products; dequantization happens tile-by-tile in VMEM right before
the MXU dot (128-aligned dims).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (256, 256, 512)  # (bm, bn, bk): MXU-aligned multiples of 128


def _body(x_ref, c_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = c_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul_kernel(x, codes, scale, *, blocks=DEFAULT_BLOCKS,
                        out_dtype=jnp.float32, interpret=False):
    """x: (M, K) f32/bf16; codes: (K, N) int8; scale: (1,1) f32 -> (M, N)."""
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2, (x.shape, codes.shape)
    bm, bn, bk = blocks
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    return pl.pallas_call(
        functools.partial(_body, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale)
