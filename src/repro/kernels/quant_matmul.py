"""Pallas TPU kernel: int8-weight dequantize-matmul (serving hot spot).

Computes ``x @ (codes * scale)`` streaming the weight as int8: the HBM
traffic on the weight stream is 1/4 of f32 (1/2 of bf16) — exactly the
memory-roofline win the paper's storage argument becomes on a TPU serving
path (decode is weight-bandwidth-bound).

Tiling: grid (M/bm, N/bn, K/bk), K innermost; an f32 VMEM scratch accumulates
partial products; dequantization happens tile-by-tile in VMEM right before
the MXU dot (128-aligned dims).

The index maps are module-level functions shared between the ``pallas_call``
and the :func:`kernel_spec` metadata the static checker
(``repro.analyze.kernel_check``) enumerates — so the checked BlockSpecs are
the lowered BlockSpecs, by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.spec import BlockOperand, KernelSpec, ScratchSpec

DEFAULT_BLOCKS = (256, 256, 512)  # (bm, bn, bk): MXU-aligned multiples of 128


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def choose_blocks(M: int, K: int, N: int, x_dtype=jnp.float32):
    """Adaptive (bm, bn, bk) for a raw (possibly ragged) M x K x N problem.

    Sublane minima: 8 rows for f32 x-blocks, 16 for bf16; 128-lane alignment
    on the contraction/output dims (see pallas_guide §Tiling Constraints).
    Decode-sized M (a handful of rows) gets an 8/16-row block instead of
    padding the batch to 256.
    """
    bm = min(DEFAULT_BLOCKS[0], _round_up(M, 8 if x_dtype == jnp.float32
                                          else 16))
    bn = min(DEFAULT_BLOCKS[1], _round_up(N, 128))
    bk = min(DEFAULT_BLOCKS[2], _round_up(K, 128))
    return bm, bn, bk


def _x_map(i, j, k):
    return (i, k)


def _w_map(i, j, k):
    return (k, j)


def _scale_map(i, j, k):
    return (0, 0)


def _out_map(i, j, k):
    return (i, j)


def _quant_matmul_body(x_ref, c_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = c_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul_kernel(x, codes, scale, *, blocks=DEFAULT_BLOCKS,
                        out_dtype=jnp.float32, interpret=False):
    """x: (M, K) f32/bf16; codes: (K, N) int8; scale: (1,1) f32 -> (M, N)."""
    M, K = x.shape
    K2, N = codes.shape
    assert K == K2, (x.shape, codes.shape)
    bm, bn, bk = blocks
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk))
    return pl.pallas_call(
        functools.partial(_quant_matmul_body, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), _x_map),
            pl.BlockSpec((bk, bn), _w_map),
            pl.BlockSpec((1, 1), _scale_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), _out_map),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scale)


def kernel_spec(M: int, K: int, N: int, *, x_dtype=jnp.float32,
                blocks=None) -> KernelSpec:
    """Static BlockSpec metadata for the wrapper-level call at (M, K, N).

    Mirrors :func:`repro.kernels.ops.quant_matmul` exactly: block choice via
    :func:`choose_blocks`, operands zero-padded to block multiples.
    """
    bm, bn, bk = blocks if blocks is not None else choose_blocks(
        M, K, N, x_dtype)
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    grid = (Mp // bm, Np // bn, Kp // bk)
    return KernelSpec(
        name="quant_matmul",
        source="quant_matmul.py:quant_matmul_kernel",
        grid=grid,
        inputs=(
            BlockOperand("x", (Mp, Kp), (bm, bk), _x_map),
            BlockOperand("codes", (Kp, Np), (bk, bn), _w_map),
            BlockOperand("scale", (1, 1), (1, 1), _scale_map,
                         coverage="any"),
        ),
        outputs=(BlockOperand("out", (Mp, Np), (bm, bn), _out_map),),
        scratch=(ScratchSpec("acc", (bm, bn), "float32", binds="out"),),
    )
