"""Jitted public wrappers + leaf-type dispatch for the Pallas kernels.

Dispatch policy: real TPU lowering on TPU backends; ``interpret=True``
(Python-emulated, correctness-checked) elsewhere.  The wrappers also handle
padding to block multiples (ragged / non-128-aligned shapes included) and the
scalar plumbing the kernels expect.

:func:`dense_dispatch` is the serving fast path's single entry point: given an
activation and either a plain array or a :class:`~repro.models.common.QTensor`
weight, it routes to the int8-streaming ``quant_matmul`` kernel when the
weight is packed, so dequantization happens tile-by-tile in VMEM instead of
materializing a full-precision copy in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention_kernel,
                                           flash_decode_kernel)
from repro.kernels.quant_matmul import choose_blocks, quant_matmul_kernel
from repro.kernels.sr_quant import sr_quant_fake_kernel, sr_quant_pack_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(x, bm, bn, value=0):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)), constant_values=value)
    return x


@functools.partial(jax.jit, static_argnames=("bits",))
def sr_quantize_fused(w: jnp.ndarray, key: jax.Array, bits: int):
    """Fake-quantize a 2-D weight with SR at ``bits`` (kernel-fused path).

    Equivalent to :func:`repro.core.quantization.sr_quantize` with a
    per-tensor scale; used by benchmarks and (on TPU) the serving packer.
    """
    assert w.ndim == 2
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    step = (s / (2.0**bits - 1.0)).reshape(1, 1).astype(jnp.float32)
    u = jax.random.uniform(key, w.shape, dtype=jnp.float32)
    bm, bn = 256, 512
    wp, up = _pad2(w.astype(jnp.float32), bm, bn), _pad2(u, bm, bn)
    out = sr_quant_fake_kernel(wp, up, step, interpret=_interpret())
    out = out[: w.shape[0], : w.shape[1]]
    return jnp.clip(out, -s, s).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("bits",))
def sr_pack_fused(w: jnp.ndarray, key: jax.Array, bits: int = 7):
    """Pack a 2-D weight to int8 codes + scalar scale (kernel-fused path)."""
    assert w.ndim == 2 and bits <= 7
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    delta = 1.0 / (2.0**bits - 1.0)
    step = (s * delta).reshape(1, 1).astype(jnp.float32)
    u = jax.random.uniform(key, w.shape, dtype=jnp.float32)
    bm, bn = 256, 512
    wp, up = _pad2(w.astype(jnp.float32), bm, bn), _pad2(u, bm, bn)
    codes = sr_quant_pack_kernel(wp, up, step, bits=bits, interpret=_interpret())
    return codes[: w.shape[0], : w.shape[1]], (s * delta).astype(jnp.float32)


@jax.jit
def quant_matmul(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray):
    """x (M,K) @ dequant(codes (K,N) int8/int16, scale) with packed HBM
    streaming.

    Block sizes adapt to the problem: decode-sized M (a handful of rows)
    gets an 8/16-row block instead of padding the batch to 256, and ragged
    (non-128-aligned) K/N are zero-padded to the block grid — zero codes
    contribute nothing to the dot, so no masking is needed.
    """
    M, K = x.shape
    _, N = codes.shape
    # block choice shared with the static checker's kernel_spec — see
    # repro.kernels.quant_matmul.choose_blocks for the alignment rules
    bm, bn, bk = choose_blocks(M, K, N, x.dtype)
    xp = _pad2(x, bm, bk)
    cp = _pad2(codes, bk, bn)
    out = quant_matmul_kernel(xp, cp, scale.reshape(1, 1),
                              blocks=(bm, bn, bk), interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = True):
    """q,k,v: (B, H, S, D) -> (B, H, S, D); online-softmax Pallas kernel.

    Ragged S (not a multiple of the 128-aligned block) is zero-padded; the
    kernel masks the padded keys via ``s_valid`` and the padded query rows
    are sliced off here.
    """
    B, H, S, D = q.shape
    bq = bk = min(256, _round_up(S, 128))
    Sp = _round_up(S, bq)

    def flat(t):
        t = t.reshape(B * H, S, D)
        if Sp != S:
            t = jnp.pad(t, ((0, 0), (0, Sp - S), (0, 0)))
        return t

    out = flash_attention_kernel(flat(q), flat(k), flat(v), causal=causal,
                                 blocks=(bq, bk), s_valid=S,
                                 interpret=_interpret())
    return out[:, :S, :].reshape(B, H, S, D)


@jax.jit
def flash_paged_decode(q, k_pages, v_pages, page_table, lengths):
    """Batched paged flash-decode: q (B, KVh, G, hd) against page pools.

    ``k_pages``/``v_pages`` are (N_pool, page, KVh, hd) in the KV-cache
    storage dtype (f32 or bf16); ``page_table`` (B, n_pmax) int32 with -1 for
    unallocated pages; ``lengths`` (B,) valid tokens per slot (local
    coordinates).  Returns UNNORMALIZED fp32 partials ``(acc, m, l)`` so
    sequence-parallel callers can merge shards before normalizing with
    ``acc / max(l, eps)``.

    G (queries per KV head) is padded to the fp32 sublane minimum (8) for TPU
    lowering; the padded rows are computed on garbage and sliced off.
    """
    B, KV, G, hd = q.shape
    g_pad = max(G, 8)
    if g_pad != G:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, g_pad - G), (0, 0)))
    acc, m, l = flash_decode_kernel(
        q, k_pages, v_pages, page_table.astype(jnp.int32),
        lengths.astype(jnp.int32), interpret=_interpret())
    return acc[:, :, :G], m[:, :, :G], l[:, :, :G]


# ---------------------------------------------------------------------------
# Leaf-type dispatch (the serving fast path)
# ---------------------------------------------------------------------------


def _is_qtensor(w) -> bool:
    # structural check instead of an import: repro.models.common imports are
    # kept out of module scope so `repro.kernels` stays importable standalone.
    return hasattr(w, "codes") and hasattr(w, "scale")


def dense_dispatch(x: jnp.ndarray, w) -> jnp.ndarray:
    """``x (..., K) @ w`` where ``w`` is a plain ``(K, N)`` array *or* a
    packed :class:`~repro.models.common.QTensor`.

    Packed weights take the ``quant_matmul`` Pallas kernel: codes stream from
    HBM as int8/int16 and dequantize tile-by-tile in VMEM (f32 accumulate),
    so a decode step moves ~1/4 the weight bytes of the f32 path.  The
    result is cast back to ``x.dtype`` to match the eager-dequant reference.
    """
    if _is_qtensor(w):
        lead = x.shape[:-1]
        out = quant_matmul(x.reshape((-1, x.shape[-1])), w.codes, w.scale)
        return out.reshape(lead + (w.codes.shape[-1],)).astype(x.dtype)
    return x @ w


def expert_dispatch(x: jnp.ndarray, w, dtype=None) -> jnp.ndarray:
    """Per-expert batched matmul ``x (E, C, K) @ w (E, K, N) -> (E, C, N)``.

    A packed :class:`~repro.models.common.QTensor` expert stack routes every
    expert's matmul through the ``quant_matmul`` Pallas kernel (the expert
    count is static, so the loop unrolls into E kernel calls over the shared
    per-layer scale) instead of eagerly dequantizing the whole stack; plain
    arrays keep the dense einsum.  Falls back to eager dequant for the
    per-sub-tensor-scale layouts the kernel's scalar-scale ABI cannot take.
    """
    if dtype is None:
        dtype = x.dtype
    if _is_qtensor(w):
        if jnp.ndim(w.scale) == 0:
            n_experts = w.codes.shape[0]
            out = [quant_matmul(x[e], w.codes[e], w.scale)
                   for e in range(n_experts)]
            return jnp.stack(out).astype(dtype)
        # per-expert scale row: eager dequant, scale broadcast over (C, N)
        scale = jnp.reshape(w.scale.astype(jnp.float32),
                            (-1,) + (1,) * (w.codes.ndim - 1))
        dense = (w.codes.astype(jnp.float32) * scale).astype(dtype)
        return jnp.einsum("eck,ekn->ecn", x.astype(dtype), dense)
    return jnp.einsum("eck,ekn->ecn", x.astype(dtype), as_array(w, dtype))


def as_array(w, dtype=jnp.float32) -> jnp.ndarray:
    """Materialize a (possibly packed) weight as a dense array.

    Fallback for consumers the kernel cannot serve — embedding gathers and
    per-channel-scale layouts — under lazy-quant mode.
    """
    if _is_qtensor(w):
        return (w.codes.astype(jnp.float32) * w.scale.astype(jnp.float32)
                ).astype(dtype)
    return w


# Re-export the oracles for convenience in tests/benchmarks.
sr_quant_fake_ref = ref.sr_quant_fake_ref
sr_quant_pack_ref = ref.sr_quant_pack_ref
quant_matmul_ref = ref.quant_matmul_ref
flash_attention_ref = ref.flash_attention_ref
