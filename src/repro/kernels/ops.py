"""Jitted public wrappers for the Pallas kernels.

Dispatch policy: real TPU lowering on TPU backends; ``interpret=True``
(Python-emulated, correctness-checked) elsewhere.  The wrappers also handle
padding to block multiples and the scalar plumbing the kernels expect.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.quant_matmul import quant_matmul_kernel
from repro.kernels.sr_quant import sr_quant_fake_kernel, sr_quant_pack_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x, bm, bn, value=0):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)), constant_values=value)
    return x


@functools.partial(jax.jit, static_argnames=("bits",))
def sr_quantize_fused(w: jnp.ndarray, key: jax.Array, bits: int):
    """Fake-quantize a 2-D weight with SR at ``bits`` (kernel-fused path).

    Equivalent to :func:`repro.core.quantization.sr_quantize` with a
    per-tensor scale; used by benchmarks and (on TPU) the serving packer.
    """
    assert w.ndim == 2
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    step = (s / (2.0**bits - 1.0)).reshape(1, 1).astype(jnp.float32)
    u = jax.random.uniform(key, w.shape, dtype=jnp.float32)
    bm, bn = 256, 512
    wp, up = _pad2(w.astype(jnp.float32), bm, bn), _pad2(u, bm, bn)
    out = sr_quant_fake_kernel(wp, up, step, interpret=_interpret())
    out = out[: w.shape[0], : w.shape[1]]
    return jnp.clip(out, -s, s).astype(w.dtype)


@functools.partial(jax.jit, static_argnames=("bits",))
def sr_pack_fused(w: jnp.ndarray, key: jax.Array, bits: int = 7):
    """Pack a 2-D weight to int8 codes + scalar scale (kernel-fused path)."""
    assert w.ndim == 2 and bits <= 7
    s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30)
    delta = 1.0 / (2.0**bits - 1.0)
    step = (s * delta).reshape(1, 1).astype(jnp.float32)
    u = jax.random.uniform(key, w.shape, dtype=jnp.float32)
    bm, bn = 256, 512
    wp, up = _pad2(w.astype(jnp.float32), bm, bn), _pad2(u, bm, bn)
    codes = sr_quant_pack_kernel(wp, up, step, bits=bits, interpret=_interpret())
    return codes[: w.shape[0], : w.shape[1]], (s * delta).astype(jnp.float32)


@jax.jit
def quant_matmul(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray):
    """x (M,K) @ dequant(codes (K,N) int8, scale) with int8 HBM streaming."""
    M, K = x.shape
    _, N = codes.shape
    bm, bn, bk = 256, 256, 512
    xp = _pad2(x, bm, bk)
    cp = _pad2(codes, bk, bn)
    out = quant_matmul_kernel(xp, cp, scale.reshape(1, 1),
                              interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, causal: bool = True):
    """q,k,v: (B, H, S, D) -> (B, H, S, D); online-softmax Pallas kernel."""
    B, H, S, D = q.shape
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    out = flash_attention_kernel(qf, kf, vf, causal=causal,
                                 interpret=_interpret())
    return out.reshape(B, H, S, D)


# Re-export the oracles for convenience in tests/benchmarks.
sr_quant_fake_ref = ref.sr_quant_fake_ref
sr_quant_pack_ref = ref.sr_quant_pack_ref
quant_matmul_ref = ref.quant_matmul_ref
flash_attention_ref = ref.flash_attention_ref
