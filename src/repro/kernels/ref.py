"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose`` targets).

The kernels must match these references bit-for-bit where the math is exact
(sr_quant with shared uniforms) or to fp32 tolerance (matmul/attention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sr_quant_fake_ref(w: jnp.ndarray, u: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """Stochastic rounding onto a grid of pitch ``step`` (paper Eq. 1).

    w, u: (M, N) f32 (u ~ U[0,1) supplied by the caller — kernel and ref share
    the same randomness); step: scalar f32 (s * Delta_q); step == 0 bypasses.
    """
    safe = jnp.where(step > 0, step, 1.0)
    t = w / safe
    lower = jnp.floor(t)
    q = (lower + (u < (t - lower)).astype(w.dtype)) * safe
    # clamp to the representable range [-s, s]; s = step / Delta implied by
    # caller, so clamp against the max|w| the caller scaled with:
    return jnp.where(step > 0, q, w)


def sr_quant_pack_ref(w: jnp.ndarray, u: jnp.ndarray, step: jnp.ndarray,
                      lim: int) -> jnp.ndarray:
    """Integer codes version: clip(floor(w/step) + bern, -lim, lim) int8."""
    safe = jnp.where(step > 0, step, 1.0)
    t = w / safe
    lower = jnp.floor(t)
    codes = lower + (u < (t - lower)).astype(w.dtype)
    return jnp.clip(codes, -lim, lim).astype(jnp.int8)


def quant_matmul_ref(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    """x (M,K) @ dequant(codes (K,N) int8; w = codes*scale) -> (M,N)."""
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q,k,v: (B, H, S, D).  Full-softmax reference, fp32 accumulation."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S = q.shape[2]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
