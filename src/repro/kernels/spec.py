"""Declarative BlockSpec metadata the kernels export for static checking.

Each Pallas kernel in this package also publishes a :class:`KernelSpec`
mirroring exactly what its ``pallas_call`` will do for a given problem size:
the grid, every operand's padded shape / block shape / index map, and the
VMEM scratch allocations.  ``repro.analyze.kernel_check`` enumerates the
index maps over the grid against these specs — coverage, out-of-bounds DMA,
scratch consistency — without ever running the kernel.

The index-map callables here are the SAME functions the ``pallas_call``
uses (module-level, not per-call lambdas), so the spec cannot drift from
the kernel: a change to an index map changes both the lowering and the
checked metadata.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BlockOperand:
    """One pallas_call operand: padded array shape, block, and index map.

    ``coverage``: ``"full"`` — every tile of ``shape`` must be visited by
    the index map over the grid (weights, activations, outputs);
    ``"any"`` — partial/repeated visits are legal (shared pools addressed
    through a page table, broadcast scalars revisited every step).
    """

    name: str
    shape: tuple
    block: tuple
    index_map: object               # callable (*grid_ids) -> block indices
    coverage: str = "full"


@dataclasses.dataclass(frozen=True)
class ScalarOperand:
    """One scalar-prefetch operand and the value range the kernel assumes.

    Scalar-prefetch values (page tables, sequence lengths) steer index maps
    and compute guards, so an out-of-range entry is an out-of-bounds DMA
    the BlockSpec enumeration alone cannot see.  ``values`` is the CONCRETE
    integer array a launch would pass; ``lo``/``hi`` are the inclusive
    bounds the kernel's addressing arithmetic is safe under.
    """

    name: str
    values: object                  # concrete integer array (numpy is fine)
    lo: int
    hi: int
    note: str = ""                  # why the bounds are what they are


@dataclasses.dataclass(frozen=True)
class ScratchSpec:
    """One VMEM scratch allocation.

    ``binds``: name of the operand whose block this scratch accumulates
    into (its shape must equal that block with leading 1-dims squeezed),
    or ``None`` for free-form carry state (running max / running sum).
    """

    name: str
    shape: tuple
    dtype: str
    binds: str | None = None


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static description of one pallas_call at a concrete problem size."""

    name: str
    source: str                     # "file.py:kernel_fn" provenance
    grid: tuple
    inputs: tuple                   # tuple[BlockOperand, ...]
    outputs: tuple                  # tuple[BlockOperand, ...]
    scratch: tuple = ()             # tuple[ScratchSpec, ...]
    scalars: tuple = ()             # tuple[ScalarOperand, ...]

    @property
    def operands(self):
        return self.inputs + self.outputs
