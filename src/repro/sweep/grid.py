"""Declarative sweep grids: cross-products of RunSpec fields -> cells.

A :class:`Sweep` is a named experiment grid built from one base
:class:`~repro.api.spec.RunSpec` dict plus :class:`Axis` cross-products over
its fields — including nested ``options.*`` keys and the
:class:`~repro.api.precision.PrecisionPolicy` sub-dict — so "arch x mesh x
workload x {weights, kv_cache, comm} bits x serve flags" grids are one
declaration, not a hand-rolled loop (cf. the quantization x channel grids of
arXiv:2402.12957 / arXiv:2101.04866).

Every cell is keyed by a **content hash** of its canonical spec JSON
(:func:`cell_key`); the hash is what makes sweeps resumable — a results
store that has a key already holds that exact experiment, whatever order or
process produced it.

Named presets (:func:`get_preset`) cover the ROADMAP grids:

* ``roofline-all-archs``       — all 10 archs x {train_4k, prefill_32k,
  decode_32k} dryrun on the 16x16 pod, long_500k rows for the
  sub-quadratic archs, plus one 2x16x16 multi-pod cell.
* ``serve-precision-ablation`` — serve smokes over weight bits x kv-cache
  storage x KV layout (paged vs contiguous).
* ``fl-codesign-grid``         — the paper's Fig. 2 scheme grid (fl-sim).
* ``fl-fault-grid``            — fault intensity x {GBD co-design,
  fixed-bit baseline} degradation grid through the resilient round
  executor (``repro.faults``).
* ``grad-comm-wire``           — train smokes over gradient wire bits
  (consumes :func:`repro.dist.wire.grad_wire_report`).
* ``ci-tiny``                  — 2 dryrun cells + 1 fl-sim cell + 1
  long-context paged serve cell; the CI smoke grid.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json

from repro.api.spec import RunSpec


def canonical_json(d: dict) -> str:
    """Key-order-independent JSON (the hashing form)."""
    return json.dumps(d, sort_keys=True, separators=(",", ":"))


def cell_key(spec_dict: dict) -> str:
    """Content hash of one cell's full spec — the resume identity.

    Two cells collide iff their RunSpecs are identical, so a store lookup by
    key is exactly "has this experiment already run".
    """
    return hashlib.sha256(canonical_json(spec_dict).encode()).hexdigest()[:16]


def set_field(d: dict, field: str, value) -> None:
    """Dotted-path assignment (``options.shape``); dict values deep-merge."""
    parts = field.split(".")
    for p in parts[:-1]:
        d = d.setdefault(p, {})
    leaf = parts[-1]
    if isinstance(value, dict) and isinstance(d.get(leaf), dict):
        d[leaf] = {**d[leaf], **value}
    else:
        d[leaf] = value


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept dimension: a dotted RunSpec field and its values.

    ``field`` may target a top-level RunSpec field (``arch``, ``mesh``), an
    options key (``options.shape``), a precision role
    (``precision.kv_cache``), or a whole sub-dict (``precision``) — dict
    values merge into the existing sub-dict, so one axis can move several
    coupled knobs (e.g. ``{"weights": 7, "lazy": True}``).
    """

    field: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))


@dataclasses.dataclass(frozen=True)
class Cell:
    """One grid point: a concrete RunSpec plus its content-hash key."""

    spec: RunSpec
    key: str
    sweep: str

    @property
    def label(self) -> str:
        """Compact human identity for progress lines and table rows."""
        s = self.spec
        if s.workload == "dryrun":
            return f"{s.arch} x {s.opt('shape')} x {s.mesh}"
        if s.workload == "serve":
            return (f"{s.arch} w{s.precision.weights} "
                    f"kv{s.precision.kv_cache}")
        if s.workload == "fl-sim":
            f = s.opt("faults") or {}
            tag = f" faults[pl={f.get('packet_loss', 0):g}]" if f else ""
            return f"{s.arch} {s.opt('scheme', 'fwq')}{tag}"
        return f"{s.arch} {s.workload} comm{s.precision.comm}"


@dataclasses.dataclass(frozen=True)
class Sweep:
    """A named grid: base spec dict x axes, plus explicit extra cells.

    ``base`` is a RunSpec dict template; ``axes`` cross-product into it;
    ``extra_cells`` are standalone full spec dicts appended after the
    product (e.g. the one multi-pod roofline cell).
    """

    name: str
    base: dict
    axes: tuple[Axis, ...] = ()
    extra_cells: tuple[dict, ...] = ()

    def spec_dicts(self) -> list[dict]:
        out = []
        for combo in itertools.product(*[a.values for a in self.axes]):
            d = json.loads(json.dumps(self.base))        # deep copy
            for axis, v in zip(self.axes, combo):
                set_field(d, axis.field, v)
            out.append(d)
        out.extend(json.loads(json.dumps(d)) for d in self.extra_cells)
        return out

    def cells(self) -> list[Cell]:
        out = []
        for d in self.spec_dicts():
            spec = RunSpec.from_dict(d)
            # hash the ROUND-TRIPPED dict so defaults are always explicit:
            # the key identifies the experiment, not the spelling of it
            out.append(Cell(spec=spec, key=cell_key(spec.to_dict()),
                            sweep=self.name))
        return out


# ---------------------------------------------------------------------------
# Named presets (the ROADMAP grids)
# ---------------------------------------------------------------------------


def preset_roofline_all_archs(
        shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")) -> Sweep:
    """All 10 archs x shape rows (train / prefill / decode) on 16x16, plus a
    ``long_500k`` row per sub-quadratic arch and one 2x16x16 multi-pod cell.

    The train_4k cells keep their original content hashes (the shape axis
    writes the same ``options.shape`` the old single-shape preset did), so a
    pre-existing store resumes instead of recompiling them.
    """
    from repro.configs import ARCH_NAMES, get_config

    dry = {"workload": "dryrun", "mesh": "16x16", "smoke": False,
           "options": {"shape": shapes[0]}}
    long_cells = tuple(
        {"arch": a, **dry, "options": {"shape": "long_500k"}}
        for a in ARCH_NAMES if get_config(a).supports_long_context)
    return Sweep(
        name="roofline-all-archs",
        base={"arch": "", **dry},
        axes=(Axis("arch", ARCH_NAMES), Axis("options.shape", shapes)),
        extra_cells=long_cells + (
            {"arch": "mamba2-780m", **dry, "mesh": "2x16x16"},))


def preset_serve_precision_ablation(steps: int = 12,
                                    arch: str = "yi-6b",
                                    weights: tuple = (32, 7, 12),
                                    kv_cache: tuple = (32, 16),
                                    kv_layout: tuple = ("paged",
                                                        "contiguous"),
                                    s_max: int = 64) -> Sweep:
    """Serving-policy ablation: weight bits x kv-cache storage x KV layout.

    The kv_layout axis is the paged-vs-contiguous comparison on a
    mixed-length workload (``vary_prompt`` draws ragged prompts): same
    tokens, same weights — only the KV residency changes.
    """
    w_axis = tuple({"weights": 32, "lazy": False} if b >= 32
                   else {"weights": b, "lazy": True} for b in weights)
    return Sweep(
        name="serve-precision-ablation",
        base={"arch": arch, "workload": "serve", "smoke": True, "batch": 2,
              "seq": s_max, "precision": {"weights": 32},
              "options": {"steps": steps, "prompt_len": 8,
                          "attn_impl": "ref", "vary_prompt": True,
                          "quiet": True}},
        axes=(Axis("precision", w_axis),
              Axis("precision.kv_cache", kv_cache),
              Axis("options.kv_layout", kv_layout)))


def preset_fl_codesign_grid(rounds: int = 60, n_clients: int = 8,
                            arch: str = "resnet") -> Sweep:
    """Paper Fig. 2 grid: co-design scheme x (CNN fl-sim)."""
    return Sweep(
        name="fl-codesign-grid",
        base={"arch": arch, "workload": "fl-sim", "rounds": rounds,
              "batch": 16,
              "options": {"n_clients": n_clients, "lr": 0.2,
                          "error_tolerance": 4.5, "eval_every": 10}},
        axes=(Axis("options.scheme",
                   ("fwq", "full_precision", "unified_q", "rand_q")),))


def preset_fl_fault_grid(rounds: int = 24, n_clients: int = 6,
                         arch: str = "resnet") -> Sweep:
    """Degradation grid: fault intensity x co-design scheme (fl-sim).

    Three fault levels (none / mild / severe) against the GBD co-design
    (``fwq``) and the fixed-bit ``unified_q`` baseline.  Every cell runs the
    resilient round executor (deadline + retransmission + aggregation gate),
    with drift-triggered warm GBD re-solves enabled, so the table reads as
    "how gracefully does each scheme degrade": loss/energy deltas plus the
    explicit retransmission, rejected-update, and undelivered counters.
    """
    mild = {"dropout_prob": 0.05, "fade_prob": 0.1, "packet_loss": 0.05,
            "corrupt_prob": 0.05}
    severe = {"dropout_prob": 0.15, "fade_prob": 0.3, "packet_loss": 0.2,
              "corrupt_prob": 0.1, "slowdown_prob": 0.1}
    return Sweep(
        name="fl-fault-grid",
        base={"arch": arch, "workload": "fl-sim", "rounds": rounds,
              "batch": 16,
              "options": {"n_clients": n_clients, "lr": 0.2,
                          "error_tolerance": 4.5, "eval_every": 8,
                          "resolve_drift_db": 6.0}},
        axes=(Axis("options.scheme", ("fwq", "unified_q")),
              Axis("options.faults", (None, mild, severe))))


def preset_fl_adaptive_grid(rounds: int = 24, n_clients: int = 6,
                            arch: str = "resnet",
                            budget_j: float = 430.0) -> Sweep:
    """Adaptive precision program vs static fwq, with and without faults.

    2x2 grid: fault level (none / severe, the ``fl-fault-grid`` severe
    preset) x precision program (static GBD policy / ``energy_budget``
    controller).  The budget is set between the measured no-fault and
    severe-fault static totals, so the adaptive cells tell the paper's
    story: under faults the static co-design OVERSHOOTS the budget (it
    never sees the retransmission bill), while the controller demotes
    weight/comm bits as cumulative measured energy tracks over pace and
    finishes within it.  The fault-free cells double as a no-regression
    check — under budget the controller never clamps, so its cell matches
    the static one.
    """
    severe = {"dropout_prob": 0.15, "fade_prob": 0.3, "packet_loss": 0.2,
              "corrupt_prob": 0.1, "slowdown_prob": 0.1}
    # restore below the default 0.90: the severe-fault spend sits close to
    # pace, and a quick restore oscillates demote/restore and lands over
    # budget — holding demotions until spend is clearly under keeps it in
    program = {"kind": "energy_budget", "budget_j": budget_j,
               "restore": 0.75}
    return Sweep(
        name="fl-adaptive-grid",
        base={"arch": arch, "workload": "fl-sim", "rounds": rounds,
              "batch": 16,
              "options": {"n_clients": n_clients, "lr": 0.2,
                          "error_tolerance": 4.5, "eval_every": 8,
                          "scheme": "fwq", "resolve_drift_db": 6.0}},
        axes=(Axis("options.faults", (None, severe)),
              Axis("options.precision_program", (None, program))))


def preset_grad_comm_wire(rounds: int = 2) -> Sweep:
    """Gradient wire-compression ablation: train smokes over comm bits.

    The 4x1 mesh puts 4 FL clients on 4 (fake host) devices, so the
    SR-quantized all-reduce actually runs — comm bits change both the
    on-wire dtype and the training noise, not just the accounting.
    """
    return Sweep(
        name="grad-comm-wire",
        base={"arch": "yi-6b", "workload": "train", "mesh": "4x1",
              "smoke": True, "batch": 1, "seq": 16, "rounds": rounds,
              "options": {"lr": 0.05, "quiet": True}},
        axes=(Axis("precision.comm", (32, 8, 4)),))


def preset_ci_tiny() -> Sweep:
    """The CI smoke grid: 2 dryrun cells + 1 fl-sim cell, minutes on CPU.

    The dryrun cells are spec-identical to their ``roofline-all-archs``
    counterparts (same content hash), so CI exercises the exact cells the
    EXPERIMENTS.md grid records.
    """
    dry = {"workload": "dryrun", "mesh": "16x16", "smoke": False,
           "options": {"shape": "train_4k"}}
    return Sweep(
        name="ci-tiny",
        base={"arch": "", **dry},
        axes=(Axis("arch", ("mamba2-780m", "yi-6b")),),
        extra_cells=(
            {"arch": "resnet", "workload": "fl-sim", "rounds": 2, "batch": 8,
             "options": {"scheme": "fwq", "n_clients": 4, "lr": 0.1}},
            # long-context serve smoke on the PAGED path: a 5-page pool
            # against 3-page requests forces deferred admissions and page
            # reclaim, and ragged prompts exercise the prefill buckets
            {"arch": "yi-6b", "workload": "serve", "smoke": True, "batch": 2,
             "seq": 128,
             "precision": {"weights": 7, "lazy": True},
             "options": {"steps": 48, "s_max": 128, "prompt_len": 8,
                         "max_new": 10, "requests": 4, "kv_layout": "paged",
                         "page_size": 8, "pool_pages": 5,
                         "vary_prompt": True, "quiet": True}},
            # fault-injected fl-sim: nonzero dropout + packet loss + corrupt
            # through the resilient round executor — the CI contract is that
            # it completes with zero unhandled exceptions and reports the
            # retransmission / rejected-update counters
            {"arch": "resnet", "workload": "fl-sim", "rounds": 3, "batch": 8,
             "options": {"scheme": "fwq", "n_clients": 4, "lr": 0.1,
                         "faults": {"dropout_prob": 0.2, "packet_loss": 0.15,
                                    "corrupt_prob": 0.25}}},
            # adaptive-precision smoke: a deliberately tight energy budget so
            # the energy_budget controller actually demotes bits in CI, and
            # the analyzer's envelope proofs cover the demoted widths
            {"arch": "resnet", "workload": "fl-sim", "rounds": 3, "batch": 8,
             "options": {"scheme": "fwq", "n_clients": 4, "lr": 0.1,
                         "precision_program": {"kind": "energy_budget",
                                               "budget_j": 14.0}}},))


PRESETS = {
    "roofline-all-archs": preset_roofline_all_archs,
    "serve-precision-ablation": preset_serve_precision_ablation,
    "fl-codesign-grid": preset_fl_codesign_grid,
    "fl-fault-grid": preset_fl_fault_grid,
    "fl-adaptive-grid": preset_fl_adaptive_grid,
    "grad-comm-wire": preset_grad_comm_wire,
    "ci-tiny": preset_ci_tiny,
}


def get_preset(name: str, **kw) -> Sweep:
    if name not in PRESETS:
        raise KeyError(f"unknown sweep preset {name!r}; "
                       f"options: {sorted(PRESETS)}")
    return PRESETS[name](**kw)
