"""repro.sweep: resumable experiment grids over the ``repro.api`` facade.

``Sweep`` declares a grid of RunSpecs (axes cross-product + presets),
``SweepRunner`` executes it into a content-hash-keyed JSONL ``ResultsStore``
(interruption-safe: completed cells are skipped on re-run), and ``report``
renders the store into marker-delimited EXPERIMENTS.md tables.
"""

from repro.sweep.grid import (  # noqa: F401
    Axis,
    Cell,
    PRESETS,
    Sweep,
    cell_key,
    get_preset,
)
from repro.sweep.runner import (  # noqa: F401
    ResultsStore,
    SweepRunner,
    execute_cell,
    git_sha,
)
from repro.sweep.report import (  # noqa: F401
    render_tables,
    update_markers,
    write_experiments,
)
