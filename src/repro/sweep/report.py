"""Sweep results -> typed rows -> marker-delimited EXPERIMENTS.md tables.

Each workload has a row adapter that pulls the table-worthy numbers out of a
stored cell record; :func:`render_tables` assembles them into GitHub
markdown, and :func:`update_markers` splices the rendered block between

    <!-- sweep:<name>:begin -->
    ...
    <!-- sweep:<name>:end -->

replacing whatever was there (or appending a new section when the markers
don't exist yet).  Rows follow the sweep's declared cell order and contain
only run-deterministic columns by default, so regenerating a table from an
interrupted-then-resumed store is byte-identical to an uninterrupted run —
the property ``tests/test_sweep.py`` pins.
"""

from __future__ import annotations

import math

from repro.sweep.grid import Sweep
from repro.sweep.runner import ResultsStore

MARK_BEGIN = "<!-- sweep:{name}:begin -->"
MARK_END = "<!-- sweep:{name}:end -->"


def _f(x, spec="{:.3e}") -> str:
    if x is None or (isinstance(x, float) and math.isnan(x)):
        return "-"
    return spec.format(x)


# ---------------------------------------------------------------------------
# Per-workload row adapters: stored record -> ordered (column, value) rows
# ---------------------------------------------------------------------------


def roofline_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    return {
        "arch x shape x mesh":
            f"{s['arch']} x {s['options'].get('shape')} x {s['mesh']}",
        "compute_s": _f(m.get("compute_s")),
        "memory_s": _f(m.get("memory_s")),
        "collective_s": _f(m.get("collective_s")),
        "dominant": m.get("dominant", "-"),
        "useful FLOPs": _f(m.get("useful_flops_ratio"), "{:.3f}"),
    }


def serving_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    p = s["precision"]
    layout = m.get("kv_layout", "contiguous")
    if layout == "paged":
        layout = f"paged/{m.get('page_size', '?')}"
    kv_bytes = m.get("kv_bytes")
    kv_contig = m.get("kv_bytes_contiguous") or 0
    return {
        "arch": s["arch"],
        "weights": "f32" if p["weights"] >= 32 else f"{p['weights']}b packed",
        "kv cache": "bf16" if p["kv_cache"] == 16 else "f32",
        "kv layout": layout,
        "kv KB": "-" if kv_bytes is None else f"{kv_bytes / 1e3:,.1f}",
        "kv vs contig": ("-" if not kv_bytes or not kv_contig
                         else f"{kv_bytes / kv_contig:.2f}"),
        "bytes/step": f"{m['bytes_per_step_packed']:,}",
        "vs f32": _f(m.get("packed_vs_f32"), "{:.3f}"),
        "tokens": str(m.get("decoded_tokens", "-")),
        "done/admitted": f"{m.get('completed')}/{m.get('admitted')}",
    }


def _analyze_col(spec_dict: dict) -> str:
    """Overflow-proof summary recomputed from the SPEC at render time.

    Deterministic host math (no store field, no tracing), so tables
    regenerated from pre-existing stores gain the column without rerunning
    any cell; the weakest accumulator across the cell's bit lattice is
    shown with its headroom.
    """
    from repro.analyze.static_proofs import prove_spec
    from repro.api.spec import RunSpec

    records, findings = prove_spec(RunSpec.from_dict(spec_dict),
                                   rules=("overflow",))
    if findings:
        return "**OVERFLOW**"
    accum = [r for r in records if r["kind"] == "wire_accumulator"]
    if not accum:
        return "exact f32"
    worst = min(accum, key=lambda r: r["headroom_bits"])
    return f"{worst['dtype']} ok +{worst['headroom_bits']}b"


def fl_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    return {
        "scheme": s["options"].get("scheme", "fwq"),
        "rounds": str(m.get("rounds", "-")),
        "final loss": _f(m.get("final_loss"), "{:.4f}"),
        "final acc": _f(m.get("final_acc"), "{:.3f}"),
        "energy (J)": _f(m.get("total_energy_j"), "{:.2f}"),
        "time (s)": _f(m.get("total_time_s"), "{:.1f}"),
        "bits mix": ",".join(str(b) for b in m.get("bits_mix", [])) or "-",
        "analyze": _analyze_col(s),
    }


def train_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    w = m.get("wire", {})
    return {
        "arch": s["arch"],
        "comm bits": str(s["precision"].get("comm", 32)),
        "rounds": str(m.get("rounds", "-")),
        "final loss": _f(m.get("final_loss"), "{:.4f}"),
        "wire dtype": w.get("wire_dtype", "-"),
        "grad wire MB/round": _f(w.get("replicated_bytes_wire", 0) / 1e6,
                                 "{:.2f}"),
        "vs f32 wire": _f(w.get("wire_ratio"), "{:.2f}"),
        "analyze": _analyze_col(s),
    }


def fl_fault_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    faults = s["options"].get("faults") or {}
    level = ("none" if not faults else
             " ".join(f"{k.split('_')[0]}={v:g}"
                      for k, v in sorted(faults.items())))
    return {
        "scheme": s["options"].get("scheme", "fwq"),
        "faults": level,
        "final loss": _f(m.get("final_loss"), "{:.4f}"),
        "energy (J)": _f(m.get("total_energy_j"), "{:.2f}"),
        "retx": str(m.get("retransmissions", 0)),
        "retx (J)": _f(m.get("retx_energy_j"), "{:.3f}"),
        "rejected": str(m.get("rejected_updates", 0)),
        "undelivered": str(m.get("undelivered", 0)),
        "dropped": str(m.get("dropped_midround", 0)),
    }


def fl_adaptive_row(rec: dict) -> dict:
    s, m = rec["spec"], rec["metrics"]
    prog = m.get("program") or {}
    pp = s["options"].get("precision_program")
    kind = (pp.get("kind") if isinstance(pp, dict) else pp) or "static"
    budget = prog.get("budget_j") or (pp.get("budget_j")
                                      if isinstance(pp, dict) else None)
    within = ("yes" if prog.get("within_budget")
              else "NO" if prog.get("within_budget") is False else "-")
    return {
        "program": kind,
        "faults": "severe" if s["options"].get("faults") else "none",
        "final loss": _f(m.get("final_loss"), "{:.4f}"),
        "energy (J)": _f(m.get("total_energy_j"), "{:.2f}"),
        "budget (J)": _f(budget, "{:.0f}") if budget else "-",
        "within": within,
        "demotions": str(prog.get("demotions", 0)),
        "restores": str(prog.get("restores", 0)),
        "bits": "/".join(str(b) for b in m.get("bits_mix", [])),
        "comm bits": "/".join(str(b) for b in m.get("comm_bits_mix", [])),
        "retx (J)": _f(m.get("retx_energy_j"), "{:.2f}"),
    }


_ROW_ADAPTERS = {
    "dryrun": roofline_row,
    "serve": serving_row,
    "fl-sim": fl_row,
    "train": train_row,
    "fl-orchestrate": train_row,
}

#: Sweep-specific overrides: some grids want columns the generic workload
#: adapter doesn't carry (the fault grid's resilience counters).
_SWEEP_ROW_ADAPTERS = {
    "fl-fault-grid": {"fl-sim": fl_fault_row},
    "fl-adaptive-grid": {"fl-sim": fl_adaptive_row},
}


# ---------------------------------------------------------------------------
# Table rendering + marker splicing
# ---------------------------------------------------------------------------


def _md_table(rows: list[dict]) -> str:
    cols = list(rows[0].keys())
    out = ["| " + " | ".join(cols) + " |",
           "| " + " | ".join("-" * max(len(c), 3) for c in cols) + " |"]
    out += ["| " + " | ".join(str(r[c]) for c in cols) + " |" for r in rows]
    return "\n".join(out)


def render_tables(sweep: Sweep, store: ResultsStore) -> str:
    """Markdown for every completed cell, grouped by workload, in cell order.

    Cells not yet in the store (or recorded failing) are summarized in a
    trailing line rather than silently dropped — a partial grid must read
    as partial.
    """
    by_workload: dict[str, list[dict]] = {}
    missing = []
    for cell in sweep.cells():
        rec = store.get(cell.key)
        if rec is None or rec.get("status") != "ok":
            missing.append(f"{cell.label} "
                           f"({'pending' if rec is None else rec['status']})")
            continue
        adapter = (_SWEEP_ROW_ADAPTERS.get(sweep.name, {})
                   .get(cell.spec.workload, _ROW_ADAPTERS[cell.spec.workload]))
        by_workload.setdefault(cell.spec.workload, []).append(adapter(rec))
    parts = [f"*Generated by `repro-sweep run {sweep.name}` — do not edit "
             f"between the markers.*"]
    for wl, rows in by_workload.items():
        if len(by_workload) > 1:
            parts.append(f"**{wl}**")
        parts.append(_md_table(rows))
    if missing:
        parts.append("Incomplete cells: " + "; ".join(missing) + ".")
    return "\n\n".join(parts)


def update_markers(text: str, name: str, body: str) -> str:
    """Replace (or append) the ``sweep:<name>`` marker block in ``text``.

    A half-present marker pair is refused rather than guessed at: splicing
    from a dangling mid-file ``begin`` to an ``end`` appended later would
    silently delete everything in between.
    """
    begin, end = MARK_BEGIN.format(name=name), MARK_END.format(name=name)
    block = f"{begin}\n{body}\n{end}"
    has_begin, has_end = begin in text, end in text
    if has_begin != has_end or (
            has_begin and text.index(end) < text.index(begin)):
        raise ValueError(
            f"unmatched or mis-ordered sweep:{name} markers; restore the "
            f"'{begin}' / '{end}' pair before regenerating")
    if has_begin:
        head = text[: text.index(begin)]
        tail = text[text.index(end) + len(end):]
        return head + block + tail
    if text and not text.endswith("\n"):
        text += "\n"
    return text + f"\n## §Sweep — {name}\n\n{block}\n"


def write_experiments(path: str, sweep: Sweep, store: ResultsStore) -> str:
    """Refresh ``path``'s marker block for ``sweep`` from ``store``."""
    try:
        with open(path) as f:
            text = f.read()
    except FileNotFoundError:
        text = "# EXPERIMENTS\n"
    body = render_tables(sweep, store)
    new = update_markers(text, sweep.name, body)
    with open(path, "w") as f:
        f.write(new)
    return new
