"""Sweep executor: cells -> Session runs -> a resumable JSONL store.

Execution model
---------------
* ``dryrun`` and ``fl-sim`` cells run **in-process** (AOT lowering and the
  vmap simulator are cheap to host and share jax warm-up across cells).
* ``serve`` / ``train`` / ``fl-orchestrate`` cells run in a **subprocess
  with a timeout** (``python -m repro.sweep.runner --one``): the decode
  driver and the pod trainer hold compiled executables and donated buffers
  that should not accumulate across a grid, and a wedged cell must not
  wedge the sweep.

Resumability
------------
Every finished cell is appended to a :class:`ResultsStore` JSONL file keyed
by the cell's content hash (:func:`repro.sweep.grid.cell_key`).  Re-running
a sweep skips every key already recorded with ``status == "ok"`` — an
interrupted grid resumes exactly where it stopped, and a completed grid is
a no-op.  The store is append-only (last record per key wins), so a crash
mid-write loses at most the in-flight cell.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time

from repro.api.spec import RunSpec
from repro.sweep.grid import Sweep

#: Workloads isolated in a subprocess (with timeout) rather than in-process.
SUBPROCESS_WORKLOADS = ("serve", "train", "fl-orchestrate")


def git_sha() -> str:
    """Short commit hash of the working tree, or "unknown" outside git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


# ---------------------------------------------------------------------------
# Per-workload execution + metric extraction
# ---------------------------------------------------------------------------


def execute_cell(spec: RunSpec) -> dict:
    """Run one cell in this process; return its JSON-safe metrics dict."""
    from repro.api.session import Session

    sess = Session(spec)
    wl = spec.workload
    if wl == "dryrun":
        return sess.run_dryrun(verbose=False)
    if wl == "fl-sim":
        out = sess.run()
        evals = out.get("evals") or []
        energy = out.get("energy_log") or []
        return {
            "rounds": len(out["history"]),
            "final_loss": float(out["history"][-1]["loss"]),
            "final_acc": float(evals[-1]["acc"]) if evals else None,
            "total_energy_j": float(out["total_energy_j"]),
            "total_time_s": float(out["total_time_s"]),
            "mean_cohort": (sum(h.get("cohort_size", 0) for h in out["history"])
                            / max(len(out["history"]), 1)),
            "losses": [float(h["loss"]) for h in out["history"]],
            "evals": [{"round": int(e["round"]),
                       **{k: float(v) for k, v in e.items() if k != "round"}}
                      for e in evals],
            "bits_mix": sorted({int(b) for e in energy for b in e["q"]}),
            # resilient-round accounting (0 when no fault plan was active)
            "retransmissions": int(out.get("total_retransmissions", 0)),
            "retx_energy_j": float(out.get("total_retx_energy_j", 0.0)),
            "rejected_updates": int(out.get("total_rejected", 0)),
            "undelivered": int(out.get("total_undelivered", 0)),
            "dropped_midround": int(out.get("total_dropped_midround", 0)),
            # adaptive-precision controller summary (absent for the default
            # constant program) + the wire widths the schedule visited
            "program": out.get("program"),
            "comm_bits_mix": sorted({int(e.get("comm_bits", 32))
                                     for e in energy}),
        }
    if wl == "serve":
        return dataclasses.asdict(sess.serve())
    # train / fl-orchestrate: federated rounds on the pod trainer
    history = sess.run()
    return {
        "rounds": len(history),
        "final_loss": float(history[-1]["loss"]),
        "total_energy_j": float(sum(h["energy_j"] for h in history)),
        "bits_last": history[-1]["bits"],
        "wire": sess.comm_report(),
    }


# ---------------------------------------------------------------------------
# Results store
# ---------------------------------------------------------------------------


class ResultsStore:
    """Append-only JSONL of finished cells, keyed by content hash."""

    def __init__(self, path: str):
        self.path = path
        self._rows: dict[str, dict] = {}
        if os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        row = json.loads(line)
                    except json.JSONDecodeError:
                        continue            # torn tail write: drop the line
                    if "key" in row:
                        self._rows[row["key"]] = row

    @classmethod
    def for_sweep(cls, sweep: Sweep, store_dir: str = "results"):
        os.makedirs(store_dir, exist_ok=True)
        return cls(os.path.join(store_dir, f"sweep_{sweep.name}.jsonl"))

    def has_ok(self, key: str) -> bool:
        return self._rows.get(key, {}).get("status") == "ok"

    def get(self, key: str) -> dict | None:
        return self._rows.get(key)

    def rows(self) -> list[dict]:
        return list(self._rows.values())

    def append(self, row: dict) -> None:
        row = _json_sanitize(row)
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "a") as f:
            # allow_nan=False: the store must stay strict JSON (readable by
            # jq / pandas / non-Python consumers); non-finite floats were
            # already mapped to null above
            f.write(json.dumps(row, allow_nan=False) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._rows[row["key"]] = row


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SweepRunner:
    sweep: Sweep
    store: ResultsStore
    timeout_s: float = 1800.0
    subprocess_workloads: tuple = SUBPROCESS_WORKLOADS
    quiet: bool = False

    def _say(self, msg: str) -> None:
        if not self.quiet:
            print(msg, flush=True)

    def run(self, *, max_cells: int | None = None,
            rerun_failed: bool = True, force: bool = False) -> dict:
        """Execute every cell not already in the store; return a summary.

        ``max_cells`` bounds how many cells EXECUTE this call (skips are
        free) — the hook the resumability test uses to interrupt a grid
        deterministically.  ``rerun_failed=False`` also skips cells whose
        last record is an error/timeout.  ``force=True`` re-executes every
        cell regardless of the store (benchmark mode: the store becomes a
        recording, not a cache).
        """
        cells = self.sweep.cells()
        ran, skipped, failed = [], [], []
        for i, cell in enumerate(cells):
            prior = None if force else self.store.get(cell.key)
            if prior is not None and (prior.get("status") == "ok"
                                      or not rerun_failed):
                skipped.append(cell.key)
                self._say(f"[{self.sweep.name} {i + 1}/{len(cells)}] "
                          f"skip {cell.label} ({cell.key}: "
                          f"{prior.get('status')})")
                continue
            if max_cells is not None and len(ran) + len(failed) >= max_cells:
                self._say(f"[{self.sweep.name}] stopping after "
                          f"{max_cells} executed cells (resume to finish)")
                break
            self._say(f"[{self.sweep.name} {i + 1}/{len(cells)}] "
                      f"run {cell.label} ({cell.key})")
            row = self._run_cell(cell)
            self.store.append(row)
            (ran if row["status"] == "ok" else failed).append(cell.key)
            self._say(f"    -> {row['status']} ({row['wall_s']:.1f}s)")
        return {"sweep": self.sweep.name, "n_cells": len(cells),
                "ran": ran, "skipped": skipped, "failed": failed}

    def _run_cell(self, cell) -> dict:
        t0 = time.time()
        base = {"key": cell.key, "sweep": cell.sweep,
                "spec": cell.spec.to_dict(), "git_sha": git_sha()}
        try:
            if cell.spec.workload in self.subprocess_workloads:
                status, metrics = self._run_subprocess(cell)
            else:
                status, metrics = "ok", execute_cell(cell.spec)
        except Exception as e:                      # noqa: BLE001
            # an in-process cell crash becomes an explicit failed row (with
            # enough traceback to diagnose), never a dead grid: later cells
            # still run, and a resumed sweep can deterministically skip or
            # retry this key (rerun_failed)
            import traceback

            status = "error"
            metrics = {"error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
        if isinstance(metrics, dict) and metrics.get("status") == "FAIL":
            status = "error"
        return {**base, "status": status, "metrics": metrics,
                "wall_s": round(time.time() - t0, 2)}

    def _run_subprocess(self, cell) -> tuple[str, dict]:
        with tempfile.TemporaryDirectory(prefix="repro-sweep-") as td:
            in_path = os.path.join(td, "cell.json")
            out_path = os.path.join(td, "metrics.json")
            with open(in_path, "w") as f:
                json.dump(cell.spec.to_dict(), f)
            env = dict(os.environ)
            # the cell owns its own jax backend: replace any inherited fake
            # device count with exactly what the cell's mesh needs (so a
            # 4x1 train smoke gets 4 fake host devices on CPU)
            flags = _drop_device_count_flag(env.get("XLA_FLAGS", ""))
            need = _mesh_devices(cell.spec.mesh)
            if need > 1:
                flags = (f"{flags} "
                         f"--xla_force_host_platform_device_count={need}")
            env["XLA_FLAGS"] = flags.strip()
            env["PYTHONPATH"] = _src_pythonpath(env.get("PYTHONPATH", ""))
            cmd = [sys.executable, "-m", "repro.sweep.runner",
                   "--one", in_path, "--out", out_path]
            try:
                proc = subprocess.run(cmd, env=env, capture_output=True,
                                      text=True, timeout=self.timeout_s)
            except subprocess.TimeoutExpired as e:
                stderr = e.stderr or b""
                if isinstance(stderr, bytes):
                    stderr = stderr.decode(errors="replace")
                return "timeout", {"timeout_s": self.timeout_s,
                                   "stderr": stderr[-2000:]}
            if proc.returncode != 0 or not os.path.exists(out_path):
                return "error", {"returncode": proc.returncode,
                                 "stderr": proc.stderr[-2000:]}
            with open(out_path) as f:
                return "ok", json.load(f)


def _json_sanitize(x):
    """Strict-JSON form of a result row: non-finite floats become null."""
    if isinstance(x, dict):
        return {k: _json_sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_sanitize(v) for v in x]
    if isinstance(x, float) and (x != x or x in (float("inf"), float("-inf"))):
        return None
    return x


def _drop_device_count_flag(flags: str) -> str:
    return " ".join(t for t in flags.split()
                    if "xla_force_host_platform_device_count" not in t)


def _mesh_devices(mesh_spec: str) -> int:
    from repro.launch.mesh import parse_mesh

    shape, _ = parse_mesh(mesh_spec)
    n = 1
    for s in shape:
        n *= s
    return n


def _src_pythonpath(existing: str) -> str:
    """Ensure the subprocess can import ``repro`` from this checkout."""
    pkg_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    parts = [pkg_root] + [p for p in existing.split(os.pathsep) if p]
    return os.pathsep.join(dict.fromkeys(parts))


def _one_main(argv=None) -> int:
    """``python -m repro.sweep.runner --one cell.json --out metrics.json``."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--one", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args(argv)
    with open(args.one) as f:
        spec = RunSpec.from_dict(json.load(f))
    metrics = execute_cell(spec)
    with open(args.out, "w") as f:
        json.dump(metrics, f)
    return 0


if __name__ == "__main__":
    sys.exit(_one_main())
