"""``repro-sweep`` / ``python -m repro sweep`` — the sweep front door.

Usage::

    repro-sweep list
    repro-sweep run roofline-all-archs                 # resumable grid run
    repro-sweep run ci-tiny --limit 2                  # stop after 2 cells
    repro-sweep report serve-precision-ablation        # refresh tables only

``run`` executes every cell of a named preset that its JSONL store
(``results/sweep_<name>.jsonl``) doesn't already hold, then refreshes the
sweep's marker-delimited table block in EXPERIMENTS.md.  Interrupt it at any
point and re-run: completed cells are skipped by content hash.
"""

from __future__ import annotations

import argparse
import os
import sys


def _force_device_count(n: int) -> None:
    """Pin the fake-device-count XLA flag before jax initializes.

    Must run before any jax backend query; replaces an inherited value (CI
    exports an 8-device flag for the test suite) with the sweep's own.
    """
    from repro.sweep.runner import _drop_device_count_flag

    flags = _drop_device_count_flag(os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}").strip()


def _inproc_device_need(sweep) -> int:
    """Fake host devices the sweep's IN-PROCESS cells need (subprocess
    cells pin their own count; see runner._run_subprocess)."""
    from repro.sweep.runner import SUBPROCESS_WORKLOADS, _mesh_devices

    return max([_mesh_devices(c.spec.mesh) for c in sweep.cells()
                if c.spec.workload not in SUBPROCESS_WORKLOADS] + [1])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-sweep", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list the named sweep presets")
    for c in ("run", "report"):
        p = sub.add_parser(c)
        p.add_argument("preset")
        p.add_argument("--store-dir", default="results")
        p.add_argument("--experiments", default="EXPERIMENTS.md",
                       help="markdown file to refresh ('' disables)")
        if c == "run":
            p.add_argument("--limit", type=int, default=0,
                           help="execute at most N cells this invocation")
            p.add_argument("--timeout", type=float, default=1800.0,
                           help="per-cell subprocess timeout (seconds)")
            p.add_argument("--keep-failed", action="store_true",
                           help="do not re-run error/timeout cells")
            p.add_argument("--force", action="store_true",
                           help="re-run every cell, ignoring the store")
    args = ap.parse_args(argv)

    from repro.sweep.grid import PRESETS, get_preset

    if args.cmd == "list":
        for name in PRESETS:
            sweep = get_preset(name)
            print(f"{name:28s} {len(sweep.cells()):3d} cells "
                  f"({sweep.base.get('workload', 'mixed')})")
        return 0

    sweep = get_preset(args.preset)
    if args.cmd == "run":
        need = _inproc_device_need(sweep)
        if need > 1:
            _force_device_count(need)
    from repro.sweep.report import write_experiments
    from repro.sweep.runner import ResultsStore, SweepRunner

    store = ResultsStore.for_sweep(sweep, args.store_dir)
    if args.cmd == "run":
        runner = SweepRunner(sweep, store, timeout_s=args.timeout)
        summary = runner.run(max_cells=args.limit or None,
                             rerun_failed=not args.keep_failed,
                             force=args.force)
        print(f"\n{sweep.name}: {len(summary['ran'])} ran, "
              f"{len(summary['skipped'])} skipped, "
              f"{len(summary['failed'])} failed "
              f"of {summary['n_cells']} cells")
    if args.experiments:
        write_experiments(args.experiments, sweep, store)
        print(f"refreshed sweep:{sweep.name} tables in {args.experiments}")
    if args.cmd == "run" and summary["failed"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
