"""Stochastic-rounding weight quantization (paper Eq. 1).

The paper quantizes a weight vector ``w`` with per-tensor scale ``s = ||w||_inf``
onto a uniform grid of resolution ``Delta_q = 1 / (2**q - 1)`` using *stochastic
rounding* (SR, unbiased: ``E[Q(w)] = w``).  The quantization noise that the
optimization layer consumes is ``delta_i = s * Delta_{q_i}`` (Lemma 3 /
constraint (23)), and the per-coordinate second moment obeys
``E|Q(w)-w|^2 <= delta^2 / 4`` (De Sa et al., paper ref [6]).

Two concrete realizations are provided:

* **fake quantization** (:func:`sr_quantize`) — values are snapped to the grid
  but kept in floating point.  This is bit-exact w.r.t. Algorithm 1 semantics
  (the gradient is evaluated at ``Q_i(w)``) and supports *traced* per-client
  ``Delta`` so one compiled program serves every heterogeneous bit-width
  assignment the GBD layer produces.
* **packed quantization** (:func:`pack_quantize` / :func:`dequantize`) — signed
  integer codes + scale, the real storage format used on the serving path and
  by the ``quant_matmul`` Pallas kernel.

Design notes
------------
* ``q = 32`` (``FULL_PRECISION_BITS``) means bypass: ``Q(w) = w``; ``delta = 0``.
* SR randomness is supplied through ``jax.random`` keys folded per
  (client, round, tensor) by callers — fully deterministic and restartable.
* Norm-like parameters are exempted via :func:`default_exempt` (see
  DESIGN.md §6): quantizing RMSNorm scales / SSM recurrence params buys ~0
  energy and measurably hurts stability, mirroring the paper's decision to
  keep gradients/accumulators at high precision.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # pytree of arrays

FULL_PRECISION_BITS = 32
#: Bit-widths the paper allows (powers of two, 8..32; 32 = no quantization).
PAPER_BITWIDTHS = (8, 16, 32)
#: Extended set used in some ablations (paper notes >=1 bit is feasible).
EXTENDED_BITWIDTHS = (4, 8, 16, 32)


def delta_from_bits(bits) -> jnp.ndarray:
    """Quantization resolution ``Delta_q = 1/(2**q - 1)``; 0 for full precision.

    Accepts python ints or traced int arrays (per-client vectors).
    """
    bits = jnp.asarray(bits)
    full = bits >= FULL_PRECISION_BITS
    # 2**q - 1 in float to tolerate traced bits; clamp to avoid overflow at 32.
    denom = jnp.exp2(jnp.minimum(bits, 31).astype(jnp.float32)) - 1.0
    return jnp.where(full, 0.0, 1.0 / denom)


def tensor_scale(w: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor scale ``s = ||w||_inf`` (paper Eq. 1)."""
    s = jnp.max(jnp.abs(w))
    # Guard all-zero tensors; scale value is irrelevant then.
    return jnp.where(s > 0, s, 1.0).astype(jnp.float32)


def channel_scale(w: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Per-channel variant of the scale (beyond-paper option, keepdims)."""
    s = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.where(s > 0, s, 1.0).astype(jnp.float32)


def _sr_round(t: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """Unbiased stochastic rounding of ``t`` to integers: E[round(t)] = t."""
    lower = jnp.floor(t)
    frac = t - lower
    u = jax.random.uniform(key, t.shape, dtype=t.dtype)
    return lower + (u < frac).astype(t.dtype)


def sr_quantize(
    w: jnp.ndarray,
    delta: jnp.ndarray | float,
    key: jax.Array,
    *,
    scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Fake-quantize ``w`` on the SR grid with resolution ``delta`` (Eq. 1).

    ``delta`` may be a traced scalar (0 => bypass / full precision).  The
    computation is written so that ``delta == 0`` exactly returns ``w`` without
    a divide-by-zero, allowing a single program to mix quantized and
    full-precision clients.

    Differentiation: Algorithm 1 evaluates the gradient AT ``Q(w)`` and
    applies it to the full-precision ``w`` — i.e. the straight-through
    estimator.  We emit ``w + stop_gradient(Q(w) - w)``: the forward value is
    exactly ``Q(w)``; the cotangent flows to ``w`` unchanged.  (Naively
    differentiating through floor/compare is zero almost everywhere and
    silently freezes training — regression-tested in tests/test_fwq_core.py.)
    """
    w = jnp.asarray(w)
    compute_dtype = w.dtype
    wf = w.astype(jnp.float32)
    s = tensor_scale(wf) if scale is None else scale
    delta = jnp.asarray(delta, dtype=jnp.float32)
    step = s * delta  # grid pitch in real units == paper's delta_i
    safe_step = jnp.where(step > 0, step, 1.0)
    t = wf / safe_step
    q = _sr_round(t, key) * safe_step
    # Values cannot exceed s in magnitude by more than one step; clamp to grid
    # range like any fixed-point representation would.
    q = jnp.clip(q, -s, s)
    out = jnp.where(step > 0, q, wf)
    out = wf + jax.lax.stop_gradient(out - wf)   # straight-through (Alg. 1)
    return out.astype(compute_dtype)


def nearest_quantize(w: jnp.ndarray, delta: jnp.ndarray | float) -> jnp.ndarray:
    """Deterministic round-to-nearest on the same grid (biased; for ablations).

    Straight-through gradient, like :func:`sr_quantize`."""
    w = jnp.asarray(w)
    wf = w.astype(jnp.float32)
    s = tensor_scale(wf)
    step = jnp.asarray(delta, jnp.float32) * s
    safe_step = jnp.where(step > 0, step, 1.0)
    q = jnp.clip(jnp.round(wf / safe_step) * safe_step, -s, s)
    out = jnp.where(step > 0, q, wf)
    out = wf + jax.lax.stop_gradient(out - wf)
    return out.astype(w.dtype)


# ---------------------------------------------------------------------------
# Packed (real) quantization — serving path / quant_matmul kernel format.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedTensor:
    """Integer codes + scale.  ``w ~= codes * (scale * delta)``."""

    codes: jnp.ndarray  # int8 (bits<=7) or int16 (bits<=15)
    scale: jnp.ndarray  # f32 scalar or per-channel row
    bits: int

    @property
    def delta(self) -> float:
        return 1.0 / (2.0**self.bits - 1.0)

    def nbytes(self) -> int:
        return self.codes.size * self.codes.dtype.itemsize + self.scale.size * 4


def storage_dtype(bits: int):
    """Smallest signed integer dtype that holds codes in [-(2^b -1), 2^b -1]."""
    if bits <= 7:
        return jnp.int8
    if bits <= 15:
        return jnp.int16
    return jnp.int32


def pack_quantize(
    w: jnp.ndarray,
    bits: int,
    key: jax.Array,
    *,
    per_channel: bool = False,
    axis: int = -1,
) -> PackedTensor:
    """Really quantize: SR onto integer codes with ``2**bits - 1`` resolution."""
    if bits >= FULL_PRECISION_BITS:
        raise ValueError("pack_quantize is for bits < 32; use the raw tensor.")
    wf = jnp.asarray(w, jnp.float32)
    s = channel_scale(wf, axis) if per_channel else tensor_scale(wf)
    delta = 1.0 / (2.0**bits - 1.0)
    t = wf / (s * delta)
    lim = 2**bits - 1
    codes = jnp.clip(_sr_round(t, key), -lim, lim).astype(storage_dtype(bits))
    return PackedTensor(codes=codes, scale=s, bits=bits)


def dequantize(p: PackedTensor, dtype=jnp.float32) -> jnp.ndarray:
    return (p.codes.astype(jnp.float32) * (p.scale * p.delta)).astype(dtype)


# ---------------------------------------------------------------------------
# Pytree application with exemptions.
# ---------------------------------------------------------------------------

ExemptFn = Callable[[str, jnp.ndarray], bool]

#: Substrings of parameter path names never quantized (see DESIGN.md §6).
DEFAULT_EXEMPT_SUBSTRINGS = (
    "norm",        # RMSNorm / LayerNorm scales
    "/ln",         # block layer-norm scales (stacked: ndim 2)
    "ln_",
    "a_log",       # Mamba2 recurrence
    "dt_bias",
    "d_skip",
    "conv_",       # depthwise conv kernels (tiny, recurrence-adjacent)
    "router",      # MoE routing tables
    "bias",
)
# NOTE: vlm cross-attn gates are (L,)-scalars — exempted by the ndim<=1 rule.
# "w_gate" MLP projections are real weights and MUST stay quantizable.


def default_exempt(path: str, value: jnp.ndarray) -> bool:
    low = path.lower()
    if value.ndim <= 1:  # vectors (biases, norm scales) — negligible size
        return True
    return any(sub in low for sub in DEFAULT_EXEMPT_SUBSTRINGS)


def _flatten_with_paths(tree: Params):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def quantize_tree(
    params: Params,
    delta: jnp.ndarray | float,
    key: jax.Array,
    *,
    exempt: ExemptFn | None = default_exempt,
) -> Params:
    """Fake-quantize every non-exempt leaf with per-leaf folded SR keys.

    ``delta`` is the (possibly traced, possibly per-client-scalar) resolution.
    """
    paths, leaves, treedef = _flatten_with_paths(params)
    out = []
    for idx, (path, leaf) in enumerate(zip(paths, leaves)):
        if exempt is not None and exempt(path, leaf):
            out.append(leaf)
        else:
            out.append(sr_quantize(leaf, delta, jax.random.fold_in(key, idx)))
    return jax.tree_util.tree_unflatten(treedef, out)


def quantizable_size(params: Params, exempt: ExemptFn | None = default_exempt) -> tuple[int, int]:
    """(quantizable_elements, total_elements) under the exemption policy."""
    paths, leaves, _ = _flatten_with_paths(params)
    total = sum(int(l.size) for l in leaves)
    quant = sum(
        int(l.size)
        for p, l in zip(paths, leaves)
        if not (exempt is not None and exempt(p, l))
    )
    return quant, total


def expected_quant_mse(w: jnp.ndarray, bits: int) -> float:
    """Upper bound ``(d/4) * delta^2`` from Lemma 3 (per-tensor, real units)."""
    wf = jnp.asarray(w, jnp.float32)
    s = float(tensor_scale(wf))
    delta = float(delta_from_bits(bits))
    return wf.size / 4.0 * (s * delta) ** 2
