"""GBD primal problem: optimal bandwidth allocation for fixed bit-widths.

For a fixed integer assignment ``q`` the remaining problem (paper Eq. 32-34)

    v(q) = min_{B, T}  sum_r sum_i  alpha1_{i,r} / B_{i,r}   (+ const comp energy)
           s.t.  sum_i B_{i,r} <= B_max                      for every round r
                 alpha2_{i,r} / B_{i,r} <= T_r - a_i(q)      for every i, r
                 sum_r T_r <= T_max,   B > 0

with ``a_i(q) = beta1_i + beta2_i q_i`` (compute time) is convex.  We solve it
by a three-level dual decomposition, each level a monotone bisection,
vectorized across rounds:

  * inner  (omega1_r):  per-round water-filling
        B_{i,r}(w1) = max(Bmin_{i,r}, sqrt(alpha1_{i,r}/w1)),
        Bmin_{i,r} = alpha2_{i,r}/(t_r - a_i); bisect w1 so sum_i B = B_max.
        (The objective strictly decreases in B so (24) is always active.)
  * middle (t_r): round latency; by the envelope theorem
        dE_r/dt = -sum_i omega2_{i,r}   with
        omega2_{i,r} = max(0, w1_r B^2 - alpha1)/alpha2  (KKT stationarity),
        bisect t_r so that sum_i omega2_{i,r}(t_r) = omega3.
  * outer  (omega3): bisect so sum_r t_r = T_max (Eq. 27 is always active
        because energy strictly decreases in every t_r).

Feasibility of q: the minimum achievable round time t_r^min solves
``sum_i alpha2_{i,r}/(t - a_i) = B_max``; the instance is feasible iff
``sum_r t_r^min <= T_max``.  ``t^min`` is the partial minimization of ``t``
over the convex set {(t,a): sum_i alpha2_i/(t-a_i) <= B_max}, hence convex in
``a`` (and in q, which enters affinely); its supporting hyperplane is the
feasibility cut returned to the Benders master (the specialization of
Geoffrion's L2 cut, Eq. 41-42).

All math is numpy (host-side); the trainer is never blocked on this.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_BISECT_ITERS = 60


@dataclasses.dataclass(frozen=True)
class PrimalData:
    """Per-instance coefficients.  Shapes: (R, N) unless noted."""

    alpha1: np.ndarray      # J * Hz   (comm energy numerator, Eq. 30)
    alpha2: np.ndarray      # s * Hz   (comm time numerator)
    beta1: np.ndarray       # (N,) s   compute-time intercept
    beta2: np.ndarray       # (N,) s/bit
    p_comp: np.ndarray      # (N,) W   GPU runtime power (Eq. 16)
    b_max: float            # Hz
    t_max: float            # s  total training deadline

    @property
    def n_rounds(self) -> int:
        return self.alpha1.shape[0]

    @property
    def n_devices(self) -> int:
        return self.alpha1.shape[1]

    def comp_times(self, q: np.ndarray) -> np.ndarray:
        """a_i(q) = beta1 + beta2 q  (N,)."""
        return self.beta1 + self.beta2 * np.asarray(q, np.float64)

    def comp_energy(self, q: np.ndarray) -> float:
        """Total compute energy over the horizon (constant w.r.t. B)."""
        return float(self.n_rounds * np.sum(self.p_comp * self.comp_times(q)))


@dataclasses.dataclass
class PrimalSolution:
    feasible: bool
    value: float                 # v(q): total energy (comm + comp), J
    comm_energy: float
    comp_energy: float
    bandwidth: np.ndarray | None  # (R, N) Hz
    t_rounds: np.ndarray | None   # (R,) s
    omega1: np.ndarray | None     # (R,)
    omega2: np.ndarray | None     # (R, N)
    omega3: float
    # Feasibility-cut data (valid when feasible=False):
    tmin_total: float = np.inf
    tmin_grad_q: np.ndarray | None = None  # (N,) d(sum_r t_r^min)/d q_i


def _waterfill(alpha1_r, bmin_r, b_max):
    """Per-round bandwidth water-filling, vectorized over rounds.

    alpha1_r, bmin_r: (R, N).  Returns (B, omega1): (R,N), (R,).
    Assumes sum_i bmin < b_max (feasible)."""
    # Numerical safety: if sum bmin marginally exceeds b_max (bisection
    # tolerance at t ~= t_min), scale bmin down to fit — the latency slack
    # this introduces is O(bisection tolerance).
    over = bmin_r.sum(axis=1) / b_max
    bmin_r = np.where(over[:, None] > 1.0, bmin_r / over[:, None] * (1 - 1e-12), bmin_r)
    # omega1 bounds: B(w1)=max(bmin, sqrt(a1/w1)); sum B decreasing in w1.
    hi = np.max(alpha1_r / np.maximum(bmin_r, 1e-30) ** 2, axis=1)  # all at bmin
    lo = np.full_like(hi, 1e-30)
    for _ in range(_BISECT_ITERS):
        mid = np.sqrt(lo * hi)  # log-space bisection
        B = np.maximum(bmin_r, np.sqrt(alpha1_r / mid[:, None]))
        too_big = B.sum(axis=1) > b_max
        lo = np.where(too_big, mid, lo)
        hi = np.where(too_big, hi, mid)
    omega1 = np.sqrt(lo * hi)
    B = np.maximum(bmin_r, np.sqrt(alpha1_r / omega1[:, None]))
    # Renormalize tiny slack onto unconstrained devices for exactness.
    free = B > bmin_r * (1 + 1e-9)
    slack = b_max - B.sum(axis=1)
    nfree = np.maximum(free.sum(axis=1), 1)
    B = B + free * (slack / nfree)[:, None]
    B = np.maximum(B, bmin_r)
    return B, omega1


def _round_tmin(alpha2, a, b_max):
    """t_r^min: root of sum_i alpha2_i/(t - a_i) = b_max, vectorized (R,N)->(R,)."""
    lo = np.max(a) + 1e-12 + np.zeros(alpha2.shape[0])
    hi = np.max(a) + np.sum(alpha2, axis=1) / b_max + 1e-9  # generous upper bound
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        need = np.sum(alpha2 / (mid[:, None] - a[None, :]), axis=1)
        lo = np.where(need > b_max, mid, lo)
        hi = np.where(need > b_max, hi, mid)
    return 0.5 * (lo + hi)


def _tmin_gradient(alpha2, a, tmin, beta2):
    """d(t_r^min)/dq_i summed over rounds — supporting hyperplane coefficients.

    Implicit differentiation of sum_i alpha2_i/(t - a_i) = B_max:
      dt/da_i = [alpha2_i/(t-a_i)^2] / sum_j [alpha2_j/(t-a_j)^2];  da_i/dq_i = beta2_i.
    """
    gap = tmin[:, None] - a[None, :]
    wgt = alpha2 / np.maximum(gap, 1e-30) ** 2
    dt_da = wgt / wgt.sum(axis=1, keepdims=True)
    return (dt_da * beta2[None, :]).sum(axis=0)


def _omega2(alpha1, alpha2, B, omega1):
    """KKT: omega2 = max(0, omega1 B^2 - alpha1)/alpha2 (binding devices)."""
    return np.maximum(0.0, omega1[:, None] * B**2 - alpha1) / alpha2


def solve_primal(data: PrimalData, q: np.ndarray) -> PrimalSolution:
    """Solve Eq. (32)-(34) for fixed q.  Returns solution + Benders data."""
    q = np.asarray(q, np.float64)
    a = data.comp_times(q)                    # (N,)
    comp_e = data.comp_energy(q)
    R = data.n_rounds

    tmin = _round_tmin(data.alpha2, a, data.b_max)        # (R,)
    tmin_total = float(tmin.sum())
    if tmin_total > data.t_max:
        return PrimalSolution(
            feasible=False, value=np.inf, comm_energy=np.inf, comp_energy=comp_e,
            bandwidth=None, t_rounds=None, omega1=None, omega2=None, omega3=0.0,
            tmin_total=tmin_total,
            tmin_grad_q=_tmin_gradient(data.alpha2, a, tmin, data.beta2),
        )

    def solve_rounds_at(omega3: float):
        """For multiplier omega3, find t_r with sum_i omega2(t_r) = omega3."""
        lo = tmin * (1 + 1e-9)
        # upper bound: with t huge, omega2 -> 0.
        hi = tmin + data.t_max  # generous
        for _ in range(_BISECT_ITERS):
            mid = 0.5 * (lo + hi)
            bmin = data.alpha2 / np.maximum(mid[:, None] - a[None, :], 1e-30)
            B, w1 = _waterfill(data.alpha1, bmin, data.b_max)
            w2sum = _omega2(data.alpha1, data.alpha2, B, w1).sum(axis=1)
            # sum omega2 decreases in t; want it == omega3.
            lo = np.where(w2sum > omega3, mid, lo)
            hi = np.where(w2sum > omega3, hi, mid)
        t = 0.5 * (lo + hi)
        bmin = data.alpha2 / np.maximum(t[:, None] - a[None, :], 1e-30)
        B, w1 = _waterfill(data.alpha1, bmin, data.b_max)
        return t, B, w1

    # Outer bisection on omega3 >= 0 so that sum_r t_r = T_max.
    w3_lo, w3_hi = 0.0, 1.0
    for _ in range(80):  # grow hi until sum t <= T_max
        t, _, _ = solve_rounds_at(w3_hi)
        if t.sum() <= data.t_max:
            break
        w3_hi *= 8.0
    for _ in range(_BISECT_ITERS):
        w3_mid = 0.5 * (w3_lo + w3_hi)
        t, _, _ = solve_rounds_at(w3_mid)
        if t.sum() > data.t_max:
            w3_lo = w3_mid
        else:
            w3_hi = w3_mid
    # Use the feasible side (sum t <= T_max) and hand the residual slack out
    # additively: growing any t_r preserves feasibility (t_r stays >= t_r^min)
    # and can only reduce energy.  Multiplicative rescaling is NOT safe — it
    # can push a near-minimum round below t^min and blow the band budget.
    omega3 = w3_hi
    t, B, w1 = solve_rounds_at(omega3)
    t = t + (data.t_max - t.sum()) / R
    bmin = data.alpha2 / np.maximum(t[:, None] - a[None, :], 1e-30)
    B, w1 = _waterfill(data.alpha1, bmin, data.b_max)
    w2 = _omega2(data.alpha1, data.alpha2, B, w1)

    comm_e = float(np.sum(data.alpha1 / B))
    return PrimalSolution(
        feasible=True, value=comm_e + comp_e, comm_energy=comm_e,
        comp_energy=comp_e, bandwidth=B, t_rounds=t, omega1=w1, omega2=w2,
        omega3=omega3, tmin_total=tmin_total,
        tmin_grad_q=_tmin_gradient(data.alpha2, a, tmin, data.beta2),
    )


def optimality_cut(data: PrimalData, q_bar: np.ndarray, sol: PrimalSolution):
    """phi >= c0 + g . q   from the Lagrangian (Eq. 35, linear in q).

    L1(q) = v(q_bar) + sum_i beta2_i (R p_i - sum_r omega2_{i,r}) (q_i - q_bar_i)
    """
    q_bar = np.asarray(q_bar, np.float64)
    grad = data.beta2 * (data.n_rounds * data.p_comp - sol.omega2.sum(axis=0))
    c0 = sol.value - float(grad @ q_bar)
    return c0, grad


def feasibility_cut(data: PrimalData, q_bar: np.ndarray, sol: PrimalSolution):
    """sum_r t_r^min(q) <= T_max linearized at q_bar:  g . q <= rhs."""
    q_bar = np.asarray(q_bar, np.float64)
    g = sol.tmin_grad_q
    rhs = data.t_max - sol.tmin_total + float(g @ q_bar)
    return g, rhs


def solve_primal_slsqp(data: PrimalData, q: np.ndarray, x0: np.ndarray | None = None) -> float:
    """Cross-check of v(q) via scipy SLSQP (tests only; slow).

    SLSQP on this problem is sensitive to initialization; pass ``x0``
    (e.g. the fast solver's solution) to use it as a *polish* step.
    """
    from scipy.optimize import minimize

    R, N = data.alpha1.shape
    a = data.comp_times(q)
    tmin = _round_tmin(data.alpha2, a, data.b_max)
    if tmin.sum() > data.t_max:
        return np.inf
    if x0 is None:
        t0 = tmin + (data.t_max - tmin.sum()) / R
        b0 = np.maximum(data.alpha2 / (t0[:, None] - a[None, :]), data.b_max / (2 * N))
        b0 *= 0.98 * data.b_max / b0.sum(axis=1, keepdims=True)
        x0 = np.concatenate([b0.ravel(), t0])

    def unpack(x):
        return x[: R * N].reshape(R, N), x[R * N :]

    def obj(x):
        B, _ = unpack(x)
        return np.sum(data.alpha1 / B)

    cons = [
        {"type": "ineq", "fun": lambda x: data.b_max - unpack(x)[0].sum(axis=1)},
        {"type": "ineq",
         "fun": lambda x: (unpack(x)[1][:, None] - a[None, :]
                           - data.alpha2 / unpack(x)[0]).ravel()},
        {"type": "ineq", "fun": lambda x: data.t_max - unpack(x)[1].sum()},
    ]
    bounds = [(1e-3, None)] * (R * N) + [(1e-9, None)] * R
    res = minimize(obj, x0, method="SLSQP", bounds=bounds, constraints=cons,
                   options={"maxiter": 400, "ftol": 1e-12})
    return float(res.fun) + data.comp_energy(q)
