"""Paper core: FWQ quantization, convergence theory, energy models, GBD co-design."""

from repro.core.quantization import (  # noqa: F401
    PAPER_BITWIDTHS,
    EXTENDED_BITWIDTHS,
    FULL_PRECISION_BITS,
    delta_from_bits,
    sr_quantize,
    nearest_quantize,
    pack_quantize,
    dequantize,
    quantize_tree,
    default_exempt,
)
from repro.core.fwq import (  # noqa: F401
    FWQConfig,
    FWQMetrics,
    make_fwq_round,
    make_tree_quant_loss,
    make_inline_quantizer,
    delta_for_clients,
    identity_transform,
)
from repro.core.convergence import (  # noqa: F401
    ProblemConstants,
    corollary1_bound,
    corollary1_lr,
    corollary2_rounds,
    error_budget_bound,
    quant_noise,
    quantization_error_floor,
)
from repro.core.energy import (  # noqa: F401
    CommParams,
    DeviceProfile,
    alpha_coefficients,
    comm_energy_j,
    heterogeneous_fleet,
    memory_capacities,
    round_energy,
)
from repro.core.channel import ChannelModel  # noqa: F401
from repro.core.primal import PrimalData, PrimalSolution, solve_primal  # noqa: F401
from repro.core.master import MasterSpec, Cut, solve_master  # noqa: F401
from repro.core.gbd import GBDResult, run_gbd  # noqa: F401
from repro.core import baselines  # noqa: F401
