"""Energy models for computation (Eq. 16–18) and communication (Eq. 19–21).

Everything in this module is host-side simulation math (numpy): it models the
*mobile fleet* the co-design layer optimizes over, not the TPU pod that runs
the learning simulation (see DESIGN.md §2).

Computation (paper §4.1.1, mobile-GPU DVFS model):
    p_i^comp = p0 + zeta_mem * f_mem + zeta_core * V_core^2 * f_core      (16)
    T_i^comp(q) = t0 + c1(q) theta_mem / f_mem + c2(q) theta_core / f_core (17)
    E_i^comp(q) = p_i^comp * T_i^comp(q)                                   (18)
with c1, c2 linear in the bit-width q, so T^comp(q) = beta1 + beta2 * q
(the paper's simplification in §4.3).

Communication (paper §4.1.2, OFDMA uplink):
    gamma_i,r = B_i,r * ln(1 + h_i,r p_i^comm / sigma^2)                   (19)
    T_i^comm  = D_g / gamma_i,r                                            (20)
    E_i^comm  = p_i^comm * T_i^comm                                        (21)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-device hardware parameters (Eq. 16/17 coefficients).

    Frequencies in Hz, voltages in V, powers in W, cycle counts per mini-batch.
    """

    name: str = "generic-mobile-gpu"
    p_g0: float = 1.0            # static power (W)
    zeta_mem: float = 1.2e-9     # W per Hz of memory clock
    zeta_core: float = 1.6e-9    # W per (V^2 * Hz) of core clock
    v_core: float = 0.9          # core voltage (V)
    f_core: float = 1.4e9        # core frequency (Hz)
    f_mem: float = 2.0e9         # memory frequency (Hz)
    t0: float = 1e-3             # task-independent latency (s)
    theta_mem: float = 4.0e8     # memory cycles per mini-batch (32-bit ref)
    theta_core: float = 1.3e9    # core cycles per mini-batch (32-bit ref)
    c1_slope: float = 1.0 / 32.0  # c1(q) = c1_slope * q  (linear, c1(32)=1)
    c2_slope: float = 1.0 / 32.0  # c2(q) = c2_slope * q
    p_comm: float = 0.1          # transmit power (W); paper: 2..20 dBm

    def runtime_power(self) -> float:
        """Eq. (16)."""
        return (
            self.p_g0
            + self.zeta_mem * self.f_mem
            + self.zeta_core * self.v_core**2 * self.f_core
        )

    def exec_time(self, bits: np.ndarray | float) -> np.ndarray:
        """Eq. (17) with linear c1/c2 — returns seconds."""
        q = np.asarray(bits, dtype=np.float64)
        return (
            self.t0
            + self.c1_slope * q * self.theta_mem / self.f_mem
            + self.c2_slope * q * self.theta_core / self.f_core
        )

    # --- affine form used by the optimizer (paper §4.3) ------------------
    @property
    def beta1(self) -> float:
        """T^comp(q) = beta1 + beta2*q : intercept."""
        return self.t0

    @property
    def beta2(self) -> float:
        """T^comp(q) = beta1 + beta2*q : slope (s per bit)."""
        return (
            self.c1_slope * self.theta_mem / self.f_mem
            + self.c2_slope * self.theta_core / self.f_core
        )

    def comp_energy(self, bits: np.ndarray | float) -> np.ndarray:
        """Eq. (18)."""
        return self.runtime_power() * self.exec_time(bits)


def heterogeneous_fleet(
    n: int,
    *,
    seed: int = 0,
    min_core_mhz: float = 1400.0,
    group_step_mhz: float = 0.0,
    n_groups: int = 4,
    p_comm_dbm_range: tuple[float, float] = (2.0, 20.0),
    mem_capacity_mb_range: tuple[float, float] = (64.0, 2048.0),
) -> list[DeviceProfile]:
    """Build N heterogeneous device profiles (paper §5 setting).

    ``group_step_mhz`` reproduces the Fig. 4 heterogeneity knob: devices are
    split into ``n_groups`` groups with core clocks
    ``C, C+5L, C+15L, C+20L`` MHz where ``L = group_step_mhz``.
    """
    rng = np.random.default_rng(seed)
    offsets_units = np.array([0.0, 5.0, 15.0, 20.0])[:n_groups]
    fleet = []
    for i in range(n):
        g = i % n_groups
        f_core = (min_core_mhz + offsets_units[g] * group_step_mhz) * 1e6
        p_dbm = rng.uniform(*p_comm_dbm_range)
        fleet.append(
            dataclasses.replace(
                DeviceProfile(name=f"dev{i}-g{g}"),
                f_core=f_core,
                f_mem=rng.uniform(1.6e9, 2.4e9),
                theta_mem=rng.uniform(0.8, 1.2) * 4.0e8,
                theta_core=rng.uniform(0.8, 1.2) * 1.3e9,
                p_comm=10 ** (p_dbm / 10.0) / 1000.0,  # dBm -> W
            )
        )
    return fleet


def memory_capacities(n: int, *, seed: int = 1, lo_mb: float = 64.0, hi_mb: float = 2048.0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(lo_mb, hi_mb, size=n)


# ---------------------------------------------------------------------------
# Communication (Eq. 19-21)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommParams:
    """OFDMA uplink parameters shared across devices."""

    noise_dbm_per_hz: float = -174.0  # N0 (paper §5)
    b_max_hz: float = 20e6            # total bandwidth (Fig. 5: 20..38 MHz)
    grad_bytes: float = 0.0           # D_g: gradient payload (set per model)

    def noise_power(self, bandwidth_hz: np.ndarray | float) -> np.ndarray:
        """sigma^2 = N0 * B (thermal noise over the allocated band)."""
        n0_w_per_hz = 10 ** (self.noise_dbm_per_hz / 10.0) / 1000.0
        return n0_w_per_hz * np.asarray(bandwidth_hz, dtype=np.float64)


def rate_bps(bandwidth_hz, gain, p_comm_w, comm: CommParams) -> np.ndarray:
    """Achievable rate, Eq. (19): gamma = B ln(1 + h p / sigma^2) (nats/s)."""
    b = np.asarray(bandwidth_hz, dtype=np.float64)
    snr = np.asarray(gain) * np.asarray(p_comm_w) / comm.noise_power(b)
    return b * np.log1p(snr)


def comm_time_s(bandwidth_hz, gain, p_comm_w, comm: CommParams) -> np.ndarray:
    """Eq. (20): T = D_g / gamma, with D_g in bits."""
    return 8.0 * comm.grad_bytes / rate_bps(bandwidth_hz, gain, p_comm_w, comm)


def comm_energy_j(bandwidth_hz, gain, p_comm_w, comm: CommParams) -> np.ndarray:
    """Eq. (21): E = p_comm * T."""
    return np.asarray(p_comm_w) * comm_time_s(bandwidth_hz, gain, p_comm_w, comm)


def reference_rate_bps(bandwidth_hz, gain, p_comm_w, comm: CommParams) -> np.ndarray:
    """Rate under the alpha-reformulation convention (sigma^2 at B_max).

    One lossless pass over ``D_g`` at this rate costs exactly
    ``T = alpha2/B`` and ``E = alpha1/B`` — the optimizer's plan.  The
    retransmission executor (:mod:`repro.faults.executor`) bills every
    transmission attempt at this rate, so a fault-free run reproduces the
    planned comm energy to the bit and every retry shows up as a measured
    surcharge on top of it.
    """
    sigma2 = comm.noise_power(comm.b_max_hz)
    b = np.asarray(bandwidth_hz, dtype=np.float64)
    return b * np.log1p(np.asarray(gain) * np.asarray(p_comm_w) / sigma2)


def alpha_coefficients(
    gains: np.ndarray, p_comm_w: np.ndarray, comm: CommParams
) -> tuple[np.ndarray, np.ndarray]:
    """The paper's alpha^1_{i,r}, alpha^2_{i,r} (reformulation (30)).

    With sigma^2 = N0*B the SNR depends on B, which would break the paper's
    1/B separable form; following the paper (and standard practice in this
    literature) sigma^2 is evaluated at the *reference* full band B_max so
    that  E^comm = alpha1 / B  and  T^comm = alpha2 / B  exactly.

    Returns (alpha1, alpha2): alpha1 in J*Hz, alpha2 in s*Hz.
    """
    sigma2 = comm.noise_power(comm.b_max_hz)
    log_term = np.log1p(np.asarray(gains) * np.asarray(p_comm_w) / sigma2)
    d_bits = 8.0 * comm.grad_bytes
    alpha2 = d_bits / log_term
    alpha1 = np.asarray(p_comm_w) * alpha2
    return alpha1, alpha2


def round_energy(
    bits: np.ndarray,
    bandwidth_hz: np.ndarray,
    fleet: Sequence[DeviceProfile],
    gains: np.ndarray,
    comm: CommParams,
) -> dict:
    """Total per-round energy/latency breakdown for a cohort (Eq. 22/26)."""
    bits = np.asarray(bits, np.float64)
    p_comm = np.array([d.p_comm for d in fleet])
    alpha1, alpha2 = alpha_coefficients(gains, p_comm, comm)
    e_comp = np.array([d.comp_energy(b) for d, b in zip(fleet, bits)])
    t_comp = np.array([d.exec_time(b) for d, b in zip(fleet, bits)])
    e_comm = alpha1 / bandwidth_hz
    t_comm = alpha2 / bandwidth_hz
    return {
        "e_comp": e_comp,
        "e_comm": e_comm,
        "t_comp": t_comp,
        "t_comm": t_comm,
        "energy_total": float(np.sum(e_comp + e_comm)),
        "t_round": float(np.max(t_comp + t_comm)),  # Eq. (26)
    }


def model_bytes_full_precision(n_params: int) -> float:
    """U_i: model size at 32-bit full precision, in bytes."""
    return 4.0 * n_params


def c3(bits: np.ndarray | float) -> np.ndarray:
    """Constraint (25) ratio of bit-width to full precision: c3(q) = q/32."""
    return np.asarray(bits, np.float64) / 32.0
