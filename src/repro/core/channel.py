"""Wireless channel simulation for the 5G uplink (paper §2.2 / §4.1.2).

Block Rayleigh fading: the channel gain ``h_{i,r}`` of device ``i`` is redrawn
every global round ``r`` (the paper assumes gains are estimated in advance of
each round; estimation itself is out of scope there and here).

Gains combine a distance-dependent path loss with an exponential (Rayleigh
power) fast-fading term.  Devices can be organized in gain groups
``g1 <= g2 <= g3 <= g4`` to reproduce Fig. 5.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Path loss + Rayleigh block fading."""

    n_devices: int
    seed: int = 0
    cell_radius_m: float = 120.0
    min_dist_m: float = 10.0
    path_loss_exp: float = 3.76          # urban macro
    ref_loss_db: float = 35.3            # loss at 1 m
    shadowing_std_db: float = 8.0
    n_groups: int = 4                    # Fig. 5 gain groups

    def _rng(self, salt: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, salt))

    def distances(self) -> np.ndarray:
        """Static device placement: group g sits in ring g (g1 farthest)."""
        rng = self._rng(0)
        groups = np.arange(self.n_devices) % self.n_groups
        # group 0 -> outer ring (worst gain) ... group n-1 -> inner ring
        ring_hi = self.cell_radius_m * (1.0 - groups / self.n_groups)
        ring_lo = np.maximum(self.min_dist_m, ring_hi - self.cell_radius_m / self.n_groups)
        return rng.uniform(ring_lo, ring_hi)

    def path_gain(self) -> np.ndarray:
        """Linear average power gain per device (path loss + lognormal shadow)."""
        rng = self._rng(1)
        d = self.distances()
        loss_db = self.ref_loss_db + 10.0 * self.path_loss_exp * np.log10(d)
        loss_db = loss_db + rng.normal(0.0, self.shadowing_std_db, self.n_devices)
        return 10 ** (-loss_db / 10.0)

    def gains(self, round_idx: int) -> np.ndarray:
        """h_{i,r}: per-round realization (Rayleigh power fading ~ Exp(1))."""
        rng = self._rng(1000 + round_idx)
        fading = rng.exponential(1.0, self.n_devices)
        return self.path_gain() * fading

    def gain_matrix(self, n_rounds: int) -> np.ndarray:
        """(n_rounds, n_devices) gain table for the optimizer horizon."""
        return np.stack([self.gains(r) for r in range(n_rounds)])

    def group_of(self) -> np.ndarray:
        return np.arange(self.n_devices) % self.n_groups


def gain_drift_db(ref_gains: np.ndarray, gains: np.ndarray) -> float:
    """Mean absolute per-device gain drift between two realizations, in dB.

    The orchestrator compares the gains its current strategy was solved
    against with this round's *measured* (possibly fault-faded) gains; a
    drift past ``resolve_drift_db`` triggers a warm-started GBD re-solve.
    """
    ref = np.maximum(np.asarray(ref_gains, dtype=np.float64), 1e-300)
    cur = np.maximum(np.asarray(gains, dtype=np.float64), 1e-300)
    return float(np.mean(np.abs(10.0 * np.log10(cur / ref))))
