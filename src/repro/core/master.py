"""GBD master problem (paper Eq. 43-46): integer bit-width selection.

Bit-widths are one-hot encoded: ``x[i, b] = 1`` iff device ``i`` uses
``bits_options[b]``.  Everything the master sees is then *linear* in ``x``:

    q_i          = sum_b  bits_b          x[i,b]
    delta_i^2    = sum_b  (s/(2^b - 1))^2 x[i,b]
    memory (25)  : x[i,b] = 0 whenever c3(b) * U_i > C_i   (variable fixing)
    error  (23)  : sum_i delta_i^2 <= budget
    optimality cuts (44):  phi >= c0_k + g_k . q
    feasibility cuts (45): g_k . q <= rhs_k

Solved exactly with scipy's HiGHS MILP; a marginal-cost greedy provides both a
warm start and a fallback if the solver is unavailable.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.convergence import quant_noise


@dataclasses.dataclass
class MasterSpec:
    bits_options: tuple[int, ...]        # e.g. (8, 16, 32)
    n_devices: int
    error_budget: float                  # sum_i delta_i^2 <= budget  (Eq. 23)
    mem_capacity_bytes: np.ndarray       # (N,) C_i
    model_bytes_fp: float                # U_i (same model for all devices)
    weight_scale: float = 1.0            # s in delta_i = s/(2^q - 1)

    def allowed(self) -> np.ndarray:
        """(N, B) bool mask of memory-feasible options (constraint 25)."""
        bits = np.asarray(self.bits_options, np.float64)
        need = bits / 32.0 * self.model_bytes_fp           # c3(q) * U_i
        return need[None, :] <= self.mem_capacity_bytes[:, None] + 1e-9

    def delta_sq(self) -> np.ndarray:
        """(B,) quantization-noise squares per option."""
        return quant_noise(self.bits_options, self.weight_scale) ** 2


@dataclasses.dataclass
class Cut:
    kind: str              # "opt" | "feas"
    c0: float              # opt: phi >= c0 + g.q    feas: g.q <= c0
    grad: np.ndarray       # (N,)


@dataclasses.dataclass
class MasterSolution:
    status: str
    q: np.ndarray | None
    phi: float             # lower bound (valid when status == "ok")


def _validate(spec: MasterSpec) -> None:
    allowed = spec.allowed()
    if not allowed.any(axis=1).all():
        bad = np.where(~allowed.any(axis=1))[0]
        raise ValueError(f"devices {bad} cannot store the model at any bit-width")


def solve_master_milp(spec: MasterSpec, cuts: Sequence[Cut]) -> MasterSolution:
    """Exact master via scipy.optimize.milp (HiGHS branch-and-bound)."""
    from scipy.optimize import Bounds, LinearConstraint, milp

    _validate(spec)
    N, B = spec.n_devices, len(spec.bits_options)
    nx = N * B
    bits = np.asarray(spec.bits_options, np.float64)
    # variables: [x (N*B), phi]
    c = np.zeros(nx + 1)
    c[-1] = 1.0

    lb = np.zeros(nx + 1)
    ub = np.ones(nx + 1)
    allowed = spec.allowed().ravel()
    ub[:nx] = np.where(allowed, 1.0, 0.0)     # memory fixing (Eq. 25)
    lb[-1], ub[-1] = 0.0, np.inf              # phi >= 0 keeps LB finite pre-cuts

    constraints = []
    # one-hot: sum_b x[i,b] == 1
    A = np.zeros((N, nx + 1))
    for i in range(N):
        A[i, i * B : (i + 1) * B] = 1.0
    constraints.append(LinearConstraint(A, 1.0, 1.0))
    # error budget (Eq. 23)
    row = np.zeros((1, nx + 1))
    row[0, :nx] = np.tile(spec.delta_sq(), N)
    constraints.append(LinearConstraint(row, -np.inf, spec.error_budget))
    # Benders cuts (q_i = sum_b bits_b x[i,b])
    for cut in cuts:
        row = np.zeros((1, nx + 1))
        per_dev = cut.grad[:, None] * bits[None, :]       # (N, B)
        row[0, :nx] = per_dev.ravel()
        if cut.kind == "opt":
            row[0, -1] = -1.0                              # g.q - phi <= -c0
            constraints.append(LinearConstraint(row, -np.inf, -cut.c0))
        else:                                              # feas: g.q <= c0
            constraints.append(LinearConstraint(row, -np.inf, cut.c0))

    integrality = np.concatenate([np.ones(nx), np.zeros(1)])
    res = milp(c=c, constraints=constraints, integrality=integrality,
               bounds=Bounds(lb, ub))
    if res.status != 0 or res.x is None:
        return MasterSolution(status="infeasible" if res.status == 2 else "failed",
                              q=None, phi=np.inf)
    x = res.x[:nx].reshape(N, B)
    q = bits[np.argmax(x, axis=1)].astype(int)
    return MasterSolution(status="ok", q=q, phi=float(res.x[-1]))


def solve_master_greedy(spec: MasterSpec, cuts: Sequence[Cut]) -> MasterSolution:
    """Fallback/warm-start heuristic.

    Start every device at its smallest memory-feasible bit-width (cheapest
    compute); raise bit-widths by steepest error-reduction per unit cut-cost
    until the error budget (23) holds; evaluate phi as the max over optimality
    cuts; reject if any feasibility cut is violated (then raise offenders).
    """
    _validate(spec)
    N = spec.n_devices
    bits = np.asarray(spec.bits_options)
    allowed = spec.allowed()
    dsq = spec.delta_sq()

    idx = np.array([np.flatnonzero(allowed[i])[0] for i in range(N)])

    def total_err(ix):
        return float(np.sum(dsq[ix]))

    guard = 0
    while total_err(idx) > spec.error_budget and guard < 32 * N:
        guard += 1
        best, best_gain = None, -np.inf
        for i in range(N):
            nxt = idx[i] + 1
            while nxt < len(bits) and not allowed[i, nxt]:
                nxt += 1
            if nxt >= len(bits):
                continue
            gain = dsq[idx[i]] - dsq[nxt]
            if gain > best_gain:
                best, best_gain = (i, nxt), gain
        if best is None:
            return MasterSolution(status="infeasible", q=None, phi=np.inf)
        idx[best[0]] = best[1]

    # enforce feasibility cuts by raising... (cuts have positive grads in q ->
    # raising q makes them *worse*; instead lower q where possible)
    q = bits[idx].astype(float)
    for cut in cuts:
        if cut.kind != "feas":
            continue
        guard = 0
        while float(cut.grad @ q) > cut.c0 and guard < 32 * N:
            guard += 1
            order = np.argsort(-cut.grad * q)  # biggest contributor first
            moved = False
            for i in order:
                prev = idx[i] - 1
                while prev >= 0 and not allowed[i, prev]:
                    prev -= 1
                if prev < 0:
                    continue
                trial = idx.copy()
                trial[i] = prev
                if total_err(trial) <= spec.error_budget:
                    idx = trial
                    q = bits[idx].astype(float)
                    moved = True
                    break
            if not moved:
                return MasterSolution(status="infeasible", q=None, phi=np.inf)

    phi = 0.0
    for cut in cuts:
        if cut.kind == "opt":
            phi = max(phi, cut.c0 + float(cut.grad @ q))
    return MasterSolution(status="ok", q=bits[idx].astype(int), phi=phi)


def solve_master(spec: MasterSpec, cuts: Sequence[Cut], *, use_milp: bool = True) -> MasterSolution:
    if use_milp:
        try:
            sol = solve_master_milp(spec, cuts)
            if sol.status != "failed":
                return sol
        except Exception:  # pragma: no cover - scipy missing / HiGHS failure
            pass
    return solve_master_greedy(spec, cuts)
