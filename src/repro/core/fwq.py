"""FWQ — Flexible Weight-Quantized federated learning (paper Algorithm 1).

This is the paper's primary contribution as a composable JAX module.  A round:

    1.  server broadcasts full-precision ``w^r``                     (line 2)
    2.  client i quantizes:  ``w~_i = Q_i(w^r)``  (SR, bit-width q_i) (line 4)
    3.  client i computes    ``g_i = (1/M) sum grad f(w~_i)``         (line 6)
        — the gradient is *evaluated at* the quantized weights; SR is
        piecewise-constant so there is no gradient through Q itself.
    4.  server aggregates    ``G = (1/N) sum_i g_i``  in full precision
        and applies          ``w^{r+1} = w^r - eta * G``         (lines 10-11)

The per-client bit-widths arrive as a *traced* vector
``delta[i] = 1/(2**q_i - 1)`` so the compiled program is reused across every
strategy the GBD layer emits (no recompilation when ``q`` changes between
rounds — critical at pod scale).

Two integration modes:

* ``tree``   — quantize the whole parameter tree per client up front
  (simple; right for the CIFAR-scale paper repro where the tree is small).
* ``inline`` — the model quantizes each weight at its use site via a
  ``param_transform`` callback, keeping per-client quantized copies transient
  inside the layer scan (right for FSDP/TP-sharded multi-billion-param archs;
  see DESIGN.md §4).

Distribution: the client axis of ``batch``/``delta``/``rng`` is laid out on
the mesh ``("pod","data")`` axes by the caller's ``in_shardings``; the mean
over clients lowers to the cross-data-parallel all-reduce of Algorithm 1
line 10.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quantization as quantlib

Params = Any
Batch = Any
# client_loss_fn(params, batch_i, delta_i, rng_i) -> (loss, aux)
ClientLossFn = Callable[[Params, Batch, jnp.ndarray, jax.Array], tuple[jnp.ndarray, Any]]


class FWQMetrics(NamedTuple):
    loss: jnp.ndarray              # mean client loss
    grad_norm_sq: jnp.ndarray      # ||G||^2 of the aggregated gradient
    client_grad_norm_sq: jnp.ndarray  # (n_clients,) per-client ||g_i||^2
    client_loss: jnp.ndarray       # (n_clients,)


@dataclasses.dataclass(frozen=True)
class FWQConfig:
    n_clients: int
    quantize_mode: str = "tree"        # "tree" | "inline"
    server_in_f32: bool = True         # keep the global model in f32 (paper)
    donate_params: bool = True


def make_tree_quant_loss(
    plain_loss_fn: Callable[[Params, Batch, jax.Array], tuple[jnp.ndarray, Any]],
    *,
    exempt=quantlib.default_exempt,
) -> ClientLossFn:
    """Wrap a plain loss into a client loss that tree-quantizes first (mode=tree)."""

    def client_loss(params, batch, delta, rng):
        qkey, lkey = jax.random.split(rng)
        qparams = quantlib.quantize_tree(params, delta, qkey, exempt=exempt)
        return plain_loss_fn(qparams, batch, lkey)

    return client_loss


def make_fwq_round(
    client_loss_fn: ClientLossFn,
    opt_update: Callable,          # (grads, opt_state, params) -> (updates, opt_state)
    cfg: FWQConfig,
):
    """Build the jittable FWQ round function.

    Returns ``round_fn(params, opt_state, batch, delta, rng) ->
    (params, opt_state, FWQMetrics)`` where

    * ``batch``  — pytree whose leaves have leading dim ``n_clients``
    * ``delta``  — (n_clients,) f32, ``s * Delta_{q_i}`` resolutions (0 = fp)
    * ``rng``    — single key; folded per client deterministically
    """

    def client_grad(params, batch_i, delta_i, rng_i):
        # Algorithm 1 line 6: gradient evaluated AT Q_i(w).  The quantization
        # happens inside client_loss_fn (tree mode) or inside the model
        # (inline mode); either way grad flows to the *quantized values*,
        # which numerically equals d f / d w~ evaluated at w~ = Q(w).
        (loss, _aux), grads = jax.value_and_grad(
            lambda p: client_loss_fn(p, batch_i, delta_i, rng_i), has_aux=True
        )(params)
        gsq = sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(grads))
        return loss, grads, gsq

    def round_fn(params, opt_state, batch, delta, rng):
        n = delta.shape[0]  # cohort size from the data: elastic across rounds
        client_keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(n))
        losses, grads, gsqs = jax.vmap(
            client_grad, in_axes=(None, 0, 0, 0)
        )(params, batch, delta, client_keys)
        # Server aggregation, full precision (line 10).  Mean over the client
        # axis lowers to an all-reduce across the ("pod","data") mesh axes.
        G = jax.tree_util.tree_map(
            lambda g: jnp.mean(g.astype(jnp.float32), axis=0), grads
        )
        updates, opt_state = opt_update(G, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        gnorm = sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(G))
        metrics = FWQMetrics(
            loss=jnp.mean(losses),
            grad_norm_sq=gnorm,
            client_grad_norm_sq=gsqs,
            client_loss=losses,
        )
        return params, opt_state, metrics

    return round_fn


def make_fwq_client_grads(client_loss_fn: ClientLossFn):
    """Phase 1 of a *gated* round: per-client losses/grads, no aggregation.

    The resilient executor (fault injection + aggregation gate) needs the
    per-client updates on the host before the server step; pairing this with
    :func:`make_fwq_apply` splits :func:`make_fwq_round` at exactly the
    uplink boundary of Algorithm 1 (between lines 6 and 10).
    """

    def grads_fn(params, batch, delta, rng):
        n = delta.shape[0]
        client_keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(n))

        def client_grad(params, batch_i, delta_i, rng_i):
            (loss, _aux), grads = jax.value_and_grad(
                lambda p: client_loss_fn(p, batch_i, delta_i, rng_i), has_aux=True
            )(params)
            gsq = sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(grads))
            finite = jnp.stack([jnp.all(jnp.isfinite(g))
                                for g in jax.tree_util.tree_leaves(grads)]).all()
            return loss, grads, gsq, finite

        return jax.vmap(client_grad, in_axes=(None, 0, 0, 0))(
            params, batch, delta, client_keys)

    return grads_fn


def make_fwq_apply(opt_update: Callable):
    """Phase 2 of a gated round: masked aggregation + server step.

    ``accept`` is an (n_clients,) 0/1 mask from the aggregation gate;
    rejected clients are excluded via ``where`` *before* the sum (a NaN
    times zero is still NaN) and survivors are reweighted by 1/n_accepted —
    the unbiased mean over the cohort that actually delivered valid updates.
    """

    def apply_fn(params, opt_state, grads, accept):
        w = accept.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(w), 1.0)

        def agg(g):
            gf = g.astype(jnp.float32)
            mask = w.reshape((-1,) + (1,) * (gf.ndim - 1))
            return jnp.sum(jnp.where(mask > 0, gf, 0.0), axis=0) / denom

        G = jax.tree_util.tree_map(agg, grads)
        updates, opt_state = opt_update(G, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p + u).astype(p.dtype), params, updates)
        gnorm = sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(G))
        return params, opt_state, gnorm

    return apply_fn


def delta_for_clients(
    bits,
    *,
    scale: float | jnp.ndarray = 1.0,
    n_clients: int | None = None,
) -> jnp.ndarray:
    """(n_clients,) resolutions ``s * Delta_{q_i}`` from a bit-width vector.

    ``bits`` is a per-client bit vector, or a
    :class:`repro.api.precision.PrecisionPolicy` (pass ``n_clients`` then —
    the policy's ``weights`` role supplies the per-device bits).

    ``scale`` defaults to 1.0 because :func:`repro.core.quantization.sr_quantize`
    applies the per-tensor ``s = ||w||_inf`` internally; pass an explicit scale
    only for pre-normalized weight schemes.
    """
    if hasattr(bits, "bits_vector"):  # PrecisionPolicy
        if n_clients is None:
            raise ValueError("delta_for_clients(policy) needs n_clients=")
        bits = bits.bits_vector(n_clients)
    return (jnp.asarray(scale, jnp.float32)
            * quantlib.delta_from_bits(jnp.asarray(bits))).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Inline mode: weight transform threaded through model forward passes.
# ---------------------------------------------------------------------------


def make_inline_quantizer(delta: jnp.ndarray, rng: jax.Array, *, exempt=quantlib.default_exempt):
    """A ``param_transform(path, w) -> w_q`` callback for inline-mode models.

    ``delta``/``rng`` are the *per-client* scalar/key (already vmapped by the
    round function).  Each call site derives its own SR key from a stable hash
    of the parameter path so quantization noise is i.i.d. across tensors but
    deterministic per (client, round).
    """

    def transform(path: str, w: jnp.ndarray) -> jnp.ndarray:
        if exempt is not None and exempt(path, w):
            return w
        site_key = jax.random.fold_in(rng, _stable_hash(path))
        return quantlib.sr_quantize(w, delta, site_key)

    return transform


@functools.lru_cache(maxsize=4096)
def _stable_hash(path: str) -> int:
    h = 2166136261
    for ch in path.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h


def identity_transform(path: str, w: jnp.ndarray) -> jnp.ndarray:
    """Full-precision baseline transform (no quantization)."""
    return w
