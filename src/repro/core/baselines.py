"""Comparison schemes from the paper's evaluation (§5.1-3).

* **Full Precision** — every device computes at 32 bits; only the bandwidth
  allocation is optimized (the primal with q = 32).
* **Unified Q**      — one common bit-width for all devices (paper uses 16),
  regardless of per-device budgets; bandwidth optimized by the primal.
* **Rand Q**         — each device draws a random memory-feasible bit-width,
  ignoring the learning-performance constraint (23); bandwidth optimized.

Each returns the same structure as :func:`repro.core.gbd.run_gbd` so the
benchmarks can compare energy like-for-like (paper Fig. 2-4).
"""

from __future__ import annotations

import numpy as np

from repro.core.gbd import GBDResult
from repro.core.master import MasterSpec
from repro.core.primal import PrimalData, solve_primal


def _finish(data: PrimalData, q: np.ndarray, name: str) -> GBDResult:
    sol = solve_primal(data, q)
    if not sol.feasible:
        return GBDResult(q=q, bandwidth=None, t_rounds=None, energy=np.inf,
                         lower_bound=np.inf, gap=0.0, iterations=1,
                         converged=False, trace=[{"scheme": name, "feasible": False}])
    return GBDResult(q=q, bandwidth=sol.bandwidth, t_rounds=sol.t_rounds,
                     energy=sol.value, lower_bound=sol.value, gap=0.0,
                     iterations=1, converged=True,
                     trace=[{"scheme": name, "feasible": True}])


def full_precision(data: PrimalData, spec: MasterSpec) -> GBDResult:
    q = np.full(spec.n_devices, 32, dtype=int)
    return _finish(data, q, "full_precision")


def unified_q(data: PrimalData, spec: MasterSpec, bits: int = 16) -> GBDResult:
    if bits not in spec.bits_options:
        raise ValueError(f"bits={bits} not in {spec.bits_options}")
    q = np.full(spec.n_devices, bits, dtype=int)
    return _finish(data, q, f"unified_q{bits}")


def rand_q(data: PrimalData, spec: MasterSpec, *, seed: int = 0) -> GBDResult:
    rng = np.random.default_rng(seed)
    allowed = spec.allowed()
    bits = np.asarray(spec.bits_options)
    q = np.array([int(rng.choice(bits[allowed[i]])) for i in range(spec.n_devices)])
    return _finish(data, q, "rand_q")


SCHEMES = {
    "full_precision": full_precision,
    "unified_q": unified_q,
    "rand_q": rand_q,
}
