"""Generalized Benders' Decomposition driver (paper Algorithm 2).

Couples the convex primal (:mod:`repro.core.primal`) and the integer master
(:mod:`repro.core.master`):

    repeat z = 1..Z_max:
        master  -> q^(z), phi^(z);   LB = phi^(z)
        primal(q^(z)):
            feasible   -> UB = min(UB, v(q)), add optimality cut
            infeasible -> add feasibility cut
    until UB - LB <= eps

The master's optimum is non-decreasing (cuts accumulate) and the primal gives
valid upper bounds, so the gap is monotone; with the finite bit-width lattice
termination is guaranteed (each master visit of a repeated q adds its exact
value cut).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import numpy as np

from repro.core.master import Cut, MasterSpec, MasterSolution, solve_master
from repro.core.primal import (
    PrimalData,
    PrimalSolution,
    feasibility_cut,
    optimality_cut,
    solve_primal,
)

log = logging.getLogger(__name__)


@dataclasses.dataclass
class GBDResult:
    q: np.ndarray                # chosen bit-widths (N,)
    bandwidth: np.ndarray        # (R, N) Hz
    t_rounds: np.ndarray         # (R,)
    energy: float                # total J (upper bound at termination)
    lower_bound: float
    gap: float
    iterations: int
    converged: bool
    trace: list                  # per-iteration dicts (UB, LB, q)


def run_gbd(
    data: PrimalData,
    spec: MasterSpec,
    *,
    eps: float = 1e-3,
    rel_eps: float = 1e-4,
    max_rounds: int = 50,
    use_milp: bool = True,
    q0: np.ndarray | None = None,
    on_iteration: Callable[[dict], None] | None = None,
) -> GBDResult:
    """Algorithm 2.  ``eps``/``rel_eps``: absolute/relative UB-LB stopping gap.

    ``q0`` warm-starts the decomposition from an incumbent bit assignment
    (e.g. the previous strategy when re-solving after channel drift): the
    first primal solve evaluates ``q0`` instead of the conservative max-bits
    seed, so a still-good incumbent converges in one or two cuts.
    """
    cuts: list[Cut] = []
    ub = np.inf
    lb = -np.inf
    best: tuple[np.ndarray, PrimalSolution] | None = None
    trace: list[dict] = []

    # Round 0: seed with the most conservative memory-feasible q (max bits)
    # so the master starts with at least one cut (paper: B^1 init).
    allowed = spec.allowed()
    bits = np.asarray(spec.bits_options)
    q = np.array([bits[np.flatnonzero(allowed[i])[-1]] for i in range(spec.n_devices)])
    if q0 is not None:
        q0 = np.asarray(q0)
        if q0.shape != (spec.n_devices,):
            raise ValueError(f"q0 must have shape ({spec.n_devices},), "
                             f"got {q0.shape}")
        # project the incumbent onto each device's memory-feasible lattice,
        # then accept it only if it also respects the error budget — the
        # master never proposes budget-violating points, so neither may the
        # warm seed (its primal value would be an invalid upper bound)
        qw = np.empty_like(q)
        ix = np.empty(spec.n_devices, dtype=int)
        for i in range(spec.n_devices):
            opts = np.flatnonzero(allowed[i])
            ix[i] = opts[np.argmin(np.abs(bits[opts] - q0[i]))]
            qw[i] = bits[ix[i]]
        if float(np.sum(spec.delta_sq()[ix])) <= spec.error_budget:
            q = qw

    z = 0
    converged = False
    for z in range(1, max_rounds + 1):
        sol = solve_primal(data, q)
        if sol.feasible:
            if sol.value < ub:
                ub = sol.value
                best = (q.copy(), sol)
            c0, grad = optimality_cut(data, q, sol)
            cuts.append(Cut(kind="opt", c0=c0, grad=grad))
        else:
            g, rhs = feasibility_cut(data, q, sol)
            cuts.append(Cut(kind="feas", c0=rhs, grad=g))

        ms: MasterSolution = solve_master(spec, cuts, use_milp=use_milp)
        if ms.status != "ok":
            log.warning("master %s at iter %d; stopping with UB=%s", ms.status, z, ub)
            break
        lb = max(lb, ms.phi)
        rec = {"iter": z, "ub": ub, "lb": lb, "q": q.copy(),
               "feasible": sol.feasible, "next_q": ms.q.copy()}
        trace.append(rec)
        if on_iteration:
            on_iteration(rec)
        gap = ub - lb
        if gap <= eps or (np.isfinite(ub) and gap <= rel_eps * abs(ub)):
            converged = True
            break
        if best is not None and np.array_equal(ms.q, q):
            # Master re-proposes the incumbent: its exact cut is already in,
            # so LB == UB on that point; we are done.
            converged = True
            break
        q = ms.q

    if best is None:
        raise RuntimeError("GBD found no feasible bit-width assignment "
                           "(deadline/bandwidth/error budget too tight)")
    q_best, sol_best = best
    return GBDResult(
        q=q_best,
        bandwidth=sol_best.bandwidth,
        t_rounds=sol_best.t_rounds,
        energy=ub,
        lower_bound=lb,
        gap=float(ub - lb),
        iterations=z,
        converged=converged,
        trace=trace,
    )


def exhaustive_best(data: PrimalData, spec: MasterSpec) -> tuple[np.ndarray, float]:
    """Brute-force optimum over the bit lattice (tests; exponential in N)."""
    import itertools

    allowed = spec.allowed()
    bits = np.asarray(spec.bits_options)
    dsq = spec.delta_sq()
    best_q, best_v = None, np.inf
    choices = [np.flatnonzero(allowed[i]) for i in range(spec.n_devices)]
    for combo in itertools.product(*choices):
        ix = np.array(combo)
        if float(np.sum(dsq[ix])) > spec.error_budget:
            continue
        q = bits[ix]
        sol = solve_primal(data, q)
        if sol.feasible and sol.value < best_v:
            best_q, best_v = q, sol.value
    if best_q is None:
        raise RuntimeError("no feasible assignment")
    return best_q, best_v
