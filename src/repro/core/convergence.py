"""Convergence bounds for FWQ federated learning (paper §3).

Implements the closed forms of Theorem 1 and Corollaries 1–2 so that

* the optimization layer can turn a learning-performance tolerance ``lambda``
  into the quantization-error budget of constraint (23),
* tests/benchmarks can compare the empirical average squared gradient norm
  against the theoretical envelope.

Notation (paper):
    L       gradient Lipschitz constant (Assumption 1)
    tau_i   per-device SGD variance bound (Assumption 2); tau = sum_i tau_i^2
    phi     cross-device gradient dissimilarity bound (Assumption 3)
    M       mini-batch size, N devices, R rounds, d model dimension
    delta_i = s * Delta_{q_i} = s / (2**q_i - 1)   quantization noise (Lemma 3)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Constants of Assumptions 1–3 plus run geometry."""

    L: float          # smoothness
    tau_sq: float     # sum_i tau_i^2  (Assumption 2, aggregated)
    phi: float        # Assumption 3
    M: int            # mini-batch size
    N: int            # number of participating devices
    d: int            # model dimension
    F0_minus_Fstar: float  # E[F(w^0)] - F*

    def validate(self) -> None:
        if min(self.L, self.tau_sq, self.M, self.N, self.d) < 0:
            raise ValueError("constants must be non-negative")


def quant_noise(bits: Sequence[int] | np.ndarray, scale: float | np.ndarray = 1.0) -> np.ndarray:
    """delta_i = s / (2**q_i - 1); q>=32 => 0 (full precision)."""
    bits = np.asarray(bits, dtype=np.float64)
    s = np.asarray(scale, dtype=np.float64)
    denom = np.exp2(np.minimum(bits, 31.0)) - 1.0
    return np.where(bits >= 32, 0.0, s / denom)


def corollary1_lr(c: ProblemConstants, R: int) -> float:
    """Learning rate of Corollary 1: eta = 1/(4L + sqrt(R tau/(MN)) + phi sqrt(R))."""
    return 1.0 / (4.0 * c.L + math.sqrt(R * c.tau_sq / (c.M * c.N)) + c.phi * math.sqrt(R))


def quantization_error_floor(c: ProblemConstants, delta: np.ndarray) -> float:
    """eps_q = (9 d L^2 / N) * sum_i delta_i^2 — the irreducible floor (Cor. 1/2)."""
    delta = np.asarray(delta, dtype=np.float64)
    return float(9.0 * c.d * c.L**2 / c.N * np.sum(delta**2))


def corollary1_bound(c: ProblemConstants, R: int, delta: np.ndarray) -> float:
    """RHS of Corollary 1: bound on (1/R) sum_r E||grad F(w^r)||^2."""
    c.validate()
    K = 4.0 * c.F0_minus_Fstar
    term_opt = 4.0 * c.L * K / R
    term_quant = quantization_error_floor(c, delta)
    term_var = (K + 4.0 * c.L) * math.sqrt(c.tau_sq) / math.sqrt(c.M * c.N * R)
    term_hetero = (K + 8.0 * c.L) * c.phi / math.sqrt(R)
    return term_opt + term_quant + term_var + term_hetero


def theorem1_H(c: ProblemConstants, eta: float, delta: np.ndarray) -> float:
    """Per-round slack H of Theorem 1 (Eq. 8)."""
    delta = np.asarray(delta, dtype=np.float64)
    t_quant = (eta * c.L**2 * c.d + 8.0 * eta**2 * c.L**3 * c.d) / (8.0 * c.N) * np.sum(delta**2)
    t_var = 2.0 * c.L * eta**2 * c.tau_sq / (c.M * c.N)
    t_het = 4.0 * c.L * eta**2 * c.phi**2
    return float(t_quant + t_var + t_het)


def theorem1_bound(c: ProblemConstants, eta: float, R: int, delta: np.ndarray) -> float:
    """Bound on (1/R) sum_r E||grad F||^2 from Theorem 1 for a given eta."""
    coeff = (eta - 2.0 * c.L * eta**2) / 2.0
    if coeff <= 0:
        raise ValueError("eta too large: eta - 2 L eta^2 must be positive")
    return (c.F0_minus_Fstar + R * theorem1_H(c, eta, delta)) / (coeff * R)


def corollary2_rounds(c: ProblemConstants, eps: float) -> int:
    """R_eps: rounds to reach (eps + eps_q)-accuracy (Cor. 2 exact root, Eq. 15).

    Solves  eps*sqrt(MNR) - (rho1 sqrt(tau) + rho2 phi sqrt(MN)) sqrt(R)
            - 4 L chi^2 sqrt(MN) = 0    for sqrt(R), taking chi^2 = 4(F0-F*).
    """
    chi_sq = 4.0 * c.F0_minus_Fstar
    rho1 = chi_sq + 4.0 * c.L
    rho2 = chi_sq + 8.0 * c.L
    mn = math.sqrt(c.M * c.N)
    # quadratic a x^2 - b x - c0 = 0 in x = sqrt(R)
    a = eps * mn
    b = rho1 * math.sqrt(c.tau_sq) + rho2 * c.phi * mn
    c0 = 4.0 * c.L * chi_sq * mn
    x = (b + math.sqrt(b * b + 4.0 * a * c0)) / (2.0 * a)
    return int(math.ceil(x * x))


def error_budget_bound(lam: float, e2: float, d: int, N: int) -> float:
    """Constraint (23) rearranged: sum_i delta_i^2 <= lam * N / (e2 * d)."""
    if lam <= 0 or e2 <= 0:
        raise ValueError("lambda and e2 must be positive")
    return lam * N / (e2 * d)


def feasible_bits_budget(
    bits_options: Sequence[int],
    N: int,
    budget_sum_delta_sq: float,
    scale: float = 1.0,
) -> bool:
    """Whether assigning the *largest* bit-width everywhere satisfies (23).

    Sanity helper for the optimizer: if even max-bits violates the budget the
    instance is infeasible.
    """
    dmax = quant_noise([max(bits_options)] * N, scale)
    return float(np.sum(dmax**2)) <= budget_sum_delta_sq


def estimate_constants_from_trace(
    grad_sq_norms: Sequence[float],
    losses: Sequence[float],
    d: int,
    M: int,
    N: int,
) -> ProblemConstants:
    """Crude empirical fit of (L, tau, phi) from a training trace.

    Used by benchmarks to anchor the theory curves to a real run; not part of
    the algorithm itself (the paper measures these offline as well).
    """
    losses = np.asarray(losses, np.float64)
    g = np.asarray(grad_sq_norms, np.float64)
    L = float(np.clip(np.max(g) / max(2.0 * (losses[0] - losses.min()), 1e-9), 1e-3, 1e3))
    tau_sq = float(np.var(g) + 1e-12) * N
    phi = float(np.sqrt(np.mean(np.abs(np.diff(g)))) + 1e-6)
    return ProblemConstants(
        L=L, tau_sq=tau_sq, phi=phi, M=M, N=N, d=d,
        F0_minus_Fstar=float(losses[0] - losses.min()),
    )
