import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: build the sharded step,
``.lower().compile()`` it AOT (ShapeDtypeStructs only — no allocation),
print ``memory_analysis()`` / ``cost_analysis()``, and derive the roofline
terms (§Roofline).  Failures here are sharding bugs by definition.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, shapes_for
from repro.configs.base import TrainConfig
from repro.core.fwq import delta_for_clients
from repro.dist.sharding import batch_specs
from repro.launch.mesh import axis_ctx_for, make_production_mesh
from repro.launch.steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
    globalize,
    local_param_shapes,
    serving_axes,
    _batch_size,
)
from repro.models.model import build_model
from repro.optim import build_optimizer
from repro.roofline.analysis import analyze_compiled, model_flops


def _bf16(dt):
    return jnp.bfloat16 if jnp.issubdtype(dt, jnp.floating) else dt


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: dict | None = None):
    """Returns (compiled, lowered, meta) for one cell.

    ``variant`` (§Perf knobs): gather_bf16, grad_bits, capacity, serve_bits,
    no_remat.
    """
    import dataclasses as _dc

    variant = variant or {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = axis_ctx_for(mesh)
    cfg = get_config(arch)
    if variant.get("gather_bf16"):
        cfg = _dc.replace(cfg, fsdp_gather_dtype="bfloat16")
    if variant.get("capacity"):
        cfg = _dc.replace(cfg, capacity_factor=float(variant["capacity"]))
    if variant.get("no_remat"):
        cfg = _dc.replace(cfg, remat=False)
    model = build_model(cfg)
    spec = {s.name: s for s in shapes_for(cfg)}[shape_name]
    rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P()))

    if spec.kind == "train":
        opt = build_optimizer("sgd", 1e-3)
        tc = TrainConfig(grad_compression_bits=int(variant.get("grad_bits", 0)))
        ts = build_train_step(model, mesh, axes, opt, tc, donate=False)
        pshapes = local_param_shapes(model, mesh, axes)
        params_g = globalize(pshapes, ts.param_specs, mesh)
        opt_shapes = jax.eval_shape(opt.init, pshapes)
        opt_g = globalize(opt_shapes, ts.opt_specs, mesh)
        batch_tree = model.train_batch_spec(spec.global_batch, spec.seq_len)
        bspecs = batch_specs(batch_tree, axes)
        batch_g = globalize(
            jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(
                    (l.shape[0] // _batch_size(mesh, axes),) + l.shape[1:], l.dtype),
                batch_tree),
            bspecs, mesh)
        n_clients = ts.n_clients
        delta_g = jax.ShapeDtypeStruct(
            (n_clients,), jnp.float32,
            sharding=NamedSharding(mesh, P(axes.batch_axes if len(axes.batch_axes) > 1
                                           else axes.batch_axes[0])))
        step = ts.fn(batch_tree)
        lowered = step.lower(params_g, opt_g, batch_g, delta_g, rng_sds)

    elif spec.kind == "prefill":
        wrap, pspecs = build_prefill_step(model, mesh, axes)
        pshapes = local_param_shapes(model, mesh, axes)
        params_g = globalize(pshapes, pspecs, mesh, dtype_map=_bf16)
        batch_tree = model.train_batch_spec(spec.global_batch, spec.seq_len)
        batch_tree = {k: v for k, v in batch_tree.items() if k != "labels"}
        bspecs = batch_specs(batch_tree, axes)
        batch_g = globalize(
            jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(
                    (l.shape[0] // _batch_size(mesh, axes),) + l.shape[1:], l.dtype),
                batch_tree),
            bspecs, mesh)
        step = wrap(batch_tree)
        lowered = step.lower(params_g, batch_g)

    else:  # decode
        sv_axes = serving_axes(axes, spec.global_batch, mesh)
        params_tree = None
        if variant.get("serve_bits"):
            # packed int8 serving weights (QTensor): gathers stream codes
            from repro.core.quantization import default_exempt
            from repro.models.common import pack_params_for_serving
            bits = int(variant["serve_bits"])
            pshapes_local = local_param_shapes(model, mesh, sv_axes)
            params_tree = jax.eval_shape(
                lambda: pack_params_for_serving(
                    jax.tree_util.tree_map(
                        lambda l: jnp.zeros(l.shape, l.dtype), pshapes_local),
                    bits, jax.random.PRNGKey(0), exempt=default_exempt))
        ss = build_decode_step(model, mesh, sv_axes, s_max=spec.seq_len,
                               batch_global=spec.global_batch,
                               params_tree=params_tree)
        params_g = globalize(ss.param_shapes, ss.param_specs, mesh,
                             dtype_map=_bf16)
        caches_g = globalize(ss.caches_shape, ss.cache_specs, mesh)
        batch_tree = model.decode_batch_spec(spec.global_batch, spec.seq_len)
        bspecs = batch_specs(batch_tree, sv_axes)
        bsz = _batch_size(mesh, sv_axes)
        batch_g = globalize(
            jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct(
                    (l.shape[0] // max(bsz, 1),) + l.shape[1:], l.dtype),
                batch_tree),
            bspecs, mesh)
        lowered = ss.fn.lower(params_g, batch_g, caches_g)

    compiled = lowered.compile()
    meta = dict(arch=arch, shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                n_devices=512 if multi_pod else 256,
                kind=spec.kind, seq_len=spec.seq_len,
                global_batch=spec.global_batch)
    return compiled, lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, verbose=True,
             variant: dict | None = None):
    t0 = time.time()
    cfg = get_config(arch)
    spec = {s.name: s for s in shapes_for(cfg)}[shape_name]
    compiled, lowered, meta = lower_cell(arch, shape_name, multi_pod, variant)
    if variant:
        meta["variant"] = dict(variant)
    mf = model_flops(cfg, spec.kind, spec.seq_len, spec.global_batch)
    rep = analyze_compiled(compiled, arch=arch, shape=shape_name,
                           mesh_name=meta["mesh"], n_devices=meta["n_devices"],
                           model_flops_global=mf)
    d = rep.to_dict()
    d.update(meta, compile_s=round(time.time() - t0, 1), status="ok")
    if verbose:
        print(f"[{arch} x {shape_name} x {meta['mesh']}] "
              f"compile={d['compile_s']}s  "
              f"compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
              f"collective={rep.collective_s:.3e}s  dominant={rep.dominant}  "
              f"useful={rep.useful_flops_ratio:.3f}")
        print("  memory_analysis:", rep.memory_stats)
        print("  collectives:", {k: v for k, v in rep.collective_breakdown.items()})
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--gather-bf16", action="store_true")
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--capacity", type=float, default=0.0)
    ap.add_argument("--serve-bits", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()
    variant = {k: v for k, v in dict(
        gather_bf16=args.gather_bf16, grad_bits=args.grad_bits,
        capacity=args.capacity, serve_bits=args.serve_bits,
        no_remat=args.no_remat).items() if v}

    archs = list(ARCH_NAMES) if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)]
        if args.shape:
            shapes = [s for s in shapes if s == args.shape]
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp, variant=variant))
                except Exception as e:
                    traceback.print_exc()
                    results.append(dict(arch=arch, shape=shape,
                                        mesh="2x16x16" if mp else "16x16",
                                        status="FAIL", error=str(e)[-2000:]))
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
