import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run CLI (deliverable e) — a thin shim over
:meth:`repro.api.Session.run_dryrun`.

For every (architecture x input shape x mesh) cell: build the sharded step,
``.lower().compile()`` it AOT (ShapeDtypeStructs only — no allocation),
print ``memory_analysis()`` / ``cost_analysis()``, and derive the roofline
terms (§Roofline).  Failures here are sharding bugs by definition.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun.json
"""

import argparse
import json
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, verbose=True,
             variant: dict | None = None, precision=None):
    """Lower/compile/analyze one cell through the Session facade."""
    from repro.api import PrecisionPolicy, RunSpec, Session

    variant = dict(variant or {})
    if precision is None:
        # pre-facade contract: the bit knobs rode in the variant dict
        precision = PrecisionPolicy(
            weights=int(variant.get("serve_bits") or 32),
            comm=int(variant.get("grad_bits") or 32))
    spec = RunSpec(
        arch=arch, workload="dryrun",
        mesh="2x16x16" if multi_pod else "16x16", smoke=False,
        precision=precision,
        options={"shape": shape_name, "variant": variant})
    return Session(spec).run_dryrun(verbose=verbose)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--gather-bf16", action="store_true")
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--capacity", type=float, default=0.0)
    ap.add_argument("--serve-bits", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args(argv)

    from repro.api import PrecisionPolicy
    from repro.configs import ARCH_NAMES, get_config, shapes_for

    # CLI shim: the bit knobs fold into one PrecisionPolicy; the cfg knobs
    # stay a variant dict (recorded in the output rows).  lazy stays off:
    # the AOT roofline measures the packed-storage gathers; the interpret-
    # mode Pallas body would skew the CPU cost model.
    precision = PrecisionPolicy(
        weights=args.serve_bits if args.serve_bits else 32,
        comm=args.grad_bits or 32)
    variant = {k: v for k, v in dict(
        gather_bf16=args.gather_bf16, capacity=args.capacity,
        no_remat=args.no_remat, grad_bits=args.grad_bits,
        serve_bits=args.serve_bits).items() if v}

    archs = list(ARCH_NAMES) if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)]
        if args.shape:
            shapes = [s for s in shapes if s == args.shape]
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp, variant=variant,
                                            precision=precision))
                except Exception as e:
                    traceback.print_exc()
                    results.append(dict(arch=arch, shape=shape,
                                        mesh="2x16x16" if mp else "16x16",
                                        status="FAIL", error=str(e)[-2000:]))
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r.get("status") == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
