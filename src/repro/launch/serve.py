"""Continuous-batching quantized serving driver.

The FWQ-quantized model is packed once (:class:`QTensor` int8 codes + scale)
and — with lazy-quant dispatch — every decode step streams the packed bytes
straight into the ``quant_matmul`` Pallas kernel: the weight stream stays
int8 from HBM to VMEM, the serving-side realization of the paper's
storage/energy argument.

Scheduling is slot-based: ``--batch`` decode slots run in lock-step; each
sequence carries its own cache length.  When a sequence finishes, its slot is
freed and the next queued request is admitted mid-flight via a real prefill
pass (parallel forward with K/V capture; encoder + cross-attention K/V fill
for the enc-dec/VLM families) merged into just that slot — the other
sequences keep decoding undisturbed.

CPU demo (interpret-mode kernels)::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --steps 32 --batch 4 --attn-impl flash
"""

from __future__ import annotations

import argparse
import dataclasses
import time

BOS_ID = 1


@dataclasses.dataclass
class ServeStats:
    """What one driver run measured (bench_serving / tests consume this)."""

    arch: str
    bits: int
    attn_impl: str
    decode_steps: int
    decoded_tokens: int          # tokens produced by ACTIVE slots only
    completed: int               # sequences finished
    admitted: int                # sequences admitted (>= batch when the
                                 # queue forced mid-flight admissions)
    wall_s: float                # decode-loop wall clock (post-compile)
    tok_s: float
    bytes_per_step_packed: int   # weight bytes streamed per decode step
    bytes_per_step_f32: int      # same weights at f32
    packed_vs_f32: float         # packed / f32 byte ratio
    sample: list                 # first finished sequence's tokens


def _weight_bytes(tree) -> int:
    import jax

    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def run_serve(arch: str, *, smoke: bool = True, steps: int = 32, batch: int = 4,
              s_max: int = 64, prompt_len: int = 8, serve_bits: int = 7,
              attn_impl: str = "ref", mesh: str = "1x1", seed: int = 0,
              requests: int | None = None, max_new: int | None = None,
              quiet: bool = False) -> ServeStats:
    """Drive the continuous-batching decode loop; returns :class:`ServeStats`.

    ``serve_bits >= 32`` serves raw f32 weights (the baseline the packed
    ratio is measured against); ``< 32`` packs to int8/int16 ``QTensor``
    storage and decodes through the lazy-quant ``quant_matmul`` path.
    ``attn_impl``: ``ref`` (materialized/chunked jnp prefill) or ``flash``
    (Pallas flash-attention prefill kernel).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, smoke_variant
    from repro.core.quantization import default_exempt
    from repro.launch.mesh import axis_ctx_for, make_test_mesh
    from repro.launch.steps import (
        build_cached_prefill, build_decode_step, build_init_fn,
        init_global_caches)
    from repro.models.common import pack_params_for_serving
    from repro.models.model import build_model

    if attn_impl not in ("ref", "flash"):
        raise ValueError(f"attn_impl must be 'ref' or 'flash', got {attn_impl!r}")
    impl = "auto" if attn_impl == "ref" else "flash"

    def say(msg):
        if not quiet:
            print(msg)

    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg)
    d_shape = tuple(int(x) for x in mesh.split("x"))
    test_mesh = make_test_mesh(d_shape, ("data", "model"))
    axes = axis_ctx_for(test_mesh)
    prompt_len = min(prompt_len, s_max)

    init_fn, _ = build_init_fn(model, test_mesh, axes)
    params = init_fn(jax.random.PRNGKey(seed))

    # ---- pack to packed int storage (norm/router exemptions as in training)
    raw_bytes = _weight_bytes(params)
    f32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    lazy = serve_bits < 32
    if lazy:
        qparams = pack_params_for_serving(params, serve_bits,
                                          jax.random.PRNGKey(1),
                                          exempt=default_exempt)
        q_bytes = _weight_bytes(qparams)
        say(f"params: {raw_bytes/1e6:.1f} MB f32 -> {q_bytes/1e6:.1f} MB packed "
            f"({raw_bytes/q_bytes:.2f}x smaller, bits={serve_bits})")
    else:
        qparams, q_bytes = params, raw_bytes
        say(f"params: {raw_bytes/1e6:.1f} MB f32 (unpacked baseline)")

    # ---- compiled steps -------------------------------------------------
    ptree = jax.eval_shape(lambda: qparams)
    ss = build_decode_step(model, test_mesh, axes, params_tree=ptree,
                           s_max=s_max, batch_global=batch, lazy_quant=lazy)
    pf = build_cached_prefill(model, test_mesh, axes, params_tree=ptree,
                              s_max=s_max, s_prompt=prompt_len,
                              batch_global=batch, attn_impl=impl,
                              lazy_quant=lazy, bos_id=BOS_ID)
    caches = init_global_caches(model, test_mesh, axes, s_max=s_max,
                                batch_global=batch, dtype=jnp.float32)

    # ---- synthetic request queue ---------------------------------------
    budget = s_max - prompt_len - 1
    n_requests = requests if requests is not None else 2 * batch
    rng = np.random.RandomState(seed)
    # default cap: ~half the step budget, so completions (and therefore
    # mid-flight admissions) actually happen within a demo-sized run
    cap = max_new if max_new is not None else max(2, steps // 2)
    cap = max(1, min(cap, budget))
    queue = [
        {"id": i,
         "prompt": rng.randint(2, cfg.vocab_size, size=(prompt_len,)),
         # staggered lengths so completions (and admissions) interleave
         "max_new": int(rng.randint(max(1, cap // 2), cap + 1))}
        for i in range(n_requests)
    ]
    needs_tokens = "tokens" in model.prefill_batch_spec(batch, prompt_len, s_max)
    d_front = cfg.d_frontend or cfg.d_model
    n_img = cfg.n_image_tokens or 1601

    def prefill_batch(slots_to_fill):
        """Assemble the (B, ...) prefill inputs; only masked slots matter."""
        b = {}
        if needs_tokens:
            toks = np.ones((batch, prompt_len), np.int32)
            for s, req in slots_to_fill:
                toks[s] = req["prompt"]
            b["tokens"] = jnp.asarray(toks)
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(seed + 101)
            b["images"] = jax.random.normal(key, (batch, n_img, d_front),
                                            jnp.float32)
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(seed + 102)
            b["frames"] = jax.random.normal(key, (batch, s_max, d_front),
                                            jnp.float32)
        return b

    # ---- slot state (host side) ----------------------------------------
    active = np.zeros((batch,), bool)
    remaining = np.zeros((batch,), np.int64)
    seqs = [[] for _ in range(batch)]
    finished = []
    cur_tok = jnp.full((batch, 1), BOS_ID, jnp.int32)
    admitted = completed = decoded = 0

    def admit():
        nonlocal caches, cur_tok, admitted
        free = [i for i in range(batch) if not active[i]]
        if not free or not queue:
            return
        fill = [(s, queue.pop(0)) for s in free[: len(queue)]]
        mask = np.zeros((batch,), bool)
        for s, req in fill:
            mask[s] = True
        tok, caches = pf.fn(qparams, prefill_batch(fill), caches,
                            jnp.asarray(mask))
        tok = np.asarray(tok)
        new_tok = np.array(cur_tok)
        for s, req in fill:
            active[s] = True
            remaining[s] = req["max_new"]
            seqs[s] = [int(tok[s, 0])]
            new_tok[s] = tok[s]
            admitted += 1
        cur_tok = jnp.asarray(new_tok)

    admit()
    # first call compiles; its output is a real decode step, consumed below
    tok, caches = ss.fn(qparams, {"token": cur_tok}, caches)
    tok_h = np.asarray(tok)               # sync: compile finishes here
    t0, step_i, decoded_at_t0 = time.time(), 1, 0
    while True:
        done_any = False
        for s in range(batch):
            if not active[s]:
                continue
            seqs[s].append(int(tok_h[s, 0]))
            decoded += 1
            remaining[s] -= 1
            if remaining[s] <= 0 or len(seqs[s]) >= budget:
                active[s] = False
                finished.append(seqs[s])
                completed += 1
                done_any = True
        if step_i == 1:
            decoded_at_t0 = decoded       # step 1 ran pre-timer (compile)
        if step_i >= steps or (not active.any() and not queue):
            break
        cur_tok = jnp.asarray(tok_h)      # each slot feeds its own last token
        if done_any and queue:
            admit()                       # mid-flight slot reuse: overwrites
                                          # the admitted slots in cur_tok
        tok, caches = ss.fn(qparams, {"token": cur_tok}, caches)
        tok_h = np.asarray(tok)
        step_i += 1
    wall = time.time() - t0

    stats = ServeStats(
        arch=arch, bits=serve_bits, attn_impl=attn_impl,
        decode_steps=step_i, decoded_tokens=decoded, completed=completed,
        admitted=admitted, wall_s=wall,
        tok_s=(decoded - decoded_at_t0) / max(wall, 1e-9),
        bytes_per_step_packed=q_bytes, bytes_per_step_f32=f32_bytes,
        packed_vs_f32=q_bytes / max(f32_bytes, 1),
        sample=(finished[0] if finished else seqs[0])[:16],
    )
    say(f"decoded {stats.decoded_tokens} tokens over {stats.decode_steps} steps "
        f"x {batch} slots in {wall:.3f}s = {stats.tok_s:.1f} tok/s "
        f"(interpret-mode numbers off-TPU)")
    say(f"admitted {stats.admitted} / completed {stats.completed} sequences "
        f"(continuous batching over {n_requests} requests)")
    say(f"weight stream: {q_bytes/1e6:.1f} MB/step packed vs "
        f"{f32_bytes/1e6:.1f} MB/step f32 -> ratio {stats.packed_vs_f32:.3f}")
    say(f"sample: {stats.sample}")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--serve-bits", "--bits", dest="serve_bits", type=int,
                    default=7, help="serving bit-width (<=7: int8, "
                    "8..15: int16, >=32: f32 baseline)")
    ap.add_argument("--attn-impl", choices=("ref", "flash"), default="ref",
                    help="prefill attention: jnp reference or Pallas flash kernel")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None,
                    help="queue size (default 2x batch)")
    ap.add_argument("--max-new", type=int, default=None,
                    help="upper bound on per-request generation length")
    args = ap.parse_args(argv)
    return run_serve(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        s_max=args.s_max, prompt_len=args.prompt_len,
        serve_bits=args.serve_bits, attn_impl=args.attn_impl, mesh=args.mesh,
        seed=args.seed, requests=args.requests, max_new=args.max_new)


if __name__ == "__main__":
    main()
