"""Continuous-batching quantized serving CLI — a thin shim over
:class:`repro.api.Session`.

The FWQ-quantized model is packed once (:class:`QTensor` int8 codes + scale)
and — with a lazy :class:`~repro.api.PrecisionPolicy` — every decode step
streams the packed bytes straight into the ``quant_matmul`` Pallas kernel:
the weight stream stays int8 from HBM to VMEM, the serving-side realization
of the paper's storage/energy argument.  The driver itself (slot-based
continuous batching, per-sequence cache lengths, mid-flight prefill
admission) lives in :meth:`repro.api.Session.serve`.

CPU demo (interpret-mode kernels)::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --steps 32 --batch 4 --attn-impl flash
"""

from __future__ import annotations

import argparse

from repro.api.session import BOS_ID, ServeStats  # noqa: F401  (re-export)


def run_serve(arch: str, *, smoke: bool = True, steps: int = 32, batch: int = 4,
              s_max: int = 64, prompt_len: int = 8, serve_bits: int = 7,
              attn_impl: str = "ref", mesh: str = "1x1", seed: int = 0,
              requests: int | None = None, max_new: int | None = None,
              kv_layout: str | None = None, page_size: int | None = None,
              pool_pages: int | None = None, vary_prompt: bool = False,
              precision_program=None, kv_bits: int = 32,
              quiet: bool = False) -> ServeStats:
    """Compatibility wrapper: builds a RunSpec and drives ``Session.serve``.

    ``serve_bits >= 32`` serves raw f32 weights (the baseline the packed
    ratio is measured against); ``< 32`` maps to a lazy packed
    :class:`~repro.api.PrecisionPolicy` (int8/int16 ``QTensor`` storage,
    ``quant_matmul`` decode path).  ``kv_layout="paged"`` (the default for
    attention families) serves from the paged KV cache: ``pool_pages`` pages
    of ``page_size`` tokens shared across slots, allocated per request on
    admit and reclaimed on completion.

    ``precision_program`` (a kind name or config dict, see
    :mod:`repro.api.program`) plus ``kv_bits=32`` arms the paged-KV
    watermark: an f32 cache pool is demoted to bf16 when pool pressure
    crosses the program's ``kv_watermark``.
    """
    from repro.api import PrecisionPolicy, RunSpec, Session

    precision = (PrecisionPolicy(weights=serve_bits, lazy=True,
                                 kv_cache=kv_bits)
                 if serve_bits < 32
                 else PrecisionPolicy.full_precision(kv_cache=kv_bits))
    options = {"steps": steps, "s_max": s_max, "prompt_len": prompt_len,
               "attn_impl": attn_impl, "requests": requests,
               "max_new": max_new, "quiet": quiet}
    if kv_layout is not None:
        options["kv_layout"] = kv_layout
    if page_size is not None:
        options["page_size"] = page_size
    if pool_pages is not None:
        options["pool_pages"] = pool_pages
    if vary_prompt:
        options["vary_prompt"] = True
    if precision_program is not None:
        options["precision_program"] = precision_program
    spec = RunSpec(
        arch=arch, workload="serve", mesh=mesh, smoke=smoke, seed=seed,
        batch=batch, seq=s_max, precision=precision, options=options)
    return Session(spec).serve()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--serve-bits", "--bits", dest="serve_bits", type=int,
                    default=7, help="serving bit-width (<=7: int8, "
                    "8..15: int16, >=32: f32 baseline)")
    ap.add_argument("--attn-impl", choices=("ref", "flash"), default="ref",
                    help="prefill attention: jnp reference or Pallas flash kernel")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None,
                    help="queue size (default 2x batch)")
    ap.add_argument("--max-new", type=int, default=None,
                    help="upper bound on per-request generation length")
    ap.add_argument("--kv-layout", choices=("paged", "contiguous"),
                    default=None, help="KV-cache layout (default: paged "
                    "where the family supports it)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="shared page-pool size (default: the batch largest "
                    "queued requests)")
    ap.add_argument("--vary-prompt", action="store_true",
                    help="draw ragged prompt lengths (exercises the "
                    "prompt-length buckets)")
    ap.add_argument("--kv-bits", type=int, choices=(16, 32), default=32,
                    help="KV-cache storage: 32 = f32, 16 = bf16")
    ap.add_argument("--precision-program", default="",
                    help="adaptive precision controller (kind name or JSON "
                    "config); with --kv-bits 32 and a kv_watermark, paged "
                    "pools demote f32 -> bf16 under pool pressure, e.g. "
                    '\'{"kind": "constant", "kv_watermark": 0.9}\'')
    args = ap.parse_args(argv)
    program = None
    if args.precision_program:
        import json

        pp = args.precision_program
        program = json.loads(pp) if pp.lstrip().startswith("{") else pp
    return run_serve(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        s_max=args.s_max, prompt_len=args.prompt_len,
        serve_bits=args.serve_bits, attn_impl=args.attn_impl, mesh=args.mesh,
        seed=args.seed, requests=args.requests, max_new=args.max_new,
        kv_layout=args.kv_layout, page_size=args.page_size,
        pool_pages=args.pool_pages, vary_prompt=args.vary_prompt,
        precision_program=program, kv_bits=args.kv_bits)


if __name__ == "__main__":
    main()
