"""Quantized serving driver: batched greedy decoding with int8 weights.

The FWQ-quantized model is packed once (:class:`QTensor` int8 codes + scale)
and every decode step streams 1/4 the weight bytes of f32 — the serving-side
realization of the paper's storage/energy argument (see §Roofline decode
rows and the quant_matmul kernel).

CPU demo::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --steps 32 --batch 4
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--bits", type=int, default=7, help="serving bit-width (<=7: int8)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_variant
    from repro.core.quantization import default_exempt
    from repro.launch.mesh import axis_ctx_for, make_test_mesh
    from repro.launch.steps import build_decode_step, build_init_fn
    from repro.models.common import pack_params_for_serving
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg)
    d_shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(d_shape, ("data", "model"))
    axes = axis_ctx_for(mesh)

    init_fn, _ = build_init_fn(model, mesh, axes)
    params = init_fn(jax.random.PRNGKey(args.seed))

    # pack to int8 (per-tensor scales, norm/router exemptions as in training)
    qparams = pack_params_for_serving(params, args.bits,
                                      jax.random.PRNGKey(1), exempt=default_exempt)
    raw_bytes = sum(x.size * x.dtype.itemsize
                    for x in jax.tree_util.tree_leaves(params))
    q_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(qparams))
    print(f"params: {raw_bytes/1e6:.1f} MB f32 -> {q_bytes/1e6:.1f} MB packed "
          f"({raw_bytes/q_bytes:.2f}x smaller)")

    ss = build_decode_step(model, mesh, axes, params_tree=jax.eval_shape(lambda: qparams),
                           s_max=args.s_max, batch_global=args.batch)
    caches = model.init_caches(args.batch, args.s_max, tp=d_shape[1],
                               dtype=jnp.float32)
    # vlm/encdec: cross-attention K/V are cached at prefill (zeros here as
    # the demo skips the prefill pass)
    batch = {"token": jnp.ones((args.batch, 1), jnp.int32)}

    tok, caches = ss.fn(qparams, batch, caches)       # compile + step 1
    t0 = time.time()
    toks = [tok]
    for _ in range(args.steps - 1):
        tok, caches = ss.fn(qparams, {**batch, "token": tok}, caches)
        toks.append(tok)
    dt = time.time() - t0
    rate = (args.steps - 1) * args.batch / max(dt, 1e-9)
    seq = jnp.concatenate(toks, axis=1)
    print(f"decoded {args.steps} steps x {args.batch} seqs "
          f"in {dt:.3f}s = {rate:.1f} tok/s (CPU interpret-mode numbers)")
    print("sample:", seq[0, :16].tolist())
    return seq


if __name__ == "__main__":
    main()
