"""Host-side page-table management for the paged KV cache.

The device side (:class:`repro.models.attention.PagedKVCache`, the gather
reference path, the flash-decode Pallas kernel) only ever *consumes* page
tables; deciding which pool rows a request owns is a host concern, and it
lives here: a free-list :class:`PagePool` per shard plus the
:class:`SlotPager` that turns "admit this request with this token capacity"
into per-slot table rows (and back into free pages on eviction).

Allocation happens ON ADMIT for the request's full capacity (prompt +
max_new tokens, rounded up to whole pages) — decode never allocates, so the
jitted step stays allocation-free, and a request that cannot get its pages
simply waits in the queue until completions reclaim some
(:meth:`SlotPager.admit` returns ``False``).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def plan_admissions(free_pages: int, free_slots: int,
                    demands) -> tuple[list[int], list[int]]:
    """FIFO admission plan with cascading reservations (starvation-free).

    ``demands[i]`` is the page count request ``i`` needs, oldest first.
    Returns ``(admit, blocked)`` — indices into ``demands``.  A blocked
    older request *reserves* every page a younger request would otherwise
    grab: request ``i`` may only draw from the surplus beyond the sum of all
    older blocked requests' reservations (a page-blocked request reserves
    every usable page, so in practice nothing leapfrogs it).  Freed pages
    therefore accrue to the oldest waiter first, and a large request at the
    queue head admits as soon as enough completions reclaim pages — a
    stream of small younger requests can never starve it.

    ``blocked`` lists only page-limited requests (considered while a slot
    was still free); requests past the slot limit are neither admitted nor
    blocked — they were never candidates this cycle.
    """
    admit: list[int] = []
    blocked: list[int] = []
    avail = int(free_pages)
    reserved = 0
    for i, need in enumerate(demands):
        if len(admit) >= free_slots:
            break
        usable = avail - reserved
        if int(need) <= usable:
            admit.append(i)
            avail -= int(need)
        else:
            blocked.append(i)
            reserved += min(int(need), usable)
    return admit, blocked


def pages_for(cap_tokens: int, page_size: int) -> int:
    """Pages needed to cache ``cap_tokens`` tokens (ceil division) — the ONE
    place the rounding lives; the driver's pool sizing and the allocator
    must agree on it."""
    return -(-int(cap_tokens) // int(page_size))


class PagePool:
    """Free-list allocator over one shard-local page pool."""

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free = list(range(self.n_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def pressure(self) -> float:
        """Fraction of the pool in use (0..1) — the adaptive-precision
        programs' paged-KV watermark signal."""
        return self.used_pages / max(self.n_pages, 1)

    def alloc(self, n: int) -> list[int] | None:
        """n pool rows, or None (allocate-all-or-nothing) when exhausted."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"freeing page {p} outside pool "
                                 f"[0, {self.n_pages})")
        self._free.extend(int(p) for p in pages)


@dataclasses.dataclass
class SlotPager:
    """Per-slot page tables over a shared pool (host mirror of the device
    ``page_table`` array).

    ``n_slots`` decode slots, each with up to ``n_pmax`` logical pages of
    ``page_size`` tokens.  ``table`` is the (n_slots, n_pmax) int32 array the
    driver pushes to the device after every admit/evict; unallocated entries
    are -1, so an overflowing or evicted slot's writes drop instead of
    landing on a reclaimed page.
    """

    n_slots: int
    n_pmax: int
    page_size: int
    pool: PagePool

    def __post_init__(self):
        self.table = np.full((self.n_slots, self.n_pmax), -1, np.int32)

    @classmethod
    def build(cls, n_slots: int, s_max: int, page_size: int,
              pool_pages: int) -> "SlotPager":
        if s_max % page_size:
            raise ValueError(f"page_size={page_size} must divide "
                             f"s_max={s_max}")
        return cls(n_slots=n_slots, n_pmax=s_max // page_size,
                   page_size=page_size, pool=PagePool(pool_pages))

    def pages_for(self, cap_tokens: int) -> int:
        return pages_for(cap_tokens, self.page_size)

    def slot_capacity(self, slot: int) -> int:
        """Tokens slot can cache = allocated pages x page size."""
        return int((self.table[slot] >= 0).sum()) * self.page_size

    def admit(self, slot: int, cap_tokens: int) -> bool:
        """Allocate ``ceil(cap_tokens / page)`` pages into ``slot``'s row.

        Returns False (row untouched) when the pool cannot satisfy the
        request — the caller defers admission until eviction reclaims pages.
        """
        if self.table[slot].max(initial=-1) >= 0:
            raise ValueError(f"slot {slot} already holds pages; evict first")
        n = self.pages_for(cap_tokens)
        if n > self.n_pmax:
            raise ValueError(
                f"capacity {cap_tokens} tokens needs {n} pages > n_pmax="
                f"{self.n_pmax} (s_max); clamp the request first")
        pages = self.pool.alloc(n)
        if pages is None:
            if self.pool.n_pages < n:
                raise ValueError(
                    f"page pool ({self.pool.n_pages} pages) can never fit a "
                    f"{n}-page request; raise pool_pages")
            return False
        self.table[slot, :n] = pages
        return True

    def evict(self, slot: int) -> int:
        """Reclaim ``slot``'s pages; returns how many were freed."""
        row = self.table[slot]
        pages = row[row >= 0]
        self.pool.free(pages.tolist())
        row[:] = -1
        return int(pages.size)


def set_page_tables(caches, table: np.ndarray):
    """Push a host page table into every PagedKVCache leaf of a cache tree.

    ``table``: (B, n_pmax) int32 — broadcast over the layer-stack dim (every
    layer's pool is indexed by the same logical table).  Device placement
    follows each leaf's existing sharding.
    """
    import jax
    import jax.numpy as jnp

    from repro.models.attention import PagedKVCache

    def one(c):
        if not isinstance(c, PagedKVCache):
            return c
        pt = jnp.broadcast_to(jnp.asarray(table, jnp.int32)[None],
                              c.page_table.shape)
        # re-place only onto mesh shardings: a fresh (uncommitted) cache must
        # stay uncommitted, or its single-device placement would fight the
        # mesh-committed params at the next jit boundary
        if isinstance(getattr(c.page_table, "sharding", None),
                      jax.sharding.NamedSharding):
            pt = jax.device_put(pt, c.page_table.sharding)
        return PagedKVCache(c.k_pages, c.v_pages, pt, c.length)

    return jax.tree_util.tree_map(
        one, caches, is_leaf=lambda x: isinstance(x, PagedKVCache))


def kv_cache_bytes(caches) -> int:
    """Bytes resident in the K/V storage of a cache tree (slabs or pools).

    Counts only per-token-growing state (self-attention K/V); page tables,
    lengths, SSM states, and cross-attention memory are excluded so the
    paged-vs-contiguous comparison isolates exactly what paging changes.
    """
    import jax

    from repro.models.attention import KVCache, PagedKVCache

    total = 0

    def one(c):
        nonlocal total
        if isinstance(c, PagedKVCache):
            total += (c.k_pages.size * c.k_pages.dtype.itemsize
                      + c.v_pages.size * c.v_pages.dtype.itemsize)
        elif isinstance(c, KVCache):
            total += (c.k.size * c.k.dtype.itemsize
                      + c.v.size * c.v.dtype.itemsize)
        return c

    jax.tree_util.tree_map(
        one, caches,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))
    return total
