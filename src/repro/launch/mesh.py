"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips (TPU v5e);
multi-pod: 2x16x16 = 512 — the leading ``pod`` axis extends data parallelism
(FL client cohorts double).
"""

from __future__ import annotations

import jax

from repro.dist.collectives import AxisCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU smoke tests (collectives become no-ops at size 1)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def axis_ctx_for(mesh) -> AxisCtx:
    names = tuple(mesh.axis_names)
    if "pod" in names:
        batch = ("pod", "data")
    else:
        batch = ("data",)
    model = "model" if "model" in names else None
    return AxisCtx(batch_axes=batch, model_axis=model, fsdp_axes=batch)


def mesh_axis_size(mesh, name: str) -> int:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return d.get(name, 1)
