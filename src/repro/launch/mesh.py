"""Mesh construction + axis bookkeeping (the single bootstrapping point).

Every launcher used to re-derive mesh shapes and axis contexts by hand; all
of that lives here now and is consumed through :class:`repro.api.Session`.
``build_mesh``/``mesh_and_axes`` are FUNCTIONS (importing this module never
touches jax device state).  Single pod: 16x16 = 256 chips (TPU v5e);
multi-pod: 2x16x16 = 512 — the leading ``pod`` axis extends data parallelism
(FL client cohorts double).
"""

from __future__ import annotations

import jax

from repro.dist.collectives import AxisCtx

_AXES_FOR_RANK = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}


def parse_mesh(spec: str) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """``"DATAxMODEL"`` / ``"PODxDATAxMODEL"`` -> (shape, axis names)."""
    shape = tuple(int(x) for x in str(spec).lower().split("x"))
    if len(shape) not in _AXES_FOR_RANK:
        raise ValueError(f"mesh spec {spec!r} must have 1-3 'x'-separated dims")
    return shape, _AXES_FOR_RANK[len(shape)]


def build_mesh(spec: str):
    """Mesh from a ``"2x16x16"``-style string (axis names inferred by rank)."""
    shape, axes = parse_mesh(spec)
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_and_axes(spec: str):
    """The one-call bootstrap: (mesh, AxisCtx) from a mesh-spec string."""
    mesh = build_mesh(spec)
    return mesh, axis_ctx_for(mesh)


def make_production_mesh(*, multi_pod: bool = False):
    return build_mesh("2x16x16" if multi_pod else "16x16")


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU smoke tests (collectives become no-ops at size 1)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def axis_ctx_for(mesh) -> AxisCtx:
    names = tuple(mesh.axis_names)
    if "pod" in names:
        batch = ("pod", "data")
    else:
        batch = ("data",)
    model = "model" if "model" in names else None
    return AxisCtx(batch_axes=batch, model_axis=model, fsdp_axes=batch)


def mesh_axis_size(mesh, name: str | None) -> int:
    if name is None:
        return 1
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return d.get(name, 1)


def tp_size(mesh, axes: AxisCtx) -> int:
    """Model-parallel (tensor-parallel) world size."""
    return mesh_axis_size(mesh, axes.model_axis)


def fsdp_size(mesh, axes: AxisCtx) -> int:
    """Product of the FSDP axes' sizes."""
    n = 1
    for a in axes.fsdp_axes:
        n *= mesh_axis_size(mesh, a)
    return n


def batch_size(mesh, axes: AxisCtx) -> int:
    """Product of the batch (data-parallel / FL-client) axes' sizes."""
    n = 1
    for a in axes.batch_axes:
        n *= mesh_axis_size(mesh, a)
    return n
