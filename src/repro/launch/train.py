"""End-to-end FWQ-FL training driver (pod-scale path).

Maps the paper's loop onto the mesh: each data-parallel group is an FL
client; every round the GBD co-design picks per-client bit-widths from the
simulated 5G channel + device fleet; one jitted shard_map step trains at the
quantized weights; energy/latency are accounted; checkpoints land every k
rounds and resume bit-identically.

On the CPU container run the smoke configs::

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --rounds 20 --mesh 1x1
"""

from __future__ import annotations

import argparse
import json
import logging
import time

import numpy as np

log = logging.getLogger("repro.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--scheme", default="fwq",
                    choices=["fwq", "full_precision", "unified_q", "rand_q"])
    ap.add_argument("--grad-compression-bits", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_variant
    from repro.configs.base import TrainConfig
    from repro.core.energy import heterogeneous_fleet, memory_capacities
    from repro.core.fwq import delta_for_clients
    from repro.data.synthetic import SyntheticTokens
    from repro.data.pipeline import TokenBatcher
    from repro.fed.orchestrator import FLOrchestrator, OrchestratorConfig
    from repro.ckpt import CheckpointManager
    from repro.launch.mesh import axis_ctx_for, make_test_mesh
    from repro.launch.steps import build_init_fn, build_train_step
    from repro.models.model import build_model
    from repro.optim import build_optimizer

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg)

    d_shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(d_shape, ("data", "model"))
    axes = axis_ctx_for(mesh)
    init_fn, _ = build_init_fn(model, mesh, axes)
    params = init_fn(jax.random.PRNGKey(args.seed))
    opt = build_optimizer("sgd", args.lr)
    tc = TrainConfig(grad_compression_bits=args.grad_compression_bits)
    ts = build_train_step(model, mesh, axes, opt, tc, donate=False)
    n_clients = ts.n_clients
    B = n_clients * args.batch

    # --- data ------------------------------------------------------------
    tokens = SyntheticTokens(n_tokens=300_000, vocab=cfg.vocab_size,
                             seed=args.seed).generate()
    batcher = TokenBatcher(tokens, args.seq, seed=args.seed)

    # --- co-design layer ---------------------------------------------------
    fleet = heterogeneous_fleet(n_clients, seed=args.seed, group_step_mhz=5.0)
    caps = memory_capacities(n_clients, lo_mb=8, hi_mb=64) * 1e6
    n_params = cfg.param_count()
    orch = FLOrchestrator(
        OrchestratorConfig(n_devices=n_clients, n_rounds=args.rounds,
                           scheme=args.scheme, model_dim_d=n_params,
                           seed=args.seed),
        fleet, caps, grad_bytes=4.0 * n_params)

    step = ts.fn(model.train_batch_spec(B, args.seq))
    opt_state = opt.init(params)
    ck = CheckpointManager(args.ckpt_dir, every=10) if args.ckpt_dir else None
    start = 0
    if ck:
        (params_opt, start, _) = ck.restore_or({"p": params, "o": opt_state})
        if start:
            params, opt_state = params_opt["p"], params_opt["o"]
            log.info("resumed at round %d", start)

    history = []
    for r in range(start, args.rounds):
        plan = orch.plan_round(r)
        bits = plan["q"][:n_clients]
        raw = batcher.sample_round(r, n_clients, args.batch)
        batch = {
            "tokens": jnp.asarray(raw["tokens"].reshape(B, args.seq)),
            "labels": jnp.asarray(raw["labels"].reshape(B, args.seq)),
        }
        if cfg.family == "vlm":
            batch["images"] = jnp.zeros((B, cfg.n_image_tokens,
                                         cfg.d_frontend), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, args.seq, cfg.d_frontend), jnp.float32)
        delta = delta_for_clients(bits)
        t0 = time.time()
        params, opt_state, m = step(params, opt_state, batch, delta,
                                    jax.random.fold_in(jax.random.PRNGKey(args.seed), r))
        rec = {"round": r, "loss": float(m["loss"]),
               "bits": bits.tolist(),
               "energy_j": plan["energy_round"],
               "t_round_s": plan["t_round"],
               "wall_s": round(time.time() - t0, 3),
               "cohort": int(plan["cohort"].sum())}
        history.append(rec)
        log.info("round %d loss=%.4f bits=%s energy=%.2fJ", r, rec["loss"],
                 sorted(set(rec["bits"])), rec["energy_j"])
        if ck:
            ck.maybe_save(r + 1, {"p": params, "o": opt_state})

    total_e = sum(h["energy_j"] for h in history)
    print(f"\nscheme={args.scheme} rounds={len(history)} "
          f"final_loss={history[-1]['loss']:.4f} total_energy={total_e:.2f}J")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
