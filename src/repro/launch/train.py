"""End-to-end FWQ-FL training CLI — a thin shim over :class:`repro.api.Session`.

Maps the paper's loop onto the mesh: each data-parallel group is an FL
client; every round the GBD co-design picks per-client bit-widths from the
simulated 5G channel + device fleet (``--scheme fixed`` skips the co-design
and trains at the spec's fixed PrecisionPolicy); one jitted shard_map step
trains at the quantized weights; energy/latency are accounted; checkpoints
land every k rounds and resume bit-identically.

On the CPU container run the smoke configs::

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --rounds 20 --mesh 1x1
"""

from __future__ import annotations

import argparse
import logging


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--scheme", default="fwq",
                    choices=["fwq", "full_precision", "unified_q", "rand_q",
                             "fixed"])
    ap.add_argument("--bits", type=int, default=32,
                    help="fixed weight bit-width (--scheme fixed only)")
    ap.add_argument("--grad-compression-bits", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    from repro.api import PrecisionPolicy, RunSpec, Session

    logging.basicConfig(level=logging.INFO)
    comm = args.grad_compression_bits or 32
    if args.scheme == "fixed":
        workload = "train"
        precision = PrecisionPolicy.uniform(args.bits, comm=comm)
    else:
        workload = "fl-orchestrate"
        precision = PrecisionPolicy(comm=comm)
    spec = RunSpec(
        arch=args.arch, workload=workload, mesh=args.mesh, smoke=args.smoke,
        seed=args.seed, batch=args.batch, seq=args.seq, rounds=args.rounds,
        precision=precision,
        options={"scheme": args.scheme, "lr": args.lr,
                 "ckpt_dir": args.ckpt_dir, "out": args.out})
    return Session(spec).run()


if __name__ == "__main__":
    main()
