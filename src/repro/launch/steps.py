"""Step builders: FWQ train step + quantized serve step under one shard_map.

``build_train_step`` realizes Algorithm 1 on the pod (see DESIGN.md §4):
each data-parallel group *is* one FL client; the per-client bit-width enters
as a traced resolution scalar ``delta[i]`` so one compiled program serves any
heterogeneous assignment the GBD layer emits between rounds.

``build_decode_step`` / ``build_prefill`` realize the serving path with
(optionally) packed int8 weights.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.fwq import make_inline_quantizer
from repro.dist.collectives import AxisCtx, quantized_psum_batch
from repro.dist.sharding import batch_specs, cache_specs, tree_param_specs
from repro.launch.mesh import batch_size, fsdp_size, mesh_axis_size
from repro.models.common import ParamCtx, apply_fsdp_sharding, reduce_gradients
from repro.models.model import Model
from repro.optim import Optimizer

# Historical aliases (pre-facade importers).
_size = mesh_axis_size
_fsdp_size = fsdp_size
_batch_size = batch_size


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def build_init_fn(model: Model, mesh, axes: AxisCtx):
    """Returns jit(shard_map) init: key -> sharded global param tree."""
    cfg = model.cfg
    tp = _size(mesh, axes.model_axis)
    fsdp = _fsdp_size(mesh, axes)

    def local_init(key):
        tp_idx = axes.tp_index()
        local_key = jax.random.fold_in(key, tp_idx)
        params = model.init(local_key, tp)
        pc = ParamCtx(ctx=axes, compute_dtype=_compute_dtype(cfg))
        return apply_fsdp_sharding(params, pc)

    # discover the local param structure without allocating
    shapes = jax.eval_shape(local_init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = tree_param_specs(shapes, cfg, axes, fsdp)
    sm = jax.shard_map(local_init, mesh=mesh, in_specs=P(),
                       out_specs=specs, check_vma=False)
    return jax.jit(sm), specs


@dataclasses.dataclass(frozen=True)
class TrainStep:
    fn: Any                     # jitted (params, opt_state, batch, delta, rng)
    param_specs: Any
    opt_specs: Any
    batch_spec_fn: Any          # (global_batch, seq) -> ShapeDtypeStruct tree
    n_clients: int


def build_train_step(model: Model, mesh, axes: AxisCtx, opt: Optimizer,
                     train_cfg: TrainConfig, *, attn_impl: str = "auto",
                     donate: bool = True) -> TrainStep:
    cfg = model.cfg
    fsdp = _fsdp_size(mesh, axes)
    n_clients = 1
    for a in axes.batch_axes:
        n_clients *= _size(mesh, a)

    def local_step(params, opt_state, batch, delta, rng):
        # ---- client identity & SR noise (deterministic, restartable) ----
        dp_idx = axes.dp_index()
        ckey = jax.random.fold_in(rng, dp_idx)
        delta_i = delta.reshape(())          # local (1,) -> scalar
        transform = make_inline_quantizer(delta_i, ckey)
        pc = ParamCtx(ctx=axes, transform=transform,
                      compute_dtype=_compute_dtype(cfg),
                      sp=cfg.seq_parallel,
                      gather_dtype=(jnp.bfloat16 if cfg.fsdp_gather_dtype == "bfloat16"
                                    else None))

        # ---- Algorithm 1 line 6: gradient AT the quantized weights -------
        def loss_fn(p):
            loss, aux = model.train_loss(pc, p, batch, attn_impl=attn_impl)
            return loss, aux

        (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # ---- server aggregation (line 10), full precision -----------------
        if train_cfg.grad_compression_bits:
            # Beyond-paper: SR-quantized gradient all-reduce.  Applies ONLY to
            # replicated leaves — FSDP leaves are already reduce-scattered by
            # the all-gather transpose (compressing them again would both
            # double-reduce and move MORE bytes: the codes need an int32
            # accumulator on the wire).  See EXPERIMENTS.md §Perf (refuted
            # hypothesis H1.3) for the wire-model accounting.
            from repro.models.common import fsdp_plan
            paths_key = jax.random.fold_in(rng, 17)
            _, leaves, treedef, plan = fsdp_plan(
                params, axes.fsdp, check_divisibility=False)
            gleaves = jax.tree_util.tree_leaves(grads)
            out = []
            for i, (g, dim) in enumerate(zip(gleaves, plan)):
                if dim is not None:
                    out.append(g / axes.dp)          # already RS-summed
                else:
                    out.append(quantized_psum_batch(
                        axes, g, jax.random.fold_in(paths_key, i),
                        train_cfg.grad_compression_bits,
                        on_nonfinite=train_cfg.nonfinite_grads))
            grads = jax.tree_util.tree_unflatten(treedef, out)
        else:
            grads = reduce_gradients(grads, params, axes)

        # ---- server update (line 11) --------------------------------------
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)

        # Diagnostic: sum over all shards of local grad sq norms (exact for
        # FSDP leaves, axis-multiplied for replicated ones — trend metric).
        gnorm = sum(jnp.vdot(g, g).real for g in jax.tree_util.tree_leaves(grads))
        all_axes = tuple(axes.batch_axes) + ((axes.model_axis,) if axes.model_axis else ())
        if all_axes:
            gnorm = jax.lax.psum(gnorm, all_axes)
        metrics = {
            "loss": jax.lax.pmean(loss, axes.batch_axes) if axes.batch_axes else loss,
            "grad_sq_shard_sum": gnorm,
        }
        return params, opt_state, metrics

    # ---- specs ---------------------------------------------------------
    pshapes = jax.eval_shape(
        lambda key: apply_fsdp_sharding(
            model.init(key, _size(mesh, axes.model_axis)),
            ParamCtx(ctx=axes, compute_dtype=_compute_dtype(cfg)), fsdp=fsdp),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_specs = tree_param_specs(pshapes, cfg, axes, fsdp)
    opt_shapes = jax.eval_shape(opt.init, pshapes)
    opt_specs = jax.tree_util.tree_map(
        lambda leaf: P(*([None] * len(leaf.shape))), opt_shapes)
    # momentum/adam states mirror param sharding
    opt_specs = _mirror_opt_specs(opt_shapes, pshapes, param_specs)

    def wrap(batch_tree_spec):
        bspecs = batch_specs(batch_tree_spec, axes)
        delta_spec = P(axes.batch_axes if len(axes.batch_axes) > 1
                       else (axes.batch_axes[0] if axes.batch_axes else None))
        sm = jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(param_specs, opt_specs, bspecs, delta_spec, P()),
            out_specs=(param_specs, opt_specs,
                       {"loss": P(), "grad_sq_shard_sum": P()}),
            check_vma=False)
        donate_args = (0, 1) if donate else ()
        return jax.jit(sm, donate_argnums=donate_args)

    return TrainStep(fn=wrap, param_specs=param_specs, opt_specs=opt_specs,
                     batch_spec_fn=model.train_batch_spec, n_clients=n_clients)


def _mirror_opt_specs(opt_shapes, pshapes, param_specs):
    """Optimizer slots shaped like params inherit the param spec; scalars P()."""
    flat_p, _ = jax.tree_util.tree_flatten(pshapes)
    flat_s, _ = jax.tree_util.tree_flatten(param_specs)
    shape_to_spec = {}
    for leaf, spec in zip(flat_p, flat_s):
        shape_to_spec.setdefault((tuple(leaf.shape), str(leaf.dtype)), spec)

    def pick(leaf):
        key = (tuple(leaf.shape), str(leaf.dtype))
        key32 = (tuple(leaf.shape), "float32")
        if key in shape_to_spec:
            return shape_to_spec[key]
        if key32 in shape_to_spec:
            return shape_to_spec[key32]
        # match by shape only (f32 master copies of bf16 params)
        for (shp, _dt), spec in shape_to_spec.items():
            if shp == tuple(leaf.shape):
                return spec
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map(pick, opt_shapes)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def local_param_shapes(model: Model, mesh, axes: AxisCtx):
    """Per-shard parameter ShapeDtypeStructs (post-FSDP storage layout)."""
    cfg = model.cfg
    fsdp = _fsdp_size(mesh, axes)
    return jax.eval_shape(
        lambda key: apply_fsdp_sharding(
            model.init(key, _size(mesh, axes.model_axis)),
            ParamCtx(ctx=axes, compute_dtype=_compute_dtype(cfg)), fsdp=fsdp),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


@dataclasses.dataclass(frozen=True)
class ServeStep:
    fn: Any
    param_specs: Any
    cache_specs: Any
    param_shapes: Any = None
    caches_shape: Any = None


def _greedy_pick(axes: AxisCtx, tp: int, vl: int, logits):
    """Greedy token over vocab-parallel local logits (B, 1, V/tp) -> (B, 1)."""
    lg = logits[:, -1, :].astype(jnp.float32)
    mloc = jnp.max(lg, axis=-1)
    iloc = jnp.argmax(lg, axis=-1).astype(jnp.int32) + axes.tp_index() * vl
    if axes.model_axis and tp > 1:
        mglob = jax.lax.pmax(mloc, axes.model_axis)
        cand = jnp.where(mloc >= mglob, iloc, jnp.int32(2**30))
        nxt = jax.lax.pmin(cand, axes.model_axis)
    else:
        nxt = iloc
    return nxt[:, None]


def _cache_kwargs(page_size, pool_pages) -> dict:
    """init_caches kwargs for the requested KV layout (paged iff page_size)."""
    if page_size is None:
        return {}
    return {"page_size": int(page_size),
            "pool_pages": None if pool_pages is None else int(pool_pages)}


def build_decode_step(model: Model, mesh, axes: AxisCtx, *,
                      params_tree=None, s_max: int, batch_global: int,
                      policy=None,
                      page_size: int | None = None,
                      pool_pages: int | None = None, attn_impl: str = "ref"):
    """One-token decode step (greedy sampling over vocab-parallel logits).

    ``policy`` (:class:`repro.api.precision.PrecisionPolicy`): with
    ``policy.lazy``, packed ``QTensor`` weights stay int8 through the matmuls
    (quant_matmul kernel dispatch) instead of being dequantized on use.

    ``page_size`` switches the KV caches to the PAGED layout (shared
    per-shard pool of ``pool_pages`` pages + per-slot page tables —
    :class:`~repro.models.attention.PagedKVCache`); ``attn_impl="flash"``
    then routes decode attention through the batched flash-decode Pallas
    kernel instead of the (bitwise slab-equivalent) gather reference.
    """
    cfg = model.cfg
    tp = _size(mesh, axes.model_axis)
    fsdp = _fsdp_size(mesh, axes)
    from repro.models.transformer import padded_vocab_local
    vl = padded_vocab_local(cfg, tp)

    def local_decode(params, batch, caches):
        pc = ParamCtx.from_policy(axes, policy,
                                  compute_dtype=_compute_dtype(cfg))
        logits, new_caches = model.decode_step(pc, params, batch, caches,
                                               attn_impl=attn_impl)
        return _greedy_pick(axes, tp, vl, logits), new_caches

    if params_tree is None:
        params_tree = jax.eval_shape(
            lambda key: apply_fsdp_sharding(
                model.init(key, tp), ParamCtx(ctx=axes), fsdp=fsdp),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_specs = tree_param_specs(params_tree, cfg, axes, fsdp)
    b_local = batch_global // max(_batch_size(mesh, axes), 1)
    caches_shape = jax.eval_shape(
        functools.partial(model.init_caches, b_local, s_max, tp,
                          **_cache_kwargs(page_size, pool_pages)))
    c_specs = cache_specs(caches_shape, axes, cfg)
    bspec_tree = model.decode_batch_spec(batch_global, s_max)
    bspecs = batch_specs(bspec_tree, axes)
    sm = jax.shard_map(local_decode, mesh=mesh,
                       in_specs=(param_specs, bspecs, c_specs),
                       out_specs=(batch_specs(
                           {"token": jax.ShapeDtypeStruct((batch_global, 1), jnp.int32)},
                           axes)["token"], c_specs),
                       check_vma=False)
    return ServeStep(fn=jax.jit(sm), param_specs=param_specs, cache_specs=c_specs,
                     param_shapes=params_tree, caches_shape=caches_shape)


def init_global_caches(model: Model, mesh, axes: AxisCtx, *, s_max: int,
                       batch_global: int, dtype=jnp.float32,
                       page_size: int | None = None,
                       pool_pages: int | None = None):
    """Allocate the GLOBAL decode caches for a launch.

    ``model.init_caches`` returns per-shard LOCAL shapes (what the mapped
    function sees); the global arrays a jitted shard_map step consumes
    multiply every sharded dim by its axis size — e.g. the sequence-parallel
    KV cache stores S_max/tp per shard but S_max globally.  Passing the
    local-shaped tree as the global array silently truncates the cache on
    tp > 1 launches; always go through this helper (or ``globalize``).

    ``page_size``/``pool_pages`` select the paged KV layout; its page tables
    start all-unallocated (-1), everything else zeroed.
    """
    from repro.models.attention import PagedKVCache

    tp = _size(mesh, axes.model_axis)
    b_local = batch_global // max(_batch_size(mesh, axes), 1)
    shapes = jax.eval_shape(
        functools.partial(model.init_caches, b_local, s_max, tp, dtype=dtype,
                          **_cache_kwargs(page_size, pool_pages)))
    specs = cache_specs(shapes, axes, model.cfg)
    g = globalize(shapes, specs, mesh)

    def alloc(c):
        if isinstance(c, PagedKVCache):
            return PagedKVCache(
                jnp.zeros(c.k_pages.shape, c.k_pages.dtype),
                jnp.zeros(c.v_pages.shape, c.v_pages.dtype),
                jnp.full(c.page_table.shape, -1, c.page_table.dtype),
                jnp.zeros(c.length.shape, c.length.dtype))
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), c,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    return jax.tree_util.tree_map(
        alloc, g, is_leaf=lambda x: isinstance(x, PagedKVCache))


def build_cached_prefill(model: Model, mesh, axes: AxisCtx, *,
                         params_tree=None, s_max: int, s_prompt: int,
                         batch_global: int, attn_impl: str = "auto",
                         policy=None,
                         bos_id: int = 1, page_size: int | None = None,
                         pool_pages: int | None = None,
                         with_prompt_lens: bool = False):
    """Prefill-into-slots step for continuous batching.

    The jitted fn signature is ``(params, batch, caches, slot_mask) ->
    (first_token (B, 1), merged_caches)``: it runs the model's real prefill
    (parallel forward with K/V capture for attention families, recurrence
    scan for SSM, encoder + cross-K/V fill for enc-dec/VLM) over a fresh
    zeroed cache, then merges ONLY the slots selected by ``slot_mask`` into
    the live caches — so new requests join a mid-flight batch without
    disturbing the sequences still decoding in the other slots.  Paged
    caches merge at page granularity through the live page tables, which the
    driver must have populated for the admitted slots BEFORE this call.

    ``attn_impl="flash"`` routes the prompt self-attention through the
    Pallas flash-attention kernel.  ``with_prompt_lens=True`` appends a
    ``prompt_lens (B,)`` argument — prompts right-padded to the ``s_prompt``
    bucket keep their true per-slot lengths (cache stamps, last-position
    logits), which is what makes one compiled prefill serve a whole bucket.
    """
    cfg = model.cfg
    tp = _size(mesh, axes.model_axis)
    fsdp = _fsdp_size(mesh, axes)
    from repro.models.attention import fresh_slot_caches, merge_slot_caches
    from repro.models.transformer import padded_vocab_local
    vl = padded_vocab_local(cfg, tp)
    b_local = batch_global // max(_batch_size(mesh, axes), 1)

    def local_prefill(params, batch, caches, slot_mask, prompt_lens=None):
        pc = ParamCtx.from_policy(axes, policy,
                                  compute_dtype=_compute_dtype(cfg))
        kw = {"prompt_lens": prompt_lens} if prompt_lens is not None else {}
        logits, filled = model.prefill(pc, params, batch,
                                       fresh_slot_caches(caches),
                                       attn_impl=attn_impl, **kw)
        if logits is None:      # enc-dec: decode starts from BOS
            tok = jnp.full((b_local, 1), bos_id, jnp.int32)
        else:
            tok = _greedy_pick(axes, tp, vl, logits)
        return tok, merge_slot_caches(caches, filled, slot_mask)

    if params_tree is None:
        params_tree = jax.eval_shape(
            lambda key: apply_fsdp_sharding(
                model.init(key, tp), ParamCtx(ctx=axes), fsdp=fsdp),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_specs = tree_param_specs(params_tree, cfg, axes, fsdp)
    caches_shape = jax.eval_shape(
        functools.partial(model.init_caches, b_local, s_max, tp,
                          **_cache_kwargs(page_size, pool_pages)))
    c_specs = cache_specs(caches_shape, axes, cfg)
    bspec_tree = model.prefill_batch_spec(batch_global, s_prompt, s_max)
    bspecs = batch_specs(bspec_tree, axes)
    mask_spec = batch_specs(
        {"m": jax.ShapeDtypeStruct((batch_global,), jnp.bool_)}, axes)["m"]
    tok_spec = batch_specs(
        {"token": jax.ShapeDtypeStruct((batch_global, 1), jnp.int32)},
        axes)["token"]
    in_specs = [param_specs, bspecs, c_specs, mask_spec]
    if with_prompt_lens:
        in_specs.append(mask_spec)          # (B,) int32, same batch sharding
    sm = jax.shard_map(local_prefill, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=(tok_spec, c_specs), check_vma=False)
    return ServeStep(fn=jax.jit(sm), param_specs=param_specs, cache_specs=c_specs,
                     param_shapes=params_tree, caches_shape=caches_shape)


def serving_axes(axes: AxisCtx, global_batch: int, mesh) -> AxisCtx:
    """Serving AxisCtx: when the request batch cannot shard over the batch
    axes (e.g. long_500k has batch 1), replicate the batch and keep FSDP."""
    if global_batch % max(_batch_size(mesh, axes), 1) == 0:
        return axes
    return AxisCtx(batch_axes=(), model_axis=axes.model_axis,
                   fsdp_axes=axes.fsdp_axes)


def build_prefill_step(model: Model, mesh, axes: AxisCtx, *, attn_impl="auto"):
    """Forward-only prefill: batch -> last-position local logits."""
    cfg = model.cfg
    fsdp = _fsdp_size(mesh, axes)

    def local_prefill(params, batch):
        pc = ParamCtx(ctx=axes, transform=None, compute_dtype=_compute_dtype(cfg),
                      sp=cfg.seq_parallel)
        loss_free = dict(batch)
        loss_free.pop("labels", None)
        logits = model.forward(pc, params, loss_free, attn_impl=attn_impl)
        return logits[:, -1:, :]

    pshapes = jax.eval_shape(
        lambda key: apply_fsdp_sharding(
            model.init(key, _size(mesh, axes.model_axis)),
            ParamCtx(ctx=axes), fsdp=fsdp),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    param_specs = tree_param_specs(pshapes, cfg, axes, fsdp)

    def wrap(batch_tree_spec):
        batch_no_labels = {k: v for k, v in batch_tree_spec.items() if k != "labels"}
        bspecs = batch_specs(batch_no_labels, axes)
        lead = (axes.batch_axes if len(axes.batch_axes) > 1
                else (axes.batch_axes[0] if axes.batch_axes else None))
        out_spec = P(lead, None, axes.model_axis)
        sm = jax.shard_map(local_prefill, mesh=mesh,
                           in_specs=(param_specs, bspecs),
                           out_specs=out_spec, check_vma=False)
        return jax.jit(sm)

    return wrap, param_specs


def globalize(sds_tree, spec_tree, mesh, *, dtype_map=None):
    """Local ShapeDtypeStructs + PartitionSpecs -> global SDS with shardings."""
    from jax.sharding import NamedSharding

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(sds, spec):
        shape = list(sds.shape)
        for d, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shape[d] *= sizes.get(a, 1)
        dt = sds.dtype
        if dtype_map:
            dt = dtype_map(dt)
        return jax.ShapeDtypeStruct(tuple(shape), dt,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(
        one, sds_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
