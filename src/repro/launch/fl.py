"""Paper-scale federated-simulation CLI (fl-sim workload) — a thin shim over
:class:`repro.api.Session`.

Runs Algorithm 1 on the vmap simulator (CIFAR-class CNN, non-iid clients)
with the GBD co-design choosing per-device bit-widths each round::

    PYTHONPATH=src python -m repro.launch.fl --model mobilenet --rounds 10
"""

from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mobilenet",
                    choices=["mobilenet", "resnet"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--scheme", default="fwq",
                    choices=["fwq", "full_precision", "unified_q", "rand_q"])
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--error-tolerance", type=float, default=4.5)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default="",
                    help="JSON FaultPlan dict, e.g. "
                    '\'{"packet_loss": 0.1, "dropout_prob": 0.05}\' — '
                    "runs the resilient round executor")
    ap.add_argument("--resolve-drift-db", type=float, default=0.0,
                    help="warm GBD re-solve when measured gains drift past "
                    "this many dB (0 = disabled)")
    ap.add_argument("--precision-program", default="",
                    help="adaptive precision controller: a kind name "
                    "(constant | energy_budget | channel_gbd) or a JSON "
                    'config, e.g. \'{"kind": "energy_budget", '
                    '"budget_j": 120}\'')
    ap.add_argument("--ckpt-dir", default="",
                    help="round-level checkpoints; rerunning with the same "
                    "dir resumes bit-identically")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    from repro.api import RunSpec, Session

    options = {"scheme": args.scheme, "n_clients": args.clients,
               "lr": args.lr, "error_tolerance": args.error_tolerance,
               "eval_every": args.eval_every}
    if args.faults:
        options["faults"] = json.loads(args.faults)
    if args.resolve_drift_db:
        options["resolve_drift_db"] = args.resolve_drift_db
    if args.precision_program:
        pp = args.precision_program
        options["precision_program"] = (json.loads(pp)
                                        if pp.lstrip().startswith("{") else pp)
    if args.ckpt_dir:
        options["ckpt_dir"] = args.ckpt_dir
        options["ckpt_every"] = args.ckpt_every
    spec = RunSpec(
        arch=args.model, workload="fl-sim", seed=args.seed,
        batch=args.batch, rounds=args.rounds, options=options)
    out = Session(spec).run()

    print(f"\n{'round':>5} {'loss':>8} {'energy(J)':>10} {'bits chosen':>16}")
    for h, e in zip(out["history"], out["energy_log"]):
        print(f"{h['round']:>5} {h['loss']:>8.4f} {e['energy_round']:>10.3f} "
              f"{str(sorted(set(h['bits'].tolist()))):>16}")
    print(f"\ntotal energy: {out['total_energy_j']:.2f} J over "
          f"{out['total_time_s']:.1f} s (simulated wall time)")
    if "program" in out:
        print("precision program:", json.dumps(out["program"]))
    if "total_retransmissions" in out:
        print(f"faults: {out['total_retransmissions']} retransmissions "
              f"({out['total_retx_energy_j']:.3f} J), "
              f"{out['total_rejected']} rejected updates, "
              f"{out['total_undelivered']} undelivered, "
              f"{out['total_dropped_midround']} mid-round dropouts")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"total_energy_j": out["total_energy_j"],
                       "total_time_s": out["total_time_s"],
                       "losses": [h["loss"] for h in out["history"]],
                       "evals": out["evals"],
                       **{k: out[k] for k in
                          ("total_retransmissions", "total_retx_energy_j",
                           "total_rejected", "total_undelivered",
                           "total_dropped_midround") if k in out}},
                      f, indent=1)
    return out


if __name__ == "__main__":
    main()
