"""Paper-scale federated-simulation CLI (fl-sim workload) — a thin shim over
:class:`repro.api.Session`.

Runs Algorithm 1 on the vmap simulator (CIFAR-class CNN, non-iid clients)
with the GBD co-design choosing per-device bit-widths each round::

    PYTHONPATH=src python -m repro.launch.fl --model mobilenet --rounds 10
"""

from __future__ import annotations

import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mobilenet",
                    choices=["mobilenet", "resnet"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--scheme", default="fwq",
                    choices=["fwq", "full_precision", "unified_q", "rand_q"])
    ap.add_argument("--lr", type=float, default=0.08)
    ap.add_argument("--error-tolerance", type=float, default=4.5)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    from repro.api import RunSpec, Session

    spec = RunSpec(
        arch=args.model, workload="fl-sim", seed=args.seed,
        batch=args.batch, rounds=args.rounds,
        options={"scheme": args.scheme, "n_clients": args.clients,
                 "lr": args.lr, "error_tolerance": args.error_tolerance,
                 "eval_every": args.eval_every})
    out = Session(spec).run()

    print(f"\n{'round':>5} {'loss':>8} {'energy(J)':>10} {'bits chosen':>16}")
    for h, e in zip(out["history"], out["energy_log"]):
        print(f"{h['round']:>5} {h['loss']:>8.4f} {e['energy_round']:>10.3f} "
              f"{str(sorted(set(h['bits'].tolist()))):>16}")
    print(f"\ntotal energy: {out['total_energy_j']:.2f} J over "
          f"{out['total_time_s']:.1f} s (simulated wall time)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"total_energy_j": out["total_energy_j"],
                       "total_time_s": out["total_time_s"],
                       "losses": [h["loss"] for h in out["history"]],
                       "evals": out["evals"]}, f, indent=1)
    return out


if __name__ == "__main__":
    main()
