"""repro — paper reproduction grown toward a production jax system.

Importing the package installs small forward-compat adapters for the pinned
jax version (see :mod:`repro._jax_compat`) so that all modules — and the
subprocess scripts the distributed tests spawn — can use the modern
``jax.shard_map`` / ``jax.make_mesh(axis_types=...)`` surface uniformly.
"""

from repro import _jax_compat

_jax_compat.install()
