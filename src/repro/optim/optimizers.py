"""Optimizers in pure JAX (no external deps).

The paper's server update is plain SGD in full precision (Algorithm 1
line 11); momentum/AdamW are provided for the beyond-paper experiments.
API mirrors optax: ``init(params) -> state``;
``update(grads, state, params) -> (updates, state)`` where ``updates`` are
*added* to params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(lr: Callable | float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = _tmap(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return state

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr_fn(step)
        g = _tmap(lambda gg: gg.astype(jnp.float32), grads)
        if weight_decay:
            g = _tmap(lambda gg, p: gg + weight_decay * p.astype(jnp.float32), g, params)
        if momentum:
            mu = _tmap(lambda m, gg: momentum * m + gg, state["mu"], g)
            upd = _tmap(lambda m: -lr_t * m, mu)
            return upd, {"step": step + 1, "mu": mu}
        return _tmap(lambda gg: -lr_t * gg, g), {"step": step + 1}

    return Optimizer(init, update)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.float32(lr))

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(zeros, params), "v": _tmap(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        g = _tmap(lambda gg: gg.astype(jnp.float32), grads)
        m = _tmap(lambda mm, gg: b1 * mm + (1 - b1) * gg, state["m"], g)
        v = _tmap(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, state["v"], g)
        mh = _tmap(lambda mm: mm / (1 - b1 ** step.astype(jnp.float32)), m)
        vh = _tmap(lambda vv: vv / (1 - b2 ** step.astype(jnp.float32)), v)
        upd = _tmap(lambda mm, vv, p: -lr_t * (mm / (jnp.sqrt(vv) + eps)
                                               + weight_decay * p.astype(jnp.float32)),
                    mh, vh, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def build_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name}")
