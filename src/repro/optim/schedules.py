"""Learning-rate schedules (pure functions of the int32 step)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr * (final_frac + (1 - final_frac) * cos))
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    decay = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, jnp.float32(lr) * w, decay(step - warmup))
    return f
