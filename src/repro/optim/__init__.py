from repro.optim.optimizers import Optimizer, adamw, build_optimizer, sgd  # noqa: F401
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
