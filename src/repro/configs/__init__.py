"""Config registry: the 10 assigned architectures (+ paper's CNN-class repro).

``get_config(name)`` returns the exact published config; ``smoke_variant``
shrinks it to a CPU-runnable reduced config of the same family (small widths,
few layers/experts, tiny vocab) for the per-arch smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    MeshConfig,
    ModelConfig,
    ShapeSpec,
    TrainConfig,
    shapes_for,
)

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma-7b": "gemma_7b",
    "glm4-9b": "glm4_9b",
    "yi-6b": "yi_6b",
    "starcoder2-15b": "starcoder2_15b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "mamba2-780m": "mamba2_780m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def smoke_variant(cfg: ModelConfig, *, tp: int = 1) -> ModelConfig:
    """Reduced same-family config runnable on CPU in seconds."""
    r = dict(
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 0,
        head_dim=16, d_ff=128, vocab_size=512,
        compute_dtype="float32", remat=False, rope_theta=1e4,
    )
    if cfg.family == "moe":
        r.update(n_layers=2, n_experts=8, experts_per_token=2, moe_d_ff=32)
    elif cfg.family == "dense":
        r.update(n_layers=2)
    elif cfg.family == "vlm":
        r.update(n_layers=4, cross_attn_period=2, n_image_tokens=9,
                 d_frontend=32)
    elif cfg.family == "ssm":
        r.update(n_layers=2, n_heads=0, n_kv_heads=0, d_ff=0, head_dim=0,
                 ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8)
    elif cfg.family == "encdec":
        r.update(n_layers=2, n_encoder_layers=2, d_frontend=32)
    elif cfg.family == "hybrid":
        r.update(n_layers=4, attn_period=2, moe_period=2, n_experts=4,
                 experts_per_token=2, moe_d_ff=32,
                 ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **r)
