"""mamba2-780m — 48L d1536, attention-free SSD, state 128.

Sub-quadratic: runs the long_500k cell.
[arXiv:2405.21060; unverified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, supports_long_context=True,
    source="arXiv:2405.21060",
)
