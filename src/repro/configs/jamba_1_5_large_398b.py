"""jamba-1.5-large-398b — 72L hybrid: Mamba+attention 1:7 interleave,
MoE 16e top-2 on every 2nd layer; d8192 64H(kv8) d_ff 24576.

Sub-quadratic mixers dominate: runs the long_500k cell.
[arXiv:2403.19887; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, moe_d_ff=24576, vocab_size=65536,
    n_experts=16, experts_per_token=2, moe_period=2,
    attn_period=8,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, supports_long_context=True,
    mlp_act="swiglu", rope_theta=1e4,
    source="arXiv:2403.19887",
)
