"""seamless-m4t-large-v2 — enc-dec 24+24L d1024 16H d_ff 8192, multimodal.

Assignment lists "24L": interpreted as 24 encoder + 24 decoder layers (the
published model is 24/24).  Audio frontend is a stub: precomputed frame
embeddings (d=1024).
[arXiv:2308.11596; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206, d_frontend=1024,
    mlp_act="swiglu", rope_theta=1e4,
    source="arXiv:2308.11596",
)
