"""llama-3.2-vision-90b — 100L d8192 64H(kv8) d_ff 28672; cross-attn image
layers every 5th layer; vision frontend is a stub (precomputed patch
embeddings, d=1280, 1601 tokens).

[hf:meta-llama/Llama-3.2-11B-Vision scaled; unverified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    mlp_act="swiglu", rope_theta=5e5,
    cross_attn_period=5, n_image_tokens=1601, d_frontend=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
