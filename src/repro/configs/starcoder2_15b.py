"""starcoder2-15b — 40L d6144 48H(kv4) d_ff 24576, GQA RoPE, GeLU MLP.

[arXiv:2402.19173; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_act="gelu", rope_theta=1e5,
    source="arXiv:2402.19173",
)
