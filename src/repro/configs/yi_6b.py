"""yi-6b — 32L d4096 32H(kv4) d_ff 11008, llama-arch GQA.

[arXiv:2403.04652; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    mlp_act="swiglu", rope_theta=5e6,
    source="arXiv:2403.04652",
)
