"""glm4-9b — 40L d4096 32H(kv2) d_ff 13696, RoPE GQA.

[hf:THUDM/glm-4-9b; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=151552,
    mlp_act="swiglu", rope_theta=1e4,
    source="hf:THUDM/glm-4-9b",
)
