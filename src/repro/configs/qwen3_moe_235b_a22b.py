"""qwen3-moe-235b-a22b — 94L d4096 64H(kv4) expert-ffn 1536, 128e top-8.

[hf:Qwen/Qwen3-30B-A3B family; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, moe_d_ff=1536, vocab_size=151936,
    n_experts=128, experts_per_token=8,
    mlp_act="swiglu", rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
