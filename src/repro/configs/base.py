"""Config dataclasses: model architecture, input shapes, mesh, training."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "cnn"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 => d_model // n_heads
    mlp_act: str = "swiglu"            # swiglu | geglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                  # expert hidden dim (if != d_ff)
    moe_period: int = 1                # MoE every k-th layer (1 = all)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (jamba) ---
    attn_period: int = 0               # 1 attention layer every `attn_period`
    # --- enc-dec ---
    n_encoder_layers: int = 0          # 0 => decoder-only
    # --- VLM ---
    cross_attn_period: int = 0         # cross-attn layer every k layers
    n_image_tokens: int = 0
    d_frontend: int = 0                # stub frontend embedding width
    # --- numerics / distribution ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    seq_parallel: bool = True          # Megatron-SP activation sharding
    fsdp_gather_dtype: str = ""        # "" = param dtype; "bfloat16" = cast-on-gather
    # --- notes ---
    supports_long_context: bool = False  # sub-quadratic => run long_500k
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp(dff):
            mults = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            return mults * d * dff

        n_blocks = self.n_layers
        total = emb + head
        if self.family in ("dense", "vlm"):
            per = qkv + mlp(self.d_ff)
            total += n_blocks * per
            if self.family == "vlm" and self.cross_attn_period:
                n_cross = n_blocks // self.cross_attn_period
                total += n_cross * qkv  # cross-attn projections
        elif self.family == "moe":
            per = qkv + self.n_experts * mlp(self.moe_d_ff or self.d_ff)
            per += d * self.n_experts  # router
            total += n_blocks * per
        elif self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            per = d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d
            total += n_blocks * per
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            ssm_per = d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d
            n_attn = self.n_layers // (self.attn_period or 8)
            n_ssm = self.n_layers - n_attn
            moe_per = self.n_experts * mlp(self.moe_d_ff or self.d_ff) + d * self.n_experts
            n_moe = self.n_layers // max(self.moe_period, 1)
            n_dense_mlp = self.n_layers - n_moe
            total += n_attn * qkv + n_ssm * ssm_per
            total += n_moe * moe_per + n_dense_mlp * mlp(self.d_ff)
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (qkv + mlp(self.d_ff))
            dec = self.n_layers * (2 * qkv + mlp(self.d_ff))  # self + cross
            total += enc + dec
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.family not in ("moe", "hybrid") or not self.n_experts:
            return self.param_count()
        dense = self.param_count()
        mults = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        per_expert = mults * self.d_model * (self.moe_d_ff or self.d_ff)
        n_moe = (self.n_layers // max(self.moe_period, 1))
        inactive = n_moe * (self.n_experts - self.experts_per_token) * per_expert
        return int(dense - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                         # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeSpec, ...]:
    """The shape cells this arch actually runs (long_500k: sub-quadratic only)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # full-attention arch: noted skip (DESIGN.md §6)
        out.append(s)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    optimizer: str = "sgd"             # paper server update is plain SGD
    momentum: float = 0.0
    weight_decay: float = 0.0
    n_rounds: int = 100
    microbatch: int = 0                # 0 = no microbatching
    seed: int = 0
    # FWQ (bit-width assignment lives in repro.api.PrecisionPolicy now):
    n_clients: int = 16
    error_tolerance: float = 0.05      # lambda in constraint (23)
    grad_compression_bits: int = 0     # 0 = off (paper-faithful)
    nonfinite_grads: str = "raise"     # wire-quantizer NaN/Inf policy:
    #                                    "raise" | "saturate"
