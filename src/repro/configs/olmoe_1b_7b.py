"""olmoe-1b-7b — 16L d2048 16H(kv16) expert-ffn 1024, 64e top-8.

[arXiv:2409.02060; hf-verified tier]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, moe_d_ff=1024, vocab_size=50304,
    n_experts=64, experts_per_token=8,
    mlp_act="swiglu", rope_theta=1e4,
    source="arXiv:2409.02060",
)
