"""RunSpec: the declarative description of one workload run.

A :class:`RunSpec` plus a :class:`~repro.api.precision.PrecisionPolicy` is
everything :class:`~repro.api.session.Session` needs to stand up any of the
five workload kinds — there is no other configuration channel.  Specs
round-trip through plain dicts (``to_dict``/``from_dict``) so launchers,
sweep drivers, and checkpoints can persist them as JSON.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.api.precision import PrecisionPolicy

#: The workload kinds Session can launch.
WORKLOADS = ("train", "serve", "dryrun", "fl-sim", "fl-orchestrate")

#: Architectures the fl-sim (paper CIFAR-class) workload accepts; every other
#: workload takes a model-zoo registry name (repro.configs.ARCH_NAMES).
SIM_ARCHS = ("mobilenet", "resnet")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """What to run: arch + workload + mesh topology + seed + precision.

    ``mesh`` is ``"DATAxMODEL"`` (e.g. ``"1x1"``, ``"16x16"``) or
    ``"PODxDATAxMODEL"`` (e.g. ``"2x16x16"``).  ``batch`` is the per-client
    batch for training workloads and the number of decode slots for serving.
    ``seq`` is the training sequence length / serving ``s_max``.
    Workload-specific knobs (steps, prompt_len, scheme, lr, ...) live in
    ``options`` — see :class:`~repro.api.session.Session` for the per-workload
    keys it reads.
    """

    arch: str
    workload: str = "train"
    mesh: str = "1x1"
    smoke: bool = True
    seed: int = 0
    batch: int = 4
    seq: int = 32
    rounds: int = 10
    precision: PrecisionPolicy = dataclasses.field(
        default_factory=PrecisionPolicy)
    options: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.workload not in WORKLOADS:
            raise ValueError(f"workload must be one of {WORKLOADS}, "
                             f"got {self.workload!r}")
        if isinstance(self.precision, dict):
            object.__setattr__(self, "precision",
                               PrecisionPolicy.from_dict(self.precision))

    def opt(self, key: str, default: Any = None) -> Any:
        return self.options.get(key, default)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["precision"] = self.precision.to_dict()
        d["options"] = dict(self.options)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        d = dict(d)
        if "precision" in d:
            d["precision"] = PrecisionPolicy.from_dict(d["precision"])
        return cls(**d)
