"""Unified per-tensor-role precision policy.

The paper's central object is a *per-device, per-round bit-width decision*
produced by the GBD co-design.  :class:`PrecisionPolicy` is the single typed
value that decision flows through — from ``GBDResult.q`` on the optimizer
side, through the FL orchestrator and the pod trainer's traced ``delta``
vector, down to the packed :class:`~repro.models.common.QTensor` storage the
``quant_matmul`` Pallas kernel streams on the serving side.

Roles (per-tensor-family bit assignment):

* ``weights``  — model weights.  An int (uniform) or a per-device tuple
  (heterogeneous, the paper's case).  32 = full precision.
* ``grads``    — server-side gradient aggregation precision.  The paper
  aggregates in full precision (Algorithm 1 line 10); only 32 is accepted.
* ``kv_cache`` — decode-cache storage: 32 → f32, 16 → bf16.
* ``comm``     — gradient wire bits for the SR-quantized all-reduce
  (:func:`repro.dist.collectives.quantized_psum_batch`); 32 = uncompressed.

``lazy`` selects the serving fast path: packed int8/int16 codes stay packed
through every dense projection (kernel-side dequantization) instead of being
expanded on use.  ``bit_options`` is the lattice the co-design searches — the
same tuple :class:`repro.core.master.MasterSpec` consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

FULL_PRECISION_BITS = 32

#: Tensor roles a policy assigns bits to.
ROLES = ("weights", "grads", "kv_cache", "comm")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    weights: int | tuple[int, ...] = FULL_PRECISION_BITS
    grads: int = FULL_PRECISION_BITS
    kv_cache: int = FULL_PRECISION_BITS
    comm: int = FULL_PRECISION_BITS
    lazy: bool = False
    bit_options: tuple[int, ...] = (8, 16, 32)

    def __post_init__(self):
        w = self.weights
        if isinstance(w, (list, np.ndarray)):
            w = tuple(int(b) for b in np.asarray(w).reshape(-1))
            object.__setattr__(self, "weights", w)
        elif not isinstance(w, tuple):
            object.__setattr__(self, "weights", int(w))
            w = self.weights
        object.__setattr__(self, "bit_options",
                           tuple(int(b) for b in self.bit_options))
        for b in (w if isinstance(w, tuple) else (w,)):
            if not 1 <= b <= FULL_PRECISION_BITS:
                raise ValueError(f"weight bits must be in [1, 32], got {b}")
        if self.grads != FULL_PRECISION_BITS:
            raise ValueError(
                "grads must be 32: the paper aggregates gradients in full "
                "precision (Algorithm 1 line 10); wire compression is the "
                "'comm' role")
        if self.kv_cache not in (16, FULL_PRECISION_BITS):
            raise ValueError(
                "kv_cache supports 32 (f32) or 16 (bf16) today; integer "
                f"KV-cache storage is not implemented (got {self.kv_cache})")
        if not 1 <= self.comm <= FULL_PRECISION_BITS:
            raise ValueError(f"comm bits must be in [1, 32], got {self.comm}")
        if self.lazy:
            if self.heterogeneous:
                raise ValueError("lazy (packed serving) needs a uniform "
                                 "weight bit-width, got per-device bits")
            if w >= FULL_PRECISION_BITS:
                raise ValueError("lazy packing needs weights < 32 bits")

    # -- constructors ---------------------------------------------------
    @classmethod
    def uniform(cls, bits: int, **kw) -> "PrecisionPolicy":
        """Every device / tensor at the same weight bit-width."""
        return cls(weights=int(bits), **kw)

    @classmethod
    def full_precision(cls, **kw) -> "PrecisionPolicy":
        return cls(weights=FULL_PRECISION_BITS, **kw)

    @classmethod
    def lazy_int8(cls, bits: int = 7, **kw) -> "PrecisionPolicy":
        """Serving fast path: int8-packed weights, kernel-side dequant."""
        return cls(weights=int(bits), lazy=True, **kw)

    @classmethod
    def from_gbd(cls, result: Any, **kw) -> "PrecisionPolicy":
        """Per-device weight bits from a co-design solution.

        ``result`` is a :class:`repro.core.gbd.GBDResult` (or any object with
        a ``.q`` bit-width vector, e.g. the baseline schemes' results), or a
        raw per-device bits array.  This is the ONLY sanctioned way the
        optimizer's chosen bits enter the training/serving stack.
        """
        q = getattr(result, "q", result)
        return cls(weights=tuple(int(b) for b in np.asarray(q).reshape(-1)),
                   **kw)

    # -- views ----------------------------------------------------------
    @property
    def heterogeneous(self) -> bool:
        return isinstance(self.weights, tuple)

    @property
    def serve_bits(self) -> int:
        """Uniform weight bit-width (the serving path packs one model)."""
        if self.heterogeneous:
            raise ValueError("serving needs a uniform policy; got per-device "
                             f"bits {self.weights}")
        return int(self.weights)

    @property
    def packed(self) -> bool:
        """Whether weights are stored as integer codes (QTensor)."""
        return not self.heterogeneous and self.serve_bits < FULL_PRECISION_BITS

    @property
    def grad_compression_bits(self) -> int:
        """Wire bits for the gradient all-reduce (0 = uncompressed)."""
        return 0 if self.comm >= FULL_PRECISION_BITS else int(self.comm)

    def bits_vector(self, n: int) -> np.ndarray:
        """(n,) per-device weight bits (heterogeneous tuples must cover n)."""
        if self.heterogeneous:
            if len(self.weights) < n:
                raise ValueError(f"policy carries {len(self.weights)} device "
                                 f"bit-widths but {n} were requested")
            return np.asarray(self.weights[:n], np.int64)
        return np.full((n,), int(self.weights), np.int64)

    def delta(self, n: int):
        """(n,) traced SR resolutions ``s * Delta_{q_i}`` for the trainer."""
        from repro.core.fwq import delta_for_clients

        return delta_for_clients(self.bits_vector(n))

    def weight_storage_dtype(self):
        """Packed-code dtype the kernel sees (int8 / int16 / int32)."""
        from repro.core.quantization import storage_dtype

        return storage_dtype(self.serve_bits)

    def kv_cache_dtype(self):
        import jax.numpy as jnp

        return jnp.float32 if self.kv_cache >= FULL_PRECISION_BITS else jnp.bfloat16

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "weights": (list(self.weights) if self.heterogeneous
                        else int(self.weights)),
            "grads": int(self.grads),
            "kv_cache": int(self.kv_cache),
            "comm": int(self.comm),
            "lazy": bool(self.lazy),
            "bit_options": list(self.bit_options),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionPolicy":
        d = dict(d)
        w = d.get("weights", FULL_PRECISION_BITS)
        d["weights"] = tuple(w) if isinstance(w, (list, tuple)) else int(w)
        d["bit_options"] = tuple(d.get("bit_options", (8, 16, 32)))
        return cls(**d)
