"""Session: the one front door for every workload.

``Session(RunSpec(...))`` owns the mesh, :class:`AxisCtx`, model,
``ParamCtx`` construction, and checkpoint manager, and exposes the five
workload kinds behind one API::

    from repro.api import PrecisionPolicy, RunSpec, Session

    stats = Session(RunSpec("yi-6b", workload="serve",
                            precision=PrecisionPolicy.lazy_int8())).run()

Per-workload ``options`` keys:

* ``train`` / ``fl-orchestrate`` — ``scheme`` (fl-orchestrate only), ``lr``,
  ``ckpt_dir``, ``out``, ``quiet``.
* ``serve`` — ``steps``, ``s_max``, ``prompt_len``, ``attn_impl``,
  ``requests``, ``max_new``, ``quiet``.
* ``dryrun`` — ``shape``, ``variant`` (gather_bf16 / capacity / no_remat),
  ``out``.
* ``fl-sim`` — ``scheme``, ``n_clients``, ``lr``, ``error_tolerance``,
  ``eval_every``, ``quiet``, ``faults`` (a ``FaultPlan`` dict: deterministic
  fault injection + resilient rounds), ``resolve_drift_db``, ``ckpt_dir``,
  ``ckpt_every``.
* any workload — ``precision_program`` (a :mod:`repro.api.program` kind name
  or config dict): the per-round controller that turns measured state into
  the round's :class:`PrecisionPolicy`.  The default ``constant`` program is
  the identity — it reproduces the static-policy run bitwise.

The ``train`` workload runs federated rounds at the spec's FIXED
:class:`PrecisionPolicy`; ``fl-orchestrate`` is the paper's full loop — every
round the GBD co-design emits a fresh per-device policy
(``PrecisionPolicy.from_gbd``) that drives the same traced-delta train step.
A non-constant ``precision_program`` sits between the two: the program may
clamp the proposed policy round-by-round (energy budget tracking, channel
drift re-solves, paged-KV pool demotion).  Compiled train steps are cached
per compile-relevant policy key, so a schedule that visits K distinct comm
bit-widths costs K compiles, not one per round.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import logging
import time

import numpy as np

from repro.api.precision import PrecisionPolicy
from repro.api.spec import RunSpec, SIM_ARCHS

log = logging.getLogger("repro.api")

BOS_ID = 1


@dataclasses.dataclass
class ServeStats:
    """What one driver run measured (bench_serving / tests consume this)."""

    arch: str
    bits: int
    attn_impl: str
    decode_steps: int
    decoded_tokens: int          # tokens produced by ACTIVE slots only
    completed: int               # sequences finished
    admitted: int                # sequences admitted (>= batch when the
                                 # queue forced mid-flight admissions)
    wall_s: float                # decode-loop wall clock (post-compile)
    tok_s: float
    bytes_per_step_packed: int   # weight bytes streamed per decode step
    bytes_per_step_f32: int      # same weights at f32
    packed_vs_f32: float         # packed / f32 byte ratio
    sample: list                 # first finished sequence's tokens
    kv_layout: str = "contiguous"    # "paged" | "contiguous"
    page_size: int = 0               # tokens per page (0 = contiguous)
    kv_bytes: int = 0                # resident K/V bytes, this layout
    kv_bytes_contiguous: int = 0     # what a contiguous cache would reserve
    capacity_stops: int = 0          # sequences stopped AT CACHE CAPACITY
                                     # (the anti-silent-clip guard firing)
    deferred_admissions: int = 0     # admissions that waited for page reclaim
    prompt_buckets: list = dataclasses.field(default_factory=list)
    kv_demotions: int = 0            # f32 -> bf16 pool casts under pressure
                                     # (precision_program kv_watermark)
    kv_bits_final: int = 0           # KV element bits when the run ended


def _weight_bytes(tree) -> int:
    import jax

    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


class Session:
    """Owns mesh + axes + model + precision plumbing for one RunSpec."""

    def __init__(self, spec: RunSpec):
        self.spec = spec
        self._train_state: dict | None = None

    # -- lazily-built shared structure ----------------------------------
    @functools.cached_property
    def policy(self) -> PrecisionPolicy:
        return self.spec.precision

    @functools.cached_property
    def program(self):
        """The per-round precision controller (``precision_program`` option;
        defaults to the identity ``constant`` program)."""
        from repro.api.program import build_program

        return build_program(self.spec.opt("precision_program"))

    @functools.cached_property
    def cfg(self):
        from repro.configs import get_config, smoke_variant

        if self.spec.arch in SIM_ARCHS:
            raise ValueError(f"{self.spec.arch!r} is an fl-sim architecture; "
                             "the model-zoo config registry does not apply")
        cfg = get_config(self.spec.arch)
        return smoke_variant(cfg) if self.spec.smoke else cfg

    @functools.cached_property
    def model(self):
        from repro.models.model import build_model

        return build_model(self.cfg)

    @functools.cached_property
    def _mesh_and_axes(self):
        from repro.launch.mesh import mesh_and_axes, parse_mesh

        shape, _ = parse_mesh(self.spec.mesh)   # spec errors surface as-is
        if self.spec.workload == "dryrun":
            # AOT lowering needs the full device grid to exist as fake host
            # devices.  XLA reads the flag at backend init, so set it here —
            # before the first device query — rather than relying on the CLI
            # shim's import-time environ write.
            import os

            need = int(np.prod(shape))
            flags = os.environ.get("XLA_FLAGS", "")
            if "xla_force_host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    f"{flags} --xla_force_host_platform_device_count={need}"
                ).strip()
        try:
            return mesh_and_axes(self.spec.mesh)
        except ValueError as e:
            raise ValueError(
                f"mesh {self.spec.mesh!r} needs more devices than this "
                "process has (jax already initialized its backend?); start a "
                "fresh process or export XLA_FLAGS="
                "--xla_force_host_platform_device_count=<n> first") from e

    @property
    def mesh(self):
        return self._mesh_and_axes[0]

    @property
    def axes(self):
        return self._mesh_and_axes[1]

    @functools.cached_property
    def ckpt(self):
        from repro.ckpt import CheckpointManager

        ckpt_dir = self.spec.opt("ckpt_dir", "")
        every = int(self.spec.opt("ckpt_every", 10))
        return CheckpointManager(ckpt_dir, every=every) if ckpt_dir else None

    def train_config(self):
        from repro.configs.base import TrainConfig

        return TrainConfig(
            learning_rate=float(self.spec.opt("lr", 0.05)),
            seed=self.spec.seed,
            grad_compression_bits=self.policy.grad_compression_bits,
            nonfinite_grads=str(self.spec.opt("nonfinite_grads", "raise")))

    def comm_report(self) -> dict:
        """Bytes-on-wire for gradient reduction on this mesh, per round.

        The flat top-level keys are the BASE policy's one-round accounting
        (the stable contract the analyzer's ``wire.comm_report_mismatch``
        check and the sweep reporter read): replicated leaves move
        ``policy.comm``-bit codes through the SR-quantized all-reduce
        (:func:`repro.dist.collectives.quantized_psum_batch`), FSDP leaves
        reduce-scatter in f32.  Uses the same local parameter template and
        FSDP plan the compiled train step partitions with.

        ``rounds`` adds one row per round with the comm bits that round
        actually used — executed bits once rounds have run, otherwise the
        static schedule (base policy every round) — so an adaptive
        program's mixed-width schedule shows up row by row instead of being
        averaged away.  ``program`` carries the controller's comm envelope
        and the widest wire accumulator any member needs.
        """
        from repro.dist.collectives import envelope_wire_dtype
        from repro.dist.wire import grad_wire_report, grad_wire_rounds
        from repro.launch.mesh import batch_size, fsdp_size
        from repro.launch.steps import local_param_shapes

        tree = local_param_shapes(self.model, self.mesh, self.axes)
        fsdp = fsdp_size(self.mesh, self.axes)
        n = max(batch_size(self.mesh, self.axes), 1)
        rep = grad_wire_report(tree, fsdp=fsdp, n_clients=n,
                               comm_bits=self.policy.comm)
        bits_seq = self._executed_comm_bits()
        if bits_seq is None:
            bits_seq = [int(self.policy.comm)] * max(self.spec.rounds, 1)
        rows = grad_wire_rounds(tree, fsdp=fsdp, n_clients=n,
                                comm_bits_seq=bits_seq)
        rep["rounds"] = rows
        rep["total_bytes_wire"] = int(sum(r["replicated_bytes_wire"]
                                          for r in rows))
        rep["total_bytes_f32"] = int(sum(r["replicated_bytes_f32"]
                                         for r in rows))
        env = self.program.comm_envelope(self.policy)
        dt = envelope_wire_dtype(env, n)
        rep["program"] = {
            "kind": self.program.kind,
            "comm_envelope": [int(b) for b in env],
            "envelope_wire_dtype": (np.dtype(dt).name if dt is not None
                                    else "float32"),
        }
        return rep

    def _executed_comm_bits(self) -> "list[int] | None":
        """Per-round comm bits actually run so far, oldest first (None
        before any round has executed)."""
        st = self._train_state
        if not st:
            return None
        orch = st.get("orch")
        if orch is not None and orch.energy_log:
            return [int(e.get("comm_bits", self.policy.comm))
                    for e in orch.energy_log]
        hist = st.get("history") or []
        if hist and "comm_bits" in hist[0]:
            return [int(h["comm_bits"]) for h in hist]
        return None

    # -- primitive builders ---------------------------------------------
    def init_params(self, key=None):
        import jax

        from repro.launch.steps import build_init_fn

        init_fn, _ = build_init_fn(self.model, self.mesh, self.axes)
        return init_fn(key if key is not None
                       else jax.random.PRNGKey(self.spec.seed))

    def train_step(self, opt=None, *, attn_impl: str = "auto",
                   donate: bool = False):
        """Policy-driven :class:`~repro.launch.steps.TrainStep` builder."""
        from repro.launch.steps import build_train_step
        from repro.optim import build_optimizer

        tc = self.train_config()
        if opt is None:
            opt = build_optimizer("sgd", tc.learning_rate)
        return build_train_step(self.model, self.mesh, self.axes, opt, tc,
                                attn_impl=attn_impl, donate=donate)

    # -- workload dispatch ----------------------------------------------
    def run(self):
        wl = self.spec.workload
        if wl in ("train", "fl-orchestrate"):
            return self.run_train()
        if wl == "serve":
            return self.serve()
        if wl == "dryrun":
            return self.run_dryrun()
        if wl == "fl-sim":
            return self.run_fl_sim()
        raise ValueError(wl)  # unreachable: RunSpec validates

    # ------------------------------------------------------------------
    # train / fl-orchestrate: the pod FWQ-FL loop
    # ------------------------------------------------------------------
    def _ensure_train_state(self) -> dict:
        if self._train_state is not None:
            return self._train_state
        import jax
        import jax.numpy as jnp

        from repro.core.energy import heterogeneous_fleet, memory_capacities
        from repro.data.pipeline import TokenBatcher
        from repro.data.synthetic import SyntheticTokens
        from repro.fed.orchestrator import FLOrchestrator, OrchestratorConfig
        from repro.optim import build_optimizer

        spec, cfg = self.spec, self.cfg
        tc = self.train_config()
        opt = build_optimizer("sgd", tc.learning_rate)
        ts = self.train_step(opt, donate=False)
        n_clients = ts.n_clients
        B = n_clients * spec.batch

        params = self.init_params()
        opt_state = opt.init(params)

        tokens = SyntheticTokens(n_tokens=300_000, vocab=cfg.vocab_size,
                                 seed=spec.seed).generate()
        batcher = TokenBatcher(tokens, spec.seq, seed=spec.seed)

        orch = None
        if spec.workload == "fl-orchestrate":
            fleet = heterogeneous_fleet(n_clients, seed=spec.seed,
                                        group_step_mhz=5.0)
            caps = memory_capacities(n_clients, lo_mb=8, hi_mb=64) * 1e6
            n_params = cfg.param_count()
            orch = FLOrchestrator(
                OrchestratorConfig(n_devices=n_clients, n_rounds=spec.rounds,
                                   scheme=spec.opt("scheme", "fwq"),
                                   model_dim_d=n_params,
                                   precision=self.policy, seed=spec.seed,
                                   faults=spec.opt("faults"),
                                   program=spec.opt("precision_program"),
                                   resolve_drift_db=float(
                                       spec.opt("resolve_drift_db", 0.0))),
                fleet, caps, grad_bytes=4.0 * n_params)

        step = ts.fn(self.model.train_batch_spec(B, spec.seq))
        start = 0
        if self.ckpt:
            expect = None
            if orch is not None:
                expect = {"faults": (orch.cfg.faults.to_dict()
                                     if orch.cfg.faults is not None else None)}
            state, start, _ = self.ckpt.restore_or({"p": params, "o": opt_state},
                                                   expect_extra=expect)
            if start:
                params, opt_state = state["p"], state["o"]
                log.info("resumed at round %d", start)
                if orch is not None:
                    # replay the completed rounds' planning (seeded host
                    # math): rebuilds the solver cadence, fault realizations
                    # and energy log exactly as the uninterrupted run saw
                    # them, so the resumed trajectory is bit-identical
                    for r in range(start):
                        orch.plan_round(r)
                else:
                    # plain train: the session program is the only stateful
                    # planner — replay its (deterministic, observation-
                    # driven) decisions the same way
                    for r in range(start):
                        self.program.policy_for_round(
                            r, self.policy, self._observe_train(r))

        self._train_state = dict(
            jax=jax, jnp=jnp, opt=opt, step=step, params=params,
            opt_state=opt_state, batcher=batcher, orch=orch,
            n_clients=n_clients, B=B, start=start, history=[],
            step_cache={self.policy.grad_compression_bits: step},
            energy_cum=0.0)
        return self._train_state

    def _observe_train(self, r: int):
        """Controller observation for the plain ``train`` workload (no
        orchestrator energy model: cumulative spend is what the history
        rows have recorded, 0.0 before any round runs)."""
        from repro.api.program import Observation

        st = self._train_state or {}
        hist = st.get("history") or []
        return Observation(
            round=r, rounds_total=self.spec.rounds,
            energy_cum_j=float(st.get("energy_cum", 0.0)),
            energy_round_j=float(hist[-1]["energy_j"]) if hist else 0.0)

    def _train_step_for(self, policy: PrecisionPolicy):
        """Compiled train step for ``policy``, cached by its compile-relevant
        key (the gradient wire width — weight bits flow through the traced
        ``delta`` argument, so they never force a retrace).  A K-policy
        schedule therefore costs K compiles, not one per round."""
        from repro.launch.steps import build_train_step

        st = self._ensure_train_state()
        key = policy.grad_compression_bits
        cache = st["step_cache"]
        if key not in cache:
            tc = dataclasses.replace(self.train_config(),
                                     grad_compression_bits=key)
            ts = build_train_step(self.model, self.mesh, self.axes,
                                  st["opt"], tc, donate=False)
            cache[key] = ts.fn(self.model.train_batch_spec(st["B"],
                                                           self.spec.seq))
        return cache[key]

    def fl_round(self, r: int) -> dict:
        """One federated round: per-round policy -> traced delta -> step.

        Under ``fl-orchestrate`` the round's :class:`PrecisionPolicy` comes
        from the GBD co-design (``plan["policy"]``, built via
        ``PrecisionPolicy.from_gbd``); under ``train`` the spec's fixed
        policy applies every round.
        """
        st = self._ensure_train_state()
        jax, jnp = st["jax"], st["jnp"]
        spec, cfg = self.spec, self.cfg
        n_clients, B = st["n_clients"], st["B"]

        plan = st["orch"].plan_round(r) if st["orch"] is not None else None
        if plan is not None:
            # the orchestrator already ran its own program over the GBD
            # proposal — plan["policy"] is the round's final word
            policy = plan["policy"]
        else:
            policy = self.program.policy_for_round(r, self.policy,
                                                   self._observe_train(r))
        bits = policy.bits_vector(n_clients)

        raw = st["batcher"].sample_round(r, n_clients, spec.batch)
        batch = {
            "tokens": jnp.asarray(raw["tokens"].reshape(B, spec.seq)),
            "labels": jnp.asarray(raw["labels"].reshape(B, spec.seq)),
        }
        if cfg.family == "vlm":
            batch["images"] = jnp.zeros((B, cfg.n_image_tokens,
                                         cfg.d_frontend), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, spec.seq, cfg.d_frontend),
                                        jnp.float32)
        delta = policy.delta(n_clients)
        step = self._train_step_for(policy)
        t0 = time.time()
        st["params"], st["opt_state"], m = step(
            st["params"], st["opt_state"], batch, delta,
            jax.random.fold_in(jax.random.PRNGKey(spec.seed), r))
        rec = {"round": r, "loss": float(m["loss"]),
               "bits": bits.tolist(),
               "comm_bits": int(policy.comm),
               "energy_j": plan["energy_round"] if plan else 0.0,
               "t_round_s": plan["t_round"] if plan else 0.0,
               "wall_s": round(time.time() - t0, 3),
               "cohort": int(plan["cohort"].sum()) if plan else n_clients}
        if plan is not None and "retransmissions" in plan:
            rec.update(retransmissions=plan["retransmissions"],
                       retx_energy_j=plan["retx_energy_j"],
                       undelivered=plan["undelivered"],
                       dropped_midround=plan["dropped_midround"])
        st["history"].append(rec)
        st["energy_cum"] += float(rec["energy_j"])
        if self.ckpt:
            extra = {"round": r + 1}
            orch = st["orch"]
            if orch is not None:
                extra["faults"] = (orch.cfg.faults.to_dict()
                                   if orch.cfg.faults is not None else None)
            self.ckpt.maybe_save(r + 1, {"p": st["params"],
                                         "o": st["opt_state"]}, extra=extra)
        return rec

    def run_train(self) -> list[dict]:
        st = self._ensure_train_state()
        quiet = bool(self.spec.opt("quiet", False))
        for r in range(st["start"], self.spec.rounds):
            rec = self.fl_round(r)
            if not quiet:
                log.info("round %d loss=%.4f bits=%s energy=%.2fJ",
                         r, rec["loss"], sorted(set(rec["bits"])),
                         rec["energy_j"])
        history = st["history"]
        total_e = sum(h["energy_j"] for h in history)
        if not quiet and history:
            scheme = (self.spec.opt("scheme", "fwq")
                      if self.spec.workload == "fl-orchestrate" else "fixed")
            print(f"\nscheme={scheme} rounds={len(history)} "
                  f"final_loss={history[-1]['loss']:.4f} "
                  f"total_energy={total_e:.2f}J")
        out = self.spec.opt("out", "")
        if out:
            with open(out, "w") as f:
                json.dump(history, f, indent=1)
        return history

    # ------------------------------------------------------------------
    # serve: continuous-batching quantized decode driver
    # ------------------------------------------------------------------
    def serve(self, **overrides) -> ServeStats:
        """Drive the continuous-batching decode loop; returns ServeStats.

        Weight precision comes from the session policy: ``packed`` policies
        store int8/int16 ``QTensor`` codes, and ``policy.lazy`` keeps them
        packed through the ``quant_matmul`` kernel path.  ``overrides`` patch
        individual options (steps, requests, ...) for this call only.

        KV-cache layout (``kv_layout`` option, default ``"paged"`` where the
        family supports it): the paged layout allocates each request's pages
        ON ADMIT for its full capacity (prompt + max_new, page-rounded) from
        a shared pool sized by ``pool_pages`` (default: the largest
        ``batch`` concurrent requests), reclaims them on completion, and
        DEFERS admissions the pool cannot hold until a completion frees
        pages.  Either layout enforces capacity: a slot whose cache fills up
        is stopped and counted in ``capacity_stops`` instead of silently
        clipping its context.  Prompts are right-padded to power-of-two
        buckets so one compiled prefill serves every prompt length in the
        bucket (``vary_prompt`` draws ragged prompt lengths).
        """
        import jax
        import jax.numpy as jnp

        from repro.core.quantization import default_exempt
        from repro.launch.paging import (SlotPager, kv_cache_bytes,
                                         pages_for, plan_admissions,
                                         set_page_tables)
        from repro.launch.steps import (
            build_cached_prefill, build_decode_step, init_global_caches)
        from repro.models.common import pack_params_for_policy

        spec, policy = self.spec, self.policy
        o = dict(spec.options)
        o.update(overrides)
        steps = int(o.get("steps", 16))
        batch = spec.batch
        s_max = int(o.get("s_max", spec.seq))
        prompt_len = min(int(o.get("prompt_len", 8)), s_max)
        attn_impl = o.get("attn_impl", "ref")
        requests = o.get("requests")
        max_new = o.get("max_new")
        quiet = bool(o.get("quiet", False))
        vary_prompt = bool(o.get("vary_prompt", False))
        seed = spec.seed

        if attn_impl not in ("ref", "flash"):
            raise ValueError(f"attn_impl must be 'ref' or 'flash', "
                             f"got {attn_impl!r}")
        impl = "auto" if attn_impl == "ref" else "flash"

        def say(msg):
            if not quiet:
                print(msg)

        cfg, model, mesh, axes = self.cfg, self.model, self.mesh, self.axes

        # ---- KV layout ---------------------------------------------------
        kv_layout_opt = o.get("kv_layout")
        kv_layout = (kv_layout_opt if kv_layout_opt is not None
                     else "paged" if model.supports_paged_kv
                     else "contiguous")
        if kv_layout not in ("paged", "contiguous"):
            raise ValueError(f"kv_layout must be 'paged' or 'contiguous', "
                             f"got {kv_layout!r}")
        if kv_layout == "paged" and not model.supports_paged_kv:
            kv_layout = "contiguous"    # SSM: O(1) state, nothing to page
        if kv_layout == "paged":
            from repro.launch.mesh import tp_size
            from repro.models.attention import kv_cache_seq_parallel
            from repro.models.transformer import attn_dims

            if kv_cache_seq_parallel(attn_dims(cfg, tp_size(mesh, axes))):
                # the driver's host page allocator covers the kv-sharded /
                # tp=1 layouts; sequence-parallel paged decode is exercised
                # at the step level (build_decode_step).  A defaulted layout
                # falls back so tp>1 kv-replicated serving keeps working;
                # only an EXPLICIT paged request errors.
                if kv_layout_opt is None:
                    kv_layout = "contiguous"
                else:
                    raise ValueError(
                        "kv_layout='paged' is not supported by the serving "
                        "driver on sequence-parallel (kv-replicated, tp>1) "
                        "meshes; drop the option to fall back to contiguous "
                        "or drive build_decode_step directly")
        page_size = o.get("page_size")
        if page_size is None:
            page_size = next(p for p in (16, 8, 4, 2, 1) if s_max % p == 0)
        page_size = int(page_size)

        params = self.init_params()

        # ---- pack to the policy's storage (norm/router exemptions as in
        # training) ------------------------------------------------------
        raw_bytes = _weight_bytes(params)
        f32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
        serve_bits = policy.serve_bits
        qparams = pack_params_for_policy(params, policy, jax.random.PRNGKey(1),
                                         exempt=default_exempt)
        q_bytes = _weight_bytes(qparams)
        if policy.packed:
            say(f"params: {raw_bytes/1e6:.1f} MB f32 -> {q_bytes/1e6:.1f} MB "
                f"packed ({raw_bytes/q_bytes:.2f}x smaller, bits={serve_bits})")
        else:
            say(f"params: {raw_bytes/1e6:.1f} MB f32 (unpacked baseline)")

        # ---- synthetic request queue ------------------------------------
        n_requests = requests if requests is not None else 2 * batch
        rng = np.random.RandomState(seed)
        # default cap: ~half the step budget, so completions (and therefore
        # mid-flight admissions) actually happen within a demo-sized run.
        # An EXPLICIT max_new is honored as asked — a request that outgrows
        # its cache stops at capacity and is counted, never silently clipped.
        if max_new is not None:
            cap = max(1, int(max_new))
        else:
            cap = max(1, min(max(2, steps // 2), s_max - prompt_len - 1))
        needs_tokens = "tokens" in model.prefill_batch_spec(batch, prompt_len,
                                                           s_max)
        queue = []
        for i in range(n_requests):
            plen = (int(rng.randint(max(1, prompt_len // 2), prompt_len + 1))
                    if vary_prompt else prompt_len)
            queue.append(
                {"id": i,
                 "prompt": rng.randint(2, cfg.vocab_size, size=(plen,)),
                 "prompt_len": plen if needs_tokens else 0,
                 # staggered lengths so completions (and admissions) interleave
                 "max_new": int(rng.randint(max(1, cap // 2), cap + 1))})

        def bucket_of(plen: int) -> int:
            b = 4
            while b < plen:
                b *= 2
            return min(b, s_max)

        # ---- caches + pager ---------------------------------------------
        if kv_layout == "paged":
            def req_pages(req):
                tokens_cap = min(req["prompt_len"] + req["max_new"], s_max)
                return pages_for(tokens_cap, page_size)

            pool_pages = o.get("pool_pages")
            if pool_pages is None:
                # hold the `batch` largest concurrent requests — strictly
                # below the contiguous batch*s_max worst case on mixed loads
                demand = sorted((req_pages(r) for r in queue), reverse=True)
                pool_pages = max(sum(demand[:batch]), 1)
            pool_pages = int(pool_pages)
            pager = SlotPager.build(batch, s_max, page_size, pool_pages)
            cache_kw = {"page_size": page_size, "pool_pages": pool_pages}
        else:
            pager = None
            cache_kw = {}
        caches = init_global_caches(model, mesh, axes, s_max=s_max,
                                    batch_global=batch,
                                    dtype=policy.kv_cache_dtype(), **cache_kw)
        kv_bytes = kv_cache_bytes(caches)
        kv_bytes_contig = kv_cache_bytes(jax.eval_shape(
            lambda: init_global_caches(model, mesh, axes, s_max=s_max,
                                       batch_global=batch,
                                       dtype=policy.kv_cache_dtype())))

        # ---- compiled steps ---------------------------------------------
        ptree = jax.eval_shape(lambda: qparams)
        ss = build_decode_step(model, mesh, axes, params_tree=ptree,
                               s_max=s_max, batch_global=batch, policy=policy,
                               attn_impl=attn_impl, **cache_kw)
        pf_cache: dict = {}

        def prefill_for(bucket: int):
            if bucket not in pf_cache:
                pf_cache[bucket] = build_cached_prefill(
                    model, mesh, axes, params_tree=ptree, s_max=s_max,
                    s_prompt=bucket, batch_global=batch, attn_impl=impl,
                    policy=policy, bos_id=BOS_ID, with_prompt_lens=True,
                    **cache_kw)
            return pf_cache[bucket]

        d_front = cfg.d_frontend or cfg.d_model
        n_img = cfg.n_image_tokens or 1601

        def prefill_batch(slots_to_fill, bucket: int):
            """Assemble the (B, ...) prefill inputs; only masked slots matter."""
            b = {}
            if needs_tokens:
                toks = np.ones((batch, bucket), np.int32)
                for s, req in slots_to_fill:
                    toks[s, : len(req["prompt"])] = req["prompt"]
                b["tokens"] = jnp.asarray(toks)
            if cfg.family == "vlm":
                key = jax.random.PRNGKey(seed + 101)
                b["images"] = jax.random.normal(key, (batch, n_img, d_front),
                                                jnp.float32)
            if cfg.family == "encdec":
                key = jax.random.PRNGKey(seed + 102)
                b["frames"] = jax.random.normal(key, (batch, s_max, d_front),
                                                jnp.float32)
            return b

        kv_bits = 16 if policy.kv_cache_dtype() == jnp.bfloat16 else 32
        kv_demotions = 0
        pool_pressure = 0.0

        # ---- slot state (host side) -------------------------------------
        active = np.zeros((batch,), bool)
        remaining = np.zeros((batch,), np.int64)
        slot_plen = np.zeros((batch,), np.int64)   # tokens cached at admit
        slot_cap = np.full((batch,), s_max, np.int64)
        seqs = [[] for _ in range(batch)]
        finished = []
        cur_tok = jnp.full((batch, 1), BOS_ID, jnp.int32)
        admitted = completed = decoded = 0
        capacity_stops = 0
        deferred_ids: set = set()   # requests that waited at least once

        def req_cap(req):
            return min(req["prompt_len"] + req["max_new"], s_max)

        def admit():
            nonlocal caches, cur_tok, admitted, pool_pressure
            free = [i for i in range(batch) if not active[i]]
            fill = []
            if pager is None:
                while free and queue:
                    fill.append((free.pop(0), queue.pop(0)))
            else:
                # FIFO with cascading reservation (plan_admissions): younger
                # requests may fill slots out of the page surplus, but every
                # freed page accrues to the oldest page-blocked request
                # first, so a big request is never starved by small ones
                demands = [pager.pages_for(req_cap(r)) for r in queue]
                take, blocked = plan_admissions(pager.pool.free_pages,
                                                len(free), demands)
                for qi in blocked:
                    if demands[qi] > pager.pool.n_pages:
                        raise ValueError(
                            f"page pool ({pager.pool.n_pages} pages) can "
                            f"never fit a {demands[qi]}-page request; raise "
                            "pool_pages")
                    # waited at least once for page reclaim (counted once
                    # per request, however many cycles it waits)
                    deferred_ids.add(queue[qi]["id"])
                for qi in take:
                    req = queue[qi]
                    slot = free.pop(0)
                    if not pager.admit(slot, req_cap(req)):
                        raise RuntimeError(
                            "admission plan out of sync with page pool")
                    fill.append((slot, req))
                for qi in sorted(take, reverse=True):
                    queue.pop(qi)
                # watermark signal: a page-blocked admission saturates the
                # pressure (the pool is effectively full for the queue even
                # if a few pages remain free)
                pool_pressure = 1.0 if blocked else pager.pool.pressure
            if not fill:
                return
            if pager is not None:
                caches = set_page_tables(caches, pager.table)
            new_tok = np.array(cur_tok)
            by_bucket: dict[int, list] = {}
            for s, req in fill:
                by_bucket.setdefault(bucket_of(len(req["prompt"])), []).append(
                    (s, req))
            for bucket, group in sorted(by_bucket.items()):
                pf = prefill_for(bucket)
                mask = np.zeros((batch,), bool)
                plens = np.ones((batch,), np.int32)
                for s, req in group:
                    mask[s] = True
                    plens[s] = len(req["prompt"])
                tok, caches_new = pf.fn(qparams, prefill_batch(group, bucket),
                                        caches, jnp.asarray(mask),
                                        jnp.asarray(plens))
                caches = caches_new
                tok = np.asarray(tok)
                for s, req in group:
                    active[s] = True
                    remaining[s] = req["max_new"]
                    slot_plen[s] = req["prompt_len"]
                    slot_cap[s] = (pager.slot_capacity(s) if pager is not None
                                   else s_max)
                    seqs[s] = [int(tok[s, 0])]
                    new_tok[s] = tok[s]
                    admitted += 1
            cur_tok = jnp.asarray(new_tok)

        def maybe_demote_kv():
            """f32 -> bf16 pool demotion when paged-KV pressure crosses the
            program's watermark (a one-way ratchet; the jitted decode step
            retraces once on the narrower cache dtype)."""
            nonlocal caches, kv_bits, kv_demotions
            if pager is None or kv_bits <= 16:
                return
            from repro.api.program import Observation

            obs = Observation(round=admitted, pool_pressure=pool_pressure)
            if self.program.kv_demote(obs):
                from repro.models.attention import demote_kv_cache

                caches = demote_kv_cache(caches, jnp.bfloat16)
                kv_bits = 16
                kv_demotions += 1
                say(f"kv cache: pool pressure {pool_pressure:.2f} >= "
                    f"watermark {self.program.kv_watermark} -> demoted "
                    "f32 pools to bf16")

        admit()
        maybe_demote_kv()
        # first call compiles; its output is a real decode step, consumed below
        tok, caches = ss.fn(qparams, {"token": cur_tok}, caches)
        tok_h = np.asarray(tok)               # sync: compile finishes here
        t0, step_i, decoded_at_t0 = time.time(), 1, 0
        while True:
            done_any = False
            for s in range(batch):
                if not active[s]:
                    continue
                seqs[s].append(int(tok_h[s, 0]))
                decoded += 1
                remaining[s] -= 1
                # tokens cached so far (the newest token is not written until
                # it is fed back)
                cached = slot_plen[s] + len(seqs[s]) - 1
                done = remaining[s] <= 0
                if not done and cached >= slot_cap[s]:
                    # cache full: STOP the slot — decoding on would drop K/V
                    # writes and silently degrade the context (the old
                    # driver's failure mode)
                    done = True
                    capacity_stops += 1
                if done:
                    active[s] = False
                    if pager is not None:
                        pager.evict(s)
                    finished.append(seqs[s])
                    completed += 1
                    done_any = True
            if step_i == 1:
                decoded_at_t0 = decoded       # step 1 ran pre-timer (compile)
            if step_i >= steps or (not active.any() and not queue):
                break
            if done_any and pager is not None:
                # cleared table rows make the evicted slots' future writes
                # drop instead of landing on reclaimed pages
                caches = set_page_tables(caches, pager.table)
            cur_tok = jnp.asarray(tok_h)      # each slot feeds its own last token
            if done_any and queue:
                admit()                       # mid-flight slot reuse: overwrites
                                              # the admitted slots in cur_tok
                maybe_demote_kv()
            tok, caches = ss.fn(qparams, {"token": cur_tok}, caches)
            tok_h = np.asarray(tok)
            step_i += 1
        wall = time.time() - t0

        stats = ServeStats(
            arch=self.spec.arch, bits=serve_bits, attn_impl=attn_impl,
            decode_steps=step_i, decoded_tokens=decoded, completed=completed,
            admitted=admitted, wall_s=wall,
            tok_s=(decoded - decoded_at_t0) / max(wall, 1e-9),
            bytes_per_step_packed=q_bytes, bytes_per_step_f32=f32_bytes,
            packed_vs_f32=q_bytes / max(f32_bytes, 1),
            sample=(finished[0] if finished else seqs[0])[:16],
            kv_layout=kv_layout,
            page_size=page_size if kv_layout == "paged" else 0,
            kv_bytes=kv_bytes, kv_bytes_contiguous=kv_bytes_contig,
            capacity_stops=capacity_stops,
            deferred_admissions=len(deferred_ids),
            prompt_buckets=sorted(pf_cache),
            kv_demotions=kv_demotions,
            kv_bits_final=kv_bits,
        )
        say(f"decoded {stats.decoded_tokens} tokens over {stats.decode_steps} "
            f"steps x {batch} slots in {wall:.3f}s = {stats.tok_s:.1f} tok/s "
            f"(interpret-mode numbers off-TPU)")
        say(f"admitted {stats.admitted} / completed {stats.completed} sequences "
            f"(continuous batching over {n_requests} requests; "
            f"{capacity_stops} capacity stops, "
            f"{len(deferred_ids)} deferred admissions)")
        say(f"weight stream: {q_bytes/1e6:.1f} MB/step packed vs "
            f"{f32_bytes/1e6:.1f} MB/step f32 -> ratio {stats.packed_vs_f32:.3f}")
        if kv_layout == "paged":
            say(f"kv cache: {kv_bytes/1e6:.2f} MB paged pool "
                f"(page={page_size}, buckets={stats.prompt_buckets}) vs "
                f"{kv_bytes_contig/1e6:.2f} MB contiguous")
        say(f"sample: {stats.sample}")
        return stats

    # ------------------------------------------------------------------
    # dryrun: AOT lower + compile + roofline
    # ------------------------------------------------------------------
    def trace(self, shape=None, variant: dict | None = None):
        """AOT-trace one (arch x shape) cell on this mesh — no compile.

        ``shape``: a shape-cell name from ``repro.configs.shapes_for`` or an
        explicit :class:`~repro.configs.base.ShapeSpec`.  Packed serving
        weights come from the session policy (``policy.packed``), not a knob.
        Returns ``(traced, meta)`` — ``traced.jaxpr`` feeds the static
        precision lint (:mod:`repro.analyze`), ``traced.lower()`` continues
        to the compile path :meth:`lower` wraps.
        """
        import dataclasses as _dc

        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.configs import shapes_for
        from repro.configs.base import ShapeSpec
        from repro.dist.sharding import batch_specs
        from repro.launch.mesh import batch_size
        from repro.launch.steps import (
            build_decode_step, build_prefill_step, globalize,
            local_param_shapes, serving_axes)
        from repro.models.model import build_model
        from repro.optim import build_optimizer

        variant = dict(variant or self.spec.opt("variant") or {})
        spec = self.spec
        shape = shape if shape is not None else spec.opt("shape")
        cfg = self.cfg
        if variant.get("gather_bf16"):
            cfg = _dc.replace(cfg, fsdp_gather_dtype="bfloat16")
        if variant.get("capacity"):
            cfg = _dc.replace(cfg, capacity_factor=float(variant["capacity"]))
        if variant.get("no_remat"):
            cfg = _dc.replace(cfg, remat=False)
        model = build_model(cfg)
        if isinstance(shape, ShapeSpec):
            cell = shape
        else:
            cell = {s.name: s for s in shapes_for(cfg)}[shape]
        mesh, axes = self.mesh, self.axes
        rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                       sharding=NamedSharding(mesh, P()))

        def _bf16(dt):
            return jnp.bfloat16 if jnp.issubdtype(dt, jnp.floating) else dt

        if cell.kind == "train":
            opt = build_optimizer("sgd", 1e-3)
            tc = self.train_config()
            from repro.launch.steps import build_train_step

            ts = build_train_step(model, mesh, axes, opt, tc, donate=False)
            pshapes = local_param_shapes(model, mesh, axes)
            params_g = globalize(pshapes, ts.param_specs, mesh)
            opt_shapes = jax.eval_shape(opt.init, pshapes)
            opt_g = globalize(opt_shapes, ts.opt_specs, mesh)
            batch_tree = model.train_batch_spec(cell.global_batch, cell.seq_len)
            bspecs = batch_specs(batch_tree, axes)
            batch_g = globalize(
                jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(
                        (l.shape[0] // batch_size(mesh, axes),) + l.shape[1:],
                        l.dtype),
                    batch_tree),
                bspecs, mesh)
            n_clients = ts.n_clients
            delta_g = jax.ShapeDtypeStruct(
                (n_clients,), jnp.float32,
                sharding=NamedSharding(mesh, P(
                    axes.batch_axes if len(axes.batch_axes) > 1
                    else axes.batch_axes[0])))
            step = ts.fn(batch_tree)
            traced = step.trace(params_g, opt_g, batch_g, delta_g, rng_sds)

        elif cell.kind == "prefill":
            wrap, pspecs = build_prefill_step(model, mesh, axes)
            pshapes = local_param_shapes(model, mesh, axes)
            params_g = globalize(pshapes, pspecs, mesh, dtype_map=_bf16)
            batch_tree = model.train_batch_spec(cell.global_batch, cell.seq_len)
            batch_tree = {k: v for k, v in batch_tree.items() if k != "labels"}
            bspecs = batch_specs(batch_tree, axes)
            batch_g = globalize(
                jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(
                        (l.shape[0] // batch_size(mesh, axes),) + l.shape[1:],
                        l.dtype),
                    batch_tree),
                bspecs, mesh)
            step = wrap(batch_tree)
            traced = step.trace(params_g, batch_g)

        else:  # decode
            sv_axes = serving_axes(axes, cell.global_batch, mesh)
            params_tree = None
            if self.policy.packed:
                # packed serving weights (QTensor): gathers stream codes
                from repro.models.common import pack_params_for_policy

                pshapes_local = local_param_shapes(model, mesh, sv_axes)
                params_tree = jax.eval_shape(
                    lambda: pack_params_for_policy(
                        jax.tree_util.tree_map(
                            lambda l: jnp.zeros(l.shape, l.dtype),
                            pshapes_local),
                        self.policy, jax.random.PRNGKey(0)))
            page_size = spec.opt("page_size")
            ss = build_decode_step(model, mesh, sv_axes, s_max=cell.seq_len,
                                   batch_global=cell.global_batch,
                                   params_tree=params_tree,
                                   policy=self.policy,
                                   page_size=(None if page_size is None
                                              else int(page_size)),
                                   pool_pages=spec.opt("pool_pages"),
                                   attn_impl=spec.opt("attn_impl", "ref"))
            params_g = globalize(ss.param_shapes, ss.param_specs, mesh,
                                 dtype_map=_bf16)
            caches_g = globalize(ss.caches_shape, ss.cache_specs, mesh)
            batch_tree = model.decode_batch_spec(cell.global_batch,
                                                 cell.seq_len)
            bspecs = batch_specs(batch_tree, sv_axes)
            bsz = batch_size(mesh, sv_axes)
            batch_g = globalize(
                jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(
                        (l.shape[0] // max(bsz, 1),) + l.shape[1:], l.dtype),
                    batch_tree),
                bspecs, mesh)
            traced = ss.fn.trace(params_g, batch_g, caches_g)

        n_dev = int(np.prod(mesh.devices.shape))
        meta = dict(arch=spec.arch, shape=cell.name, mesh=spec.mesh,
                    n_devices=n_dev, kind=cell.kind, seq_len=cell.seq_len,
                    global_batch=cell.global_batch)
        return traced, meta

    def lower(self, shape=None, variant: dict | None = None):
        """AOT-lower + compile one cell (the :meth:`trace` continuation).

        Returns ``(compiled, lowered, meta)``.
        """
        traced, meta = self.trace(shape, variant)
        lowered = traced.lower()
        return lowered.compile(), lowered, meta

    def analyze(self, *, compile: bool = True, allowlist: str | None = None,
                check_kernels: bool = True, rules=None,
                proofs: list | None = None) -> list:
        """Static precision / wire / kernel / range lint over this spec.

        Traces (and, with ``compile=True``, compiles) the step graphs the
        RunSpec implies and returns a list of
        :class:`repro.analyze.findings.Finding` — nothing is executed.
        ``allowlist`` names an ``analyze.toml`` to mark known-legitimate
        findings (``None`` skips allowlisting).  ``rules`` selects rule
        families (see ``repro.analyze.runner.ALL_RULE_FAMILIES``); the
        ``overflow``/``numerics`` families run the abstract interpreter and
        append positive proof records (accumulator headroom, error budget)
        to ``proofs`` when a list is passed.
        """
        from repro.analyze.runner import analyze_session

        return analyze_session(self, compile=compile,
                               allowlist_path=allowlist,
                               check_kernels=check_kernels,
                               rules=rules, proofs=proofs)

    def run_dryrun(self, shape=None, variant: dict | None = None,
                   *, verbose: bool = True) -> dict:
        """Lower+compile one cell and derive its roofline report dict."""
        from repro.configs import shapes_for
        from repro.configs.base import ShapeSpec
        from repro.roofline.analysis import analyze_compiled, model_flops

        t0 = time.time()
        shape = shape if shape is not None else self.spec.opt("shape")
        variant = dict(variant or self.spec.opt("variant") or {})
        compiled, lowered, meta = self.lower(shape, variant)
        if variant:
            meta["variant"] = dict(variant)
        cell = (shape if isinstance(shape, ShapeSpec)
                else {s.name: s for s in shapes_for(self.cfg)}[meta["shape"]])
        mf = model_flops(self.cfg, cell.kind, cell.seq_len, cell.global_batch)
        rep = analyze_compiled(compiled, arch=meta["arch"], shape=meta["shape"],
                               mesh_name=meta["mesh"],
                               n_devices=meta["n_devices"],
                               model_flops_global=mf)
        d = rep.to_dict()
        d.update(meta, compile_s=round(time.time() - t0, 1), status="ok")
        if verbose:
            print(f"[{meta['arch']} x {meta['shape']} x {meta['mesh']}] "
                  f"compile={d['compile_s']}s  "
                  f"compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
                  f"collective={rep.collective_s:.3e}s  "
                  f"dominant={rep.dominant}  "
                  f"useful={rep.useful_flops_ratio:.3f}")
            print("  memory_analysis:", rep.memory_stats)
            print("  collectives:",
                  {k: v for k, v in rep.collective_breakdown.items()})
        return d

    # ------------------------------------------------------------------
    # fl-sim: the paper's CIFAR-class experiment loop
    # ------------------------------------------------------------------
    def run_fl_sim(self) -> dict:
        """FLSimulation (vmap Algorithm 1) + GBD orchestrator, CNN-scale."""
        import jax.numpy as jnp

        from repro.core.energy import heterogeneous_fleet, memory_capacities
        from repro.data import (ClientBatcher, SyntheticImages,
                                dirichlet_partition)
        from repro.fed.orchestrator import FLOrchestrator, OrchestratorConfig
        from repro.fed.simulation import FLSimulation, SimConfig
        from repro.models.cnn import mobilenet, resnet, xent_loss

        spec = self.spec
        o = spec.options
        n_clients = int(o.get("n_clients", 8))
        seed = spec.seed
        if spec.arch == "resnet":
            model = resnet(depth_blocks=(1, 1), width=8)
        elif spec.arch == "mobilenet":
            model = mobilenet(width=8, n_stages=2)
        else:
            raise ValueError(f"fl-sim arch must be one of {SIM_ARCHS}, "
                             f"got {spec.arch!r}")
        loss = xent_loss(model)
        sim = FLSimulation(loss, model.init,
                           SimConfig(n_clients=n_clients,
                                     lr=float(o.get("lr", 0.08)), seed=seed))
        imgs, labels = SyntheticImages(n=2048, hw=16, seed=seed).generate()
        parts = dirichlet_partition(labels, n_clients, alpha=0.5, seed=seed)
        batcher = ClientBatcher(imgs, labels, parts, batch=spec.batch,
                                seed=seed)
        fleet = heterogeneous_fleet(n_clients, seed=seed, group_step_mhz=5.0)
        caps = memory_capacities(n_clients, lo_mb=2.0, hi_mb=8.0) * 1e6
        orch = FLOrchestrator(
            OrchestratorConfig(
                n_devices=n_clients, n_rounds=spec.rounds,
                scheme=o.get("scheme", "fwq"),
                model_dim_d=int(o.get("model_dim_d", 1 << 16)),
                error_tolerance=float(o.get("error_tolerance", 4.5)),
                precision=self.policy, seed=seed,
                faults=o.get("faults"),
                program=o.get("precision_program"),
                resolve_drift_db=float(o.get("resolve_drift_db", 0.0)),
                ckpt_dir=str(o.get("ckpt_dir", "")),
                ckpt_every=int(o.get("ckpt_every", 10))),
            fleet, caps, grad_bytes=float(o.get("grad_bytes", 1e6)))

        def batch_fn(r, cohort):
            x, y = batcher.sample_round(r, cohort)
            return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

        eval_every = int(o.get("eval_every", 0))
        eval_fn = None
        if eval_every:
            eimgs, elabels = SyntheticImages(n=512, hw=16,
                                             seed=seed + 999).generate()
            ebatch = {"x": jnp.asarray(eimgs), "y": jnp.asarray(elabels)}
            eval_fn = lambda s: s.evaluate(loss, ebatch)  # noqa: E731

        return orch.run(sim, batch_fn, eval_fn=eval_fn, eval_every=eval_every)
