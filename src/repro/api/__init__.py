"""repro.api — the one front door.

* :class:`RunSpec` — declarative description of a workload run (arch, mesh,
  workload kind, seed, precision); round-trips through dicts/JSON.
* :class:`PrecisionPolicy` — unified per-tensor-role bit assignment
  (weights / grads / kv-cache / comm) spanning FL co-design and serving;
  ``PrecisionPolicy.from_gbd`` is how the optimizer's chosen bits enter
  the stack.
* :class:`PrecisionProgram` — the per-round controller layer over the
  policy (``constant`` / ``energy_budget`` / ``channel_gbd``): produces the
  round's :class:`PrecisionPolicy` from measured state (energy spend,
  channel drift, wire bytes, KV pool pressure).
* :class:`Session` — owns mesh/AxisCtx/model/checkpoints and launches all
  five workload kinds (train, serve, dryrun, fl-sim, fl-orchestrate).
"""

from repro.api.precision import PrecisionPolicy, ROLES  # noqa: F401
from repro.api.program import (  # noqa: F401
    ChannelGBDProgram,
    ConstantProgram,
    EnergyBudgetProgram,
    Observation,
    PrecisionProgram,
    build_program,
)
from repro.api.session import ServeStats, Session  # noqa: F401
from repro.api.spec import RunSpec, SIM_ARCHS, WORKLOADS  # noqa: F401
