"""repro.api — the one front door.

* :class:`RunSpec` — declarative description of a workload run (arch, mesh,
  workload kind, seed, precision); round-trips through dicts/JSON.
* :class:`PrecisionPolicy` — unified per-tensor-role bit assignment
  (weights / grads / kv-cache / comm) spanning FL co-design and serving;
  ``PrecisionPolicy.from_gbd`` is how the optimizer's chosen bits enter
  the stack.
* :class:`Session` — owns mesh/AxisCtx/model/checkpoints and launches all
  five workload kinds (train, serve, dryrun, fl-sim, fl-orchestrate).
"""

from repro.api.precision import PrecisionPolicy, ROLES  # noqa: F401
from repro.api.session import ServeStats, Session  # noqa: F401
from repro.api.spec import RunSpec, SIM_ARCHS, WORKLOADS  # noqa: F401
