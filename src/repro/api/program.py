"""Per-round precision control: the program layer over PrecisionPolicy.

:class:`~repro.api.precision.PrecisionPolicy` stays the immutable per-round
value object every consumer reads; a :class:`PrecisionProgram` is the
*controller* that produces that value each round from measured state.  The
split closes the co-design loop the paper solves once up front (§IV,
Algorithm 1): energy-optimal bits depend on channel state and energy
budgets, so the bits should be re-decided as conditions drift — the move
Doubly Adaptive Quantization (arXiv:2402.12957) makes per round.

Contract
--------
Each round the caller (``FLOrchestrator.plan_round`` or
``Session.fl_round``) builds an :class:`Observation` of what was *measured*
so far — cumulative ``energy_log`` spend, channel ``gain_drift_db``,
gradient wire bytes, paged-KV pool pressure — and asks the program::

    policy = program.policy_for_round(r, proposed, obs)

``proposed`` is whatever the static path would have used (the spec policy,
or the GBD solution), so programs compose with the solver instead of
replacing it.  The returned policy is a plain frozen
:class:`PrecisionPolicy`; downstream consumers are unchanged.

Controllers
-----------
* ``constant``      — returns ``proposed`` unchanged (the identity wrap of
  any static policy; bitwise-equal to the pre-program stack by
  construction, pinned by ``tests/test_program.py``).
* ``energy_budget`` — walks a cap down/up the policy's ``bit_options``
  lattice: when cumulative measured energy tracks over the pro-rata budget
  pace, weight/comm bits are clamped one lattice step down; when spend
  falls back under pace, the cap is restored one step.
* ``channel_gbd``   — generalizes the drift re-solve that used to live as
  ``resolve_drift_db``: ``wants_resolve`` fires a warm GBD re-solve when
  measured gains drift past a dB threshold.

Because a program makes its decision from the observation sequence alone
(no wall clock, no private RNG), checkpoint-resume replay of
``plan_round(0..start)`` reconstructs the controller state bit-identically.

``kv_watermark`` (any controller) arms the serving-side lever: when paged
KV pool pressure crosses the watermark, ``Session.serve`` demotes the
f32 pools to bf16 (``models.attention.demote_kv_cache``) instead of
deferring admissions forever.
"""

from __future__ import annotations

import dataclasses

from repro.api.precision import PrecisionPolicy


@dataclasses.dataclass(frozen=True)
class Observation:
    """What one round's controller decision may depend on — all *measured*.

    ``energy_cum_j`` is the billed spend of rounds ``< round`` (the
    orchestrator's ``energy_log``); ``gain_drift_db`` compares the current
    (fault-faded) gains against the strategy's solve-time gains;
    ``wire_bytes_round`` is the previous round's gradient bytes on the wire
    (``grad_wire_report``); ``pool_pressure`` is used/total KV pages
    (1.0 = a request is blocked on reclaim).
    """

    round: int
    rounds_total: int = 0
    energy_cum_j: float = 0.0
    energy_round_j: float = 0.0
    gain_drift_db: float = 0.0
    wire_bytes_round: float = 0.0
    pool_pressure: float = 0.0


class PrecisionProgram:
    """Base controller: identity policy, no re-solves, optional KV lever."""

    kind = "constant"

    def __init__(self, *, kv_watermark: float | None = None):
        self.kv_watermark = (None if kv_watermark is None
                             else float(kv_watermark))

    # -- the per-round decision ----------------------------------------
    def policy_for_round(self, round_idx: int, proposed: PrecisionPolicy,
                         obs: Observation) -> PrecisionPolicy:
        return proposed

    def wants_resolve(self, obs: Observation) -> bool:
        """Ask for a warm GBD re-solve this round (channel controllers)."""
        return False

    @property
    def uses_drift(self) -> bool:
        """Whether the caller must measure ``gain_drift_db`` for us."""
        return False

    def kv_demote(self, obs: Observation) -> bool:
        """Serving lever: demote f32 KV pools to bf16 under pool pressure."""
        return (self.kv_watermark is not None
                and obs.pool_pressure >= self.kv_watermark)

    # -- schedule envelope (static analysis) ---------------------------
    def comm_envelope(self, base: PrecisionPolicy) -> tuple[int, ...]:
        """Every comm bit-width this program could emit over a run.

        The analyzer proves ``overflow.wire_accumulator`` for each member,
        so the certificate covers the whole schedule, not one policy.
        """
        return (int(base.comm),)

    def weight_envelope(self, base: PrecisionPolicy) -> tuple[int, ...]:
        """Every weight bit-width this program could emit (sorted)."""
        w = base.weights if base.heterogeneous else (base.weights,)
        return tuple(sorted({int(b) for b in w}))

    # -- bookkeeping ----------------------------------------------------
    def reset(self) -> None:
        """Forget controller state (a fresh run over the same instance)."""

    def summary(self) -> dict:
        """JSON-safe counters for result rows / sweep tables."""
        return {"kind": self.kind}

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        d = {"kind": self.kind}
        if self.kv_watermark is not None:
            d["kv_watermark"] = self.kv_watermark
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionProgram":
        d = dict(d)
        kind = d.pop("kind", "constant")
        if kind not in PROGRAMS:
            raise ValueError(f"unknown precision program kind {kind!r}; "
                             f"options: {sorted(PROGRAMS)}")
        return PROGRAMS[kind](**d)

    def __repr__(self):
        return f"{type(self).__name__}({self.to_dict()})"


class ConstantProgram(PrecisionProgram):
    """The identity wrap: whatever the static path proposes, runs."""

    kind = "constant"


class EnergyBudgetProgram(PrecisionProgram):
    """Demote bits along the lattice when measured energy tracks over budget.

    Controller law (evaluated at the START of round ``r`` from rounds
    ``< r``'s billed energy): the pro-rata pace is
    ``budget_j * r / rounds_total``.  Spend above ``slack * pace`` drops the
    bit cap one ``bit_options`` step (weights and/or comm, per the
    ``demote_*`` flags); spend below ``restore * pace`` raises it one step.
    One step per round keeps the policy schedule K-valued with K tiny —
    which is exactly what the session's compiled-variant cache amortizes.

    Physics note: with the paper's energy model the lever that matters is
    the *weights* role — ``e_comp = p_comp * (beta1 + beta2 * q)`` is affine
    in the weight bits q, while ``e_comm = alpha1 / B`` is independent of
    comm bits (the uplink payload D_g is the f32 gradient either way).
    Comm demotion still shrinks the pod-trainer bytes on the wire, so both
    default on.
    """

    kind = "energy_budget"

    def __init__(self, budget_j: float, *, slack: float = 1.05,
                 restore: float = 0.90, demote_weights: bool = True,
                 demote_comm: bool = True, kv_watermark: float | None = None):
        super().__init__(kv_watermark=kv_watermark)
        self.budget_j = float(budget_j)
        if self.budget_j <= 0:
            raise ValueError(f"budget_j must be > 0, got {budget_j}")
        self.slack = float(slack)
        self.restore = float(restore)
        if not self.restore <= self.slack:
            raise ValueError(f"restore ({restore}) must be <= slack "
                             f"({slack}) or the cap oscillates every round")
        self.demote_weights = bool(demote_weights)
        self.demote_comm = bool(demote_comm)
        self.reset()

    def reset(self) -> None:
        self._cap_idx: int | None = None   # index into the sorted lattice
        self.demotions = 0
        self.restores = 0
        self.cap_bits: int | None = None

    # ------------------------------------------------------------------
    def _lattice(self, proposed: PrecisionPolicy) -> tuple[int, ...]:
        return tuple(sorted({int(b) for b in proposed.bit_options}))

    def policy_for_round(self, round_idx: int, proposed: PrecisionPolicy,
                         obs: Observation) -> PrecisionPolicy:
        lattice = self._lattice(proposed)
        if self._cap_idx is None or self._cap_idx >= len(lattice):
            self._cap_idx = len(lattice) - 1
        pace = (self.budget_j * obs.round / obs.rounds_total
                if obs.rounds_total > 0 else 0.0)
        if obs.round > 0 and pace > 0:
            if obs.energy_cum_j > self.slack * pace and self._cap_idx > 0:
                self._cap_idx -= 1
                self.demotions += 1
            elif (obs.energy_cum_j < self.restore * pace
                  and self._cap_idx < len(lattice) - 1):
                self._cap_idx += 1
                self.restores += 1
        cap = lattice[self._cap_idx]
        self.cap_bits = cap
        return self._clamp(proposed, cap)

    def _clamp(self, proposed: PrecisionPolicy,
               cap: int) -> PrecisionPolicy:
        changes = {}
        if self.demote_weights:
            if proposed.heterogeneous:
                w = tuple(min(int(b), cap) for b in proposed.weights)
                if w != proposed.weights:
                    changes["weights"] = w
            elif int(proposed.weights) > cap:
                changes["weights"] = cap
        if self.demote_comm and int(proposed.comm) > cap:
            changes["comm"] = cap
        if not changes:
            return proposed      # identity: the constant-equivalence path
        return dataclasses.replace(proposed, **changes)

    # ------------------------------------------------------------------
    def comm_envelope(self, base: PrecisionPolicy) -> tuple[int, ...]:
        bits = {int(base.comm)}
        if self.demote_comm:
            bits.update(b for b in base.bit_options if b < base.comm)
        return tuple(sorted(bits))

    def weight_envelope(self, base: PrecisionPolicy) -> tuple[int, ...]:
        bits = set(super().weight_envelope(base))
        if self.demote_weights:
            top = max(bits)
            bits.update(b for b in base.bit_options if b < top)
        return tuple(sorted(bits))

    def summary(self) -> dict:
        return {"kind": self.kind, "budget_j": self.budget_j,
                "demotions": self.demotions, "restores": self.restores,
                "cap_bits": self.cap_bits}

    def to_dict(self) -> dict:
        d = super().to_dict()
        d.update(budget_j=self.budget_j, slack=self.slack,
                 restore=self.restore, demote_weights=self.demote_weights,
                 demote_comm=self.demote_comm)
        return d


class ChannelGBDProgram(PrecisionProgram):
    """Warm GBD re-solve when measured channel gains drift past a threshold.

    The program form of the orchestrator's ``resolve_drift_db`` knob: the
    observation carries ``gain_drift_db`` (current fault-faded gains vs. the
    strategy's solve-time gains, :func:`repro.core.channel.gain_drift_db`)
    and ``wants_resolve`` fires the same ``resolve(warm=True, gains0=...)``
    path.  Policy values pass through untouched — the *solver* is the
    controller here.
    """

    kind = "channel_gbd"

    def __init__(self, drift_db: float, *, kv_watermark: float | None = None):
        super().__init__(kv_watermark=kv_watermark)
        self.drift_db = float(drift_db)
        if self.drift_db <= 0:
            raise ValueError(f"drift_db must be > 0, got {drift_db}")
        self.reset()

    def reset(self) -> None:
        self.resolves = 0

    @property
    def uses_drift(self) -> bool:
        return True

    def wants_resolve(self, obs: Observation) -> bool:
        if obs.gain_drift_db > self.drift_db:
            self.resolves += 1
            return True
        return False

    def summary(self) -> dict:
        return {"kind": self.kind, "drift_db": self.drift_db,
                "resolves": self.resolves}

    def to_dict(self) -> dict:
        d = super().to_dict()
        d["drift_db"] = self.drift_db
        return d


PROGRAMS: dict[str, type] = {
    "constant": ConstantProgram,
    "energy_budget": EnergyBudgetProgram,
    "channel_gbd": ChannelGBDProgram,
}


def build_program(obj) -> PrecisionProgram:
    """The one coercion funnel: None / kind string / dict / instance.

    ``None`` means "no program" and builds the identity
    :class:`ConstantProgram`, so every caller can hold a program
    unconditionally and the static path stays the zero-configuration
    default.
    """
    if obj is None:
        return ConstantProgram()
    if isinstance(obj, PrecisionProgram):
        return obj
    if isinstance(obj, str):
        return PrecisionProgram.from_dict({"kind": obj})
    if isinstance(obj, dict):
        return PrecisionProgram.from_dict(obj)
    raise TypeError(f"cannot build a PrecisionProgram from {type(obj).__name__}")
