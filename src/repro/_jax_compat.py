"""Forward-compatibility shims for the pinned jax in this container.

The codebase (and the subprocess scripts embedded in the tests) target the
modern mesh/shard_map surface:

* ``jax.make_mesh(shape, names, axis_types=...)``
* ``jax.sharding.AxisType.{Auto,Explicit,Manual}``
* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``

On older jax (0.4.x) those spell ``jax.make_mesh`` without ``axis_types``,
no ``AxisType`` enum, and ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` keyword.  :func:`install` bridges the gap by installing thin
adapters onto the ``jax`` module — only for attributes that are missing, so
on a modern jax this is a no-op.  It is idempotent and runs on ``import
repro`` (see ``repro/__init__.py``), which every entry point and test
script hits before touching jax meshes.
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding

_INSTALLED = False


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    global _INSTALLED
    if _INSTALLED:
        return
    _INSTALLED = True

    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    # --- make_mesh: provide it, or tolerate (and drop) axis_types ---------
    _orig_make_mesh = getattr(jax, "make_mesh", None)
    if _orig_make_mesh is None:        # pre-0.4.35 jax: build the Mesh by hand

        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None, **kw):
            del axis_types, kw
            import numpy as np

            devs = np.asarray(devices if devices is not None
                              else jax.devices())
            n = int(np.prod(axis_shapes))
            return jax.sharding.Mesh(devs[:n].reshape(tuple(axis_shapes)),
                                     tuple(axis_names))

        jax.make_mesh = make_mesh
    else:
        try:
            import inspect

            accepts_axis_types = "axis_types" in inspect.signature(
                _orig_make_mesh).parameters
        except (TypeError, ValueError):  # pragma: no cover - exotic builds
            accepts_axis_types = True
        if not accepts_axis_types:

            @functools.wraps(_orig_make_mesh)
            def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
                del axis_types  # pre-AxisType jax: shard_map treats as Auto
                return _orig_make_mesh(axis_shapes, axis_names, **kw)

            jax.make_mesh = make_mesh

    # --- shard_map: top-level alias with check_vma -> check_rep -----------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _esm

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kw):
            if check_rep is None:
                check_rep = True if check_vma is None else bool(check_vma)
            return _esm(f, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=check_rep, **kw)

        jax.shard_map = shard_map
