"""Non-iid client partitioning (paper §5.1: "non-i.i.d setting")."""

from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 0.3,
                        *, seed: int = 0, min_per_client: int = 8) -> list[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew partition.

    Smaller alpha => more heterogeneous clients (paper Assumption 3's phi
    grows).  Returns per-client index arrays covering the dataset.
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for ix in idx_by_class:
        rng.shuffle(ix)
    for attempt in range(100):
        props = rng.dirichlet([alpha] * n_clients, n_classes)  # (C, N)
        client_bins: list[list[np.ndarray]] = [[] for _ in range(n_clients)]
        for c, ix in enumerate(idx_by_class):
            cuts = (np.cumsum(props[c])[:-1] * len(ix)).astype(int)
            for i, part in enumerate(np.split(ix, cuts)):
                client_bins[i].append(part)
        parts = [np.concatenate(b) if b else np.empty(0, int) for b in client_bins]
        if min(len(p) for p in parts) >= min_per_client:
            break
    for p in parts:
        rng.shuffle(p)
    return parts


def heterogeneity_phi(labels: np.ndarray, parts: list[np.ndarray]) -> float:
    """Empirical proxy for Assumption 3's phi: mean TV distance of client
    label distributions from the global one."""
    n_classes = int(labels.max()) + 1
    glob = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for p in parts:
        if len(p) == 0:
            continue
        loc = np.bincount(labels[p], minlength=n_classes) / len(p)
        tvs.append(0.5 * np.abs(loc - glob).sum())
    return float(np.mean(tvs))
