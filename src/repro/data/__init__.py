from repro.data.partition import dirichlet_partition  # noqa: F401
from repro.data.synthetic import SyntheticImages, SyntheticTokens  # noqa: F401
from repro.data.pipeline import ClientBatcher, TokenBatcher  # noqa: F401
