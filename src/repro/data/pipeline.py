"""Batching pipelines: per-client mini-batches for the FL simulator and
token batches for the pod trainer."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientBatcher:
    """Per-client mini-batch sampler over a partition (deterministic)."""

    data: np.ndarray            # (n, ...) features
    labels: np.ndarray          # (n,)
    parts: list                 # per-client index arrays
    batch: int                  # M in the paper
    seed: int = 0

    def sample_round(self, round_idx: int, cohort: np.ndarray):
        """Returns (x (len(cohort), M, ...), y (len(cohort), M)) stacked."""
        xs, ys = [], []
        for ci in cohort:
            rng = np.random.default_rng((self.seed, int(ci), round_idx))
            part = self.parts[int(ci)]
            take = rng.choice(part, self.batch, replace=len(part) < self.batch)
            xs.append(self.data[take])
            ys.append(self.labels[take])
        return np.stack(xs), np.stack(ys)


@dataclasses.dataclass
class TokenBatcher:
    """Contiguous LM batches: (clients, per_client_batch, seq+1) slices."""

    tokens: np.ndarray
    seq_len: int
    seed: int = 0

    def sample_round(self, round_idx: int, n_clients: int, per_client: int):
        rng = np.random.default_rng((self.seed, round_idx))
        total = n_clients * per_client
        max_start = len(self.tokens) - self.seq_len - 1
        starts = rng.integers(0, max_start, total)
        windows = np.stack([self.tokens[s : s + self.seq_len + 1] for s in starts])
        windows = windows.reshape(n_clients, per_client, self.seq_len + 1)
        return {"tokens": windows[..., :-1].astype(np.int32),
                "labels": windows[..., 1:].astype(np.int32)}
