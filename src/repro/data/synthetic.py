"""Deterministic synthetic datasets (offline container: no downloads).

* :class:`SyntheticImages` — CIFAR-like labelled images whose classes are
  separable (class-dependent means + structured noise), so training curves
  behave like the paper's Fig. 2 (loss decreases, quantization hurts in a
  controlled way) while staying fully reproducible.
* :class:`SyntheticTokens` — a Zipf-ish Markov token stream for LM-family
  end-to-end runs.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticImages:
    n: int = 50_000
    n_classes: int = 10
    hw: int = 32
    channels: int = 3
    seed: int = 0

    def generate(self):
        """Returns (images f32 (n, hw, hw, c), labels int32 (n,))."""
        rng = np.random.default_rng(self.seed)
        labels = rng.integers(0, self.n_classes, self.n).astype(np.int32)
        # class templates: low-frequency patterns
        yy, xx = np.mgrid[0:self.hw, 0:self.hw] / self.hw
        templates = np.stack([
            np.sin(2 * np.pi * ((k % 3 + 1) * xx + (k % 5) * yy + k / self.n_classes))
            for k in range(self.n_classes)
        ])  # (K, hw, hw)
        imgs = templates[labels][..., None].repeat(self.channels, -1)
        imgs = imgs * (0.5 + 0.1 * (labels % 4))[:, None, None, None]
        imgs = imgs + 0.22 * rng.standard_normal(imgs.shape)
        return imgs.astype(np.float32), labels


@dataclasses.dataclass
class SyntheticTokens:
    n_tokens: int = 2_000_000
    vocab: int = 512
    seed: int = 0

    def generate(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        # sparse Markov chain over a Zipf marginal
        ranks = np.arange(1, self.vocab + 1)
        marginal = 1.0 / ranks
        marginal /= marginal.sum()
        # each token deterministically biases the next towards (t*7+3) % V
        out = np.empty(self.n_tokens, np.int32)
        t = 0
        base = rng.choice(self.vocab, self.n_tokens, p=marginal)
        jump = rng.random(self.n_tokens) < 0.65
        for i in range(self.n_tokens):
            t = (t * 7 + 3) % self.vocab if jump[i] else int(base[i])
            out[i] = t
        return out
