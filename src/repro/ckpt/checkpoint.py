"""Fault-tolerant checkpointing: atomic, manifest-verified, resumable.

Round-level checkpoint/restart is the first line of fault tolerance for the
FL orchestrator (node failure => restart from the last round; PRNG keys are
folded from (seed, round) so the restarted trajectory is bit-identical).

Format: one ``.npz`` per checkpoint with flattened ``path -> array`` entries
plus a JSON manifest (round index, rng seed, config hash, leaf checksums).
Writes go to a temp file + ``os.replace`` (atomic on POSIX); a crash mid-write
never corrupts the latest-good checkpoint.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any

import jax
import ml_dtypes
import numpy as np

from repro.models.common import QTensor, tree_paths_leaves

#: dtypes numpy's npz can't round-trip natively -> stored as a u16/u8 view
_VIEW_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
                "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _encode(v: np.ndarray):
    name = str(v.dtype)
    if name in _VIEW_DTYPES:
        return v.view(_VIEW_DTYPES[name][0]), name
    return v, name


def _decode(v: np.ndarray, dtype_name: str):
    if dtype_name in _VIEW_DTYPES:
        return v.view(_VIEW_DTYPES[dtype_name][1])
    return v


def _flatten(tree):
    paths, leaves, treedef = tree_paths_leaves(tree)
    flat = {}
    for path, leaf in zip(paths, leaves):
        if isinstance(leaf, QTensor):
            flat[path + "@codes"] = np.asarray(leaf.codes)
            flat[path + "@scale"] = np.asarray(leaf.scale)
        else:
            flat[path] = np.asarray(leaf)
    return flat, treedef


def save_checkpoint(directory: str, step: int, state: Any, *,
                    extra: dict | None = None, keep: int = 3) -> str:
    """Atomically write ``state`` (any pytree) as checkpoint ``step``."""
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(state)
    name = f"ckpt_{step:08d}"
    tmp = os.path.join(directory, f".{name}.tmp.npz")
    final = os.path.join(directory, f"{name}.npz")
    encoded, dtypes = {}, {}
    for k, v in flat.items():
        encoded[k], dtypes[k] = _encode(v)
    np.savez(tmp, **encoded)
    manifest = {
        "step": step,
        "time": time.time(),
        "extra": extra or {},
        "leaves": {k: [list(v.shape), dtypes[k],
                       hashlib.sha1(v.tobytes()).hexdigest()[:16]]
                   for k, v in encoded.items()},
    }
    mtmp = os.path.join(directory, f".{name}.tmp.json")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final)
    os.replace(mtmp, os.path.join(directory, f"{name}.json"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    for f in ckpts[:-keep]:
        try:
            os.remove(os.path.join(directory, f))
            os.remove(os.path.join(directory, f.replace(".npz", ".json")))
        except OSError:
            pass


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template: Any, *, step: int | None = None,
                    verify: bool = True):
    """Restore into the structure of ``template``.  Returns (state, manifest)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    name = f"ckpt_{step:08d}"
    with np.load(os.path.join(directory, f"{name}.npz")) as zf:
        flat = {k: zf[k] for k in zf.files}
    with open(os.path.join(directory, f"{name}.json")) as f:
        manifest = json.load(f)
    if verify:
        for k, (shape, dtype, sha) in manifest["leaves"].items():
            v = flat[k]
            if list(v.shape) != shape:
                raise ValueError(f"checkpoint leaf {k} shape mismatch")
            if hashlib.sha1(v.tobytes()).hexdigest()[:16] != sha:
                raise ValueError(f"checkpoint leaf {k} checksum mismatch")
    flat = {k: _decode(v, manifest["leaves"][k][1]) for k, v in flat.items()}

    paths, leaves, treedef = tree_paths_leaves(template)
    out = []
    for path, leaf in zip(paths, leaves):
        if isinstance(leaf, QTensor):
            out.append(QTensor(jax.numpy.asarray(flat[path + "@codes"]),
                               jax.numpy.asarray(flat[path + "@scale"])))
        else:
            if path not in flat:
                raise KeyError(f"checkpoint missing leaf {path}")
            out.append(jax.numpy.asarray(flat[path]))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


@dataclasses.dataclass
class CheckpointManager:
    """Save-every-k with resume; the orchestrator's persistence handle."""

    directory: str
    every: int = 10
    keep: int = 3

    def maybe_save(self, step: int, state: Any, extra: dict | None = None):
        if self.every and step % self.every == 0:
            return save_checkpoint(self.directory, step, state,
                                   extra=extra, keep=self.keep)
        return None

    def restore_or(self, template: Any, default_extra: dict | None = None,
                   *, expect_extra: dict | None = None):
        """(state, step, extra) from the latest checkpoint, or the template.

        ``expect_extra``: keys that must match the saved manifest's extra
        (when present there) — e.g. the fault plan a resumable FL run was
        started with.  A mismatch raises instead of silently splicing two
        different trajectories into one "resumed" run.
        """
        step = latest_step(self.directory)
        if step is None:
            return template, 0, dict(default_extra or {})
        state, manifest = load_checkpoint(self.directory, template, step=step)
        extra = manifest.get("extra", {})
        for k, v in (expect_extra or {}).items():
            if k in extra and extra[k] != v:
                raise ValueError(
                    f"checkpoint in {self.directory} was written with "
                    f"{k}={extra[k]!r} but this run expects {k}={v!r}; "
                    "refusing to resume a different trajectory")
        return state, manifest["step"], extra
