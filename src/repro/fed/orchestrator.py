"""FL round orchestrator: the paper's full control loop, production-shaped.

Per round r:
  1. channel realization  h_{i,r}  (block fading, :mod:`repro.core.channel`)
  2. co-design            q, B <- GBD (or a baseline scheme) under the
     energy/latency/learning constraints (paper §4); strategies are re-solved
     every ``resolve_every`` rounds (gains are re-drawn each round, the
     optimizer horizon uses the measured gain window)
  3. cohort control       straggler deadline (Eq. 26): clients whose
     comp+comm time exceeds the round budget are dropped THIS round;
     random client failures (node loss) are masked the same way
  4. training             one FWQ round on the surviving cohort
  5. accounting           energy/latency bookkeeping per device
  6. persistence          checkpoint every k rounds (crash => bit-identical
     resume: all randomness is folded from (seed, round))

Elasticity: the cohort size may change between rounds (clients join/leave);
the simulator's jitted round is shape-polymorphic via per-size compile cache.
"""

from __future__ import annotations

import dataclasses
import logging
import warnings
from typing import Callable

import numpy as np

from repro.api.precision import PrecisionPolicy
from repro.ckpt import CheckpointManager
from repro.core import baselines as baselines_mod
from repro.core.channel import ChannelModel
from repro.core.convergence import error_budget_bound
from repro.core.energy import CommParams, DeviceProfile, alpha_coefficients
from repro.core.gbd import run_gbd
from repro.core.master import MasterSpec
from repro.core.primal import PrimalData

log = logging.getLogger(__name__)


@dataclasses.dataclass
class OrchestratorConfig:
    n_devices: int
    n_rounds: int
    scheme: str = "fwq"              # fwq | full_precision | unified_q | rand_q
    precision: PrecisionPolicy | None = None  # bit lattice + tensor roles
    bits_options: tuple | None = None         # DEPRECATED: use precision
    unified_bits: int = 16
    b_max_hz: float = 20e6
    t_max_s: float = 0.0             # 0 => auto (t_factor x min feasible)
    t_factor: float = 1.5
    error_tolerance: float = 0.05    # lambda (constraint 23)
    e2: float = 9.0                  # big-O constant of eps_q
    model_dim_d: int = 1 << 20       # d in constraint (23)
    resolve_every: int = 5
    horizon: int = 4                 # rounds of gains per optimization
    dropout_prob: float = 0.0        # random client failure rate
    straggler_slack: float = 1.25    # per-round deadline = slack * planned T_r
    seed: int = 0
    ckpt_dir: str = ""
    ckpt_every: int = 25

    def __post_init__(self):
        if self.bits_options is not None:
            warnings.warn(
                "OrchestratorConfig(bits_options=...) is deprecated; pass "
                "precision=PrecisionPolicy(bit_options=...)",
                DeprecationWarning, stacklevel=3)
            if (self.precision is not None
                    and tuple(self.precision.bit_options)
                    != tuple(self.bits_options)):
                raise ValueError(
                    f"conflicting bits_options={tuple(self.bits_options)} and "
                    f"precision.bit_options={self.precision.bit_options}")
            base = self.precision or PrecisionPolicy()
            self.precision = dataclasses.replace(
                base, bit_options=tuple(self.bits_options))
        if self.precision is None:
            self.precision = PrecisionPolicy()


class FLOrchestrator:
    def __init__(self, cfg: OrchestratorConfig, fleet: list[DeviceProfile],
                 mem_capacity_bytes: np.ndarray, grad_bytes: float,
                 weight_scale: float = 1.0):
        self.cfg = cfg
        self.fleet = fleet
        self.comm = CommParams(b_max_hz=cfg.b_max_hz, grad_bytes=grad_bytes)
        self.channel = ChannelModel(n_devices=cfg.n_devices, seed=cfg.seed)
        self.spec = MasterSpec(
            bits_options=cfg.precision.bit_options,
            n_devices=cfg.n_devices,
            error_budget=error_budget_bound(cfg.error_tolerance, cfg.e2,
                                            cfg.model_dim_d, cfg.n_devices),
            mem_capacity_bytes=mem_capacity_bytes,
            model_bytes_fp=4.0 * cfg.model_dim_d,
            weight_scale=weight_scale,
        )
        self._beta1 = np.array([d.beta1 for d in fleet])
        self._beta2 = np.array([d.beta2 for d in fleet])
        self._p_comp = np.array([d.runtime_power() for d in fleet])
        self._p_comm = np.array([d.p_comm for d in fleet])
        self._strategy: dict | None = None
        self.energy_log: list[dict] = []
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, every=cfg.ckpt_every)
                     if cfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def _primal_data(self, round_idx: int) -> PrimalData:
        gains = np.stack([self.channel.gains(round_idx + h)
                          for h in range(self.cfg.horizon)])
        a1 = np.zeros_like(gains)
        a2 = np.zeros_like(gains)
        for r in range(self.cfg.horizon):
            a1[r], a2[r] = alpha_coefficients(gains[r], self._p_comm, self.comm)
        if self.cfg.t_max_s:
            t_max = self.cfg.t_max_s * self.cfg.horizon / max(self.cfg.n_rounds, 1)
        else:
            from repro.core.primal import _round_tmin
            tmin = _round_tmin(a2, self._beta1 + 32 * self._beta2, self.cfg.b_max_hz)
            t_max = float(self.cfg.t_factor * tmin.sum())
        return PrimalData(alpha1=a1, alpha2=a2, beta1=self._beta1,
                          beta2=self._beta2, p_comp=self._p_comp,
                          b_max=self.cfg.b_max_hz, t_max=t_max)

    def resolve(self, round_idx: int) -> dict:
        """(Re-)run the co-design and cache the strategy."""
        data = self._primal_data(round_idx)
        scheme = self.cfg.scheme
        if scheme == "fwq":
            res = run_gbd(data, self.spec, max_rounds=30)
        elif scheme == "full_precision":
            res = baselines_mod.full_precision(data, self.spec)
        elif scheme == "unified_q":
            res = baselines_mod.unified_q(data, self.spec, bits=self.cfg.unified_bits)
        elif scheme == "rand_q":
            res = baselines_mod.rand_q(data, self.spec, seed=self.cfg.seed + round_idx)
        else:
            raise ValueError(scheme)
        # The solver's chosen bits enter the stack ONLY as a PrecisionPolicy:
        # the same object the trainer's traced delta and the serving packer
        # consume (per-device heterogeneous weights role).
        policy = PrecisionPolicy.from_gbd(
            res, comm=self.cfg.precision.comm,
            kv_cache=self.cfg.precision.kv_cache,
            bit_options=self.cfg.precision.bit_options)
        self._strategy = {"policy": policy,
                          "q": policy.bits_vector(self.cfg.n_devices),
                          "bandwidth": res.bandwidth,
                          "t_rounds": res.t_rounds, "energy_plan": res.energy,
                          "resolved_at": round_idx}
        return self._strategy

    # ------------------------------------------------------------------
    def plan_round(self, round_idx: int) -> dict:
        """Strategy + cohort survival for this round.

        Returns dict with q (bits), surviving cohort mask, per-device energy
        and the round latency (Eq. 26 bookkeeping).
        """
        if (self._strategy is None
                or round_idx - self._strategy["resolved_at"] >= self.cfg.resolve_every):
            self.resolve(round_idx)
        st = self._strategy
        q = st["q"]
        h = self._strategy["resolved_at"]
        B = st["bandwidth"][min(round_idx - h, st["bandwidth"].shape[0] - 1)]
        gains = self.channel.gains(round_idx)
        a1, a2 = alpha_coefficients(gains, self._p_comm, self.comm)

        t_comp = self._beta1 + self._beta2 * q
        t_comm = a2 / B
        e_comp = self._p_comp * t_comp
        e_comm = a1 / B
        t_total = t_comp + t_comm

        planned = st["t_rounds"][min(round_idx - h, len(st["t_rounds"]) - 1)]
        deadline = self.cfg.straggler_slack * planned
        rng = np.random.default_rng((self.cfg.seed, round_idx, 77))
        alive = rng.random(self.cfg.n_devices) >= self.cfg.dropout_prob
        on_time = t_total <= deadline
        cohort = alive & on_time
        if not cohort.any():        # never lose the round entirely
            cohort = alive if alive.any() else np.ones_like(alive)

        rec = {
            "round": round_idx, "policy": st["policy"],
            "q": q.copy(), "bandwidth": B.copy(),
            "t_comp": t_comp, "t_comm": t_comm,
            "t_round": float(np.max(np.where(cohort, t_total, 0.0))),
            "e_comp": e_comp, "e_comm": e_comm,
            "energy_round": float(np.sum(np.where(cohort, e_comp + e_comm, 0.0))),
            "cohort": cohort, "n_stragglers": int((~on_time).sum()),
            "n_failed": int((~alive).sum()),
        }
        self.energy_log.append(rec)
        return rec

    # ------------------------------------------------------------------
    def run(self, sim, batch_fn: Callable[[int, np.ndarray], dict],
            *, eval_fn: Callable | None = None, eval_every: int = 0) -> dict:
        """Drive ``sim`` (FLSimulation) for n_rounds with full bookkeeping."""
        start = 0
        if self.ckpt is not None:
            state, start, _ = self.ckpt.restore_or(sim.state())
            if start:
                sim.load_state(state, start)
                log.info("resumed from round %d", start)
        evals = []
        for r in range(start, self.cfg.n_rounds):
            plan = self.plan_round(r)
            cohort_idx = np.flatnonzero(plan["cohort"])
            batch = batch_fn(r, cohort_idx)
            # per-device bits reach the simulator only through the round's
            # PrecisionPolicy (built by PrecisionPolicy.from_gbd in resolve)
            bits = plan["policy"].bits_vector(self.cfg.n_devices)[cohort_idx]
            # elastic cohort: the simulator round is sized by the batch
            rec = sim.run_round(batch, bits)
            rec.update(energy=plan["energy_round"], t_round=plan["t_round"],
                       cohort_size=len(cohort_idx))
            if eval_fn is not None and eval_every and (r + 1) % eval_every == 0:
                evals.append({"round": r, **eval_fn(sim)})
            if self.ckpt is not None:
                self.ckpt.maybe_save(r + 1, sim.state(), extra={"round": r + 1})
        total_energy = float(sum(e["energy_round"] for e in self.energy_log))
        total_time = float(sum(e["t_round"] for e in self.energy_log))
        return {"history": sim.history, "energy_log": self.energy_log,
                "evals": evals, "total_energy_j": total_energy,
                "total_time_s": total_time}
