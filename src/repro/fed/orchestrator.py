"""FL round orchestrator: the paper's full control loop, production-shaped.

Per round r:
  1. channel realization  h_{i,r}  (block fading, :mod:`repro.core.channel`)
  2. co-design            q, B <- GBD (or a baseline scheme) under the
     energy/latency/learning constraints (paper §4); strategies are re-solved
     every ``resolve_every`` rounds (gains are re-drawn each round, the
     optimizer horizon uses the measured gain window)
  3. cohort control       straggler deadline (Eq. 26): clients whose
     comp+comm time exceeds the round budget are dropped THIS round;
     random client failures (node loss) are masked the same way
  4. training             one FWQ round on the surviving cohort
  5. accounting           energy/latency bookkeeping per device
  6. persistence          checkpoint every k rounds (crash => bit-identical
     resume: all randomness is folded from (seed, round))

Elasticity: the cohort size may change between rounds (clients join/leave);
the simulator's jitted round is shape-polymorphic via per-size compile cache.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import numpy as np

from repro.api.precision import PrecisionPolicy
from repro.api.program import Observation, PrecisionProgram, build_program
from repro.ckpt import CheckpointManager
from repro.core import baselines as baselines_mod
from repro.core.channel import ChannelModel, gain_drift_db
from repro.core.convergence import error_budget_bound
from repro.core.energy import (
    CommParams,
    DeviceProfile,
    alpha_coefficients,
    reference_rate_bps,
)
from repro.core.gbd import run_gbd
from repro.core.master import MasterSpec
from repro.core.primal import PrimalData
from repro.faults import FaultPlan, UpdateFaults, transmit_update

log = logging.getLogger(__name__)


@dataclasses.dataclass
class OrchestratorConfig:
    n_devices: int
    n_rounds: int
    scheme: str = "fwq"              # fwq | full_precision | unified_q | rand_q
    precision: PrecisionPolicy | None = None  # bit lattice + tensor roles
    unified_bits: int = 16
    b_max_hz: float = 20e6
    t_max_s: float = 0.0             # 0 => auto (t_factor x min feasible)
    t_factor: float = 1.5
    error_tolerance: float = 0.05    # lambda (constraint 23)
    e2: float = 9.0                  # big-O constant of eps_q
    model_dim_d: int = 1 << 20       # d in constraint (23)
    resolve_every: int = 5
    horizon: int = 4                 # rounds of gains per optimization
    dropout_prob: float = 0.0        # random client failure rate
    straggler_slack: float = 1.25    # per-round deadline = slack * planned T_r
    seed: int = 0
    ckpt_dir: str = ""
    ckpt_every: int = 25
    faults: FaultPlan | dict | None = None  # seeded fault injection plan
    resolve_drift_db: float = 0.0    # warm re-solve when measured gains drift
    #                                  past this (dB, 0 => disabled)
    program: "PrecisionProgram | dict | str | None" = None
    #                                  per-round precision controller
    #                                  (repro.api.program); None = constant

    def __post_init__(self):
        if isinstance(self.faults, dict):
            self.faults = FaultPlan.from_dict(self.faults)
        if self.precision is None:
            self.precision = PrecisionPolicy()
        self.program = build_program(self.program)


class FLOrchestrator:
    def __init__(self, cfg: OrchestratorConfig, fleet: list[DeviceProfile],
                 mem_capacity_bytes: np.ndarray, grad_bytes: float,
                 weight_scale: float = 1.0):
        self.cfg = cfg
        self.fleet = fleet
        self.comm = CommParams(b_max_hz=cfg.b_max_hz, grad_bytes=grad_bytes)
        self.channel = ChannelModel(n_devices=cfg.n_devices, seed=cfg.seed)
        self.spec = MasterSpec(
            bits_options=cfg.precision.bit_options,
            n_devices=cfg.n_devices,
            error_budget=error_budget_bound(cfg.error_tolerance, cfg.e2,
                                            cfg.model_dim_d, cfg.n_devices),
            mem_capacity_bytes=mem_capacity_bytes,
            model_bytes_fp=4.0 * cfg.model_dim_d,
            weight_scale=weight_scale,
        )
        self._beta1 = np.array([d.beta1 for d in fleet])
        self._beta2 = np.array([d.beta2 for d in fleet])
        self._p_comp = np.array([d.runtime_power() for d in fleet])
        self._p_comm = np.array([d.p_comm for d in fleet])
        self._strategy: dict | None = None
        self.program: PrecisionProgram = cfg.program
        self.energy_log: list[dict] = []
        self._energy_cum = 0.0    # running sum of energy_log rounds: the
        #                           controller observation (O(1) per round,
        #                           rebuilt identically on resume replay)
        self.ckpt = (CheckpointManager(cfg.ckpt_dir, every=cfg.ckpt_every)
                     if cfg.ckpt_dir else None)
        self.faults = (cfg.faults.schedule(cfg.seed, cfg.n_devices)
                       if cfg.faults is not None and cfg.faults.active
                       else None)

    # ------------------------------------------------------------------
    def _primal_data(self, round_idx: int,
                     gains0: np.ndarray | None = None) -> PrimalData:
        gains = np.stack([self.channel.gains(round_idx + h)
                          for h in range(self.cfg.horizon)])
        if gains0 is not None:
            # re-solve against the *measured* (fault-faded) current gains;
            # future-horizon rounds keep the nominal channel prediction
            gains = gains.copy()
            gains[0] = gains0
        a1 = np.zeros_like(gains)
        a2 = np.zeros_like(gains)
        for r in range(self.cfg.horizon):
            a1[r], a2[r] = alpha_coefficients(gains[r], self._p_comm, self.comm)
        if self.cfg.t_max_s:
            t_max = self.cfg.t_max_s * self.cfg.horizon / max(self.cfg.n_rounds, 1)
        else:
            from repro.core.primal import _round_tmin
            tmin = _round_tmin(a2, self._beta1 + 32 * self._beta2, self.cfg.b_max_hz)
            t_max = float(self.cfg.t_factor * tmin.sum())
        return PrimalData(alpha1=a1, alpha2=a2, beta1=self._beta1,
                          beta2=self._beta2, p_comp=self._p_comp,
                          b_max=self.cfg.b_max_hz, t_max=t_max)

    def resolve(self, round_idx: int, *, warm: bool = False,
                gains0: np.ndarray | None = None) -> dict:
        """(Re-)run the co-design and cache the strategy.

        ``warm=True`` seeds the GBD from the incumbent strategy's q — used
        for drift-triggered mid-cadence re-solves, where the previous
        assignment is usually near-optimal for the perturbed channel.
        """
        data = self._primal_data(round_idx, gains0)
        scheme = self.cfg.scheme
        if scheme == "fwq":
            q0 = (self._strategy["q"] if warm and self._strategy is not None
                  else None)
            res = run_gbd(data, self.spec, max_rounds=30, q0=q0)
        elif scheme == "full_precision":
            res = baselines_mod.full_precision(data, self.spec)
        elif scheme == "unified_q":
            res = baselines_mod.unified_q(data, self.spec, bits=self.cfg.unified_bits)
        elif scheme == "rand_q":
            res = baselines_mod.rand_q(data, self.spec, seed=self.cfg.seed + round_idx)
        else:
            raise ValueError(scheme)
        # The solver's chosen bits enter the stack ONLY as a PrecisionPolicy:
        # the same object the trainer's traced delta and the serving packer
        # consume (per-device heterogeneous weights role).
        policy = PrecisionPolicy.from_gbd(
            res, comm=self.cfg.precision.comm,
            kv_cache=self.cfg.precision.kv_cache,
            bit_options=self.cfg.precision.bit_options)
        self._strategy = {"policy": policy,
                          "q": policy.bits_vector(self.cfg.n_devices),
                          "bandwidth": res.bandwidth,
                          "t_rounds": res.t_rounds, "energy_plan": res.energy,
                          "resolved_at": round_idx,
                          "gains0": (gains0 if gains0 is not None
                                     else self.channel.gains(round_idx)),
                          "warm": bool(warm)}
        return self._strategy

    def observe(self, round_idx: int, drift: float = 0.0) -> Observation:
        """The measured state the precision program decides from."""
        last = self.energy_log[-1] if self.energy_log else None
        return Observation(
            round=round_idx, rounds_total=self.cfg.n_rounds,
            energy_cum_j=self._energy_cum,
            energy_round_j=float(last["energy_round"]) if last else 0.0,
            gain_drift_db=float(drift))

    # ------------------------------------------------------------------
    def plan_round(self, round_idx: int) -> dict:
        """Strategy + cohort survival for this round.

        Returns dict with q (bits), surviving cohort mask, per-device energy
        and the round latency (Eq. 26 bookkeeping).  With a fault plan
        active the round is *executed* against the realized faults: faded
        gains, throttled compute, and a per-client retransmission loop whose
        every attempt is billed real transmit energy.

        The proposed strategy (cadence / drift re-solved GBD or baseline)
        passes through ``cfg.program.policy_for_round`` before any energy is
        modeled, so an adaptive controller's bit clamps feed the same
        ``e_comp = p_comp (beta1 + beta2 q)`` bookkeeping the static path
        uses.  The default constant program returns the proposal unchanged.
        """
        rf = (self.faults.round_faults(round_idx)
              if self.faults is not None else None)
        gains = self.channel.gains(round_idx)
        eff_gains = gains * rf.fade_lin if rf is not None else gains

        drift = 0.0
        resolved = False
        if (self._strategy is None
                or round_idx - self._strategy["resolved_at"] >= self.cfg.resolve_every):
            # cadence re-solve: cold start, nominal gains (legacy behavior)
            self.resolve(round_idx,
                         gains0=eff_gains if rf is not None else None)
            resolved = True
        elif self.cfg.resolve_drift_db > 0 or self.program.uses_drift:
            drift = gain_drift_db(self._strategy["gains0"], eff_gains)
            legacy = (self.cfg.resolve_drift_db > 0
                      and drift > self.cfg.resolve_drift_db)
            if legacy or self.program.wants_resolve(
                    self.observe(round_idx, drift)):
                self.resolve(round_idx, warm=True, gains0=eff_gains)
                resolved = True
        st = self._strategy
        # the controller's round decision: clamp/keep the proposed policy
        policy = self.program.policy_for_round(
            round_idx, st["policy"], self.observe(round_idx, drift))
        q = (st["q"] if policy is st["policy"]
             else policy.bits_vector(self.cfg.n_devices))
        h = self._strategy["resolved_at"]
        B = st["bandwidth"][min(round_idx - h, st["bandwidth"].shape[0] - 1)]
        a1, a2 = alpha_coefficients(eff_gains, self._p_comm, self.comm)

        t_comp = self._beta1 + self._beta2 * q
        if rf is not None:
            t_comp = t_comp * rf.slow
        t_comm = a2 / B
        e_comp = self._p_comp * t_comp
        e_comm = a1 / B            # lossless planned optimum
        t_total = t_comp + t_comm

        planned = st["t_rounds"][min(round_idx - h, len(st["t_rounds"]) - 1)]
        deadline = self.cfg.straggler_slack * planned
        rng = np.random.default_rng((self.cfg.seed, round_idx, 77))
        alive = rng.random(self.cfg.n_devices) >= self.cfg.dropout_prob
        on_time = t_total <= deadline

        if rf is None:
            cohort = alive & on_time
            if not cohort.any():        # never lose the round entirely
                cohort = alive if alive.any() else np.ones_like(alive)
            rec = {
                "round": round_idx, "policy": policy,
                "q": q.copy(), "comm_bits": int(policy.comm),
                "bandwidth": B.copy(),
                "t_comp": t_comp, "t_comm": t_comm,
                "t_round": float(np.max(np.where(cohort, t_total, 0.0))),
                "e_comp": e_comp, "e_comm": e_comm,
                "energy_round": float(np.sum(np.where(cohort, e_comp + e_comm, 0.0))),
                "cohort": cohort, "n_stragglers": int((~on_time).sum()),
                "n_failed": int((~alive).sum()),
            }
        else:
            rec = self._execute_faulty_round(
                round_idx, rf, policy, q, B, eff_gains, alive, deadline,
                t_comp, t_comm, e_comp, e_comm, drift, resolved)
        self.energy_log.append(rec)
        self._energy_cum += rec["energy_round"]
        return rec

    def _execute_faulty_round(self, round_idx, rf, policy, q, B, eff_gains,
                              alive, deadline, t_comp, t_comm, e_comp,
                              e_comm, drift, resolved) -> dict:
        """Realize one round under faults: who delivers, and at what cost.

        Energy semantics: every *alive* client computes (mid-round dropout
        happens after local training), and every client that attempts the
        uplink pays for each transmission attempt — delivered or not.
        ``e_comm`` stays the lossless plan; ``e_comm_actual`` is the bill.
        """
        from repro.dist.wire import wire_scale

        n = self.cfg.n_devices
        plan = self.faults.plan
        # the uplink carries the SR-compressed payload: comm demotion (an
        # adaptive program's lever) shrinks every retransmission attempt.
        # wire_scale is exactly 1.0 at comm=32, so static runs are untouched.
        payload_bits = (8.0 * self.comm.grad_bytes
                        * wire_scale(int(policy.comm), n))
        rate = reference_rate_bps(B, eff_gains, self._p_comm, self.comm)

        delivered = np.zeros(n, dtype=bool)
        e_comm_act = np.zeros(n)
        t_comm_act = np.zeros(n)
        attempts = np.zeros(n, dtype=int)
        retx = np.zeros(n, dtype=int)
        e_retx = np.zeros(n)
        uploads = alive & ~rf.drop
        for i in np.flatnonzero(uploads):
            out = transmit_update(
                payload_bits, float(rate[i]), float(self._p_comm[i]),
                rf.loss_prob, self.faults.chunk_rng(round_idx, i), plan,
                budget_s=max(0.0, deadline - float(t_comp[i])))
            delivered[i] = out.delivered
            e_comm_act[i] = out.e_comm_j
            t_comm_act[i] = out.t_comm_s
            attempts[i] = out.attempts
            retx[i] = out.retransmissions
            e_retx[i] = out.e_retx_j

        cohort = delivered
        forced = False
        if not cohort.any():
            # nobody made the deadline: rather than lose the round, extend
            # it for the best-effort cohort (energy already billed above)
            forced = True
            cohort = (uploads if uploads.any()
                      else (alive if alive.any() else np.ones(n, dtype=bool)))

        t_active = np.where(cohort, t_comp + t_comm_act, 0.0)
        # alive clients all burn compute (dropout strikes after training);
        # uplink attempts are billed whether or not they delivered
        billed = float(np.sum(np.where(alive, e_comp, 0.0)) + e_comm_act.sum())
        return {
            "round": round_idx, "policy": policy,
            "q": q.copy(), "comm_bits": int(policy.comm),
            "bandwidth": B.copy(),
            "t_comp": t_comp, "t_comm": t_comm,
            "t_round": float(np.max(t_active)) if t_active.size else 0.0,
            "e_comp": e_comp, "e_comm": e_comm,
            "e_comm_actual": e_comm_act,
            "energy_round": billed,
            "cohort": cohort,
            "n_stragglers": int((uploads & ~delivered).sum()),
            "n_failed": int((~alive).sum()),
            "dropped_midround": int((alive & rf.drop).sum()),
            "undelivered": int((uploads & ~delivered).sum()),
            "attempts": int(attempts.sum()),
            "retransmissions": int(retx.sum()),
            "retx_energy_j": float(e_retx.sum()),
            "corrupt_kind": rf.corrupt_kind.copy(),
            "fade_db": rf.fade_db.copy(),
            "drift_db": float(drift),
            "resolved": bool(resolved),
            "warm_resolve": bool(self._strategy.get("warm", False)),
            "forced_cohort": forced,
        }

    # ------------------------------------------------------------------
    def run(self, sim, batch_fn: Callable[[int, np.ndarray], dict],
            *, eval_fn: Callable | None = None, eval_every: int = 0) -> dict:
        """Drive ``sim`` (FLSimulation) for n_rounds with full bookkeeping."""
        start = 0
        plan_dict = (self.faults.plan.to_dict()
                     if self.faults is not None else None)
        if self.ckpt is not None:
            state, start, _ = self.ckpt.restore_or(
                sim.state(), expect_extra={"faults": plan_dict})
            if start:
                sim.load_state(state, start)
                log.info("resumed from round %d", start)
                # replay planning for the completed rounds: pure host math
                # (seeded solver cadence, fault realizations, energy log) so
                # the resumed run's strategy state and bookkeeping are
                # bit-identical to the uninterrupted run's at round `start`
                for r in range(start):
                    self.plan_round(r)
        evals = []
        for r in range(start, self.cfg.n_rounds):
            plan = self.plan_round(r)
            cohort_idx = np.flatnonzero(plan["cohort"])
            batch = batch_fn(r, cohort_idx)
            # per-device bits reach the simulator only through the round's
            # PrecisionPolicy (built by PrecisionPolicy.from_gbd in resolve)
            bits = plan["policy"].bits_vector(self.cfg.n_devices)[cohort_idx]
            upd = None
            if self.faults is not None:
                upd = UpdateFaults(
                    kinds=plan["corrupt_kind"][cohort_idx],
                    rngs=tuple(self.faults.corrupt_rng(r, int(i))
                               for i in cohort_idx),
                    gate_factor=self.faults.plan.gate_norm_factor)
            # elastic cohort: the simulator round is sized by the batch
            rec = sim.run_round(batch, bits, faults=upd,
                                comm_bits=plan["comm_bits"])
            rec.update(energy=plan["energy_round"], t_round=plan["t_round"],
                       cohort_size=len(cohort_idx))
            if upd is not None:
                plan["n_rejected"] = rec.get("n_rejected", 0)
                rec.update(retransmissions=plan["retransmissions"],
                           retx_energy_j=plan["retx_energy_j"])
            if eval_fn is not None and eval_every and (r + 1) % eval_every == 0:
                evals.append({"round": r, **eval_fn(sim)})
            if self.ckpt is not None:
                self.ckpt.maybe_save(r + 1, sim.state(),
                                     extra={"round": r + 1,
                                            "faults": plan_dict})
        total_energy = float(sum(e["energy_round"] for e in self.energy_log))
        total_time = float(sum(e["t_round"] for e in self.energy_log))
        out = {"history": sim.history, "energy_log": self.energy_log,
               "evals": evals, "total_energy_j": total_energy,
               "total_time_s": total_time}
        prog = self.program.summary()
        if prog.get("kind", "constant") != "constant":
            if "budget_j" in prog:
                prog["within_budget"] = total_energy <= prog["budget_j"]
            out["program"] = prog
        if self.faults is not None:
            out.update(
                total_retransmissions=int(sum(
                    e.get("retransmissions", 0) for e in self.energy_log)),
                total_retx_energy_j=float(sum(
                    e.get("retx_energy_j", 0.0) for e in self.energy_log)),
                total_rejected=int(sum(
                    h.get("n_rejected", 0) for h in sim.history)),
                total_undelivered=int(sum(
                    e.get("undelivered", 0) for e in self.energy_log)),
                total_dropped_midround=int(sum(
                    e.get("dropped_midround", 0) for e in self.energy_log)),
            )
        return out
