from repro.fed.simulation import FLSimulation, SimConfig  # noqa: F401
from repro.fed.orchestrator import FLOrchestrator, OrchestratorConfig  # noqa: F401
