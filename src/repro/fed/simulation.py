"""vmap-based FWQ-FL simulator (the paper-experiment path; CPU-friendly).

One jitted round = Algorithm 1 exactly: per-client SR tree-quantization at
traced resolutions, gradients at quantized weights, full-precision server
SGD.  Clients map onto the vmapped leading axis; the pod trainer
(launch/steps.py) is the shard_map twin of this for datacenter scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.core.fwq import FWQConfig, delta_for_clients, make_fwq_round, make_tree_quant_loss
from repro.optim import Optimizer, build_optimizer


@dataclasses.dataclass
class SimConfig:
    n_clients: int
    lr: float = 0.05
    optimizer: str = "sgd"
    momentum: float = 0.0
    seed: int = 0


class FLSimulation:
    """Stateful wrapper: holds params/opt, steps one FL round at a time."""

    def __init__(self, loss_fn: Callable, init_fn: Callable, cfg: SimConfig):
        """loss_fn(params, batch, rng) -> (loss, aux); init_fn(key) -> params."""
        self.cfg = cfg
        self.opt: Optimizer = build_optimizer(cfg.optimizer, cfg.lr,
                                              **({"momentum": cfg.momentum}
                                                 if cfg.optimizer == "sgd" else {}))
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_fn(key)
        self.opt_state = self.opt.init(self.params)
        client_loss = make_tree_quant_loss(loss_fn)
        round_fn = make_fwq_round(client_loss, self.opt.update,
                                  FWQConfig(n_clients=cfg.n_clients))
        self._round = jax.jit(round_fn)
        self.round_idx = 0
        self.history: list[dict] = []

    def state(self):
        return {"params": self.params, "opt": self.opt_state}

    def load_state(self, state, round_idx: int):
        self.params, self.opt_state = state["params"], state["opt"]
        self.round_idx = round_idx

    def run_round(self, batch, bits) -> dict:
        """batch: leaves with leading dim n_clients; bits: (n_clients,) ints
        or a :class:`repro.api.precision.PrecisionPolicy` whose weights role
        covers exactly this round's cohort."""
        if hasattr(bits, "bits_vector"):  # PrecisionPolicy
            n = jax.tree_util.tree_leaves(batch)[0].shape[0]
            if bits.heterogeneous and len(bits.weights) != n:
                # a device-indexed policy cannot be positionally mapped onto
                # an elastic sub-cohort: the caller must select the cohort's
                # bits itself (see FLOrchestrator.run)
                raise ValueError(
                    f"policy carries {len(bits.weights)} per-device bits but "
                    f"the round batch has {n} clients; pass the cohort's own "
                    "bits (policy.bits_vector(n_devices)[cohort_idx])")
            bits = bits.bits_vector(n)
        delta = delta_for_clients(np.asarray(bits))
        rng = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), self.round_idx)
        self.params, self.opt_state, m = self._round(
            self.params, self.opt_state, batch, delta, rng)
        rec = {
            "round": self.round_idx,
            "loss": float(m.loss),
            "grad_norm_sq": float(m.grad_norm_sq),
            "client_loss": np.asarray(m.client_loss),
            "bits": np.asarray(bits).copy(),
        }
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def evaluate(self, loss_fn, batch) -> dict:
        loss, aux = jax.jit(loss_fn)(self.params, batch, jax.random.PRNGKey(0))
        out = {"loss": float(loss)}
        out.update({k: float(v) for k, v in aux.items()})
        return out
