"""vmap-based FWQ-FL simulator (the paper-experiment path; CPU-friendly).

One jitted round = Algorithm 1 exactly: per-client SR tree-quantization at
traced resolutions, gradients at quantized weights, full-precision server
SGD.  Clients map onto the vmapped leading axis; the pod trainer
(launch/steps.py) is the shard_map twin of this for datacenter scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.core.fwq import (
    FWQConfig,
    delta_for_clients,
    make_fwq_apply,
    make_fwq_client_grads,
    make_fwq_round,
    make_tree_quant_loss,
)
from repro.faults.executor import UpdateFaults, gate_mask, inject_corruption
from repro.optim import Optimizer, build_optimizer


@dataclasses.dataclass
class SimConfig:
    n_clients: int
    lr: float = 0.05
    optimizer: str = "sgd"
    momentum: float = 0.0
    seed: int = 0


class FLSimulation:
    """Stateful wrapper: holds params/opt, steps one FL round at a time."""

    def __init__(self, loss_fn: Callable, init_fn: Callable, cfg: SimConfig):
        """loss_fn(params, batch, rng) -> (loss, aux); init_fn(key) -> params."""
        self.cfg = cfg
        self.opt: Optimizer = build_optimizer(cfg.optimizer, cfg.lr,
                                              **({"momentum": cfg.momentum}
                                                 if cfg.optimizer == "sgd" else {}))
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_fn(key)
        self.opt_state = self.opt.init(self.params)
        client_loss = make_tree_quant_loss(loss_fn)
        round_fn = make_fwq_round(client_loss, self.opt.update,
                                  FWQConfig(n_clients=cfg.n_clients))
        self._round = jax.jit(round_fn)
        self._client_loss = client_loss
        self._gated = None  # (grads_fn, apply_fn) — built on first fault use
        self.round_idx = 0
        self.history: list[dict] = []

    def state(self):
        return {"params": self.params, "opt": self.opt_state}

    def load_state(self, state, round_idx: int):
        self.params, self.opt_state = state["params"], state["opt"]
        self.round_idx = round_idx

    def run_round(self, batch, bits, *, faults: UpdateFaults | None = None,
                  comm_bits: int | None = None) -> dict:
        """batch: leaves with leading dim n_clients; bits: (n_clients,) ints
        or a :class:`repro.api.precision.PrecisionPolicy` whose weights role
        covers exactly this round's cohort.

        ``faults`` (from the resilient orchestrator) switches to the gated
        two-phase round: per-client grads -> host-side payload corruption ->
        aggregation gate (finite check + relative norm bound) -> masked
        server step.  ``faults=None`` is the legacy single-jit round,
        bit-identical to before the gate existed.

        ``comm_bits`` records this round's gradient wire bit-width in the
        history row (adaptive programs change it mid-run, so per-round
        truth lives in the rows, not the spec); it does not change the
        simulator's math — the vmap round aggregates in full precision per
        Algorithm 1, wire compression is the pod trainer's concern.
        """
        if hasattr(bits, "bits_vector"):  # PrecisionPolicy
            if comm_bits is None:
                comm_bits = int(bits.comm)
            n = jax.tree_util.tree_leaves(batch)[0].shape[0]
            if bits.heterogeneous and len(bits.weights) != n:
                # a device-indexed policy cannot be positionally mapped onto
                # an elastic sub-cohort: the caller must select the cohort's
                # bits itself (see FLOrchestrator.run)
                raise ValueError(
                    f"policy carries {len(bits.weights)} per-device bits but "
                    f"the round batch has {n} clients; pass the cohort's own "
                    "bits (policy.bits_vector(n_devices)[cohort_idx])")
            bits = bits.bits_vector(n)
        delta = delta_for_clients(np.asarray(bits))
        rng = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), self.round_idx)
        if faults is None:
            self.params, self.opt_state, m = self._round(
                self.params, self.opt_state, batch, delta, rng)
            rec = {
                "round": self.round_idx,
                "loss": float(m.loss),
                "grad_norm_sq": float(m.grad_norm_sq),
                "client_loss": np.asarray(m.client_loss),
                "bits": np.asarray(bits).copy(),
            }
        else:
            rec = self._run_gated_round(batch, delta, rng, bits, faults)
        if comm_bits is not None:
            rec["comm_bits"] = int(comm_bits)
        self.history.append(rec)
        self.round_idx += 1
        return rec

    def _run_gated_round(self, batch, delta, rng, bits,
                         faults: UpdateFaults) -> dict:
        if self._gated is None:
            self._gated = (jax.jit(make_fwq_client_grads(self._client_loss)),
                           jax.jit(make_fwq_apply(self.opt.update)))
        grads_fn, apply_fn = self._gated
        losses, grads, gsqs, finite = grads_fn(self.params, batch, delta, rng)
        norms_sq = np.array(gsqs, dtype=np.float64)
        finite = np.array(finite, dtype=bool)

        kinds = np.asarray(faults.kinds)
        if (kinds > 0).any():
            # pull per-client updates to the host, damage the flagged ones in
            # their flattened-payload view, and re-stage for aggregation
            leaves = [np.array(g) for g in jax.tree_util.tree_leaves(grads)]
            for ci in np.flatnonzero(kinds):
                vec = np.concatenate([leaf[ci].ravel() for leaf in leaves])
                vec = inject_corruption(vec, int(kinds[ci]), faults.rngs[ci])
                off = 0
                for leaf in leaves:
                    size = leaf[ci].size
                    leaf[ci] = vec[off:off + size].reshape(leaf[ci].shape)
                    off += size
                with np.errstate(over="ignore", invalid="ignore"):
                    norms_sq[ci] = float(sum(
                        np.sum(leaf[ci].astype(np.float64) ** 2)
                        for leaf in leaves))
                finite[ci] = all(np.isfinite(leaf[ci]).all() for leaf in leaves)
            grads = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(grads), leaves)

        accept = gate_mask(norms_sq, finite, faults.gate_factor)
        n_rejected = int((~accept).sum())
        if accept.any():
            self.params, self.opt_state, gnorm = apply_fn(
                self.params, self.opt_state, grads,
                jax.numpy.asarray(accept.astype(np.float32)))
            gnorm = float(gnorm)
            skipped = False
        else:
            # every update rejected: hold the global model for this round
            gnorm = 0.0
            skipped = True
        return {
            "round": self.round_idx,
            "loss": float(jax.numpy.mean(losses)),
            "grad_norm_sq": gnorm,
            "client_loss": np.asarray(losses),
            "bits": np.asarray(bits).copy(),
            "accepted": accept,
            "n_rejected": n_rejected,
            "gate_skipped": skipped,
        }

    def evaluate(self, loss_fn, batch) -> dict:
        loss, aux = jax.jit(loss_fn)(self.params, batch, jax.random.PRNGKey(0))
        out = {"loss": float(loss)}
        out.update({k: float(v) for k, v in aux.items()})
        return out
