"""Structural HLO parser: loop-aware FLOP / byte / collective accounting.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE — for scan-over-
layers models that understates FLOPs by ~n_layers.  This parser walks the
partitioned HLO text instead:

* splits the module into computations,
* builds a global name -> shape table (instruction results + computation
  parameters),
* accounts per computation: dot FLOPs/bytes and collective wire bytes,
* propagates multipliers along the call graph — ``while`` bodies multiply by
  the ``known_trip_count`` XLA records in ``backend_config``, fusions/calls
  by 1 — starting at ENTRY.

Conventions:
* dot FLOPs  = 2 * prod(result dims) * prod(contracted lhs dims)
* dot bytes  = lhs + rhs + result bytes (the MXU stream; elementwise ops ride
  along inside fusions and are excluded — documented under §Roofline)
* collective wire bytes per device (ring model, group size n):
    all-gather:        (n-1)/n * result
    reduce-scatter:    (n-1)/n * input  (= (n-1) * result)
    all-reduce:        2(n-1)/n * result
    all-to-all:        (n-1)/n * result
    collective-permute: result

Besides the aggregate :class:`ModuleCosts` totals, every collective
instruction is recorded as a :class:`CollectiveOp` (kind, element dtype,
elements, bytes, group size, loop multiplier, instruction name) — the wire
lint in ``repro.analyze.wire_lint`` consumes those records.  Hardening
notes: ``*-done`` halves are never counted (only ``-start`` carries
shapes); ``async-start`` wrappers contribute through their called
computation, the wrapper line itself is skipped; multi-result tuple
collectives (the all-reduce combiner's output) sum their tuple parts;
explicit single-participant ``replica_groups={{0}}`` groups move zero wire
bytes (degenerate collectives on 1-device meshes).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[su](?:4|8|16|32|64)|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->")
_OPCODE_RE = re.compile(
    r"\b(dot|while|fusion|call|conditional|async-start|all-gather|all-reduce"
    r"|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w\.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=(%[\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype], n


@dataclasses.dataclass
class CollectiveOp:
    """One collective instruction, as executed (loop multiplier applied).

    ``parts`` lists the result tuple's (dtype, elems) pairs — a single
    non-tuple result is one part; ``dtype``/``elems`` summarize the first /
    total.  ``bytes`` and ``wire_bytes`` are per execution; multiply by
    ``mult`` for the per-step totals the aggregate fields report.
    """

    kind: str
    dtype: str
    elems: int
    bytes: float                      # result bytes, one execution
    wire_bytes: float                 # ring-model wire bytes, one execution
    group_size: int
    mult: float                       # loop trip multiplier from the walk
    name: str                         # instruction var, e.g. %all-reduce.3
    computation: str
    parts: tuple = ()

    def to_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    dot_bytes: float
    collective_bytes: float           # wire-model bytes, per device
    collective_by_kind: dict
    collective_counts: dict
    n_while: int
    collectives: list = dataclasses.field(default_factory=list)

    def to_dict(self):
        return dataclasses.asdict(self)


def _unknown_collective_record(line: str, comp: str) -> CollectiveOp | None:
    """Best-effort record for a replica-grouped op the walker doesn't know.

    ``*-done`` halves and shapeless lines are skipped (consistent with the
    known-op path); wire bytes are the full result bytes — an upper bound,
    so byte accounting can over- but never under-count the unknown op.
    """
    md = _DEF_RE.match(line)
    if md is None:
        return None
    head = md.group(2).split("(", 1)[0].strip()
    opcode = head.split()[-1] if head.split() else "?"
    if opcode.endswith("-done") or "[" in opcode:
        return None
    res = _SHAPE_RE.findall(head)
    if not res:
        return None
    out_b = sum(_shape_bytes(d, dims)[0] for d, dims in res)
    parts = tuple((d, _shape_bytes(d, dims)[1]) for d, dims in res)
    mg = _GROUP_RE.search(line)
    if mg:
        n = len(mg.group(1).split(","))
    else:
        mg2 = _GROUP_V2_RE.search(line)
        n = int(mg2.group(2)) if mg2 else 2
    return CollectiveOp(
        kind=f"unknown:{opcode}", dtype=parts[0][0], elems=sum(e for _, e in parts),
        bytes=out_b, wire_bytes=0.0 if n <= 1 else float(out_b),
        group_size=n, mult=1.0, name=md.group(1), computation=comp,
        parts=parts)


def parse_module(text: str) -> ModuleCosts:
    # ---- pass 1: split computations, collect result/param shapes ----------
    comps: dict[str, list[str]] = {}
    shapes: dict[str, tuple[str, str]] = {}
    current = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            current = mc.group(1)
            comps[current] = []
            # parameter shapes: "name: f32[4,8], other: (f32[], s32[2])"
            for pname, ptype in re.findall(r"([\w\.\-]+)\s*:\s*([^,()]*(?:\([^)]*\))?[^,]*)",
                                           mc.group(2)):
                ms = _SHAPE_RE.search(ptype)
                if ms:
                    shapes["%" + pname] = (ms.group(1), ms.group(2))
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        comps[current].append(line)
        md = _DEF_RE.match(line)
        if md:
            ms = _SHAPE_RE.search(md.group(2))
            if ms:
                shapes[md.group(1)] = (ms.group(1), ms.group(2))

    # ---- pass 2: per-computation costs + call edges ------------------------
    comp_cost = {}
    for name, lines in comps.items():
        flops = 0.0
        dbytes = 0.0
        coll = defaultdict(float)
        counts = defaultdict(int)
        edges = []
        n_while = 0
        coll_ops: list[CollectiveOp] = []
        for line in lines:
            mo = _OPCODE_RE.search(line)
            if not mo:
                # catch-all: a replica-grouped instruction whose opcode the
                # walker doesn't model (collective-broadcast, ragged
                # all-to-all, ...).  Record it as ``unknown:<opcode>`` with
                # conservative wire bytes (= result bytes) instead of
                # silently under-counting — the wire lint turns these into
                # ``wire.unknown_collective`` findings.
                if "replica_groups=" in line:
                    rec = _unknown_collective_record(line, name)
                    if rec is not None:
                        coll[rec.kind] += rec.wire_bytes
                        counts[rec.kind] += 1
                        coll_ops.append(rec)
                continue
            op = mo.group(1)
            md = _DEF_RE.match(line)
            res = _SHAPE_RE.findall(md.group(2)) if md else []
            if op == "dot":
                out_b, _ = _shape_bytes(*res[0])
                # operands: first two %refs inside the call parens
                tail = line[mo.end():]
                refs = re.findall(r"(%[\w\.\-]+)", tail.split(")")[0])
                lhs = shapes.get(refs[0]) if refs else None
                rhs = shapes.get(refs[1]) if len(refs) > 1 else None
                cd = _LHS_CDIMS_RE.search(line)
                k = 1
                if lhs and cd:
                    dims = [int(x) for x in lhs[1].split(",") if x]
                    for c in (int(x) for x in cd.group(1).split(",") if x):
                        if c < len(dims):
                            k *= dims[c]
                out_elems = _shape_bytes(*res[0])[1]
                flops += 2.0 * out_elems * k
                dbytes += out_b
                for s in (lhs, rhs):
                    if s:
                        dbytes += _shape_bytes(*s)[0]
            elif op == "while":
                trip = 1
                mt = _TRIP_RE.search(line)
                if mt:
                    trip = int(mt.group(1))
                n_while += 1
                mb, mcnd = _BODY_RE.search(line), _COND_RE.search(line)
                if mb:
                    edges.append((mb.group(1), trip))
                if mcnd:
                    edges.append((mcnd.group(1), trip))
            elif op in ("fusion", "call", "conditional", "async-start"):
                # async-start wraps a collective in a called computation —
                # count the inner op once via the edge, never the wrapper
                for mr in (_CALLS_RE, _TOAPPLY_RE):
                    mm = mr.search(line)
                    if mm:
                        edges.append((mm.group(1), 1))
            elif op in COLLECTIVE_KINDS:
                # Wire bytes derive from the RESULT type, which sits left of
                # the opcode ("%x = f32[64,32] all-gather(f32[16,32] %p)...");
                # shapes right of it are inline operand types / metadata and
                # must not be counted.  ``*-start`` forms return a tuple
                # (operands..., results..., context...): drop scalar context
                # slots (u32[] handles), then keep the result half.
                res = _SHAPE_RE.findall(line[: mo.start()])
                if not res:
                    continue
                if mo.group(0).endswith("-start("):
                    res = [r for r in res if r[1]]      # drop scalar context
                    if len(res) >= 2:
                        res = res[len(res) // 2:]
                out_b = sum(_shape_bytes(d, dims)[0] for d, dims in res)
                parts = tuple((d, _shape_bytes(d, dims)[1])
                              for d, dims in res)
                elems = sum(e for _, e in parts)
                mg = _GROUP_RE.search(line)
                if mg:
                    n = len(mg.group(1).split(","))
                else:
                    mg2 = _GROUP_V2_RE.search(line)
                    n = int(mg2.group(2)) if mg2 else 2
                if n <= 1:
                    # explicit single-participant group: a degenerate
                    # collective on a 1-device (sub)mesh — nothing crosses
                    # a wire
                    wire = 0.0
                elif op == "all-gather":
                    wire = (n - 1) / n * out_b
                elif op == "reduce-scatter":
                    wire = (n - 1) * out_b
                elif op == "all-reduce":
                    wire = 2 * (n - 1) / n * out_b
                elif op == "all-to-all":
                    wire = (n - 1) / n * out_b
                else:
                    wire = out_b
                coll[op] += wire
                counts[op] += 1
                inst = md.group(1) if md else "%?"
                coll_ops.append(CollectiveOp(
                    kind=op, dtype=parts[0][0] if parts else "?",
                    elems=elems, bytes=out_b, wire_bytes=wire,
                    group_size=n, mult=1.0, name=inst, computation=name,
                    parts=parts))
        comp_cost[name] = dict(flops=flops, dbytes=dbytes, coll=dict(coll),
                               counts=dict(counts), edges=edges,
                               n_while=n_while, coll_ops=coll_ops)

    # ---- pass 3: propagate multipliers from ENTRY --------------------------
    entry = None
    for name in comps:
        if ".main" in name or name.endswith("main") or "main." in name:
            entry = name
    if entry is None:  # fall back: the computation nobody calls
        called = {c for v in comp_cost.values() for c, _ in v["edges"]}
        roots = [n for n in comps if n not in called]
        entry = roots[-1] if roots else next(iter(comps))

    total = dict(flops=0.0, dbytes=0.0, n_while=0)
    coll_total = defaultdict(float)
    counts_total = defaultdict(int)
    coll_records: list[CollectiveOp] = []
    seen_stack = []

    def walk(name, mult):
        c = comp_cost.get(name)
        if c is None or name in seen_stack:
            return
        seen_stack.append(name)
        total["flops"] += mult * c["flops"]
        total["dbytes"] += mult * c["dbytes"]
        total["n_while"] += c["n_while"]
        for k, v in c["coll"].items():
            coll_total[k] += mult * v
        for k, v in c["counts"].items():
            counts_total[k] += v
        for rec in c["coll_ops"]:
            coll_records.append(dataclasses.replace(rec, mult=mult))
        for callee, m in c["edges"]:
            walk(callee, mult * m)
        seen_stack.pop()

    walk(entry, 1.0)
    return ModuleCosts(
        flops=total["flops"], dot_bytes=total["dbytes"],
        collective_bytes=sum(coll_total.values()),
        collective_by_kind=dict(coll_total),
        collective_counts=dict(counts_total),
        n_while=total["n_while"],
        collectives=coll_records,
    )
