from repro.roofline.analysis import RooflineReport, analyze_compiled  # noqa: F401
from repro.roofline.hw import TPU_V5E  # noqa: F401
