"""Roofline terms from compiled dry-run artifacts.

Sources (deliverable g):
* ``compiled.cost_analysis()``  -> HLO FLOPs + HBM bytes (per device: the
  module is the SPMD-partitioned per-device program).
* ``compiled.as_text()``        -> collective bytes: sum of operand sizes of
  every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute instruction (shapes parsed from the HLO text).

Terms (seconds, per training/serving step, per device):
    compute    = flops / peak
    memory     = bytes_accessed / hbm_bw
    collective = collective_bytes / ici_link_bw
"""

from __future__ import annotations

import dataclasses
import re

from repro.roofline.hw import ChipSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[su]\d+|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_by_kind(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind operand bytes, from the partitioned HLO text.

    A line looks like::

        %all-gather.7 = bf16[4096,512]{1,0} all-gather(bf16[256,512]{1,0} %p),
            replica_groups=..., dimensions={0}

    We sum the *operand* shapes (inside the parens).  ``*-start`` ops are
    counted; their ``*-done`` halves carry no shapes and are skipped.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+[^=]*?\b(" + "|".join(_COLLECTIVES) + r")(-start)?\(",
                      stripped)
        if not m:
            continue
        kind = m.group(1)
        # operand section: everything inside the outermost call parens
        call = stripped[m.end() - 1:]
        depth, end = 0, len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[:end]
        b = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands))
        if b == 0.0:
            # operands printed without inline types: fall back to result shape
            head = stripped[: m.start()]
            b = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        out[kind] += b
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float          # loop-aware structural count (hlo_parse)
    bytes_per_device: float          # dot-stream bytes, bf16-equivalent
    bytes_per_device_raw: float      # as compiled (CPU backend upcasts bf16)
    collective_bytes: float          # wire-model bytes, bf16-equivalent
    collective_bytes_raw: float
    collective_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_flops_ratio: float        # MODEL_FLOPS / (HLO_FLOPs * devices)
    memory_stats: dict
    cost_analysis_flops: float       # XLA's (loop bodies counted once)
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)

    def summary_row(self):
        return (f"{self.arch},{self.shape},{self.mesh},{self.compute_s:.3e},"
                f"{self.memory_s:.3e},{self.collective_s:.3e},{self.dominant},"
                f"{self.useful_flops_ratio:.3f}")


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops_global: float,
                     chip: ChipSpec = TPU_V5E, note: str = "") -> RooflineReport:
    from repro.roofline.hlo_parse import parse_module

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    txt = compiled.as_text()
    mc = parse_module(txt)
    # The CPU backend upcasts bf16 compute to f32; on the v5e target the hot
    # tensors are bf16.  Report the bf16-equivalent byte terms (f32 bytes
    # halved) alongside the raw compiled ones; FLOP counts are unaffected.
    mc_bf16 = parse_module(txt.replace("f32[", "bf16["))

    try:
        ma = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate": int(ma.argument_size_in_bytes
                                 + ma.output_size_in_bytes
                                 + ma.temp_size_in_bytes
                                 - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        mem_stats = {"error": str(e)}

    flops = mc.flops
    compute_s = flops / chip.peak_flops_bf16
    memory_s = mc_bf16.dot_bytes / chip.hbm_bw
    collective_s = mc_bf16.collective_bytes / chip.ici_link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_global / max(flops * n_devices, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops,
        bytes_per_device=mc_bf16.dot_bytes, bytes_per_device_raw=mc.dot_bytes,
        collective_bytes=mc_bf16.collective_bytes,
        collective_bytes_raw=mc.collective_bytes,
        collective_breakdown={"bytes": mc_bf16.collective_by_kind,
                              "counts": mc.collective_counts},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_global=model_flops_global,
        useful_flops_ratio=useful, memory_stats=mem_stats,
        cost_analysis_flops=float(cost.get("flops", 0.0)), note=note,
    )


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (N = active params).

    D counts processed tokens: train/prefill -> batch*seq; decode -> batch*1.
    """
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch  # decode: one token per sequence
