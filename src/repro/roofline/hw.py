"""Hardware constants for the roofline model (target: TPU v5e)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_link_bw: float         # bytes/s per ICI link
    hbm_bytes: float           # capacity per chip
    vmem_bytes: float


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16e9,
    vmem_bytes=128 * 1024 * 1024 / 8,  # 16 MiB effective scalar+vector memory
)
