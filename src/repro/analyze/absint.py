"""Forward abstract interpreter over traced jaxprs (analyze v2 tentpole).

Walks the same jaxprs :mod:`repro.analyze.precision_flow` taint-walks, but
instead of boolean taint it propagates an :class:`repro.analyze.ranges.AbsVal`
per array — a value interval, an integer-exactness flag, and a
quantization-error bound — through arithmetic, the dequant idiom
(``convert_element_type`` + ``mul``-by-scale), scan/while/cond/shard_map
sub-jaxprs (loop carries widen to a fixpoint), and collectives
(``psum`` multiplies the interval by the axis size; ``all_gather`` and
``pmax`` preserve it).

Two refinements make real transformer graphs provable instead of drowning
in ⊤:

* **comparison-guarded selects** — ``where(x > k, x, fallback)`` refines the
  taken branch with the predicate, so the ``s = where(s > 0, s, 1.0)`` guard
  in the wire quantizer yields a provably positive scale;
* **the max-subtraction idiom** — ``exp(x - max(x))`` is recognized via a
  producer walk, bounding the exponent by 0 and the sum of the result below
  by 1, which keeps softmax / logsumexp free of spurious domain findings.

Rule families emitted here:

* ``overflow.wire_accumulator`` (error) — an integer ``psum`` whose interval,
  multiplied by the axis size, cannot be proven to fit its accumulator
  dtype.  The clip in ``quantized_psum_batch`` bounds the codes to
  ``±(2^bits - 1)``, so a well-formed wire path *proves* and is recorded in
  ``AbsintResult.proofs`` with its headroom; a graph missing the clamp (or
  forced one dtype tier too narrow) fails the proof statically instead of
  wrapping at runtime.
* ``numerics.unguarded`` (warn) — exp/log/div/rsqrt/sqrt consuming an
  interval containing 0 (domain edge) or of unbounded magnitude, with no
  clamp/where/eps guard visible upstream.  The static complement of the
  runtime ``on_nonfinite`` guard.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.analyze import ranges as R
from repro.analyze.findings import Finding, source_key
from repro.analyze.precision_flow import _inner, _is_var, _jaxpr_params
from repro.analyze.ranges import INF, AbsVal

#: primitives whose output carries the first operand's values unchanged (and
#: through which the max-sub / attains-one provenance walks)
_PASSTHROUGH = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "rev", "copy",
    "copy_p", "stop_gradient", "optimization_barrier", "reduce_precision",
    "real", "expand_dims", "sharding_constraint", "device_put",
    "pbroadcast", "pvary",
})

#: pass-through, but element-dropping: values stay bounded by the operand's
#: interval, yet "contains an element == 1" style facts do NOT survive
_SUBSET = frozenset({
    "slice", "dynamic_slice", "gather", "take", "dynamic_gather",
})

_PSUM = frozenset({"psum", "psum2", "psum_invariant"})
_RSCATTER = frozenset({"psum_scatter", "reduce_scatter"})

_BOUNDED_UNARY = {
    "tanh": (-1.0, 1.0), "sin": (-1.0, 1.0), "cos": (-1.0, 1.0),
    "logistic": (0.0, 1.0), "erf": (-1.0, 1.0), "erfc": (0.0, 2.0),
    "atan": (-math.pi / 2, math.pi / 2), "asin": (-math.pi / 2, math.pi / 2),
    "acos": (0.0, math.pi),
}

_MAX_FIX_ITERS = 5


@dataclasses.dataclass
class AbsintResult:
    """What one interpretation produced."""
    findings: list
    proofs: list          # dicts: integer-psum overflow proof certificates
    out: list             # AbsVal per jaxpr outvar


class _Scope:
    """Per-interpretation state: env + provenance used by refinements.

    ``alias`` maps an inlined sub-jaxpr's invar to the outer var it was
    bound to, so producer chases (select_n predicate refinement, the
    max-sub idiom) cross pjit/remat/custom_* boundaries instead of dying
    at the first wrapper ``jnp.where`` emits.
    """

    __slots__ = ("env", "producer", "alias", "maxsub", "attains_one")

    def __init__(self):
        self.env: dict = {}
        self.producer: dict = {}
        self.alias: dict = {}
        self.maxsub: set = set()       # vars of the form x - max(x)
        self.attains_one: set = set()  # arrays containing an element == 1


def _literal_val(val) -> AbsVal:
    try:
        a = np.asarray(val)
        if a.size == 0:
            return R.TOP
        lo, hi = float(np.min(a)), float(np.max(a))
        exact = (a.dtype.kind in "iub"
                 or bool(np.all(a == np.round(a))))
        return AbsVal(lo, hi, exact=exact)
    except Exception:
        return R.TOP


def _aval_top(aval) -> AbsVal:
    try:
        return R.dtype_top(aval.dtype)
    except Exception:
        return R.TOP


def _is_float(v) -> bool:
    try:
        return np.dtype(v.aval.dtype).kind == "f"
    except Exception:
        return False


def _is_int(v) -> bool:
    try:
        return np.dtype(v.aval.dtype).kind in "iu"
    except Exception:
        return False


def headroom_bits(capacity: float, need: float) -> int:
    """Whole powers of two between the worst-case sum and the dtype limit."""
    if need <= 0:
        return int(capacity).bit_length()
    if need > capacity:
        return 0
    return int(math.floor(math.log2(capacity / need)))


class _Interp:
    def __init__(self, *, axis_sizes=None, cell="", rules=None):
        self.axis_sizes = dict(axis_sizes or {})
        self.cell = cell
        self.rules = frozenset(rules if rules is not None
                               else ("overflow", "numerics"))
        self.findings: dict[tuple, Finding] = {}
        self.proofs: list[dict] = []
        self._proof_sites: set = set()

    # -- findings --------------------------------------------------------
    def _emit(self, rule, severity, message, eqn):
        if rule.split(".")[0] not in self.rules:
            return
        key, where = source_key(eqn.source_info)
        ident = (rule, key, where)
        if ident not in self.findings:
            self.findings[ident] = Finding(
                rule=rule, severity=severity, message=message, key=key,
                where=where, cell=self.cell)

    # -- env helpers -----------------------------------------------------
    def _read(self, v, sc: _Scope) -> AbsVal:
        if not _is_var(v):
            return _literal_val(v.val)
        got = sc.env.get(v)
        if got is None:
            got = _aval_top(v.aval)
            sc.env[v] = got
        return got

    def _origin(self, v, sc: _Scope):
        """Chase a var back through shape-only ops to its producing value."""
        seen = 0
        while _is_var(v) and seen < 128:
            seen += 1
            eqn = sc.producer.get(v)
            if eqn is None:
                nxt = sc.alias.get(v)
                if nxt is None:
                    return v
                v = nxt
                continue
            name = eqn.primitive.name.replace("-", "_")
            if name in _PASSTHROUGH or name == "convert_element_type":
                v = eqn.invars[0]
                continue
            nxt = sc.alias.get(v)
            if nxt is not None and nxt is not v:
                v = nxt
                continue
            return v
        return v

    def _max_dominators(self, v, sc: _Scope) -> set:
        """Origins ``x`` with ``v >= x`` elementwise (maybe via a row max).

        Walks value-preserving ops, ``reduce_max``/``pmax``, and BOTH
        operands of ``max`` (``max(a, b) >= a`` and ``>= b`` — the online-
        softmax carry ``m_new = max(m, rowmax(s))`` needs the two-var
        branch; ``jnp.max`` alone inserts ``max(-inf, reduce_max(x))``).
        Every hop keeps the invariant *chased value >= walked var*.  A
        terminal var (no producer) dominates itself: ``m - max(m, ...)``
        proves ``<= 0`` by reaching ``m`` directly, no reduce_max needed.
        """
        out, work, visited = set(), [v], set()
        while work and len(visited) < 256:
            v = work.pop()
            if not _is_var(v) or v in visited:
                continue
            visited.add(v)
            eqn = sc.producer.get(v)
            if eqn is None:
                nxt = sc.alias.get(v)
                if nxt is not None and nxt is not v:
                    work.append(nxt)
                else:
                    out.add(v)
                continue
            name = eqn.primitive.name.replace("-", "_")
            if name in _PASSTHROUGH or name == "convert_element_type":
                work.append(eqn.invars[0])
                continue
            if name == "max":
                work.extend(iv for iv in eqn.invars if _is_var(iv))
                continue
            if name == "pmax":
                # cross-shard max of a local max still bounds the local
                # values below: keep walking toward the reduce_max
                work.append(eqn.invars[0])
                continue
            if name == "reduce_max":
                out.add(self._origin(eqn.invars[0], sc))
                continue
            nxt = sc.alias.get(v)
            if nxt is not None and nxt is not v:
                work.append(nxt)
        return out

    # -- interpretation --------------------------------------------------
    def run(self, jaxpr, in_vals, const_vals=None) -> list[AbsVal]:
        """Walk ``jaxpr`` in a fresh scope (top level, loop bodies)."""
        return self._run_in(jaxpr, in_vals, _Scope(), const_vals)

    def _run_in(self, jaxpr, in_vals, sc: _Scope, const_vals=None,
                alias_from=None) -> list[AbsVal]:
        for v, val in zip(jaxpr.invars, in_vals):
            sc.env[v] = val if val is not None else _aval_top(v.aval)
        if alias_from is not None:
            for sv, ov in zip(jaxpr.invars, alias_from):
                if _is_var(ov) or not hasattr(ov, "aval"):
                    sc.alias[sv] = ov
        consts = const_vals or []
        for i, v in enumerate(jaxpr.constvars):
            sc.env[v] = consts[i] if i < len(consts) else _aval_top(v.aval)
        for eqn in jaxpr.eqns:
            outs = self._eqn(eqn, sc)
            for v, val in zip(eqn.outvars, outs):
                sc.env[v] = val
                sc.producer[v] = eqn
        return [self._read(v, sc) for v in jaxpr.outvars]

    def _tops(self, eqn) -> list[AbsVal]:
        return [_aval_top(v.aval) for v in eqn.outvars]

    def _eqn(self, eqn, sc: _Scope) -> list[AbsVal]:
        prim = eqn.primitive.name.replace("-", "_")
        vals = [self._read(v, sc) for v in eqn.invars]

        # -- structured control flow & sub-jaxprs ------------------------
        if prim == "scan":
            return self._scan(eqn, vals)
        if prim == "while":
            return self._while(eqn, vals)
        if prim == "cond":
            return self._cond(eqn, vals)
        subs = _jaxpr_params(eqn)
        if subs:
            # pjit / shard_map / remat / custom_*: inline into the SAME
            # scope with invar aliases so provenance (guards, max-sub)
            # survives the wrapper jnp.where/jnp.clip emit around bodies
            out = None
            for _, sj in subs:
                sub = _inner(sj)
                if len(sub.invars) == len(eqn.invars):
                    res = self._run_in(sub, vals, sc, alias_from=eqn.invars)
                    if len(res) == len(eqn.outvars):
                        for sv, ov in zip(sub.outvars, eqn.outvars):
                            if _is_var(sv):
                                if sv in sc.maxsub:
                                    sc.maxsub.add(ov)
                                if sv in sc.attains_one:
                                    sc.attains_one.add(ov)
                                sc.alias[ov] = sv
                        res = [R.join(a, b) for a, b in zip(out, res)] \
                            if out is not None else res
                        out = res
            return out if out is not None else self._tops(eqn)

        handler = getattr(self, "_p_" + prim, None)
        if handler is not None:
            out = handler(eqn, vals, sc)
            return out if isinstance(out, list) else [out]
        if prim in _PASSTHROUGH:
            self._propagate_marks(eqn, sc)
            return [vals[0] for _ in eqn.outvars]
        if prim in _SUBSET:
            return [vals[0] for _ in eqn.outvars]
        if prim in _BOUNDED_UNARY:
            lo, hi = _BOUNDED_UNARY[prim]
            return [R.meet_interval(R.TOP, lo, hi)]
        return self._tops(eqn)

    def _propagate_marks(self, eqn, sc: _Scope):
        if eqn.invars and _is_var(eqn.invars[0]):
            src = eqn.invars[0]
            if src in sc.maxsub:
                sc.maxsub.update(eqn.outvars)
            if src in sc.attains_one:
                sc.attains_one.update(eqn.outvars)

    # ================= structured control flow =========================
    def _scan(self, eqn, vals):
        body = _inner(eqn.params["jaxpr"])
        nc = int(eqn.params.get("num_consts", 0))
        ncar = int(eqn.params.get("num_carry", 0))
        consts, carry, xs = vals[:nc], vals[nc:nc + ncar], vals[nc + ncar:]
        res = self.run(body, consts + carry + xs)
        for it in range(_MAX_FIX_ITERS):
            new = [R.join(c, o) for c, o in zip(carry, res[:ncar])]
            if it >= 2:
                new = [R.widen(c, n) for c, n in zip(carry, new)]
            if new == carry:
                break
            carry = new
            res = self.run(body, consts + carry + xs)
        return res

    def _while(self, eqn, vals):
        cn = int(eqn.params.get("cond_nconsts", 0))
        bn = int(eqn.params.get("body_nconsts", 0))
        cond = _inner(eqn.params["cond_jaxpr"])
        body = _inner(eqn.params["body_jaxpr"])
        cconsts, bconsts = vals[:cn], vals[cn:cn + bn]
        carry = vals[cn + bn:]
        res = carry
        for it in range(_MAX_FIX_ITERS):
            out = self.run(body, bconsts + carry)
            new = [R.join(c, o) for c, o in zip(carry, out)]
            if it >= 2:
                new = [R.widen(c, n) for c, n in zip(carry, new)]
            if new == carry:
                res = new
                break
            carry = new
            res = new
        # walk the cond jaxpr too: its numerics findings are real code
        self.run(cond, cconsts + list(res))
        return list(res)

    def _cond(self, eqn, vals):
        out = None
        for br in eqn.params["branches"]:
            res = self.run(_inner(br), vals[1:])
            out = res if out is None else [R.join(a, b)
                                           for a, b in zip(out, res)]
        return out if out is not None else self._tops(eqn)

    # ================= collectives =====================================
    def _axis_prod(self, axes) -> int:
        if axes is None:
            return 1
        if not isinstance(axes, (tuple, list)):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= int(self.axis_sizes.get(a, 1))
        return n

    def _psum_like(self, eqn, vals, *, kind: str) -> list[AbsVal]:
        n = self._axis_prod(eqn.params.get("axes", ()))
        outs = []
        for v, val in zip(eqn.invars, vals):
            summed = R.scale_by_count(val, n)
            if n > 1 and _is_var(v) and _is_int(v):
                self._check_int_accumulator(eqn, v, val, summed, n, kind)
            outs.append(summed)
        return outs

    def _check_int_accumulator(self, eqn, v, val, summed, n, kind):
        if "overflow" not in self.rules:
            return
        dt = np.dtype(v.aval.dtype)
        info = np.iinfo(dt)
        cap_hi, cap_lo = float(info.max), float(info.min)
        top = R.dtype_top(dt)
        need = summed.mag
        ok = summed.hi <= cap_hi and summed.lo >= cap_lo
        key, where = source_key(eqn.source_info)
        site = (kind, key, where, dt.name, n)
        if site not in self._proof_sites:
            self._proof_sites.add(site)
            self.proofs.append({
                "kind": kind, "dtype": dt.name, "n": n,
                "bound": None if not val.bounded else val.mag,
                "worst_sum": None if need == INF else need,
                "capacity": cap_hi,
                "headroom_bits": headroom_bits(cap_hi, need) if ok else 0,
                "ok": bool(ok), "key": key, "where": where,
            })
        if ok:
            return
        if val.lo <= top.lo and val.hi >= top.hi:
            msg = (f"{kind} over n={n} shards accumulates {dt.name} values "
                   "with no provable bound (no clamp upstream): the integer "
                   "sum cannot be proven to fit the accumulator")
        else:
            msg = (f"{kind} over n={n} shards of {dt.name} values in "
                   f"[{val.lo:g}, {val.hi:g}] sums to ±{need:g} > "
                   f"{dt.name} capacity {cap_hi:g}: the reduction wraps "
                   "on the wire")
        self._emit("overflow.wire_accumulator", "error", msg, eqn)

    def _p_psum(self, eqn, vals, sc):
        return self._psum_like(eqn, vals, kind="psum")

    _p_psum2 = _p_psum_invariant = _p_psum

    def _p_psum_scatter(self, eqn, vals, sc):
        return self._psum_like(eqn, vals, kind="reduce-scatter")

    _p_reduce_scatter = _p_psum_scatter

    def _p_pmax(self, eqn, vals, sc):
        return list(vals)

    _p_pmin = _p_ppermute = _p_all_to_all = _p_pmax

    def _p_all_gather(self, eqn, vals, sc):
        self._propagate_marks(eqn, sc)
        return list(vals)

    def _p_axis_index(self, eqn, vals, sc):
        n = self._axis_prod(eqn.params.get("axis_name", ()))
        return AbsVal(0.0, float(max(n - 1, 0)), exact=True)

    # ================= arithmetic ======================================
    def _p_add(self, eqn, vals, sc):
        return R.add(vals[0], vals[1])

    def _p_sub(self, eqn, vals, sc):
        out = R.sub(vals[0], vals[1])
        # max-subtraction idiom: x - max(x) <= 0 elementwise
        if _is_var(eqn.invars[1]):
            doms = self._max_dominators(eqn.invars[1], sc)
            if doms and self._origin(eqn.invars[0], sc) in doms:
                out = R.meet_interval(out, -INF, 0.0)
                sc.maxsub.update(eqn.outvars)
        return out

    def _p_mul(self, eqn, vals, sc):
        a, b = eqn.invars[0], eqn.invars[1]
        out = R.mul(vals[0], vals[1])
        if (_is_var(a) and _is_var(b)
                and self._origin(a, sc) == self._origin(b, sc)):
            out = R.meet_interval(out, 0.0, INF)    # x * x is a square
        return out

    def _p_div(self, eqn, vals, sc):
        den = vals[1]
        if den.contains(0.0):
            self._emit(
                "numerics.unguarded", "warn",
                f"div by interval {den} containing 0 with no positive guard "
                "upstream (clamp / where(x > 0, ...) / +eps would bound it)",
                eqn)
        return R.div(vals[0], den)

    def _p_neg(self, eqn, vals, sc):
        return R.neg(vals[0])

    def _p_abs(self, eqn, vals, sc):
        return R.abs_(vals[0])

    def _p_max(self, eqn, vals, sc):
        return R.max_(vals[0], vals[1])

    def _p_min(self, eqn, vals, sc):
        return R.min_(vals[0], vals[1])

    def _p_clamp(self, eqn, vals, sc):
        return R.clamp(vals[0], vals[1], vals[2])

    def _p_exp(self, eqn, vals, sc):
        v = vals[0]
        if _is_var(eqn.invars[0]) and eqn.invars[0] in sc.maxsub:
            v = R.meet_interval(v, -INF, 0.0)
            out = R.exp(v)
            sc.attains_one.update(eqn.outvars)   # exp(0) = 1 is attained
            return out
        if v.hi == INF and _is_float(eqn.invars[0]):
            self._emit(
                "numerics.unguarded", "warn",
                f"exp of unbounded interval {v} overflows to inf for "
                "moderate inputs; subtract the running max (softmax idiom) "
                "or clamp the exponent", eqn)
        return R.exp(v)

    def _p_exp2(self, eqn, vals, sc):
        return R._mono(lambda x: 2.0 ** min(x, 4000.0), vals[0])

    def _p_log(self, eqn, vals, sc):
        v = vals[0]
        if v.lo <= 0 and _is_float(eqn.invars[0]):
            self._emit(
                "numerics.unguarded", "warn",
                f"log of interval {v} whose domain includes <= 0 with no "
                "guard upstream (max(x, eps) or the logsumexp idiom would "
                "bound it)", eqn)
        return R.log(v)

    def _p_log1p(self, eqn, vals, sc):
        v = vals[0]
        if v.lo <= -1 and _is_float(eqn.invars[0]):
            self._emit(
                "numerics.unguarded", "warn",
                f"log1p of interval {v} reaching <= -1 with no guard "
                "upstream", eqn)
        return R.log1p(v)

    def _p_sqrt(self, eqn, vals, sc):
        v = vals[0]
        if v.lo < 0 and _is_float(eqn.invars[0]):
            self._emit(
                "numerics.unguarded", "warn",
                f"sqrt of interval {v} reaching below 0 (NaN) with no "
                "clamp upstream", eqn)
        return R.sqrt(v)

    def _p_rsqrt(self, eqn, vals, sc):
        v = vals[0]
        if v.lo <= 0 and _is_float(eqn.invars[0]):
            self._emit(
                "numerics.unguarded", "warn",
                f"rsqrt of interval {v} whose domain includes <= 0 with no "
                "+eps guard upstream (rmsnorm-style `rsqrt(mean(x^2)+eps)` "
                "is the provable form)", eqn)
        return R.rsqrt(v)

    def _p_integer_pow(self, eqn, vals, sc):
        return R.integer_pow(vals[0], eqn.params.get("y", 1))

    def _p_square(self, eqn, vals, sc):
        return R.integer_pow(vals[0], 2)

    def _p_pow(self, eqn, vals, sc):
        a, b = vals
        if a.lo > 0 and a.bounded and b.bounded:
            cands = []
            for x in (a.lo, a.hi):
                for y in (b.lo, b.hi):
                    try:
                        cands.append(x ** y)
                    except OverflowError:
                        cands.append(INF)
            return AbsVal(min(cands), max(cands))
        return R.TOP

    def _p_floor(self, eqn, vals, sc):
        return R.round_family(vals[0], max_delta=1.0)

    def _p_ceil(self, eqn, vals, sc):
        return R.round_family(vals[0], max_delta=1.0)

    def _p_round(self, eqn, vals, sc):
        return R.round_family(vals[0], max_delta=0.5)

    def _p_sign(self, eqn, vals, sc):
        return AbsVal(-1.0, 1.0, exact=True)

    def _p_nextafter(self, eqn, vals, sc):
        return R.join(vals[0], vals[1])

    # ================= conversions / shape / structure =================
    def _p_convert_element_type(self, eqn, vals, sc):
        self._propagate_marks(eqn, sc)
        v = vals[0]
        dt = np.dtype(eqn.params["new_dtype"])
        if dt.kind == "b":
            return R.BOOL
        if dt.kind in "iu":
            src_int = _is_int(eqn.invars[0])
            conv = v if src_int else R.to_integer(v)
            info = np.iinfo(dt)
            if conv.lo < info.min or conv.hi > info.max:
                return R.dtype_top(dt)       # narrowing wraps: all bets off
            return conv
        # float target: integer exactness survives while the mantissa holds
        if v.exact:
            try:
                nmant = np.finfo(dt).nmant
            except ValueError:            # ml_dtypes (bf16/f8) float types
                import ml_dtypes

                nmant = ml_dtypes.finfo(dt).nmant
            if v.mag > 2.0 ** nmant:
                return AbsVal(v.lo, v.hi, exact=False, qerr=v.qerr)
        return v

    def _p_bitcast_convert_type(self, eqn, vals, sc):
        return R.dtype_top(eqn.params["new_dtype"])

    def _p_iota(self, eqn, vals, sc):
        shape = eqn.params.get("shape", ())
        dim = eqn.params.get("dimension", 0)
        n = int(shape[dim]) if shape else 1
        return AbsVal(0.0, float(max(n - 1, 0)), exact=True)

    def _p_concatenate(self, eqn, vals, sc):
        out = vals[0]
        for v in vals[1:]:
            out = R.join(out, v)
        return out

    def _p_pad(self, eqn, vals, sc):
        return R.join(vals[0], vals[1])

    def _p_select_n(self, eqn, vals, sc):
        pred_v, cases = eqn.invars[0], eqn.invars[1:]
        # NaN-propagation selects (`where(x != x, nan_path, y)`): intervals
        # bound the real-valued elements, for which the is-NaN branch is
        # vacuous — keep the other branch instead of joining in its top
        if _is_var(pred_v) and len(cases) == 2:
            porigin = self._origin(pred_v, sc)
            prod = sc.producer.get(porigin) if _is_var(porigin) else None
            if prod is not None and prod.primitive.name in ("ne", "eq"):
                x, y = prod.invars
                if (_is_var(x) and _is_var(y)
                        and self._origin(x, sc) == self._origin(y, sc)):
                    return vals[1] if prod.primitive.name == "ne" else vals[2]
        out = None
        for i, (cv, cval) in enumerate(zip(cases, vals[1:])):
            refined = self._refine_case(pred_v, cv, cval, taken=bool(i), sc=sc)
            out = refined if out is None else R.join(out, refined)
        return out if out is not None else self._tops(eqn)[0]

    def _refine_case(self, pred, case_var, case_val, *, taken, sc) -> AbsVal:
        """Narrow a select_n branch with its comparison predicate.

        For ``select_n(x > k, f, t)`` the ``t`` branch only sees ``x > k``:
        when the branch value IS ``x``, meet its interval with the
        half-line.  ``taken=False`` refines with the negated predicate.
        """
        if not _is_var(pred) or not _is_var(case_var):
            return case_val
        porigin = self._origin(pred, sc)
        if not _is_var(porigin):
            return case_val
        prod = sc.producer.get(porigin)
        if prod is None or prod.primitive.name not in ("gt", "ge", "lt", "le"):
            return case_val
        op = prod.primitive.name
        x, y = prod.invars
        corigin = self._origin(case_var, sc)
        if _is_var(x) and self._origin(x, sc) == corigin:
            kside = self._read(y, sc)
        elif _is_var(y) and self._origin(y, sc) == corigin:
            kside = self._read(x, sc)
            op = {"gt": "lt", "ge": "le", "lt": "gt", "le": "ge"}[op]
        else:
            return case_val
        if kside.lo != kside.hi:
            return case_val
        kval = kside.lo
        if not taken:
            op = {"gt": "le", "ge": "lt", "lt": "ge", "le": "gt"}[op]
        eps_up = float(np.nextafter(kval, np.inf))
        eps_dn = float(np.nextafter(kval, -np.inf))
        if op == "gt":
            return R.meet_interval(case_val, eps_up, INF)
        if op == "ge":
            return R.meet_interval(case_val, kval, INF)
        if op == "lt":
            return R.meet_interval(case_val, -INF, eps_dn)
        return R.meet_interval(case_val, -INF, kval)

    def _p_dynamic_update_slice(self, eqn, vals, sc):
        return R.join(vals[0], vals[1])

    def _p_scatter(self, eqn, vals, sc):
        return R.join(vals[0], vals[-1])

    _p_scatter_max = _p_scatter_min = _p_scatter

    def _p_scatter_add(self, eqn, vals, sc):
        # worst case: every update lands on one element of the operand
        upd = vals[-1]
        try:
            n = int(np.prod(eqn.invars[-1].aval.shape))
        except Exception:
            return self._tops(eqn)[0]
        return R.add(vals[0],
                     R.scale_by_count(R.join(R.point(0.0), upd), n))

    # ================= reductions ======================================
    def _reduced_count(self, eqn) -> int:
        try:
            inn = int(np.prod(eqn.invars[0].aval.shape))
            out = max(int(np.prod(eqn.outvars[0].aval.shape)), 1)
            return max(inn // out, 1)
        except Exception:
            return 1

    def _p_reduce_sum(self, eqn, vals, sc):
        out = R.scale_by_count(vals[0], self._reduced_count(eqn))
        src = eqn.invars[0]
        if (_is_var(src) and src in sc.attains_one and vals[0].lo >= 0.0):
            # the array provably contains an element == 1 and none negative
            out = R.meet_interval(out, 1.0, INF)
        return out

    def _p_reduce_max(self, eqn, vals, sc):
        out = vals[0]
        src = eqn.invars[0]
        if _is_var(src) and src in sc.attains_one:
            out = R.meet_interval(out, 1.0, INF)
        return out

    def _p_reduce_min(self, eqn, vals, sc):
        return vals[0]

    def _p_reduce_and(self, eqn, vals, sc):
        return R.BOOL

    _p_reduce_or = _p_reduce_and

    def _p_cumsum(self, eqn, vals, sc):
        try:
            n = int(eqn.invars[0].aval.shape[eqn.params.get("axis", 0)])
        except Exception:
            n = 1
        return R.scale_by_count(vals[0], n)

    def _p_cummax(self, eqn, vals, sc):
        return vals[0]

    _p_cummin = _p_cummax

    def _p_argmax(self, eqn, vals, sc):
        return AbsVal(0.0, float(max(self._reduced_count(eqn) - 1, 0)),
                      exact=True)

    _p_argmin = _p_argmax

    def _p_dot_general(self, eqn, vals, sc):
        try:
            (lc, _), _ = eqn.params["dimension_numbers"]
            lshape = eqn.invars[0].aval.shape
            k = 1
            for d in lc:
                k *= int(lshape[d])
        except Exception:
            k = 1
        return R.scale_by_count(R.mul(vals[0], vals[1]), k)

    def _p_sort(self, eqn, vals, sc):
        return list(vals)

    def _p_is_finite(self, eqn, vals, sc):
        return R.BOOL

    def _p_eq(self, eqn, vals, sc):
        return R.BOOL

    _p_ne = _p_lt = _p_le = _p_gt = _p_ge = _p_eq
    _p_and = _p_or = _p_xor = _p_not = _p_eq


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def abstract_eval(closed_jaxpr, in_vals=None, *, axis_sizes=None,
                  rules=()) -> list[AbsVal]:
    """Propagate AbsVals through ``closed_jaxpr``; returns per-outvar values.

    ``in_vals``: one AbsVal per invar (None entries default to the dtype
    top).  With ``rules=()`` this is a pure evaluator — the form the
    soundness property tests drive.
    """
    return interpret_jaxpr(closed_jaxpr, in_vals=in_vals,
                           axis_sizes=axis_sizes, rules=rules).out


def interpret_jaxpr(closed_jaxpr, *, in_vals=None, axis_sizes=None, cell="",
                    rules=("overflow", "numerics")) -> AbsintResult:
    """Interpret one traced step; returns findings + proofs + out values."""
    jaxpr = _inner(closed_jaxpr)
    interp = _Interp(axis_sizes=axis_sizes, cell=cell, rules=rules)
    if in_vals is None:
        in_vals = [None] * len(jaxpr.invars)
    const_vals = [_literal_val(c) for c in
                  getattr(closed_jaxpr, "consts", None) or []]
    out = interp.run(jaxpr, list(in_vals), const_vals)
    return AbsintResult(findings=list(interp.findings.values()),
                        proofs=interp.proofs, out=out)
