"""Wire lint: per-collective dtype/byte rules over the partitioned HLO.

Consumes the :class:`repro.roofline.hlo_parse.CollectiveOp` records and a
:class:`WireContext` describing what the RunSpec's policy and mesh imply
should be on the wire:

* ``wire.f32_allreduce``   — a large float all-reduce in a train step whose
  ``PrecisionPolicy.comm`` < 32: the gradient reduction that was supposed
  to move SR-quantized codes is moving f32 (the regression that silently
  erases the paper's comm-energy term).
* ``wire.narrow_allreduce`` / ``wire.wide_allreduce`` — integer all-reduce
  whose element dtype is narrower (overflow!) / wider (wasted bytes) than
  ``wire_dtype(comm, n)`` implies.
* ``wire.unexpected_allgather`` — an all-gather whose element dtype the
  sharding rule table doesn't predict on this mesh (unintended resharding;
  on a pure-DP mesh ANY all-gather is unexpected).
* ``wire.narrow_reduce_scatter`` / ``wire.wide_reduce_scatter`` — the same
  accumulator contract applied to integer reduce-scatters (XLA rewrites
  sharded all-reduces into them); float reduce-scatters are the FSDP
  gradient path and pass.
* ``wire.unknown_collective`` — a replica-grouped op no wire rule models
  (``hlo_parse`` records it as ``unknown:<opcode>`` with conservative
  bytes); the accounting cannot silently under-count.
* ``wire.comm_report_mismatch`` — the HLO's integer all-reduce +
  reduce-scatter bytes disagree with
  :func:`repro.dist.wire.grad_wire_report` — the two byte accountings
  (lint vs ``Session.comm_report()``) must not drift.

Degenerate records (``group_size <= 1``) never fire rules: a collective
over one participant moves nothing.
"""

from __future__ import annotations

import dataclasses

from repro.analyze.findings import Finding

_FLOAT_DTYPES = {"f64", "f32", "bf16", "f16"}
_INT_BYTES = {"s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
              "s64": 8, "u64": 8}


@dataclasses.dataclass(frozen=True)
class WireContext:
    """What the policy + mesh predict for one lint cell's collectives."""

    policy: object                       # PrecisionPolicy
    kind: str                            # "train" | "prefill" | "decode"
    n_clients: int = 1                   # DP / FL-client world size
    fsdp: int = 1
    tp: int = 1
    expected_gather_dtypes: frozenset = frozenset()
    min_flagged_elems: int = 1024        # scalar/diagnostic reductions pass

    @property
    def compressed(self) -> bool:
        return (self.kind == "train" and self.n_clients > 1
                and getattr(self.policy, "grad_compression_bits", 0) > 0)


def expected_gathers(*, fsdp: int, tp: int, packed: bool,
                     gather_bf16: bool = False) -> frozenset:
    """Element dtypes the sharding rule table predicts for all-gathers.

    FSDP re-gathers parameters in their storage dtype (f32, bf16 when the
    ``fsdp_gather_dtype`` variant is on, int codes when serving packed);
    tensor/sequence parallelism gathers activations (f32/bf16) and token
    ids (s32).  ``fsdp == tp == 1`` predicts NO all-gathers at all.
    """
    out = set()
    if fsdp > 1:
        out |= {"f32"}
        if gather_bf16:
            out |= {"bf16"}
        if packed:
            out |= {"s8", "s16"}
    if tp > 1:
        out |= {"f32", "bf16", "s32"}
    return frozenset(out)


def lint_module(mc, ctx: WireContext, cell: str = "") -> list[Finding]:
    """Apply the wire rules to one parsed module's collective records."""
    from repro.dist.collectives import wire_dtype

    findings = []
    required = None
    if ctx.compressed:
        try:
            import numpy as np

            required = np.dtype(wire_dtype(ctx.policy.comm, ctx.n_clients))
        except Exception:
            required = None

    for rec in mc.collectives:
        if rec.group_size <= 1:
            continue
        key = f"{rec.kind}:{rec.dtype}"
        where = f"{rec.name} in {rec.computation}"

        if rec.kind == "all-reduce":
            if (ctx.compressed and rec.dtype in _FLOAT_DTYPES
                    and rec.elems >= ctx.min_flagged_elems):
                findings.append(Finding(
                    rule="wire.f32_allreduce", severity="error",
                    message=(f"{rec.dtype}[{rec.elems}] all-reduce "
                             f"(group {rec.group_size}) in a train step "
                             f"with comm={ctx.policy.comm} bits: gradient "
                             "codes should cross the wire as "
                             "SR-quantized ints, not floats"),
                    key=key, where=where, cell=cell))
            elif (required is not None and rec.dtype in _INT_BYTES):
                have = _INT_BYTES[rec.dtype]
                if have < required.itemsize:
                    findings.append(Finding(
                        rule="wire.narrow_allreduce", severity="error",
                        message=(f"{rec.dtype} all-reduce accumulator is "
                                 f"narrower than {required.name} = "
                                 f"wire_dtype(comm={ctx.policy.comm}, "
                                 f"n={ctx.n_clients}): the summed codes "
                                 "overflow"),
                        key=key, where=where, cell=cell))
                elif have > required.itemsize:
                    findings.append(Finding(
                        rule="wire.wide_allreduce", severity="warn",
                        message=(f"{rec.dtype} all-reduce is wider than "
                                 f"{required.name} implies — "
                                 f"{have / required.itemsize:.0f}x the "
                                 "necessary wire bytes"),
                        key=key, where=where, cell=cell))

        elif rec.kind == "reduce-scatter":
            # FSDP gradients reduce-scatter in f32 by design (the comm role
            # compresses only the DP all-reduce), so floats pass; an
            # INTEGER reduce-scatter carries summed wire codes and must
            # obey the same accumulator contract as the all-reduce.
            if required is not None and rec.dtype in _INT_BYTES:
                have = _INT_BYTES[rec.dtype]
                if have < required.itemsize:
                    findings.append(Finding(
                        rule="wire.narrow_reduce_scatter", severity="error",
                        message=(f"{rec.dtype} reduce-scatter accumulator "
                                 f"is narrower than {required.name} = "
                                 f"wire_dtype(comm={ctx.policy.comm}, "
                                 f"n={ctx.n_clients}): the scattered code "
                                 "sums overflow"),
                        key=key, where=where, cell=cell))
                elif have > required.itemsize:
                    findings.append(Finding(
                        rule="wire.wide_reduce_scatter", severity="warn",
                        message=(f"{rec.dtype} reduce-scatter is wider than "
                                 f"{required.name} implies — "
                                 f"{have / required.itemsize:.0f}x the "
                                 "necessary wire bytes"),
                        key=key, where=where, cell=cell))

        elif rec.kind.startswith("unknown:"):
            findings.append(Finding(
                rule="wire.unknown_collective", severity="warn",
                message=(f"{rec.kind.split(':', 1)[1]} moves "
                         f"{rec.dtype}[{rec.elems}] over group "
                         f"{rec.group_size} but no wire rule models it: "
                         "byte accounting treats the full result as wire "
                         "bytes (upper bound) — teach hlo_parse/wire_lint "
                         "this opcode"),
                key=key, where=where, cell=cell))

        elif rec.kind == "all-gather":
            if rec.dtype not in ctx.expected_gather_dtypes:
                expect = (sorted(ctx.expected_gather_dtypes)
                          if ctx.expected_gather_dtypes else "none at all")
                findings.append(Finding(
                    rule="wire.unexpected_allgather", severity="warn",
                    message=(f"{rec.dtype}[{rec.elems}] all-gather (group "
                             f"{rec.group_size}) — the sharding rule table "
                             f"predicts {expect} on this mesh "
                             f"(fsdp={ctx.fsdp}, tp={ctx.tp}): unintended "
                             "resharding?"),
                    key=key, where=where, cell=cell))
    return findings


def check_comm_report(mc, report: dict, cell: str = "",
                      rel_tol: float = 1e-6) -> list[Finding]:
    """Cross-check HLO integer all-reduce bytes vs ``grad_wire_report``.

    The report says the replicated gradient leaves move
    ``replicated_elems * itemsize(wire_dtype)`` bytes of codes per round;
    the compiled module's integer all-reduce results must sum to exactly
    that (the all-reduce combiner may merge leaves into tuples — the
    element totals survive merging).  Only meaningful when compression is
    on (``wire_dtype != 'none'/'float32'``).
    """
    wd = str(report.get("wire_dtype", "none"))
    if wd in ("none", "float32"):
        return []
    itemsize = _INT_BYTES.get({"int8": "s8", "int16": "s16",
                               "int32": "s32"}.get(wd, wd), None)
    if itemsize is None:
        return []
    expect = int(report["replicated_elems"]) * itemsize
    have = 0.0
    for rec in mc.collectives:
        # integer codes may cross as an all-reduce OR a reduce-scatter
        # (XLA rewrites the former into the latter under sharding): both
        # count toward the same wire budget
        if rec.kind not in ("all-reduce", "reduce-scatter"):
            continue
        for dt, elems in (rec.parts or ((rec.dtype, rec.elems),)):
            if dt in _INT_BYTES:
                have += elems * _INT_BYTES[dt] * rec.mult
    if abs(have - expect) > rel_tol * max(expect, 1):
        return [Finding(
            rule="wire.comm_report_mismatch", severity="error",
            message=(f"compiled HLO moves {have:.0f} integer all-reduce "
                     f"bytes but comm_report() accounts "
                     f"{expect} ({report['replicated_elems']} replicated "
                     f"elems x {wd}): the wire accountings drifted"),
            key="module:comm_report", cell=cell)]
    return []
