"""Pallas kernel checker: enumerate BlockSpec index maps, statically.

For every :class:`repro.kernels.spec.KernelSpec` the kernels export, walk
the full grid and evaluate each operand's index map:

* ``kernel.oob_dma``       — ``index * block + block`` exceeds the padded
  operand shape (the DMA would read/write out of bounds);
* ``kernel.index_rank``    — the map returns the wrong number of indices;
* ``kernel.block_misaligned`` — a full-coverage operand whose block does
  not tile its padded shape (the last tile would overrun);
* ``kernel.coverage_gap``  — grid enumeration never visits some tile of a
  full-coverage operand (e.g. an index map that skips the last k step:
  part of the weight is silently never read / part of the output never
  written);
* ``kernel.scratch_shape`` / ``kernel.scratch_dtype`` — a VMEM scratch
  bound to an operand must match that operand's block (leading 1-dims
  squeezed) and accumulate in float32.

At most one finding is reported per (kernel, operand): an OOB usually
implies a coverage gap too, and the acceptance contract is one finding
per seeded defect.
"""

from __future__ import annotations

import itertools

from repro.analyze.findings import Finding

_MAX_GRID_POINTS = 1_000_000


def _as_int(x):
    return int(x)


def _check_operand(spec, op, cell) -> Finding | None:
    ranges = [range(int(g)) for g in spec.grid]
    n_points = 1
    for r in ranges:
        n_points *= len(r)
    if n_points > _MAX_GRID_POINTS:
        return Finding(
            rule="kernel.grid_too_large", severity="info",
            message=f"grid {spec.grid} has {n_points} points; enumeration "
                    "skipped", key=f"{spec.name}:{op.name}",
            where=spec.source, cell=cell)
    seen = set()
    for g in itertools.product(*ranges):
        idx = op.index_map(*g)
        if not isinstance(idx, tuple):
            idx = (idx,)
        if len(idx) != len(op.block):
            return Finding(
                rule="kernel.index_rank", severity="error",
                message=f"index map returned {len(idx)} indices for a "
                        f"rank-{len(op.block)} block at grid point {g}",
                key=f"{spec.name}:{op.name}", where=spec.source, cell=cell)
        ints = tuple(_as_int(i) for i in idx)
        for d, (bi, b, s) in enumerate(zip(ints, op.block, op.shape)):
            off = bi * b
            if off < 0 or off + b > s:
                return Finding(
                    rule="kernel.oob_dma", severity="error",
                    message=(f"grid point {g} maps dim {d} to block "
                             f"[{off}:{off + b}) of an extent-{s} operand: "
                             "out-of-bounds DMA"),
                    key=f"{spec.name}:{op.name}", where=spec.source,
                    cell=cell)
        seen.add(ints)
    if op.coverage != "full":
        return None
    for d, (b, s) in enumerate(zip(op.block, op.shape)):
        if s % b:
            return Finding(
                rule="kernel.block_misaligned", severity="error",
                message=f"block extent {b} does not tile operand extent "
                        f"{s} on dim {d} (operand must be padded to a "
                        "block multiple)",
                key=f"{spec.name}:{op.name}", where=spec.source, cell=cell)
    tiles = [range(s // b) for b, s in zip(op.block, op.shape)]
    n_tiles = 1
    for t in tiles:
        n_tiles *= len(t)
    if n_tiles <= _MAX_GRID_POINTS and len(seen) < n_tiles:
        missing = next(t for t in itertools.product(*tiles) if t not in seen)
        return Finding(
            rule="kernel.coverage_gap", severity="error",
            message=(f"{n_tiles - len(seen)} of {n_tiles} tiles never "
                     f"visited (first missing: block index {missing}) — "
                     "part of the operand is silently skipped"),
            key=f"{spec.name}:{op.name}", where=spec.source, cell=cell)
    return None


def _check_scratch(spec, sc, cell) -> Finding | None:
    if sc.dtype != "float32":
        return Finding(
            rule="kernel.scratch_dtype", severity="error",
            message=f"scratch {sc.name} accumulates in {sc.dtype}; partial "
                    "products must accumulate in float32",
            key=f"{spec.name}:{sc.name}", where=spec.source, cell=cell)
    if sc.binds:
        bound = next((o for o in spec.operands if o.name == sc.binds), None)
        if bound is None:
            return Finding(
                rule="kernel.scratch_shape", severity="error",
                message=f"scratch {sc.name} binds unknown operand "
                        f"{sc.binds!r}",
                key=f"{spec.name}:{sc.name}", where=spec.source, cell=cell)
        want = tuple(b for b in bound.block if b != 1) or (1,)
        have = tuple(s for s in sc.shape if s != 1) or (1,)
        if want != have:
            return Finding(
                rule="kernel.scratch_shape", severity="error",
                message=(f"scratch {sc.name} shape {tuple(sc.shape)} does "
                         f"not match operand {sc.binds!r} block "
                         f"{tuple(bound.block)}"),
                key=f"{spec.name}:{sc.name}", where=spec.source, cell=cell)
    return None


def _check_scalar(spec, sc, cell) -> Finding | None:
    """``kernel.scalar_oob`` — scalar-prefetch values outside their range.

    BlockSpec enumeration can only see index maps; the VALUES a launch
    prefetches (page-table entries, lengths) steer those maps at runtime,
    so each declared :class:`~repro.kernels.spec.ScalarOperand` is
    range-checked against the bounds the kernel's addressing assumes.
    """
    import numpy as np

    vals = np.asarray(sc.values)
    if vals.size == 0:
        return None
    vmin, vmax = int(vals.min()), int(vals.max())
    if vmin < sc.lo or vmax > sc.hi:
        n_bad = int(np.sum((vals < sc.lo) | (vals > sc.hi)))
        return Finding(
            rule="kernel.scalar_oob", severity="error",
            message=(f"scalar operand {sc.name}: {n_bad} value(s) outside "
                     f"[{sc.lo}, {sc.hi}] (observed [{vmin}, {vmax}])"
                     + (f" — {sc.note}" if sc.note else "")),
            key=f"{spec.name}:{sc.name}", where=spec.source, cell=cell)
    return None


def check_kernel_spec(spec, cell: str = "") -> list[Finding]:
    """All kernel rules over one spec; at most one finding per operand."""
    findings = []
    for op in spec.operands:
        f = _check_operand(spec, op, cell)
        if f is not None:
            findings.append(f)
    for sc in spec.scratch:
        f = _check_scratch(spec, sc, cell)
        if f is not None:
            findings.append(f)
    for sc in getattr(spec, "scalars", ()):
        f = _check_scalar(spec, sc, cell)
        if f is not None:
            findings.append(f)
    return findings


def shipped_kernel_specs(*, d_model: int = 512, d_ff: int = 2048,
                         heads: int = 8, head_dim: int = 64,
                         batch: int = 4, seq: int = 160, page: int = 8,
                         n_pool: int = 6, n_pmax: int = 4) -> list:
    """The shipped kernels' specs at representative (ragged) serving dims.

    ``seq=160`` is deliberately not a block multiple and ``d_model`` feeds
    a ragged decode M — the wrappers' padding rules are part of what the
    checker verifies.
    """
    import numpy as np

    from repro.kernels.flash_attention import attention_spec, decode_spec
    from repro.kernels.quant_matmul import kernel_spec as qm_spec

    # decode-sized x (a handful of rows) and a ragged K: the wrapper pads
    specs = [
        qm_spec(batch, d_model, d_ff),
        qm_spec(3, d_model + 1, d_ff),           # ragged M and K
        attention_spec(batch * heads, seq, head_dim),
    ]
    # page table: slots own 0..n_pmax pages, -1 beyond their length;
    # pool rows assigned round-robin like the pager does
    pt = -np.ones((batch, n_pmax), dtype=np.int32)
    nxt = 0
    lengths = []
    for b in range(batch):
        n_pages = (b % n_pmax) + 1
        for j in range(n_pages):
            pt[b, j] = nxt % n_pool
            nxt += 1
        lengths.append(n_pages * page - 3)
    g = 8                                         # G padded to sublane min
    specs.append(decode_spec(batch, max(heads // 4, 1), g, head_dim,
                             page=page, n_pool=n_pool, page_table=pt,
                             lengths=np.asarray(lengths, np.int32)))
    return specs
