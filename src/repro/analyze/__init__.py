"""Static precision / wire / kernel / value-range lint over traced graphs.

Five rule families, none of which execute any compiled code:

* ``precision.*`` (:mod:`repro.analyze.precision_flow`,
  :mod:`repro.analyze.static_proofs`) — walks traced jaxprs tracking which
  ``dot_general`` ops consume QTensor codes that were eagerly dequantized
  instead of riding the ``quant_matmul`` / ``expert_dispatch`` fast path,
  and certifies the error budget: the quantization error the policy's bits
  imply must fit the convergence-bound term GBD optimizes against.
* ``overflow.*`` / ``numerics.*`` (:mod:`repro.analyze.absint`,
  :mod:`repro.analyze.ranges`) — a forward abstract interpreter
  propagating value intervals, integer exactness, and quantization-error
  bounds through the same jaxprs: proves every integer ``psum``
  accumulator holds its worst-case code sum (recording headroom), and
  flags exp/log/div/rsqrt consuming unguarded zero-crossing or unbounded
  intervals.  :mod:`repro.analyze.static_proofs` adds the closed-form
  per-cell complement (works for ``fl-sim`` cells with no graph).
* ``wire.*`` (:mod:`repro.analyze.wire_lint`) — reads the per-collective
  records :func:`repro.roofline.hlo_parse.parse_module` extracts from the
  partitioned HLO and flags f32 all-reduces under a low-bit
  ``PrecisionPolicy.comm``, mis-sized integer wire dtypes (all-reduce and
  reduce-scatter), unmodeled collectives, all-gathers the sharding rule
  table doesn't predict, and drift against ``Session.comm_report()``.
* ``kernel.*`` (:mod:`repro.analyze.kernel_check`) — enumerates every
  Pallas BlockSpec index map over its grid from the
  :class:`repro.kernels.spec.KernelSpec` metadata the kernels export
  (coverage, out-of-bounds DMA, scratch consistency) and range-checks
  scalar-prefetch operands (page-table entries within the pool, lengths
  within the owned pages).

Front doors: ``Session.analyze()``, the ``repro-analyze`` CLI
(``python -m repro analyze``), the ``analyze.toml`` allowlist for the
known-legitimate exceptions (stale entries surface as
``meta.dead_allowlist``), and the differential baseline gate
(:mod:`repro.analyze.baseline`) CI runs with.
"""

from repro.analyze.absint import abstract_eval, interpret_jaxpr
from repro.analyze.allowlist import (
    apply_allowlist,
    dead_allowlist_findings,
    dead_entries,
    load_allowlist,
)
from repro.analyze.baseline import (
    diff_against_baseline,
    finding_identity,
    load_baseline,
    write_baseline,
)
from repro.analyze.findings import Finding, source_key, worst_severity
from repro.analyze.kernel_check import check_kernel_spec, shipped_kernel_specs
from repro.analyze.precision_flow import lint_jaxpr
from repro.analyze.ranges import AbsVal
from repro.analyze.runner import ALL_RULE_FAMILIES, analyze_session
from repro.analyze.static_proofs import (
    check_error_budget,
    overflow_margin_table,
    prove_spec,
    prove_wire_accumulator,
)
from repro.analyze.wire_lint import WireContext, check_comm_report, lint_module

__all__ = [
    "ALL_RULE_FAMILIES", "AbsVal", "Finding", "WireContext", "abstract_eval",
    "analyze_session", "apply_allowlist", "check_comm_report",
    "check_error_budget", "check_kernel_spec", "dead_allowlist_findings",
    "dead_entries", "diff_against_baseline", "finding_identity",
    "interpret_jaxpr", "lint_jaxpr", "lint_module", "load_allowlist",
    "load_baseline", "overflow_margin_table", "prove_spec",
    "prove_wire_accumulator", "shipped_kernel_specs", "source_key",
    "worst_severity", "write_baseline",
]
