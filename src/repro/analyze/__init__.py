"""Static precision / wire / kernel lint over jaxprs and lowered HLO.

Three rule families, none of which execute any compiled code:

* ``precision.*`` (:mod:`repro.analyze.precision_flow`) — walks traced
  jaxprs tracking which ``dot_general`` ops consume QTensor codes that were
  eagerly dequantized instead of riding the ``quant_matmul`` /
  ``expert_dispatch`` fast path, and flags integer ``psum`` accumulators
  narrower than ``n * (2^bits - 1)`` requires.
* ``wire.*`` (:mod:`repro.analyze.wire_lint`) — reads the per-collective
  records :func:`repro.roofline.hlo_parse.parse_module` extracts from the
  partitioned HLO and flags f32 all-reduces under a low-bit
  ``PrecisionPolicy.comm``, mis-sized integer wire dtypes, all-gathers the
  sharding rule table doesn't predict, and drift against
  ``Session.comm_report()``.
* ``kernel.*`` (:mod:`repro.analyze.kernel_check`) — enumerates every
  Pallas BlockSpec index map over its grid from the
  :class:`repro.kernels.spec.KernelSpec` metadata the kernels export:
  coverage, out-of-bounds DMA, scratch shape/dtype consistency.

Front doors: ``Session.analyze()``, the ``repro-analyze`` CLI
(``python -m repro analyze``), and the ``analyze.toml`` allowlist for the
known-legitimate eager fallbacks.
"""

from repro.analyze.allowlist import apply_allowlist, load_allowlist
from repro.analyze.findings import Finding, source_key, worst_severity
from repro.analyze.kernel_check import check_kernel_spec, shipped_kernel_specs
from repro.analyze.precision_flow import lint_jaxpr
from repro.analyze.runner import analyze_session
from repro.analyze.wire_lint import WireContext, check_comm_report, lint_module

__all__ = [
    "Finding", "WireContext", "analyze_session", "apply_allowlist",
    "check_comm_report", "check_kernel_spec", "lint_jaxpr", "lint_module",
    "load_allowlist", "shipped_kernel_specs", "source_key", "worst_severity",
]
