"""The structured lint result type shared by every rule family."""

from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warn", "info")

_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint result.

    ``key`` is the STABLE identity the allowlist matches against — built
    from file + function (``"layers.py:vocab_embed"``) or kernel + operand
    (``"quant_matmul:codes"``), never from line numbers.  ``where`` is the
    human-facing provenance (``file:line`` / instruction name) and may
    drift freely.
    """

    rule: str                     # "precision.eager_dequant", "wire.…", …
    severity: str                 # "error" | "warn" | "info"
    message: str
    key: str                      # allowlist identity
    where: str = ""               # file:line / HLO instruction provenance
    cell: str = ""                # lint cell (workload x shape) it came from
    allowed: bool = False
    allow_reason: str = ""

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def format(self) -> str:
        mark = "ALLOWED " if self.allowed else ""
        cell = f"[{self.cell}] " if self.cell else ""
        where = f"  ({self.where})" if self.where else ""
        tail = f"  -- allowed: {self.allow_reason}" if self.allowed else ""
        return (f"{cell}{mark}{self.severity.upper():5s} {self.rule} "
                f"{self.key}: {self.message}{where}{tail}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def worst_severity(findings, *, include_allowed: bool = False) -> str | None:
    """Most severe unallowlisted severity present, or None."""
    worst = None
    for f in findings:
        if f.allowed and not include_allowed:
            continue
        if worst is None or _RANK[f.severity] < _RANK[worst]:
            worst = f.severity
    return worst


def at_or_above(findings, threshold: str):
    """Unallowlisted findings at/above a severity threshold."""
    cut = _RANK[threshold]
    return [f for f in findings
            if not f.allowed and _RANK[f.severity] <= cut]


def source_key(source_info) -> tuple[str, str]:
    """(allowlist key, provenance) from a jaxpr eqn's ``source_info``.

    Key is ``basename:function`` — stable across line drift; provenance is
    ``path:line``.  Both degrade to ``"?"`` when jax gives no user frame
    (e.g. eqns synthesized by transforms).
    """
    import os

    try:
        from jax._src import source_info_util
        fr = source_info_util.user_frame(source_info)
    except Exception:
        fr = None
    if fr is None:
        return "?", "?"
    return (f"{os.path.basename(fr.file_name)}:{fr.function_name}",
            f"{fr.file_name}:{fr.start_line}")
