"""The ``analyze.toml`` allowlist: known-legitimate findings, with reasons.

Format — one ``[[allow]]`` table per entry::

    [[allow]]
    rule   = "precision.eager_dequant"     # fnmatch pattern over rule ids
    key    = "ops.py:expert_dispatch"      # fnmatch pattern over finding keys
    reason = "per-channel scale rows: the kernel's scalar-scale ABI …"

A finding is allowlisted when BOTH patterns match; it stays in the report
(flagged ``allowed``, with the reason) but no longer counts toward the
``--fail-on`` gate.  Entries without a reason are rejected: the file is
the audit trail for every deliberate fast-path exception.
"""

from __future__ import annotations

import dataclasses
import fnmatch


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    key: str
    reason: str

    def matches(self, finding) -> bool:
        return (fnmatch.fnmatchcase(finding.rule, self.rule)
                and fnmatch.fnmatchcase(finding.key, self.key))


def load_allowlist(path) -> list[AllowEntry]:
    """Parse ``analyze.toml`` -> entries.  Missing file -> empty list."""
    import os

    if not path or not os.path.exists(path):
        return []
    try:
        import tomllib as toml                     # py311+
    except ImportError:                            # pragma: no cover
        try:
            import tomli as toml                   # the baked-in backport
        except ImportError as e:
            raise RuntimeError(
                f"cannot parse {path}: no tomllib/tomli in this "
                "environment") from e
    with open(path, "rb") as f:
        doc = toml.load(f)
    entries = []
    for i, raw in enumerate(doc.get("allow", [])):
        if not raw.get("reason"):
            raise ValueError(
                f"{path}: allow entry #{i + 1} ({raw.get('rule', '?')} / "
                f"{raw.get('key', '?')}) has no reason; every allowlisted "
                "fallback must say why it is legitimate")
        entries.append(AllowEntry(rule=str(raw.get("rule", "*")),
                                  key=str(raw.get("key", "*")),
                                  reason=str(raw["reason"])))
    return entries


def apply_allowlist(findings, entries):
    """Return findings with matching ones re-flagged as allowed."""
    if not entries:
        return list(findings)
    out = []
    for f in findings:
        hit = next((e for e in entries if e.matches(f)), None)
        if hit is not None and not f.allowed:
            f = dataclasses.replace(f, allowed=True, allow_reason=hit.reason)
        out.append(f)
    return out


def dead_entries(findings, entries) -> list[AllowEntry]:
    """Allowlist entries whose patterns matched zero findings.

    A dead entry means the code it excused moved or was fixed — the audit
    trail is stale.  Call over the FULL run's findings (all cells), never
    per cell: an entry is alive if ANY cell still triggers it.
    """
    return [e for e in entries
            if not any(e.matches(f) for f in findings)]


def dead_allowlist_findings(findings, entries, *, path: str = ""):
    """``meta.dead_allowlist`` warnings for :func:`dead_entries`."""
    from repro.analyze.findings import Finding

    out = []
    for e in dead_entries(findings, entries):
        out.append(Finding(
            rule="meta.dead_allowlist", severity="warn",
            message=(f"allowlist entry (rule={e.rule!r}, key={e.key!r}) "
                     "matched no finding in this run — the exception it "
                     "excused is gone; delete the entry"
                     + (f" from {path}" if path else "")),
            key=f"allow:{e.rule}:{e.key}", where=path))
    return out
