"""``repro-analyze`` / ``python -m repro analyze`` — the static-lint gate.

Usage::

    repro-analyze                                  # ci-tiny grid, analyze.toml
    repro-analyze --preset ci-tiny --fail-on error # the CI gate
    repro-analyze --rules overflow,numerics,precision --preset grad-comm-wire
    repro-analyze --arch yi-6b --workload serve --precision lazy_int8
    repro-analyze --no-compile --json              # no XLA compiles
    repro-analyze --write-baseline results/analyze_baseline.json
    repro-analyze --baseline results/analyze_baseline.json   # diff gate

Runs :func:`repro.analyze.runner.analyze_session` over every cell of a
named sweep preset (default ``ci-tiny`` — the same grid CI executes), or
over one ad-hoc RunSpec built from ``--arch``/``--workload`` flags.
Findings matching ``analyze.toml`` stay visible but don't gate; allowlist
entries that matched nothing across the WHOLE run surface as
``meta.dead_allowlist`` warnings.  With ``--baseline`` the gate is
*differential*: only findings absent from the committed snapshot count,
so rule families can be broadened without allowlist churn.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _force_device_count(n: int) -> None:
    from repro.sweep.runner import _drop_device_count_flag

    flags = _drop_device_count_flag(os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}").strip()


def _cells(args) -> list:
    if args.arch:
        from repro.api.spec import RunSpec

        precision = {}
        if args.precision == "lazy_int8":
            precision = {"weights": 7, "lazy": True}
        elif args.precision:
            precision = json.loads(args.precision)
        d = {"arch": args.arch, "workload": args.workload,
             "mesh": args.mesh, "smoke": True, "batch": args.batch,
             "seq": args.seq}
        if precision:
            d["precision"] = precision
        return [RunSpec.from_dict(d)]
    from repro.sweep.grid import PRESETS, get_preset

    names = ([p for p in args.preset.split(",") if p]
             if args.preset != "all" else sorted(PRESETS))
    specs, seen = [], set()
    for name in names:
        for c in get_preset(name).cells():
            if c.key in seen:          # presets share cells (ci-tiny does)
                continue
            seen.add(c.key)
            specs.append(c.spec)
    return specs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-analyze", description=__doc__)
    ap.add_argument("--preset", default="ci-tiny",
                    help="sweep preset(s) naming the spec matrix to analyze "
                         "(comma-separated, or 'all'; duplicate cells "
                         "dedupe by content hash)")
    ap.add_argument("--arch", default="",
                    help="analyze one ad-hoc RunSpec instead of a preset")
    ap.add_argument("--workload", default="serve")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--precision", default="lazy_int8",
                    help="'lazy_int8' or a PrecisionPolicy JSON dict")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule families to run "
                         "(precision,wire,kernel,overflow,numerics; "
                         "'' = all)")
    ap.add_argument("--fail-on", choices=("error", "warn", "never"),
                    default="error",
                    help="exit non-zero when an unallowlisted finding at or "
                         "above this severity exists")
    ap.add_argument("--allowlist", default="analyze.toml",
                    help="per-rule allowlist file ('' disables)")
    ap.add_argument("--baseline", default="",
                    help="committed findings snapshot: gate only on findings "
                         "NOT already in it (differential mode)")
    ap.add_argument("--write-baseline", default="",
                    help="write this run's findings as a new baseline "
                         "snapshot and exit 0")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the HLO wire lint (no XLA compiles)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings (and proofs) as JSON on stdout")
    ap.add_argument("--json-out", default="",
                    help="also write the findings+proofs JSON to this path "
                         "(the CI artifact)")
    args = ap.parse_args(argv)

    specs = _cells(args)

    # one process analyzes every cell: pin the fake-device flag to the
    # largest mesh before jax initializes its backend
    from repro.sweep.runner import _mesh_devices

    _force_device_count(max([_mesh_devices(s.mesh) for s in specs] + [1]))

    from repro.analyze.allowlist import dead_allowlist_findings, load_allowlist
    from repro.analyze.baseline import (
        diff_against_baseline,
        load_baseline,
        write_baseline,
    )
    from repro.analyze.findings import at_or_above
    from repro.analyze.runner import normalize_rules
    from repro.api.session import Session

    rules = normalize_rules(args.rules) if args.rules else None
    allowlist = args.allowlist or None
    findings, proofs = [], []
    for spec in specs:
        label = f"{spec.arch}:{spec.workload}"
        if not args.json:
            print(f"== analyzing {label} (mesh {spec.mesh}) ==",
                  flush=True)
        findings.extend(Session(spec).analyze(
            compile=not args.no_compile, allowlist=allowlist,
            rules=rules, proofs=proofs))

    # dead-allowlist detection runs over the AGGREGATE: an entry is alive
    # if any cell of the whole run still triggers it
    if allowlist:
        entries = load_allowlist(allowlist)
        findings.extend(dead_allowlist_findings(findings, entries,
                                                path=allowlist))

    if args.write_baseline:
        extra = (load_baseline(args.baseline) if args.baseline
                 and os.path.exists(args.baseline) else ())
        doc = write_baseline(findings, args.write_baseline,
                             extra_identities=extra)
        print(f"baseline written: {args.write_baseline} "
              f"({len(findings)} findings, "
              f"{len(doc['identities'])} identities)")
        return 0

    gated = findings
    if args.baseline:
        gated = diff_against_baseline(findings, load_baseline(args.baseline))

    doc = {"findings": [f.to_dict() for f in findings],
           "proofs": proofs,
           "new_findings": ([f.to_dict() for f in gated]
                            if args.baseline else None)}
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
    if args.json:
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f.format())
        n_err = sum(1 for f in findings
                    if f.severity == "error" and not f.allowed)
        n_warn = sum(1 for f in findings
                     if f.severity == "warn" and not f.allowed)
        n_allowed = sum(1 for f in findings if f.allowed)
        n_proved = sum(1 for p in proofs if p.get("ok"))
        print(f"-- {len(findings)} findings: {n_err} errors, {n_warn} "
              f"warnings, {n_allowed} allowlisted; {n_proved}/{len(proofs)} "
              "proofs hold --")
        if args.baseline:
            print(f"-- differential vs {args.baseline}: "
                  f"{len(gated)} new finding(s) --")

    if args.fail_on != "never" and at_or_above(gated, args.fail_on):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
