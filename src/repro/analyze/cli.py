"""``repro-analyze`` / ``python -m repro analyze`` — the static-lint gate.

Usage::

    repro-analyze                                  # ci-tiny grid, analyze.toml
    repro-analyze --preset ci-tiny --fail-on error # the CI gate
    repro-analyze --arch yi-6b --workload serve --precision lazy_int8
    repro-analyze --no-compile --json              # jaxpr+kernel rules only

Runs :func:`repro.analyze.runner.analyze_session` over every cell of a
named sweep preset (default ``ci-tiny`` — the same grid CI executes), or
over one ad-hoc RunSpec built from ``--arch``/``--workload`` flags.
Findings matching ``analyze.toml`` stay visible but don't gate; the exit
code is non-zero iff any unallowlisted finding reaches ``--fail-on``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _force_device_count(n: int) -> None:
    from repro.sweep.runner import _drop_device_count_flag

    flags = _drop_device_count_flag(os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}").strip()


def _cells(args) -> list:
    if args.arch:
        from repro.api.spec import RunSpec

        precision = {}
        if args.precision == "lazy_int8":
            precision = {"weights": 7, "lazy": True}
        elif args.precision:
            precision = json.loads(args.precision)
        d = {"arch": args.arch, "workload": args.workload,
             "mesh": args.mesh, "smoke": True, "batch": args.batch,
             "seq": args.seq}
        if precision:
            d["precision"] = precision
        return [RunSpec.from_dict(d)]
    from repro.sweep.grid import get_preset

    return [c.spec for c in get_preset(args.preset).cells()]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-analyze", description=__doc__)
    ap.add_argument("--preset", default="ci-tiny",
                    help="sweep preset naming the spec matrix to analyze")
    ap.add_argument("--arch", default="",
                    help="analyze one ad-hoc RunSpec instead of a preset")
    ap.add_argument("--workload", default="serve")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--precision", default="lazy_int8",
                    help="'lazy_int8' or a PrecisionPolicy JSON dict")
    ap.add_argument("--fail-on", choices=("error", "warn", "never"),
                    default="error",
                    help="exit non-zero when an unallowlisted finding at or "
                         "above this severity exists")
    ap.add_argument("--allowlist", default="analyze.toml",
                    help="per-rule allowlist file ('' disables)")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the HLO wire lint (no XLA compiles)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as a JSON list")
    args = ap.parse_args(argv)

    specs = _cells(args)

    # one process analyzes every cell: pin the fake-device flag to the
    # largest mesh before jax initializes its backend
    from repro.sweep.runner import _mesh_devices

    _force_device_count(max([_mesh_devices(s.mesh) for s in specs] + [1]))

    from repro.analyze.findings import at_or_above
    from repro.api.session import Session

    allowlist = args.allowlist or None
    findings = []
    for spec in specs:
        label = f"{spec.arch}:{spec.workload}"
        if not args.json:
            print(f"== analyzing {label} (mesh {spec.mesh}) ==",
                  flush=True)
        findings.extend(Session(spec).analyze(
            compile=not args.no_compile, allowlist=allowlist))

    if args.json:
        print(json.dumps([f.to_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        n_err = sum(1 for f in findings
                    if f.severity == "error" and not f.allowed)
        n_warn = sum(1 for f in findings
                     if f.severity == "warn" and not f.allowed)
        n_allowed = sum(1 for f in findings if f.allowed)
        print(f"-- {len(findings)} findings: {n_err} errors, {n_warn} "
              f"warnings, {n_allowed} allowlisted --")

    if args.fail_on != "never" and at_or_above(findings, args.fail_on):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
