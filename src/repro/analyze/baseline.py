"""Differential findings gate: fail CI only on NEW unallowlisted findings.

A baseline is the committed snapshot of one full analyze run
(``results/analyze_baseline.json``): the findings list plus the identity
set the differ matches against.  A finding's identity is
``(rule, key, cell)`` — deliberately line-number-free (``where`` drifts
with every edit) so broadening a rule family or moving code does not churn
the gate; only a genuinely new (rule, site) pair does.

Workflow::

    repro-analyze --preset ci-tiny --write-baseline results/analyze_baseline.json
    # commit the file; from then on
    repro-analyze --preset ci-tiny --baseline results/analyze_baseline.json
    # exits non-zero iff an unallowlisted finding at --fail-on severity
    # exists that the baseline does not contain

Fixed findings age out silently (the differ never fails on disappearance);
refresh the snapshot with ``--write-baseline`` whenever the accepted set
shrinks so the file stays an honest record.
"""

from __future__ import annotations

import json


def finding_identity(f) -> tuple[str, str, str]:
    """The stable triple the differ matches on: (rule, key, cell)."""
    return (f.rule, f.key, f.cell)


def write_baseline(findings, path: str, extra_identities=()) -> dict:
    """Snapshot ``findings`` (allowlisted ones included, marked) to JSON.

    ``extra_identities`` unions in identities from a previous snapshot —
    the CLI passes the loaded ``--baseline`` set so a multi-invocation
    regeneration (ci-tiny with compile, then the heavy presets without)
    accumulates instead of clobbering.
    """
    idents = {"|".join(finding_identity(f)) for f in findings}
    idents |= {"|".join(i) for i in extra_identities}
    doc = {
        "version": 1,
        "identities": sorted(idents),
        "findings": [f.to_dict() for f in findings],
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return doc


def load_baseline(path: str) -> set[tuple[str, str, str]]:
    """Identity set of a committed baseline file."""
    with open(path) as fh:
        doc = json.load(fh)
    out = set()
    for ident in doc.get("identities", []):
        parts = ident.split("|")
        if len(parts) == 3:
            out.add(tuple(parts))
    # tolerate hand-written baselines that only carry raw findings
    for f in doc.get("findings", []):
        out.add((f.get("rule", ""), f.get("key", ""), f.get("cell", "")))
    return out


def diff_against_baseline(findings, baseline: set) -> list:
    """Findings whose identity the baseline does not contain."""
    return [f for f in findings if finding_identity(f) not in baseline]
