"""Interval / quantization-error lattice for the abstract interpreter.

One :class:`AbsVal` summarizes every element of one array:

* ``lo``/``hi`` — a closed interval bounding every element's value.  ``±inf``
  endpoints mean "unbounded on that side"; the interval is a *bound on
  values*, not a claim that the endpoints are attained.
* ``exact`` — every element is an exactly-representable integer (quantized
  codes after SR rounding, token ids, iota, booleans).  Integer dtypes are
  exact by construction; floats become exact through ``floor``/``round`` and
  stay exact under +, -, * and integer conversion.
* ``qerr`` — worst-case rounding deviation accrued by round-family ops,
  scaled through subsequent arithmetic: after ``codes = round(x/step)`` and
  ``deq = codes * step`` the lattice carries ``qerr(deq) <= step * 0.5`` (or
  ``step * 1.0`` for stochastic rounding via floor), which is exactly the
  per-role resolution ``delta = s/(2^q - 1)`` the convergence bound feeds
  GBD.  ``qerr`` is a *reconstruction* of that bound from the traced graph,
  not a full relational error analysis.

Everything here is pure host math over Python floats — no jax arrays — so
the interpreter can run over thousand-eqn jaxprs without touching a device.
"""

from __future__ import annotations

import dataclasses
import math

INF = math.inf


def _clean(x: float) -> float:
    """Map NaN endpoint candidates (0*inf, inf-inf) to the safe extreme."""
    return x if x == x else INF


@dataclasses.dataclass(frozen=True)
class AbsVal:
    """Abstract value: interval + integer-exactness + quantization error."""

    lo: float = -INF
    hi: float = INF
    exact: bool = False
    qerr: float = 0.0

    def __post_init__(self):
        # Normalize away NaN endpoints and empty intervals defensively: a
        # wrong-way interval would make every downstream bound unsound.
        lo, hi = self.lo, self.hi
        if lo != lo:
            lo = -INF
        if hi != hi:
            hi = INF
        if lo > hi:
            lo, hi = -INF, INF
        object.__setattr__(self, "lo", float(lo))
        object.__setattr__(self, "hi", float(hi))
        object.__setattr__(self, "qerr", float(max(self.qerr, 0.0)))

    # -- predicates ------------------------------------------------------
    @property
    def mag(self) -> float:
        """Largest absolute value any element can take."""
        return max(abs(self.lo), abs(self.hi))

    def contains(self, x: float) -> bool:
        return self.lo <= x <= self.hi

    @property
    def bounded(self) -> bool:
        return self.lo > -INF and self.hi < INF

    def __repr__(self):  # compact for findings / debugging
        e = "i" if self.exact else "f"
        q = f",q<={self.qerr:g}" if self.qerr else ""
        return f"[{self.lo:g},{self.hi:g}]{e}{q}"


TOP = AbsVal()
UNIT = AbsVal(0.0, 1.0)          # probabilities, sigmoids, uniforms
BOOL = AbsVal(0.0, 1.0, exact=True)


def point(v: float, *, exact: bool | None = None) -> AbsVal:
    v = float(v)
    if exact is None:
        exact = float(v).is_integer()
    return AbsVal(v, v, exact=exact)


def interval(lo: float, hi: float, *, exact: bool = False,
             qerr: float = 0.0) -> AbsVal:
    return AbsVal(lo, hi, exact=exact, qerr=qerr)


def join(a: AbsVal, b: AbsVal) -> AbsVal:
    """Least upper bound: either value could flow here (cond joins, select)."""
    return AbsVal(min(a.lo, b.lo), max(a.hi, b.hi),
                  exact=a.exact and b.exact, qerr=max(a.qerr, b.qerr))


def widen(old: AbsVal, new: AbsVal) -> AbsVal:
    """Widening for loop carries: any still-growing bound jumps to ±inf.

    Guarantees fixpoint termination in one extra iteration — a carry whose
    interval grew twice is assumed unbounded rather than chased.
    """
    return AbsVal(old.lo if new.lo >= old.lo else -INF,
                  old.hi if new.hi <= old.hi else INF,
                  exact=old.exact and new.exact,
                  qerr=old.qerr if new.qerr <= old.qerr else INF)


def meet_interval(a: AbsVal, lo: float, hi: float) -> AbsVal:
    """Refine ``a`` with external knowledge ``value in [lo, hi]``."""
    nlo, nhi = max(a.lo, lo), min(a.hi, hi)
    if nlo > nhi:                 # contradictory refinement: keep original
        return a
    return AbsVal(nlo, nhi, exact=a.exact, qerr=a.qerr)


# ---------------------------------------------------------------------------
# Arithmetic transfer functions
# ---------------------------------------------------------------------------


def _mul_e(x: float, y: float) -> float:
    """Endpoint product with the interval convention 0 * inf = 0."""
    if x == 0.0 or y == 0.0:
        return 0.0
    return x * y


def add(a: AbsVal, b: AbsVal) -> AbsVal:
    return AbsVal(_clean(a.lo + b.lo), _clean(a.hi + b.hi),
                  exact=a.exact and b.exact, qerr=a.qerr + b.qerr)


def sub(a: AbsVal, b: AbsVal) -> AbsVal:
    return AbsVal(_clean(a.lo - b.hi), _clean(a.hi - b.lo),
                  exact=a.exact and b.exact, qerr=a.qerr + b.qerr)


def neg(a: AbsVal) -> AbsVal:
    return AbsVal(-a.hi, -a.lo, exact=a.exact, qerr=a.qerr)


#: smallest positive double: keeps strictly-positive bounds strictly
#: positive when an endpoint product/quotient underflows to 0.0
TINY = 5e-324


def mul(a: AbsVal, b: AbsVal) -> AbsVal:
    cands = [_mul_e(x, y) for x in (a.lo, a.hi) for y in (b.lo, b.hi)]
    lo, hi = min(cands), max(cands)
    if a.lo > 0 and b.lo > 0:
        lo = max(lo, TINY)            # pos * pos stays pos despite underflow
    # |a*b - a'*b'| <= |a| qb + |b| qa + qa qb for |a-a'|<=qa, |b-b'|<=qb
    q = a.mag * b.qerr + b.mag * a.qerr + a.qerr * b.qerr
    return AbsVal(lo, hi, exact=a.exact and b.exact, qerr=_clean(q))


def div(a: AbsVal, b: AbsVal) -> AbsVal:
    if b.contains(0.0):
        return AbsVal(exact=False, qerr=INF if (a.qerr or b.qerr) else 0.0)
    cands = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            c = x / y if y != 0 else INF
            if c != c:                # inf/inf endpoint: unbounded limit
                cands += [-INF, INF]
            else:
                cands.append(c)
    lo, hi = min(cands), max(cands)
    if a.lo > 0 and b.lo > 0:
        lo = max(lo, TINY)
    bmin = min(abs(b.lo), abs(b.hi))
    q = (a.qerr + max(abs(lo), abs(hi)) * b.qerr) / bmin \
        if (a.qerr or b.qerr) else 0.0
    return AbsVal(lo, hi, exact=False, qerr=_clean(q))


def abs_(a: AbsVal) -> AbsVal:
    if a.lo >= 0:
        return a
    if a.hi <= 0:
        return neg(a)
    return AbsVal(0.0, a.mag, exact=a.exact, qerr=a.qerr)


def min_(a: AbsVal, b: AbsVal) -> AbsVal:
    return AbsVal(min(a.lo, b.lo), min(a.hi, b.hi),
                  exact=a.exact and b.exact, qerr=max(a.qerr, b.qerr))


def max_(a: AbsVal, b: AbsVal) -> AbsVal:
    return AbsVal(max(a.lo, b.lo), max(a.hi, b.hi),
                  exact=a.exact and b.exact, qerr=max(a.qerr, b.qerr))


def clamp(lo_b: AbsVal, x: AbsVal, hi_b: AbsVal) -> AbsVal:
    """``lax.clamp(min, x, max) = max(min, min(x, max))`` elementwise."""
    return max_(lo_b, min_(x, hi_b))


def scale_by_count(a: AbsVal, n: int) -> AbsVal:
    """Sum of ``n`` values each in ``a``: psum, reduce_sum, dot contraction."""
    n = int(n)
    return AbsVal(_mul_e(float(n), a.lo), _mul_e(float(n), a.hi),
                  exact=a.exact, qerr=_clean(n * a.qerr))


def to_integer(a: AbsVal) -> AbsVal:
    """Any int-rounding conversion: result integral, within [floor, ceil]."""
    lo = math.floor(a.lo) if a.lo > -INF else -INF
    hi = math.ceil(a.hi) if a.hi < INF else INF
    # rounding moves a value by < 1 relative to its float input
    q = a.qerr if a.exact else a.qerr + 1.0
    return AbsVal(lo, hi, exact=True, qerr=q)


def round_family(a: AbsVal, *, max_delta: float = 1.0) -> AbsVal:
    """floor/ceil/round: integral result within ``max_delta`` of the input."""
    lo = math.floor(a.lo) if a.lo > -INF else -INF
    hi = math.ceil(a.hi) if a.hi < INF else INF
    return AbsVal(lo, hi, exact=True,
                  qerr=a.qerr if a.exact else a.qerr + max_delta)


# -- monotone unary wrappers -------------------------------------------------


def _mono(fn, a: AbsVal, *, exact=False, qerr=INF) -> AbsVal:
    """Apply a monotone-increasing fn to both endpoints."""
    def safe(x):
        try:
            return fn(x)
        except (ValueError, OverflowError):
            return INF if x > 0 else -INF
    return AbsVal(safe(a.lo), safe(a.hi), exact=exact,
                  qerr=0.0 if a.qerr == 0 else qerr)


def exp(a: AbsVal) -> AbsVal:
    return _mono(math.exp, a)


def log(a: AbsVal) -> AbsVal:
    def f(x):
        if x <= 0:
            return -INF
        return math.log(x)
    return _mono(f, a)


def log1p(a: AbsVal) -> AbsVal:
    def f(x):
        if x <= -1:
            return -INF
        return math.log1p(x)
    return _mono(f, a)


def sqrt(a: AbsVal) -> AbsVal:
    def f(x):
        return math.sqrt(max(x, 0.0)) if x < INF else INF
    return _mono(f, a)


def rsqrt(a: AbsVal) -> AbsVal:
    if a.hi <= 0:
        return TOP
    lo = 0.0 if a.hi == INF else 1.0 / math.sqrt(a.hi)
    hi = INF if a.lo <= 0 else 1.0 / math.sqrt(a.lo)
    return AbsVal(lo, hi)


def integer_pow(a: AbsVal, k: int) -> AbsVal:
    k = int(k)
    if k == 0:
        return point(1.0)
    if k < 0:
        return div(point(1.0), integer_pow(a, -k))
    cands = [_clean(a.lo ** k), _clean(a.hi ** k)]
    lo, hi = min(cands), max(cands)
    if k % 2 == 0 and a.lo < 0 < a.hi:
        lo = 0.0
    q = 0.0 if a.qerr == 0 else INF if k > 1 else a.qerr
    return AbsVal(lo, hi, exact=a.exact, qerr=q)


def dtype_top(dtype) -> AbsVal:
    """Default (sound, maximally imprecise) value for an array of ``dtype``."""
    import numpy as np
    dt = np.dtype(dtype)
    if dt.kind == "b":
        return BOOL
    if dt.kind in "iu":
        info = np.iinfo(dt)
        return AbsVal(float(info.min), float(info.max), exact=True)
    return TOP
