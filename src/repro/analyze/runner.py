"""Orchestrate the three rule families over one Session (no execution).

:func:`analyze_session` is what ``Session.analyze()`` and the
``repro-analyze`` CLI call: it decides which step graphs a RunSpec implies
(train -> its train step; serve -> the packed decode step plus a prefill;
dryrun -> its shape cell), traces each via ``Session.trace()`` for the
precision-flow lint, optionally compiles for the wire lint +
``comm_report`` cross-check, and runs the kernel checker over the shipped
:class:`~repro.kernels.spec.KernelSpec` metadata at this config's
dimensions.  ``fl-sim`` cells have no jaxpr to lint (the CNN simulation is
not a model-zoo graph) and are skipped with an info finding.
"""

from __future__ import annotations

from repro.analyze.allowlist import apply_allowlist, load_allowlist
from repro.analyze.findings import Finding

DEFAULT_ALLOWLIST = "analyze.toml"


def _pow2_at_least(n: int, lo: int = 8) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


def lint_cells(session) -> list[tuple[str, object]]:
    """(label, shape-arg for ``Session.trace``) per step graph to lint."""
    from repro.configs.base import ShapeSpec

    spec = session.spec
    wl = spec.workload
    if wl == "dryrun":
        name = spec.opt("shape")
        return [(f"dryrun:{name}", name)]
    if wl in ("train", "fl-orchestrate"):
        from repro.launch.mesh import batch_size

        n_clients = max(batch_size(session.mesh, session.axes), 1)
        cell = ShapeSpec("train_step", seq_len=spec.seq,
                         global_batch=n_clients * spec.batch, kind="train")
        return [(f"{wl}:train_step", cell)]
    if wl == "serve":
        s_max = int(spec.opt("s_max", spec.seq))
        bucket = _pow2_at_least(int(spec.opt("prompt_len", 8)))
        return [
            ("serve:decode",
             ShapeSpec("serve_decode", seq_len=s_max,
                       global_batch=spec.batch, kind="decode")),
            ("serve:prefill",
             ShapeSpec("serve_prefill", seq_len=bucket,
                       global_batch=spec.batch, kind="prefill")),
        ]
    return []                                     # fl-sim


def _wire_context(session, kind: str):
    from repro.analyze.wire_lint import WireContext, expected_gathers
    from repro.launch.mesh import batch_size, fsdp_size, tp_size
    from repro.launch.steps import serving_axes

    axes = session.axes
    if kind == "decode":
        axes = serving_axes(axes, session.spec.batch, session.mesh)
    policy = session.policy
    fsdp = fsdp_size(session.mesh, axes)
    tp = tp_size(session.mesh, axes)
    return WireContext(
        policy=policy, kind=kind,
        n_clients=max(batch_size(session.mesh, session.axes), 1),
        fsdp=fsdp, tp=tp,
        expected_gather_dtypes=expected_gathers(
            fsdp=fsdp, tp=tp,
            packed=policy.packed and kind != "train",
            gather_bf16=(getattr(session.cfg, "fsdp_gather_dtype", "")
                         == "bfloat16")))


def _kernel_cells(session) -> list:
    from repro.analyze.kernel_check import shipped_kernel_specs

    cfg = session.cfg
    d = int(getattr(cfg, "d_model", 512)) or 512
    heads = int(getattr(cfg, "n_heads", 8)) or 8
    hd = int(cfg.resolved_head_dim) if hasattr(cfg, "resolved_head_dim") \
        else max(d // heads, 8)
    return shipped_kernel_specs(
        # SSM archs have no MLP (d_ff == 0): check the kernel at 4*d
        d_model=d, d_ff=int(getattr(cfg, "d_ff", 0) or 4 * d), heads=heads,
        head_dim=max(int(hd), 8), batch=max(int(session.spec.batch), 1),
        seq=max(int(session.spec.opt("prompt_len", 8)), 8) * 2 + 1,
        page=int(session.spec.opt("page_size", 8)),
        n_pool=int(session.spec.opt("pool_pages", 6)))


#: the rule families ``analyze_session`` can run (``rules=None`` = all)
ALL_RULE_FAMILIES = ("precision", "wire", "kernel", "overflow", "numerics")


def _want(rules, family: str) -> bool:
    return rules is None or family in rules


def normalize_rules(rules) -> frozenset | None:
    """Parse a rules selection (None / iterable / comma string) -> set."""
    if rules is None:
        return None
    if isinstance(rules, str):
        rules = [r for r in rules.split(",") if r]
    out = frozenset(str(r).strip() for r in rules)
    unknown = out - set(ALL_RULE_FAMILIES)
    if unknown:
        raise ValueError(f"unknown rule families {sorted(unknown)}; "
                         f"options: {ALL_RULE_FAMILIES}")
    return out


def analyze_session(session, *, compile: bool = True, allowlist_path=None,
                    check_kernels: bool = True, rules=None,
                    proofs: list | None = None) -> list[Finding]:
    """All rule families over one Session's step graphs.

    ``compile=False`` skips the HLO wire lint (jaxpr + kernel rules only)
    — much faster, but blind to collectives.  ``allowlist_path=None``
    skips allowlisting entirely (the CLI passes ``analyze.toml``).
    ``rules`` selects families from :data:`ALL_RULE_FAMILIES` (``None`` =
    all): ``overflow``/``numerics`` drive the abstract interpreter over
    each traced graph plus the analytic per-cell accumulator proof;
    ``precision`` adds the error-budget certificate on FL cells.  Positive
    proof records (accumulator fits, budget holds) are appended to
    ``proofs`` when a list is passed — findings only report failures.
    """
    from repro.analyze.absint import interpret_jaxpr
    from repro.analyze.kernel_check import check_kernel_spec
    from repro.analyze.precision_flow import lint_jaxpr
    from repro.analyze.static_proofs import prove_spec
    from repro.analyze.wire_lint import check_comm_report, lint_module
    from repro.roofline.hlo_parse import parse_module

    rules = normalize_rules(rules)
    absint_rules = tuple(r for r in ("overflow", "numerics")
                         if _want(rules, r))
    findings: list[Finding] = []
    spec = session.spec

    if spec.workload == "fl-sim":
        findings.append(Finding(
            rule="analyze.skipped", severity="info",
            message=("fl-sim cells have no model-zoo step graph to lint; "
                     "analytic proofs only"),
            key=f"fl-sim:{spec.arch}", cell=f"fl-sim:{spec.arch}"))
    else:
        axis_sizes = dict(zip(session.mesh.axis_names,
                              session.mesh.devices.shape))
        policy = session.policy
        for label, shape in lint_cells(session):
            traced, meta = session.trace(shape)
            kind = meta["kind"]
            if _want(rules, "precision"):
                findings.extend(lint_jaxpr(
                    traced.jaxpr, policy=policy, axis_sizes=axis_sizes,
                    cell=label,
                    expect_fastpath=(policy.lazy and policy.packed
                                     and kind == "decode")))
            if absint_rules:
                res = interpret_jaxpr(traced.jaxpr, axis_sizes=axis_sizes,
                                      cell=label, rules=absint_rules)
                findings.extend(res.findings)
                if proofs is not None:
                    proofs.extend(res.proofs)
            if compile and _want(rules, "wire"):
                compiled = traced.lower().compile()
                mc = parse_module(compiled.as_text())
                findings.extend(lint_module(
                    mc, _wire_context(session, kind), cell=label))
                if kind == "train":
                    findings.extend(check_comm_report(
                        mc, session.comm_report(), cell=label))

    proof_rules = tuple(r for r in ("overflow", "precision")
                        if _want(rules, r))
    if proof_rules:
        records, fs = prove_spec(spec, rules=proof_rules)
        findings.extend(fs)
        if proofs is not None:
            proofs.extend(records)

    if check_kernels and spec.workload != "fl-sim" and _want(rules, "kernel"):
        for ks in _kernel_cells(session):
            findings.extend(check_kernel_spec(ks, cell=f"kernels:{ks.name}"))

    if allowlist_path:
        findings = apply_allowlist(findings, load_allowlist(allowlist_path))
    return findings
