"""Precision-flow lint: a taint walk over traced jaxprs.

TAINT SOURCES are quantized-code arrays: integer leaves of itemsize <= 2
(int8/int16 QTensor codes) with rank >= 2 — token ids, page tables and
lengths are int32/rank-1 and never taint.  Taint PROPAGATES through the
dequantization idiom (``convert_element_type``, ``mul`` by a scale,
reshapes/transposes/slices, FSDP ``all_gather``) and STOPS with a finding
at any ``dot_general`` consuming a tainted operand: that matmul read a
weight that was eagerly dequantized to floats in HBM instead of streaming
codes through the ``quant_matmul`` Pallas kernel — the exact silent
fallback that erases the paper's storage/bandwidth win (arXiv 2012.11070).

Taint deliberately does NOT propagate through ``gather``/``take`` (the
embedding-row read is a lookup, not a matmul weight) nor through ``add``
(residual streams would smear taint over the whole graph).

The walk also checks integer ``psum`` accumulators: summing ``n`` clients'
``bits``-wide codes needs the dtype of ``n * (2^bits - 1)``
(:func:`repro.dist.collectives.wire_dtype`); anything narrower overflows
on the wire.

Sub-jaxprs (scan/while/cond/pjit/shard_map/remat/custom_*) are entered
with taint mapped across their invars; loop carries iterate to a fixpoint
before findings are collected, so a dequant inside a scanned layer body is
reported exactly once.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.analyze.findings import Finding, source_key

# primitives the dequant dataflow can pass through without changing what
# the values ARE (codes, possibly scaled)
_PROPAGATE = frozenset({
    "convert_element_type", "mul", "div", "broadcast_in_dim", "transpose",
    "reshape", "squeeze", "expand_dims", "slice", "dynamic_slice",
    "all_gather", "copy", "rev", "concatenate", "pad", "stop_gradient",
    "optimization_barrier",
})

# eqn params that hold sub-jaxprs entered with invars mapped 1:1
_ONE_TO_ONE_SUBJAXPR_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "remat", "remat2", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "shard_map", "scan",
})


def _is_var(v) -> bool:
    """True for jaxpr Vars (hashable); Literals carry ``.val``."""
    return hasattr(v, "aval") and not hasattr(v, "val")


def _is_code_like(aval) -> bool:
    try:
        return (jnp.issubdtype(aval.dtype, jnp.integer)
                and aval.dtype.itemsize <= 2 and aval.ndim >= 2)
    except Exception:
        return False


def _inner(j):
    """Jaxpr from either a ClosedJaxpr or a raw Jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _jaxpr_params(eqn):
    """(param_name, jaxpr-ish) pairs found in an eqn's params."""
    out = []
    for k, v in eqn.params.items():
        if hasattr(v, "eqns") or (hasattr(v, "jaxpr")
                                  and hasattr(v.jaxpr, "eqns")):
            out.append((k, v))
        elif isinstance(v, (tuple, list)):
            for vi in v:
                if hasattr(vi, "eqns") or (hasattr(vi, "jaxpr")
                                           and hasattr(vi.jaxpr, "eqns")):
                    out.append((k, vi))
    return out


class _Walker:
    def __init__(self, *, policy, axis_sizes, cell, collect):
        self.policy = policy
        self.axis_sizes = dict(axis_sizes or {})
        self.cell = cell
        self.collect = collect
        self.findings: dict[tuple, Finding] = {}
        self.n_dots = 0
        self.n_fastpath = 0

    # -- finding helpers -------------------------------------------------
    def _emit(self, rule, severity, message, key, where):
        if not self.collect:
            return
        ident = (rule, key, where)
        if ident not in self.findings:
            self.findings[ident] = Finding(
                rule=rule, severity=severity, message=message, key=key,
                where=where, cell=self.cell)

    # -- the walk --------------------------------------------------------
    def run(self, jaxpr, in_taint):
        """Walk one (raw) jaxpr; returns per-outvar taint flags."""
        tainted = set()
        for v, t in zip(jaxpr.invars, in_taint):
            if t:
                tainted.add(v)
        for v in jaxpr.constvars:
            if _is_code_like(v.aval):
                tainted.add(v)
        for eqn in jaxpr.eqns:
            self._eqn(eqn, tainted)
        out = []
        for v in jaxpr.outvars:
            out.append(_is_var(v) and v in tainted)
        return out

    def _taint_of(self, eqn, tainted):
        return [_is_var(v) and v in tainted for v in eqn.invars]

    def _eqn(self, eqn, tainted):
        prim = eqn.primitive.name
        in_taint = self._taint_of(eqn, tainted)

        if prim == "pallas_call":
            # the fast path itself: codes are consumed INSIDE the kernel
            name = str(eqn.params.get("name_and_src_info", ""))
            if "quant_matmul" in name:
                self.n_fastpath += 1
            return

        if prim in ("dot_general", "conv_general_dilated"):
            self.n_dots += 1
            if any(in_taint):
                key, where = source_key(eqn.source_info)
                operand = "lhs" if in_taint[0] else "rhs"
                shapes = [tuple(v.aval.shape) for v in eqn.invars
                          if hasattr(v, "aval")]
                sev = "error" if self.policy.lazy else "info"
                self._emit(
                    "precision.eager_dequant", sev,
                    f"{prim} {operand} consumes eagerly-dequantized QTensor "
                    f"codes (shapes {shapes}); the quant_matmul fast path "
                    "streams codes instead", key, where)
            return                              # dot output is activations

        if prim in ("psum", "psum2", "psum_invariant"):
            self._check_psum(eqn, tainted)
            if any(in_taint):
                for v in eqn.outvars:
                    tainted.add(v)
            return

        if prim in ("gather", "take", "dynamic_gather"):
            return                              # embedding-row reads

        subs = _jaxpr_params(eqn)
        if subs:
            self._sub(eqn, subs, in_taint, tainted)
            return

        if prim in _PROPAGATE and any(in_taint):
            for v in eqn.outvars:
                tainted.add(v)

    def _check_psum(self, eqn, tainted):
        from repro.dist.collectives import wire_dtype

        bits = getattr(self.policy, "comm", 32)
        if bits >= 32:
            return
        axes = eqn.params.get("axes", ())
        n = 1
        for a in axes:
            n *= int(self.axis_sizes.get(a, 1))
        if n <= 1:
            return
        try:
            required = jnp.dtype(wire_dtype(bits, n))
        except Exception:
            return
        for v in eqn.invars:
            if not hasattr(v, "aval"):
                continue
            dt = v.aval.dtype
            if jnp.issubdtype(dt, jnp.integer) and dt.itemsize < required.itemsize:
                key, where = source_key(eqn.source_info)
                self._emit(
                    "precision.narrow_accumulator", "error",
                    f"psum over {axes} (n={n}) accumulates {dt.name} codes "
                    f"but n*(2^{bits}-1) needs {required.name}: the "
                    "reduction overflows on the wire", key, where)

    def _sub(self, eqn, subs, in_taint, tainted):
        prim = eqn.primitive.name
        out_taint = [False] * len(eqn.outvars)

        if prim == "while":
            cn = int(eqn.params.get("cond_nconsts", 0))
            bn = int(eqn.params.get("body_nconsts", 0))
            body = _inner(eqn.params["body_jaxpr"])
            body_in = in_taint[cn:]             # body consts + carry
            carry_in = body_in[bn:]
            for _ in range(3):                  # taint fixpoint over carry
                res = self.run(body, body_in)
                new_carry = [a or b for a, b in zip(carry_in, res)]
                if new_carry == carry_in:
                    break
                carry_in = new_carry
                body_in = body_in[:bn] + carry_in
            out_taint = carry_in
        elif prim == "scan":
            sub = _inner(eqn.params["jaxpr"])
            nc = int(eqn.params.get("num_consts", 0))
            ncar = int(eqn.params.get("num_carry", 0))
            sub_in = list(in_taint)
            for _ in range(3):
                res = self.run(sub, sub_in)
                new_carry = [a or b
                             for a, b in zip(sub_in[nc:nc + ncar], res[:ncar])]
                if new_carry == sub_in[nc:nc + ncar]:
                    out_taint = res
                    break
                sub_in[nc:nc + ncar] = new_carry
            else:
                out_taint = res
        elif prim == "cond":
            for _, br in subs:
                res = self.run(_inner(br), in_taint[1:])
                out_taint = [a or b for a, b in zip(out_taint, res)]
        else:
            # pjit / shard_map / remat / custom_* and any unknown primitive
            # whose sub-jaxpr invars align 1:1 with the eqn's
            for _, sj in subs:
                sub = _inner(sj)
                if len(sub.invars) == len(eqn.invars):
                    res = self.run(sub, in_taint)
                    out_taint = [a or b for a, b in zip(out_taint, res)]
                # non-aligned unknown sub-jaxpr: skip (conservative: its
                # outputs are treated as untainted)

        for v, t in zip(eqn.outvars, out_taint):
            if t:
                tainted.add(v)


def lint_jaxpr(closed_jaxpr, *, policy, axis_sizes=None, cell="",
               expect_fastpath=None) -> list[Finding]:
    """Precision-flow lint over one traced step's ClosedJaxpr.

    ``axis_sizes``: mesh axis name -> size (for the psum accumulator rule).
    ``expect_fastpath``: when True (default: ``policy.lazy``), a module
    that contains matmuls but not one ``quant_matmul`` pallas_call gets a
    ``precision.no_fastpath`` warning — the wholesale-dispatch-loss guard.
    """
    w = _Walker(policy=policy, axis_sizes=axis_sizes, cell=cell,
                collect=True)
    jaxpr = _inner(closed_jaxpr)
    in_taint = [_is_code_like(v.aval) for v in jaxpr.invars]
    w.run(jaxpr, in_taint)
    findings = list(w.findings.values())
    expect = policy.lazy if expect_fastpath is None else expect_fastpath
    if expect and w.n_dots > 0 and w.n_fastpath == 0:
        findings.append(Finding(
            rule="precision.no_fastpath", severity="warn",
            message=f"policy is lazy but none of the {w.n_dots} matmuls "
                    "went through the quant_matmul kernel — dispatch lost "
                    "wholesale?",
            key="module:no_fastpath", cell=cell))
    return findings
