"""Analytic (spec-level) proofs: wire-accumulator overflow + error budget.

The closed-form complement of the jaxpr interpreter in
:mod:`repro.analyze.absint`: pure host arithmetic over a RunSpec's precision
policy and mesh topology — no tracing, no compilation.  That makes the same
two guarantees available for cells that have no model-zoo graph to interpret
(``fl-sim``) and cheap enough to recompute per sweep cell at report time.

* :func:`prove_wire_accumulator` — the accumulator of the SR-quantized
  all-reduce must hold ``n_clients * code_bound(bits)``; both sides of the
  comparison come from :mod:`repro.dist.collectives` (the exactness
  contract), so the static proof and the runtime clip can't drift apart.
  ``force_dtype`` overrides the accumulator for seeded-negative tests.
* :func:`check_error_budget` — reconstruct the worst-case per-device
  quantization error ``sum_i delta_i^2`` implied by the policy's bits and
  compare it against the convergence-bound budget (constraint 23) that
  ``core/convergence.py`` feeds GBD; also cross-check that the trainer's
  traced ``delta_for_clients`` vector agrees elementwise with the
  optimizer's ``quant_noise`` model.
"""

from __future__ import annotations

import math

from repro.analyze.findings import Finding

#: options / defaults mirrored from ``fed.orchestrator.OrchestratorConfig``
_DEFAULT_LAMBDA = 0.05
_DEFAULT_E2 = 9.0
_DEFAULT_MODEL_DIM = 1 << 20


def headroom_bits(capacity: float, need: float) -> int:
    """Whole bits of slack between a worst-case sum and its accumulator."""
    if need <= 0:
        return 0
    return max(int(math.floor(math.log2(capacity / need))), 0)


def spec_n_clients(spec) -> int:
    """Data-parallel world size (= FL clients) a RunSpec implies.

    ``fl-sim`` carries it explicitly in options; every other workload
    derives it from the mesh string — the product of all axes except the
    trailing model axis (``"4x1"`` -> 4, ``"2x16x16"`` -> 32).
    """
    if spec.workload == "fl-sim":
        return max(int(spec.opt("n_clients", 1)), 1)
    parts = [int(p) for p in str(spec.mesh).split("x")]
    n = 1
    for p in parts[:-1]:
        n *= max(p, 1)
    return max(n, 1)


def prove_wire_accumulator(comm_bits: int, n_clients: int, *,
                           force_dtype=None, cell: str = "",
                           key: str = "policy.comm"):
    """(proof record, findings) for one (comm bits, client count) cell.

    The proof obligation is ``n * code_bound(bits) <= iinfo(dtype).max``
    where ``dtype`` is what :func:`repro.dist.collectives.wire_dtype` would
    pick (or ``force_dtype``, for seeded negatives).  ``bits >= 32`` or a
    single client means no integer accumulator exists — trivially safe,
    recorded as an ``uncompressed`` proof so tables stay total.
    """
    import numpy as np

    from repro.core.quantization import FULL_PRECISION_BITS
    from repro.dist.collectives import code_bound, wire_dtype

    bits, n = int(comm_bits), max(int(n_clients), 1)
    if bits >= FULL_PRECISION_BITS or n == 1:
        return ({"kind": "uncompressed", "bits": bits, "n": n,
                 "dtype": "f32", "code_bound": 0, "worst_sum": 0,
                 "capacity": 0, "headroom_bits": 0, "ok": True,
                 "key": key, "cell": cell}, [])

    bound = code_bound(bits)
    worst = n * bound
    if force_dtype is None:
        try:
            dt = np.dtype(wire_dtype(bits, n))
        except ValueError as e:
            return ({"kind": "wire_accumulator", "bits": bits, "n": n,
                     "dtype": "none", "code_bound": bound, "worst_sum": worst,
                     "capacity": 0, "headroom_bits": 0, "ok": False,
                     "key": key, "cell": cell}, [Finding(
                         rule="overflow.wire_accumulator", severity="error",
                         message=f"no supported accumulator holds the code "
                                 f"sum: {e}", key=key, cell=cell)])
    else:
        dt = np.dtype(force_dtype)
    capacity = int(np.iinfo(dt).max)
    ok = worst <= capacity
    proof = {"kind": "wire_accumulator", "bits": bits, "n": n,
             "dtype": dt.name, "code_bound": bound, "worst_sum": worst,
             "capacity": capacity,
             "headroom_bits": headroom_bits(capacity, worst) if ok else 0,
             "ok": ok, "key": key, "cell": cell}
    findings = []
    if not ok:
        findings.append(Finding(
            rule="overflow.wire_accumulator", severity="error",
            message=(f"{n} clients x code_bound({bits}) = {worst} exceeds "
                     f"{dt.name} capacity {capacity}: the integer all-reduce "
                     "provably overflows"),
            key=key, cell=cell))
    return proof, findings


def check_error_budget(policy, n_clients: int, *, lam: float | None = None,
                       e2: float | None = None, d: int | None = None,
                       scale: float = 1.0, cell: str = ""):
    """(record, findings) certifying the policy against constraint (23).

    Three obligations, all against ``core/convergence.py`` closed forms:

    1. *model agreement* — the trainer's traced ``delta_for_clients``
       resolutions equal the optimizer's ``quant_noise`` deltas elementwise
       (the two implementations of ``s/(2^q - 1)`` must not drift);
    2. *instance feasibility* — the widest option in ``bit_options``
       satisfies the budget (otherwise GBD has no feasible point);
    3. *policy feasibility* — if the policy pins concrete weight bits, the
       implied ``sum_i delta_i^2`` fits the budget the orchestrator would
       hand the master problem.
    """
    import numpy as np

    from repro.core.convergence import (
        error_budget_bound,
        feasible_bits_budget,
        quant_noise,
    )
    from repro.core.fwq import delta_for_clients

    lam = _DEFAULT_LAMBDA if lam is None else float(lam)
    e2 = _DEFAULT_E2 if e2 is None else float(e2)
    d = _DEFAULT_MODEL_DIM if d is None else int(d)
    n = max(int(n_clients), 1)
    key = "policy.weights"

    budget = error_budget_bound(lam, e2, d, n)
    bits = policy.bits_vector(n)
    noise = quant_noise(bits, scale)
    sum_dsq = float(np.sum(noise ** 2))
    traced = np.asarray(delta_for_clients(bits, scale=scale), np.float64)
    agree = bool(np.allclose(traced, noise, rtol=1e-5, atol=1e-12))
    feasible = feasible_bits_budget(policy.bit_options, n, budget, scale)

    record = {"kind": "error_budget", "n": n, "lam": lam, "e2": e2, "d": d,
              "budget": budget, "sum_delta_sq": sum_dsq,
              "bits": [int(b) for b in bits], "model_agreement": agree,
              "max_bits_feasible": feasible, "ok": agree and feasible
              and sum_dsq <= budget, "key": key, "cell": cell}
    findings = []
    if not agree:
        findings.append(Finding(
            rule="precision.error_budget", severity="error",
            message=("trainer delta_for_clients disagrees with the "
                     "optimizer's quant_noise model: the executed graph and "
                     "GBD reason about different quantization error"),
            key=key, cell=cell))
    if not feasible:
        findings.append(Finding(
            rule="precision.error_budget", severity="error",
            message=(f"even max bits {max(policy.bit_options)} violates the "
                     f"budget sum delta^2 <= {budget:.3e}: the GBD instance "
                     "is infeasible (loosen lambda or shrink d)"),
            key=key, cell=cell))
    if sum_dsq > budget:
        findings.append(Finding(
            rule="precision.error_budget", severity="error",
            message=(f"policy bits {sorted(set(record['bits']))} imply "
                     f"sum delta^2 = {sum_dsq:.3e} > budget {budget:.3e} "
                     f"(lambda={lam:g}, e2={e2:g}, d={d}, N={n}): the "
                     "executed quantization error exceeds what the "
                     "convergence bound was optimized against"),
            key=key, cell=cell))
    return record, findings


def prove_spec(spec, *, rules=("overflow", "precision"), cell: str = ""):
    """All analytic proofs one RunSpec admits: (records, findings).

    ``overflow`` covers the comm role (train / fl-orchestrate) and, for
    ``fl-sim``, every option of the policy's bit lattice — the scheme grid
    re-quantizes at whichever width GBD picks per round, so each must hold.
    A ``precision_program`` option widens the obligation to the program's
    comm ENVELOPE (every wire width any schedule it emits can visit), so
    one green analyze run certifies the whole adaptive run, not just the
    base policy.  ``precision`` (the error budget) applies to the FL
    workloads, where the spec's options carry the constraint-(23)
    constants.
    """
    cell = cell or f"{spec.workload}:{spec.arch}"
    n = spec_n_clients(spec)
    policy = spec.precision
    records, findings = [], []

    if any(r.startswith("overflow") for r in rules):
        bit_cells = [("policy.comm", policy.comm)]
        if spec.workload == "fl-sim":
            bit_cells += [(f"policy.bit_options[{b}]", b)
                          for b in policy.bit_options]
        prog_opt = spec.opt("precision_program")
        if prog_opt is not None:
            from repro.api.program import build_program

            program = build_program(prog_opt)
            seen = {b for _, b in bit_cells}
            bit_cells += [(f"program.comm[{b}]", b)
                          for b in program.comm_envelope(policy)
                          if b not in seen]
        for key, bits in bit_cells:
            proof, fs = prove_wire_accumulator(bits, n, cell=cell, key=key)
            records.append(proof)
            findings.extend(fs)

    if (any(r.startswith("precision") for r in rules)
            and spec.workload in ("fl-sim", "fl-orchestrate")):
        rec, fs = check_error_budget(
            policy, n,
            lam=spec.opt("error_tolerance"), e2=spec.opt("e2"),
            d=spec.opt("model_dim_d"), cell=cell)
        records.append(rec)
        findings.extend(fs)
    return records, findings


# ---------------------------------------------------------------------------
# Overflow-margin table (EXPERIMENTS.md §analyze)
# ---------------------------------------------------------------------------


def overflow_margin_rows(preset_names=("grad-comm-wire",
                                       "fl-codesign-grid")) -> list[dict]:
    """One row per distinct proved accumulator margin, per preset.

    Deterministic in the presets alone (no store, no tracing), so the
    generated table never goes stale against old results.  Cells that
    prove the identical obligation (same bits / clients / dtype — e.g.
    every fl-codesign scheme shares one bit lattice) collapse into one
    row labeled by the first cell that carries it.
    """
    from repro.sweep.grid import get_preset

    rows, seen = [], set()
    for name in preset_names:
        for c in get_preset(name).cells():
            records, _ = prove_spec(c.spec, rules=("overflow",),
                                    cell=c.label)
            for r in records:
                sig = (name, r["bits"], r["n"], r["dtype"])
                if sig in seen:
                    continue
                seen.add(sig)
                rows.append({"sweep": name, "cell": c.label,
                             "bits": r["bits"], "n": r["n"],
                             "dtype": r["dtype"],
                             "worst_sum": r["worst_sum"],
                             "capacity": r["capacity"],
                             "headroom_bits": r["headroom_bits"],
                             "ok": r["ok"]})
    return rows


def overflow_margin_table(preset_names=("grad-comm-wire",
                                        "fl-codesign-grid")) -> str:
    """Markdown overflow-margin table for :func:`overflow_margin_rows`."""
    rows = overflow_margin_rows(preset_names)
    head = ("| sweep | cell | bits | clients | accumulator | worst sum "
            "| capacity | headroom | proved |")
    sep = "| --- | --- | --- | --- | --- | --- | --- | --- | --- |"
    out = [head, sep]
    for r in rows:
        uncompressed = r["dtype"] == "f32"
        dt = "exact f32 pmean" if uncompressed else r["dtype"]
        ws = "-" if uncompressed else f"{r['worst_sum']:,}"
        cap = "-" if uncompressed else f"{r['capacity']:,}"
        hr = "-" if uncompressed else f"{r['headroom_bits']}b"
        ok = "yes" if r["ok"] else "**NO**"
        out.append(f"| {r['sweep']} | {r['cell']} | {r['bits']} | {r['n']} "
                   f"| {dt} | {ws} | {cap} | {hr} | {ok} |")
    return "\n".join(out)
