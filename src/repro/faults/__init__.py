"""Deterministic fault injection + resilient round execution for FL runs."""

from repro.faults.executor import (
    TransmissionOutcome,
    UpdateFaults,
    gate_mask,
    inject_corruption,
    transmit_update,
)
from repro.faults.plan import FaultPlan, FaultSchedule, RoundFaults

__all__ = [
    "FaultPlan",
    "FaultSchedule",
    "RoundFaults",
    "TransmissionOutcome",
    "UpdateFaults",
    "gate_mask",
    "inject_corruption",
    "transmit_update",
]
