"""Resilient uplink execution: retransmission, corruption, aggregation gate.

This is the host-side half of the resilient round.  Everything here is plain
numpy on concrete values — the jitted training round never sees a fault, it
only sees the surviving cohort and (possibly) corrupted-then-gated updates.

Energy semantics (the point of the whole exercise): the paper's
``E^comm = alpha1 / B`` is the *lossless optimum* — one error-free pass over
the payload.  Under packet loss the device pays for every attempt, so the
billed energy is ``(total attempts / chunks) x`` the optimum.  Backoff waits
cost wall-clock latency (they count against the round deadline) but no
transmit energy: the radio is idle while waiting.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.faults.plan import FaultPlan


@dataclasses.dataclass(frozen=True)
class TransmissionOutcome:
    """What one client's uplink actually cost this round."""

    delivered: bool
    chunks: int             # payload chunks (1 error-free attempt each, ideally)
    attempts: int           # total transmission attempts across all chunks
    retransmissions: int    # attempts - chunks_attempted (pure waste)
    t_comm_s: float         # wall-clock on air + backoff waits
    e_comm_j: float         # billed transmit energy (every attempt pays)
    e_retx_j: float         # energy of the retransmitted attempts alone


def transmit_update(payload_bits: float, rate_bps: float, p_comm_w: float,
                    loss_prob: float, rng: np.random.Generator,
                    plan: FaultPlan, budget_s: float = math.inf,
                    ) -> TransmissionOutcome:
    """Push one quantized update uplink, chunk by chunk, retrying losses.

    Each chunk is attempted up to ``1 + plan.max_retries`` times; attempt k's
    failure waits ``backoff_base_s * 2^k`` before the retry.  Delivery fails
    if any chunk exhausts its retries or the cumulative wall-clock exceeds
    ``budget_s`` (the round deadline) — either way the energy already spent
    stays spent.
    """
    if rate_bps <= 0:
        return TransmissionOutcome(False, 0, 0, 0, 0.0, 0.0, 0.0)
    chunk_bits = plan.chunk_bytes * 8.0
    n_chunks = max(1, int(math.ceil(payload_bits / chunk_bits)))
    t_chunk = (payload_bits / n_chunks) / rate_bps
    e_chunk = p_comm_w * t_chunk

    t = 0.0
    e = 0.0
    attempts = 0
    retx = 0
    for _ in range(n_chunks):
        for attempt in range(1 + plan.max_retries):
            if t + t_chunk > budget_s:
                return TransmissionOutcome(False, n_chunks, attempts, retx,
                                           t, e, retx * e_chunk)
            attempts += 1
            t += t_chunk
            e += e_chunk
            if attempt > 0:
                retx += 1
            if loss_prob <= 0 or rng.random() >= loss_prob:
                break  # chunk through
            if attempt < plan.max_retries:
                t += plan.backoff_base_s * (2.0 ** attempt)
        else:
            # chunk exhausted its retries: the update is lost this round
            return TransmissionOutcome(False, n_chunks, attempts, retx,
                                       t, e, retx * e_chunk)
    return TransmissionOutcome(True, n_chunks, attempts, retx,
                               t, e, retx * e_chunk)


# ----------------------------------------------------------------------
# payload corruption + aggregation gate
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UpdateFaults:
    """Per-cohort-client corruption instructions handed to the simulator.

    ``kinds[i]`` is 0 (clean), 1 (NaN poisoning) or 2 (exponent-scale
    bit-flip); ``rngs[i]`` decides *where* in the flattened update the
    damage lands.  ``gate_factor`` parameterizes the aggregation gate.
    """

    kinds: np.ndarray                     # (cohort,) int
    rngs: tuple                           # (cohort,) np.random.Generator
    gate_factor: float = 50.0

    @property
    def any_corrupt(self) -> bool:
        return bool((self.kinds > 0).any())


def inject_corruption(flat: np.ndarray, kind: int,
                      rng: np.random.Generator) -> np.ndarray:
    """Damage ~1% (at least 1 entry) of a flattened update.

    kind 1: NaN poisoning (torn write / failed decode).
    kind 2: exponent bit-flip — entries scaled by 2^106, the effect of
    flipping a high exponent bit in an f32.  Both are guaranteed detectable:
    kind 1 trips the finite check, kind 2 the norm bound (any nonzero entry
    at 2^106 dwarfs a trained gradient's norm by many orders of magnitude).
    """
    if kind == 0:
        return flat
    out = np.array(flat, copy=True)
    n = out.size
    k = max(1, n // 100)
    idx = rng.choice(n, size=k, replace=False)
    if kind == 1:
        out[idx] = np.nan
    else:
        out[idx] = out[idx] * (2.0 ** 106) + 2.0 ** 40
    return out


def gate_mask(norms_sq: np.ndarray, finite: np.ndarray,
              factor: float) -> np.ndarray:
    """Accept mask over cohort updates: finite AND within the norm bound.

    The bound is relative — ``factor x median`` of the *finite* survivors'
    update norms — so it self-calibrates as gradients shrink over training
    instead of hard-coding a scale.  With no finite survivor the mask is all
    False and the caller must skip aggregation for the round.
    """
    finite = np.asarray(finite, dtype=bool)
    norms_sq = np.asarray(norms_sq, dtype=np.float64)
    accept = finite.copy()
    if not accept.any():
        return accept
    med = float(np.median(np.sqrt(norms_sq[accept])))
    if med > 0 and np.isfinite(med):
        accept &= np.sqrt(np.where(finite, norms_sq, np.inf)) <= factor * med
    return accept
