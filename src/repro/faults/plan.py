"""Deterministic fault planning: (seed, round, client) -> what breaks.

The paper's setting is FL over *unreliable* mobile devices on a fading
uplink; this module is the seeded source of truth for everything that goes
wrong in a simulated deployment.  A :class:`FaultPlan` declares fault
*intensities* (probabilities + magnitudes); a :class:`FaultSchedule` turns a
plan plus a seed into concrete per-round realizations.

Determinism is the design contract: every draw is keyed by
``(seed, salt, round[, client])`` through ``np.random.default_rng`` — never
by call order or wall clock — so

* the same ``RunSpec`` seed produces the identical schedule, and
* a run killed at round *k* and resumed replays rounds ``k..R`` against the
  exact fault realizations the uninterrupted run would have seen (the
  bitwise-resume property ``tests/test_faults.py`` pins).

Fault taxonomy (all per client per round unless noted):

* **mid-round dropout** — the client computes its update, then vanishes
  before upload (battery death, app backgrounded).  Compute energy is spent;
  nothing is delivered.
* **channel fade**   — a deep fade attenuates the gain by
  ``fade_depth_db`` (scaled by a seeded draw in [0.5, 1.5)), cutting the
  achievable rate for the whole round; the drift can trip the
  orchestrator's warm-started GBD re-solve.
* **packet loss**    — each uplink payload chunk is lost i.i.d. with
  ``packet_loss`` probability per transmission *attempt*; lost chunks are
  retransmitted with exponential backoff and every attempt is billed real
  transmission energy (:mod:`repro.faults.executor`).
* **compute slowdown** — thermal throttling: ``T^comp`` multiplied by
  ``slowdown_factor`` (can push the client past the round deadline).
* **corrupted update** — the payload arrives but its contents are damaged:
  kind 1 poisons values with NaN, kind 2 is an exponent-scale bit-flip
  (entries blown up by 2^106).  Both are *detectable by construction* by the
  aggregation gate's finite-check + norm bound.
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: rng salts: one stream per fault family, never shared
_SALT_ROUND = 0xFA17
_SALT_CHUNK = 0xC4A7
_SALT_CORRUPT = 0xB17F


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Fault intensities + retry policy (JSON round-trip, sweep-hashable)."""

    dropout_prob: float = 0.0       # mid-round client loss (post-compute)
    fade_prob: float = 0.0          # deep-fade event probability
    fade_depth_db: float = 12.0     # nominal fade attenuation
    packet_loss: float = 0.0        # per-chunk per-attempt loss probability
    chunk_bytes: float = 64e3       # payload chunking for retransmission
    slowdown_prob: float = 0.0      # compute-throttling probability
    slowdown_factor: float = 2.5    # T^comp multiplier when throttled
    corrupt_prob: float = 0.0       # damaged-payload probability
    corrupt_nan_frac: float = 0.5   # P(kind=NaN | corrupt); rest bit-flip
    max_retries: int = 4            # extra attempts per chunk before giving up
    backoff_base_s: float = 0.01    # backoff after attempt k waits base*2^k
    gate_norm_factor: float = 50.0  # norm bound = factor * median survivor norm

    def __post_init__(self):
        for f in ("dropout_prob", "fade_prob", "packet_loss",
                  "slowdown_prob", "corrupt_prob", "corrupt_nan_frac"):
            p = getattr(self, f)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f} must be a probability, got {p}")
        if self.packet_loss >= 1.0:
            raise ValueError("packet_loss=1.0 can never deliver; use <1")
        if self.chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    @property
    def active(self) -> bool:
        """Whether any fault family can actually fire."""
        return any(p > 0 for p in (self.dropout_prob, self.fade_prob,
                                   self.packet_loss, self.slowdown_prob,
                                   self.corrupt_prob))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown FaultPlan fields {sorted(bad)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    def schedule(self, seed: int, n_devices: int) -> "FaultSchedule":
        return FaultSchedule(plan=self, seed=int(seed),
                             n_devices=int(n_devices))


@dataclasses.dataclass(frozen=True)
class RoundFaults:
    """One round's realization over the whole fleet (index = device id)."""

    drop: np.ndarray          # (n,) bool — mid-round dropout
    fade_db: np.ndarray       # (n,) float — gain attenuation (0 = clear)
    slow: np.ndarray          # (n,) float — T^comp multiplier (1 = nominal)
    corrupt_kind: np.ndarray  # (n,) int — 0 clean, 1 NaN, 2 bit-flip
    loss_prob: float          # per-chunk per-attempt packet loss

    @property
    def fade_lin(self) -> np.ndarray:
        """Multiplicative linear gain factor of the fade (<= 1)."""
        return 10.0 ** (-self.fade_db / 10.0)

    @property
    def any_fault(self) -> bool:
        return bool(self.drop.any() or (self.fade_db > 0).any()
                    or (self.slow > 1).any() or (self.corrupt_kind > 0).any()
                    or self.loss_prob > 0)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Seeded realization stream: pure function of (plan, seed, round)."""

    plan: FaultPlan
    seed: int
    n_devices: int

    def round_faults(self, round_idx: int) -> RoundFaults:
        p, n = self.plan, self.n_devices
        rng = np.random.default_rng((self.seed, _SALT_ROUND, int(round_idx)))
        # one fixed-size draw per family, in a fixed order, so each family's
        # realization is independent of the other probabilities
        u_drop = rng.random(n)
        u_fade = rng.random(n)
        depth = rng.random(n)
        u_slow = rng.random(n)
        u_corr = rng.random(n)
        u_kind = rng.random(n)
        fade_db = np.where(u_fade < p.fade_prob,
                           p.fade_depth_db * (0.5 + depth), 0.0)
        corrupt = u_corr < p.corrupt_prob
        kind = np.where(corrupt,
                        np.where(u_kind < p.corrupt_nan_frac, 1, 2), 0)
        return RoundFaults(
            drop=u_drop < p.dropout_prob,
            fade_db=fade_db,
            slow=np.where(u_slow < p.slowdown_prob, p.slowdown_factor, 1.0),
            corrupt_kind=kind.astype(np.int64),
            loss_prob=float(p.packet_loss),
        )

    def chunk_rng(self, round_idx: int, device: int) -> np.random.Generator:
        """Per-(round, device) stream for packet-loss draws: the number of
        draws a client consumes (retries vary!) never perturbs anyone else."""
        return np.random.default_rng(
            (self.seed, _SALT_CHUNK, int(round_idx), int(device)))

    def corrupt_rng(self, round_idx: int, device: int) -> np.random.Generator:
        """Per-(round, device) stream for payload-corruption placement."""
        return np.random.default_rng(
            (self.seed, _SALT_CORRUPT, int(round_idx), int(device)))
