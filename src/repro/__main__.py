"""``python -m repro`` — one dispatcher for every workload CLI.

Usage::

    python -m repro train  --arch yi-6b --smoke --rounds 5
    python -m repro serve  --arch yi-6b --smoke --steps 16
    python -m repro dryrun --arch mamba2-780m --shape train_4k
    python -m repro fl     --model mobilenet --rounds 10
    python -m repro sweep  run roofline-all-archs
    python -m repro analyze --preset ci-tiny --fail-on error

Each subcommand is a thin CLI over :class:`repro.api.Session` (``sweep``
drives grids of them through :mod:`repro.sweep`); the installed console
scripts (``repro-train``, ``repro-serve``, ``repro-dryrun``, ``repro-fl``,
``repro-sweep``) map to the same entry points.
"""

from __future__ import annotations

import sys

_COMMANDS = {
    "train": "repro.launch.train",
    "serve": "repro.launch.serve",
    "dryrun": "repro.launch.dryrun",
    "fl": "repro.launch.fl",
    "sweep": "repro.sweep.cli",
    "analyze": "repro.analyze.cli",
}


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd not in _COMMANDS:
        print(f"unknown command {cmd!r}; options: {', '.join(_COMMANDS)}",
              file=sys.stderr)
        return 2
    # import late: repro.launch.dryrun must set XLA_FLAGS before jax
    # initializes its backend, and the other CLIs defer jax themselves.
    import importlib

    mod = importlib.import_module(_COMMANDS[cmd])
    rc = mod.main(rest)
    # launcher mains return run artifacts (history dicts); only int is a code
    return rc if isinstance(rc, int) else 0


if __name__ == "__main__":
    sys.exit(main())
