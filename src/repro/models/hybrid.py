"""Jamba-style hybrid: Mamba2 + attention (1:`attn_period`) with periodic MoE.

Layer pattern (period = ``attn_period``, default 8):
    sublayer 0:        attention mixer
    sublayers 1..p-1:  mamba2 (SSD) mixers
    ffn of sublayer j: MoE when the *global* layer index hits ``moe_period``,
                       dense MLP otherwise (jamba: every 2nd layer is MoE).

The outer ``lax.scan`` runs over periods (72 layers -> 9 iterations); the 8
sublayers inside a period are unrolled, which keeps the HLO small while
allowing the heterogeneous structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    decode_self_attention, init_attention, init_kv_cache,
    init_paged_kv_cache, self_attention,
)
from repro.models.common import ParamCtx, init_dense, key_iter
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import (
    SSMDims, init_ssm, init_ssm_cache, ssm_block, ssm_decode_step,
)
from repro.models.transformer import attn_dims, moe_dims, padded_vocab_local, _stack


def ssm_dims(cfg: ModelConfig, tp: int) -> SSMDims:
    return SSMDims(
        d_model=cfg.d_model, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
        expand=cfg.ssm_expand, conv_width=cfg.ssm_conv_width,
        chunk=cfg.ssm_chunk, tp=tp,
    )


def _layer_kinds(cfg: ModelConfig):
    """Per-sublayer (mixer, ffn) kinds within one period."""
    p = cfg.attn_period
    kinds = []
    for j in range(p):
        mixer = "attn" if j == 0 else "ssm"
        ffn = "moe" if (j % max(cfg.moe_period, 1)) == 0 and cfg.n_experts else "mlp"
        kinds.append((mixer, ffn))
    return kinds


def init_hybrid(cfg: ModelConfig, key, tp: int, dtype=jnp.float32) -> dict:
    assert cfg.n_layers % cfg.attn_period == 0
    n_periods = cfg.n_layers // cfg.attn_period
    ks = key_iter(key)
    ad = attn_dims(cfg, tp)
    sd = ssm_dims(cfg, tp)
    md = moe_dims(cfg, tp)
    kinds = _layer_kinds(cfg)
    vl = padded_vocab_local(cfg, tp)

    def one_period(_):
        subs = []
        for mixer, ffn in kinds:
            sp = {"ln1": L.init_rmsnorm(cfg.d_model), "ln2": L.init_rmsnorm(cfg.d_model)}
            sp["mixer"] = (init_attention(ks, ad, dtype) if mixer == "attn"
                           else init_ssm(ks, sd, dtype))
            sp["ffn"] = (init_moe(ks, md, dtype) if ffn == "moe"
                         else L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype))
            subs.append(sp)
        return {f"sub{j}": s for j, s in enumerate(subs)}

    return {
        "embed": {"table": L.init_vocab_embed(next(ks), vl, cfg.d_model, dtype)},
        "periods": _stack([one_period(i) for i in range(n_periods)]),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"w": init_dense(next(ks), cfg.d_model, vl, dtype)},
    }


def _period_fn(cfg: ModelConfig, pc: ParamCtx, tp: int, attn_impl: str):
    ad = attn_dims(cfg, tp)
    sd = ssm_dims(cfg, tp)
    md = moe_dims(cfg, tp)
    kinds = _layer_kinds(cfg)

    def period(x, pp):
        for j, (mixer, ffn) in enumerate(kinds):
            sp = pp[f"sub{j}"]
            h = L.sp_gather(pc, L.rmsnorm(pc, f"sub{j}/ln1", sp["ln1"], x, cfg.norm_eps))
            if mixer == "attn":
                a, _ = self_attention(pc, f"sub{j}/attn", sp["mixer"], h, ad,
                                      impl=attn_impl)
            else:
                a = ssm_block(pc, f"sub{j}/ssm", sp["mixer"], h, sd)
            x = x + a
            h = L.sp_gather(pc, L.rmsnorm(pc, f"sub{j}/ln2", sp["ln2"], x, cfg.norm_eps))
            if ffn == "moe":
                m, _ = moe_block(pc, f"sub{j}/moe", sp["ffn"], h, md)
            else:
                m = L.mlp(pc, f"sub{j}/mlp", sp["ffn"], h, cfg.mlp_act)
            x = x + m
        return x, ()

    return period


def forward(cfg: ModelConfig, pc: ParamCtx, params, tokens, *, attn_impl="auto", return_hidden=False):
    tp = pc.ctx.tp
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)
    period = _period_fn(cfg, pc, tp, attn_impl)
    if cfg.remat:
        period = jax.checkpoint(period, prevent_cse=False)
    x, _ = jax.lax.scan(period, x, params["periods"])
    x = L.sp_gather(pc, L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps))
    if return_hidden:
        return x
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)


def train_loss(cfg: ModelConfig, pc: ParamCtx, params, batch, *, attn_impl="auto"):
    x = forward(cfg, pc, params, batch["tokens"], attn_impl=attn_impl,
                return_hidden=True)
    vl = padded_vocab_local(cfg, pc.ctx.tp)
    loss = L.fused_vocab_xent(pc, "unembed/w", params["unembed"]["w"], x,
                              batch["labels"], vl)
    return loss, {}


# ---------------------------------------------------------------------------
# Decode: attention sublayers carry a KV cache, mamba sublayers an SSM state.
# ---------------------------------------------------------------------------


def init_hybrid_caches(cfg: ModelConfig, batch: int, s_max: int, tp: int,
                       dtype=jnp.bfloat16, *, page_size=None, pool_pages=None):
    n_periods = cfg.n_layers // cfg.attn_period
    ad = attn_dims(cfg, tp)
    sd = ssm_dims(cfg, tp)
    kinds = _layer_kinds(cfg)
    caches = {}
    for j, (mixer, _ffn) in enumerate(kinds):
        if mixer == "attn":
            one = (init_paged_kv_cache(batch, s_max, ad, dtype,
                                       page_size=page_size,
                                       pool_pages=pool_pages)
                   if page_size else init_kv_cache(batch, s_max, ad, dtype))
        else:
            one = init_ssm_cache(batch, sd, dtype)
        caches[f"sub{j}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    return caches


def prefill(cfg: ModelConfig, pc: ParamCtx, params, tokens, caches,
            *, attn_impl="auto", prompt_lens=None):
    """Hybrid prefill: scan of decode steps over the prompt — the SSM
    sublayers advance their constant-size state and the attention sublayers
    fill their KV caches (per-sequence lengths end at each slot's own
    prompt length under bucketed prompts).
    tokens: (B, S_p).  Returns (last-position local logits, caches)."""
    del attn_impl  # decode path drives both mixer kinds
    from repro.models.ssm_lm import prefill_by_decode

    return prefill_by_decode(
        lambda t, c: decode_step(cfg, pc, params, t, c),
        tokens, caches, prompt_lens)


def decode_step(cfg: ModelConfig, pc: ParamCtx, params, token, caches,
                *, attn_impl="auto"):
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    sd = ssm_dims(cfg, tp)
    md = moe_dims(cfg, tp)
    kinds = _layer_kinds(cfg)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], token, vl)
    x = x.astype(pc.compute_dtype)
    decode_impl = "flash" if attn_impl == "flash" else "ref"

    def period(x, scanned):
        pp, pcache = scanned
        new_caches = {}
        for j, (mixer, ffn) in enumerate(kinds):
            sp = pp[f"sub{j}"]
            h = L.rmsnorm(pc, f"sub{j}/ln1", sp["ln1"], x, cfg.norm_eps)
            if mixer == "attn":
                a, nc = decode_self_attention(pc, f"sub{j}/attn", sp["mixer"], h,
                                              pcache[f"sub{j}"], ad,
                                              impl=decode_impl)
            else:
                a, nc = ssm_decode_step(pc, f"sub{j}/ssm", sp["mixer"], h,
                                        pcache[f"sub{j}"], sd)
            new_caches[f"sub{j}"] = nc
            x = x + a
            h = L.rmsnorm(pc, f"sub{j}/ln2", sp["ln2"], x, cfg.norm_eps)
            if ffn == "moe":
                m, _ = moe_block(pc, f"sub{j}/moe", sp["ffn"], h, md)
            else:
                m = L.mlp(pc, f"sub{j}/mlp", sp["ffn"], h, cfg.mlp_act)
            x = x + m
        return x, new_caches

    x, new_caches = jax.lax.scan(period, x, (params["periods"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    logits = L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)
    return logits, new_caches
