"""Pure-SSM language model (mamba2 class): norm -> SSD mixer -> residual.

No attention, no per-token KV growth: decode state is O(1) in context length,
which is why this family runs the 500k-context cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import ParamCtx, init_dense, key_iter
from repro.models.hybrid import ssm_dims
from repro.models.ssm import init_ssm, init_ssm_cache, ssm_block, ssm_decode_step
from repro.models.transformer import padded_vocab_local, _stack


def init_ssm_lm(cfg: ModelConfig, key, tp: int, dtype=jnp.float32) -> dict:
    ks = key_iter(key)
    sd = ssm_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)

    def one_block(_):
        return {"ln": L.init_rmsnorm(cfg.d_model), "ssm": init_ssm(ks, sd, dtype)}

    return {
        "embed": {"table": L.init_vocab_embed(next(ks), vl, cfg.d_model, dtype)},
        "blocks": _stack([one_block(i) for i in range(cfg.n_layers)]),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"w": init_dense(next(ks), cfg.d_model, vl, dtype)},
    }


def forward(cfg: ModelConfig, pc: ParamCtx, params, tokens, *, attn_impl="auto", return_hidden=False):
    tp = pc.ctx.tp
    sd = ssm_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)

    def block(x, lp):
        h = L.sp_gather(pc, L.rmsnorm(pc, "blocks/ln", lp["ln"], x, cfg.norm_eps))
        return x + ssm_block(pc, "blocks/ssm", lp["ssm"], h, sd), ()

    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = L.sp_gather(pc, L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps))
    if return_hidden:
        return x
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)


def train_loss(cfg: ModelConfig, pc: ParamCtx, params, batch, *, attn_impl="auto"):
    x = forward(cfg, pc, params, batch["tokens"], attn_impl=attn_impl,
                return_hidden=True)
    vl = padded_vocab_local(cfg, pc.ctx.tp)
    loss = L.fused_vocab_xent(pc, "unembed/w", params["unembed"]["w"], x,
                              batch["labels"], vl)
    return loss, {}


def init_ssm_lm_caches(cfg: ModelConfig, batch: int, tp: int, dtype=jnp.bfloat16):
    sd = ssm_dims(cfg, tp)
    one = init_ssm_cache(batch, sd, dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def prefill(cfg: ModelConfig, pc: ParamCtx, params, tokens, caches,
            *, attn_impl="auto", prompt_lens=None):
    """SSM prefill: run the recurrence over the prompt (scan of decode steps
    — the state update IS the prefill for a constant-state mixer).
    tokens: (B, S_p).  Returns (last-position local logits, caches).

    ``prompt_lens`` (B,): per-slot true lengths under bucketed (right-padded)
    prompts — each slot's state stops advancing at its own length, so padding
    never leaks into the recurrence."""
    del attn_impl  # no attention in this family
    return prefill_by_decode(
        lambda t, c: decode_step(cfg, pc, params, t, c),
        tokens, caches, prompt_lens)


def prefill_by_decode(step_fn, tokens, caches, prompt_lens=None):
    """Shared scan-of-decode-steps prefill for recurrent families.

    ``step_fn(token (B,1), caches) -> (logits (B,1,Vl), caches)``.  Without
    ``prompt_lens`` this is a plain scan; with it, every cache leaf advances
    per-slot only while the step index is inside that slot's prompt
    (:func:`repro.models.attention.merge_slot_caches` — page-granular for
    paged KV pools), and the returned logits are each slot's own
    last-position logits.
    """
    from repro.models.attention import merge_slot_caches

    if prompt_lens is None:
        def step(caches, t):
            logits, caches = step_fn(t[:, None], caches)
            return caches, logits

        caches, logits = jax.lax.scan(step, caches, jnp.moveaxis(tokens, 1, 0))
        return logits[-1], caches

    plens = prompt_lens.astype(jnp.int32)

    def step(carry, it):
        caches, last = carry
        i, t = it
        logits, new = step_fn(t[:, None], caches)
        caches = merge_slot_caches(caches, new, i < plens)
        last = jnp.where((i == plens - 1)[:, None, None], logits, last)
        return (caches, last), ()

    S_p = tokens.shape[1]
    probe = jax.eval_shape(step_fn, tokens[:, :1], caches)[0]
    last0 = jnp.zeros(probe.shape, probe.dtype)
    (caches, last), _ = jax.lax.scan(
        step, (caches, last0),
        (jnp.arange(S_p), jnp.moveaxis(tokens, 1, 0)))
    return last, caches


def decode_step(cfg: ModelConfig, pc: ParamCtx, params, token, caches):
    tp = pc.ctx.tp
    sd = ssm_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], token, vl)
    x = x.astype(pc.compute_dtype)

    def block(x, scanned):
        lp, cache = scanned
        h = L.rmsnorm(pc, "blocks/ln", lp["ln"], x, cfg.norm_eps)
        a, nc = ssm_decode_step(pc, "blocks/ssm", lp["ssm"], h, cache, sd)
        return x + a, nc

    x, new_caches = jax.lax.scan(block, x, (params["blocks"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x), new_caches
