"""Family dispatch facade: one uniform surface over the model zoo.

``build_model(cfg)`` returns a :class:`Model` with

* ``init(key, tp)``                      -> local-TP params (pre-FSDP)
* ``train_loss(pc, params, batch)``      -> (scalar, aux)
* ``decode_step(pc, params, batch, caches)`` -> (logits, new_caches)
* ``init_caches(batch, s_max, tp)``      -> decode caches
* ``train_batch_spec(shape)`` / ``decode_batch_spec(shape)`` -> ShapeDtypeStructs
  (the ``input_specs()`` of the assignment: weak-type-correct stand-ins, no
  device allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer, vlm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable
    forward: Callable            # (pc, params, batch, **kw) -> local logits
    decode_step: Callable
    init_caches: Callable
    train_batch_spec: Callable
    decode_batch_spec: Callable
    # serving prefill: (pc, params, batch, caches, **kw) ->
    # (last-position local logits | None, filled caches).  None logits mean
    # "seed decode with BOS" (enc-dec: the prompt is the source modality).
    prefill: Callable = None
    # (b, s_prompt, s_max) -> ShapeDtypeStruct tree for the prefill batch
    prefill_batch_spec: Callable = None
    # whether init_caches understands page_size/pool_pages (families whose
    # decode state grows per token; SSM state is O(1) — nothing to page)
    supports_paged_kv: bool = False


def _tokens_spec(b, s):
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }


def _token_prefill_spec(b, s_prompt, s_max):
    return {"tokens": jax.ShapeDtypeStruct((b, s_prompt), jnp.int32)}


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe"):
        return Model(
            cfg=cfg,
            init=lambda key, tp: transformer.init_lm(cfg, key, tp),
            train_loss=lambda pc, p, b, **kw: transformer.train_loss(cfg, pc, p, b, **kw),
            forward=lambda pc, p, b, **kw: transformer.forward(cfg, pc, p, b["tokens"], **kw),
            decode_step=lambda pc, p, b, caches, **kw: transformer.decode_step(
                cfg, pc, p, b["token"], caches, **kw),
            init_caches=lambda batch, s_max, tp, dtype=jnp.bfloat16, **kw:
                transformer.init_caches(cfg, batch, s_max, tp, dtype, **kw),
            supports_paged_kv=True,
            train_batch_spec=lambda b, s: _tokens_spec(b, s),
            decode_batch_spec=lambda b, s: {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
            prefill=lambda pc, p, b, caches, **kw: transformer.prefill(
                cfg, pc, p, b["tokens"], caches, **kw),
            prefill_batch_spec=_token_prefill_spec,
        )

    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key, tp: ssm_lm.init_ssm_lm(cfg, key, tp),
            train_loss=lambda pc, p, b, **kw: ssm_lm.train_loss(cfg, pc, p, b, **kw),
            forward=lambda pc, p, b, **kw: ssm_lm.forward(cfg, pc, p, b["tokens"], **kw),
            decode_step=lambda pc, p, b, caches, **kw: ssm_lm.decode_step(
                cfg, pc, p, b["token"], caches),
            # constant-state mixer: nothing grows per token, nothing to page
            init_caches=lambda batch, s_max, tp, dtype=jnp.bfloat16, **kw:
                ssm_lm.init_ssm_lm_caches(cfg, batch, tp, dtype),
            train_batch_spec=lambda b, s: _tokens_spec(b, s),
            decode_batch_spec=lambda b, s: {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
            prefill=lambda pc, p, b, caches, **kw: ssm_lm.prefill(
                cfg, pc, p, b["tokens"], caches, **kw),
            prefill_batch_spec=_token_prefill_spec,
        )

    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key, tp: hybrid.init_hybrid(cfg, key, tp),
            train_loss=lambda pc, p, b, **kw: hybrid.train_loss(cfg, pc, p, b, **kw),
            forward=lambda pc, p, b, **kw: hybrid.forward(cfg, pc, p, b["tokens"], **kw),
            decode_step=lambda pc, p, b, caches, **kw: hybrid.decode_step(
                cfg, pc, p, b["token"], caches, **kw),
            init_caches=lambda batch, s_max, tp, dtype=jnp.bfloat16, **kw:
                hybrid.init_hybrid_caches(cfg, batch, s_max, tp, dtype, **kw),
            supports_paged_kv=True,
            train_batch_spec=lambda b, s: _tokens_spec(b, s),
            decode_batch_spec=lambda b, s: {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
            prefill=lambda pc, p, b, caches, **kw: hybrid.prefill(
                cfg, pc, p, b["tokens"], caches, **kw),
            prefill_batch_spec=_token_prefill_spec,
        )

    if fam == "encdec":
        d_front = cfg.d_frontend or cfg.d_model

        def train_spec(b, s):
            return {
                "frames": jax.ShapeDtypeStruct((b, s, d_front), jnp.float32),
                **_tokens_spec(b, s),
            }

        def decode_spec(b, s):
            # encoder memory is consumed at prefill (cross K/V cached)
            return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

        return Model(
            cfg=cfg,
            init=lambda key, tp: encdec.init_encdec(cfg, key, tp),
            train_loss=lambda pc, p, b, **kw: encdec.train_loss(cfg, pc, p, b, **kw),
            forward=lambda pc, p, b, **kw: encdec.decode_train(
                cfg, pc, p, encdec.encode(cfg, pc, p, b["frames"], **kw),
                b["tokens"], **kw),
            decode_step=lambda pc, p, b, caches, **kw: encdec.decode_step(
                cfg, pc, p, b["token"], caches, **kw),
            init_caches=lambda batch, s_max, tp, dtype=jnp.bfloat16, **kw:
                encdec.init_decoder_caches(cfg, batch, s_max, tp, dtype, **kw),
            supports_paged_kv=True,
            train_batch_spec=train_spec,
            decode_batch_spec=decode_spec,
            prefill=lambda pc, p, b, caches, **kw: encdec.prefill(
                cfg, pc, p, b["frames"], caches, **kw),
            # the cross caches are sized by s_max, so the source spans it
            prefill_batch_spec=lambda b, s_prompt, s_max: {
                "frames": jax.ShapeDtypeStruct((b, s_max, d_front), jnp.float32)},
        )

    if fam == "vlm":
        d_front = cfg.d_frontend or cfg.d_model
        n_img = cfg.n_image_tokens or 1601

        def train_spec(b, s):
            return {
                "images": jax.ShapeDtypeStruct((b, n_img, d_front), jnp.float32),
                **_tokens_spec(b, s),
            }

        def decode_spec(b, s):
            # images are consumed at prefill (cross K/V cached); decode takes
            # only the token stream
            return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

        return Model(
            cfg=cfg,
            init=lambda key, tp: vlm.init_vlm(cfg, key, tp),
            train_loss=lambda pc, p, b, **kw: vlm.train_loss(cfg, pc, p, b, **kw),
            forward=lambda pc, p, b, **kw: vlm.forward(cfg, pc, p, b["tokens"], b["images"], **kw),
            decode_step=lambda pc, p, b, caches, **kw: vlm.decode_step(
                cfg, pc, p, b["token"], caches, **kw),
            init_caches=lambda batch, s_max, tp, dtype=jnp.bfloat16, **kw:
                vlm.init_vlm_caches(cfg, batch, s_max, tp, dtype, **kw),
            supports_paged_kv=True,
            train_batch_spec=train_spec,
            decode_batch_spec=decode_spec,
            prefill=lambda pc, p, b, caches, **kw: vlm.prefill(
                cfg, pc, p, b["tokens"], b["images"], caches, **kw),
            prefill_batch_spec=lambda b, s_prompt, s_max: {
                "tokens": jax.ShapeDtypeStruct((b, s_prompt), jnp.int32),
                "images": jax.ShapeDtypeStruct((b, n_img, d_front), jnp.float32)},
        )

    raise ValueError(f"unknown family {fam}")
