"""Mamba2 (SSD — state-space duality) mixer, TP-sharded over heads.

The SSD computation follows Dao & Gu 2024: the selective SSM
``s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t``, ``y_t = C_t s_t + D x_t``
is evaluated in chunks: a quadratic attention-like *intra-chunk* term plus a
linear *inter-chunk* recurrence over chunk summary states — O(S·Q) work and
O(S) memory for chunk size Q, sub-quadratic end to end (this is why the SSM
archs run the 500k-context cell).

TP: heads are sharded over the model axis (x/z/dt projections column-parallel,
out-projection row-parallel + psum).  B/C projections use a single group
(mamba2 default) and stay replicated.  Recurrence-critical params
(``a_log``, ``dt_bias``, ``d_skip``) are exempt from quantization
(DESIGN.md §6) — mirroring the paper's own high-precision exemptions.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamCtx, init_dense
from repro.models.layers import dense, sp_out


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int
    head_dim: int
    expand: int
    conv_width: int
    chunk: int
    tp: int

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def heads_local(self) -> int:
        assert self.n_heads % self.tp == 0
        return self.n_heads // self.tp

    @property
    def d_inner_local(self) -> int:
        return self.heads_local * self.head_dim


def init_ssm(keys, dims: SSMDims, dtype=jnp.float32):
    d, dl, hl, n = dims.d_model, dims.d_inner_local, dims.heads_local, dims.d_state
    return {
        "wx": init_dense(next(keys), d, dl, dtype),
        "wz": init_dense(next(keys), d, dl, dtype),
        "w_bc": init_dense(next(keys), d, 2 * n, dtype),
        "w_dt": init_dense(next(keys), d, hl, dtype),
        "conv_x": (jax.random.normal(next(keys), (dims.conv_width, dl)) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(next(keys), (dims.conv_width, 2 * n)) * 0.1).astype(dtype),
        "a_log": jnp.zeros((hl,), jnp.float32),       # A = -exp(a_log): init -1
        "dt_bias": jnp.full((hl,), -2.0, jnp.float32),  # softplus ~= 0.12
        "d_skip": jnp.ones((hl,), jnp.float32),
        "wo": init_dense(next(keys), dl, d, dtype),
        "norm": jnp.zeros((dl,), jnp.float32),
    }


def _causal_depthwise_conv(x, kernel):
    """x: (B, S, C); kernel: (W, C).  Causal depthwise conv, no FLOP bloat."""
    W = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for w in range(W):
        out = out + pad[:, w : w + S, :] * kernel[w][None, None, :]
    return out


def _ssd_scan(xdt, la, Bm, Cm, chunk: int):
    """Chunked SSD.

    xdt: (B,S,H,P) inputs pre-scaled by dt; la: (B,S,H) log-decay (dt*A, <=0);
    Bm/Cm: (B,S,N).  Returns y: (B,S,H,P) and final state (B,H,N,P).
    """
    Bsz, S, H, P = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, "sequence must divide the SSD chunk"
    C = S // Q
    xdt = xdt.reshape(Bsz, C, Q, H, P)
    la = la.reshape(Bsz, C, Q, H)
    Bm = Bm.reshape(Bsz, C, Q, N)
    Cm = Cm.reshape(Bsz, C, Q, N)

    L = jnp.cumsum(la, axis=2)                       # within-chunk cum log decay
    Ltot = L[:, :, -1:, :]                           # (B,C,1,H)

    # intra-chunk (quadratic in Q only).  Looped over heads with lax.map so
    # the (B,C,Q,Q) score block is materialized for ONE head at a time —
    # without this the decay tensor is (B,C,Q,Q,H): gigabytes per layer for
    # the jamba-scale mixers.
    dotCB = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)    # shared across heads
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None]

    def one_head(args):
        Lh, xh_ = args                               # (B,C,Q), (B,C,Q,P)
        decay = jnp.exp(Lh[:, :, :, None] - Lh[:, :, None, :])
        att = dotCB * jnp.where(causal, decay, 0.0)
        return jnp.einsum("bcij,bcjp->bcip", att, xh_)

    Lh_all = jnp.moveaxis(L, -1, 0)                  # (H,B,C,Q)
    xdt_h = jnp.moveaxis(xdt, -2, 0)                 # (H,B,C,Q,P)
    y_intra = jnp.moveaxis(jax.lax.map(one_head, (Lh_all, xdt_h)), 0, -2)

    # chunk summary states: S_c = sum_j exp(Ltot - L_j) B_j (x dt)_j
    w_end = jnp.exp(Ltot - L)                        # (B,C,Q,H)
    Sc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bm, w_end, xdt)

    # inter-chunk recurrence over chunk states
    dc = jnp.exp(Ltot[:, :, 0, :])                   # (B,C,H) total chunk decay

    def step(R, inp):
        d, s = inp                                   # (B,H), (B,H,N,P)
        R_new = R * d[..., None, None] + s
        return R_new, R                              # emit state BEFORE chunk

    R0 = jnp.zeros((Bsz, H, N, P), xdt.dtype)
    Rlast, Rprev = jax.lax.scan(
        step,
        R0,
        (jnp.moveaxis(dc, 1, 0), jnp.moveaxis(Sc, 1, 0)),
    )
    Rprev = jnp.moveaxis(Rprev, 0, 1)                # (B,C,H,N,P)

    w_start = jnp.exp(L)                             # decay from chunk start
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cm, w_start, Rprev)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, Rlast


def _gated_norm(pc: ParamCtx, path, scale, y, z, eps=1e-6):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = yf * jax.lax.rsqrt(var + eps)
    return (yn * (1.0 + pc.use_small(path, scale))).astype(y.dtype)


def ssm_block(pc: ParamCtx, path: str, p, x, dims: SSMDims):
    """Training/prefill mixer.  x: (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    hl, P, N = dims.heads_local, dims.head_dim, dims.d_state

    xr = dense(pc, f"{path}/wx", p["wx"], x)         # (B,S,dl)
    z = dense(pc, f"{path}/wz", p["wz"], x)
    bc = dense(pc, f"{path}/w_bc", p["w_bc"], x)     # replicated
    dt = dense(pc, f"{path}/w_dt", p["w_dt"], x)     # (B,S,hl)

    xr = jax.nn.silu(_causal_depthwise_conv(xr, pc.use_small(f"{path}/conv_x", p["conv_x"])))
    bc = jax.nn.silu(_causal_depthwise_conv(bc, pc.use_small(f"{path}/conv_bc", p["conv_bc"])))
    Bm, Cm = bc[..., :N], bc[..., N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + pc.use_small(f"{path}/dt_bias", p["dt_bias"]).astype(jnp.float32))
    A = -jnp.exp(pc.use_small(f"{path}/a_log", p["a_log"]).astype(jnp.float32))
    la = dt * A[None, None, :]                       # (B,S,hl), <= 0

    xh = xr.reshape(B, S, hl, P)
    xdt = xh * dt[..., None].astype(xh.dtype)
    y, _ = _ssd_scan(xdt, la.astype(xh.dtype), Bm, Cm, dims.chunk)
    y = y + xh * pc.use_small(f"{path}/d_skip", p["d_skip"]).astype(xh.dtype)[None, None, :, None]

    y = y.reshape(B, S, dims.d_inner_local)
    y = _gated_norm(pc, f"{path}/norm", p["norm"], y, z)
    out = dense(pc, f"{path}/wo", p["wo"], y)
    return sp_out(pc, out)


# ---------------------------------------------------------------------------
# Decode path: O(1) per token — constant state, no KV cache growth.
# ---------------------------------------------------------------------------


class SSMCache(NamedTuple):
    state: jnp.ndarray        # (B, H_local, N, P)
    conv_x: jnp.ndarray       # (B, W-1, d_inner_local)
    conv_bc: jnp.ndarray      # (B, W-1, 2N)


def init_ssm_cache(batch: int, dims: SSMDims, dtype=jnp.bfloat16):
    return SSMCache(
        state=jnp.zeros((batch, dims.heads_local, dims.d_state, dims.head_dim), dtype),
        conv_x=jnp.zeros((batch, dims.conv_width - 1, dims.d_inner_local), dtype),
        conv_bc=jnp.zeros((batch, dims.conv_width - 1, 2 * dims.d_state), dtype),
    )


def ssm_decode_step(pc: ParamCtx, path: str, p, x, cache: SSMCache, dims: SSMDims):
    """x: (B, 1, D) -> (y, new_cache)."""
    B = x.shape[0]
    hl, P, N = dims.heads_local, dims.head_dim, dims.d_state

    xr = dense(pc, f"{path}/wx", p["wx"], x)
    z = dense(pc, f"{path}/wz", p["wz"], x)
    bc = dense(pc, f"{path}/w_bc", p["w_bc"], x)
    dt = dense(pc, f"{path}/w_dt", p["w_dt"], x)

    # rolling conv caches
    cx = jnp.concatenate([cache.conv_x, xr.astype(cache.conv_x.dtype)], axis=1)
    cb = jnp.concatenate([cache.conv_bc, bc.astype(cache.conv_bc.dtype)], axis=1)
    kx = pc.use_small(f"{path}/conv_x", p["conv_x"])
    kb = pc.use_small(f"{path}/conv_bc", p["conv_bc"])
    xr1 = jax.nn.silu(jnp.einsum("bwc,wc->bc", cx.astype(kx.dtype), kx))[:, None, :]
    bc1 = jax.nn.silu(jnp.einsum("bwc,wc->bc", cb.astype(kb.dtype), kb))[:, None, :]
    Bm, Cm = bc1[..., :N], bc1[..., N:]

    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + pc.use_small(f"{path}/dt_bias", p["dt_bias"]).astype(jnp.float32))[:, 0]
    A = -jnp.exp(pc.use_small(f"{path}/a_log", p["a_log"]).astype(jnp.float32))
    decay = jnp.exp(dtv * A[None, :])                # (B, hl)

    xh = xr1.reshape(B, hl, P)
    upd = jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                     (xh * dtv[..., None].astype(xh.dtype)).astype(jnp.float32))
    state = cache.state.astype(jnp.float32) * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), state).astype(x.dtype)
    y = y + xh * pc.use_small(f"{path}/d_skip", p["d_skip"]).astype(xh.dtype)[None, :, None]

    y = y.reshape(B, 1, dims.d_inner_local)
    y = _gated_norm(pc, f"{path}/norm", p["norm"], y, z)
    out = pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))
    new = SSMCache(state=state.astype(cache.state.dtype),
                   conv_x=cx[:, 1:], conv_bc=cb[:, 1:])
    return out, new
