"""Mixture-of-Experts with expert parallelism over the model axis.

Dispatch is **sort-based and shard-local** (no one-hot dispatch matmuls, no
all-to-all): activations are replicated across the model axis between blocks
(Megatron TP), so each model shard simply

  1. routes its data-shard's tokens (router runs replicated, fp — exempt from
     quantization, DESIGN.md §6),
  2. keeps the (token, expert) assignments that hit its *local* experts,
  3. groups them into an ``(e_local, capacity, d)`` buffer via scatter
     (gathers/scatters are byte-moves, not FLOPs — the compiled cost stays
     faithful to the MoE's 6·N_active·D model FLOPs),
  4. runs the expert SwiGLU as one batched matmul (MXU-dense),
  5. scatters partial outputs back and ``psum``s over the model axis —
     the only collective in the block.

Tokens beyond ``capacity = ceil(T*k/E * capacity_factor)`` are dropped
(standard practice; the capacity factor is a config knob).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ops import expert_dispatch
from repro.models.common import ParamCtx, init_dense
from repro.models.layers import sp_out


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    k: int
    d_model: int
    d_ff: int                 # per-expert hidden
    tp: int
    capacity_factor: float = 1.25
    act: str = "swiglu"

    @property
    def e_local(self) -> int:
        assert self.n_experts % self.tp == 0, "experts must divide tp"
        return self.n_experts // self.tp

    def capacity(self, n_tokens: int) -> int:
        cap = int(n_tokens * self.k * self.capacity_factor / self.n_experts) + 1
        return max(cap, 4)


def init_moe(keys, dims: MoEDims, dtype=jnp.float32):
    e, d, f = dims.e_local, dims.d_model, dims.d_ff
    def stack(maker):
        return jnp.stack([maker() for _ in range(e)])
    p = {
        "router": init_dense(next(keys), d, dims.n_experts, jnp.float32),
        "w_up": stack(lambda: init_dense(next(keys), d, f, dtype)),
        "w_down": stack(lambda: init_dense(next(keys), f, d, dtype)),
    }
    if dims.act in ("swiglu", "geglu"):
        p["w_gate"] = stack(lambda: init_dense(next(keys), d, f, dtype))
    return p


def moe_block(pc: ParamCtx, path: str, p, x, dims: MoEDims):
    """x: (B, S, D) local tokens (replicated over model axis).  Returns y."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)

    # --- routing (replicated, fp32, not quantized) -----------------------
    logits = xt.astype(jnp.float32) @ pc.use_small(f"{path}/router", p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, dims.k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- local assignment grouping ---------------------------------------
    tp_idx = pc.ctx.tp_index()
    e_lo = tp_idx * dims.e_local
    flat_e = ids.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]                                         # sorted expert ids
    tok = order // dims.k                                      # source token
    gw = gate.reshape(-1)[order]                               # gate weight
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * dims.k) - first                       # rank within expert
    cap = dims.capacity(T)
    local = se - e_lo
    valid = (local >= 0) & (local < dims.e_local) & (pos < cap)
    le = jnp.where(valid, local, 0)
    lp = jnp.where(valid, pos, cap)                            # trash slot

    # One-shot gather+scatter dispatch.  The (T*k, D) gather is transient and
    # fuses into the scatter on the TPU backend; the CPU dry-run's
    # memory_analysis().temp_size over-reports it (no TPU buffer scheduling)
    # — see EXPERIMENTS.md §Dry-run notes.
    buf = jnp.zeros((dims.e_local, cap + 1, D), x.dtype)
    buf = buf.at[le, lp].set(jnp.where(valid[:, None], xt[tok], 0))
    buf = buf[:, :cap]                                         # (e_loc, cap, D)

    # --- expert FFN (batched matmul over local experts) -------------------
    # Under lazy-quant the stacks stay packed: expert_dispatch routes each
    # expert's matmul through the quant_matmul kernel (int8 codes stream
    # straight from HBM; the expert loop is static and unrolls).
    up = expert_dispatch(buf, pc.use(f"{path}/w_up", p["w_up"]), x.dtype)
    if dims.act in ("swiglu", "geglu"):
        g = expert_dispatch(buf, pc.use(f"{path}/w_gate", p["w_gate"]), x.dtype)
        h = (jax.nn.silu(g) if dims.act == "swiglu"
             else jax.nn.gelu(g, approximate=True)) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    out = expert_dispatch(h, pc.use(f"{path}/w_down", p["w_down"]), x.dtype)
    # out: (e_loc, cap, D)

    # --- un-dispatch + combine --------------------------------------------
    out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))               # trash row back
    ys = out[le, lp] * jnp.where(valid, gw, 0.0)[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok].add(ys)
    y = sp_out(pc, y.reshape(B, S, D))
    return y, {"router_probs_mean": jnp.mean(probs, axis=0)}
