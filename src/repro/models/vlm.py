"""Vision-language backbone (llama-3.2-vision class).

100 layers = 20 periods of [1 cross-attention layer + 4 self-attention
layers].  The vision frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings ``(B, n_image_tokens, d_frontend)``; a
linear adapter projects them to the backbone width and they serve as the
cross-attention memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    cross_attention, cross_attention_cached, decode_self_attention,
    init_attention, init_kv_cache, init_paged_kv_cache, prefill_kv_cache,
    project_cross_kv, self_attention,
)
from repro.models.common import ParamCtx, init_dense, key_iter
from repro.models.transformer import (attn_dims, last_position_logits,
                                      padded_vocab_local, _stack)


def init_vlm(cfg: ModelConfig, key, tp: int, dtype=jnp.float32) -> dict:
    period = cfg.cross_attn_period
    assert cfg.n_layers % period == 0
    n_periods = cfg.n_layers // period
    ks = key_iter(key)
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    d_front = cfg.d_frontend or cfg.d_model

    def one_period(_):
        p = {"cross": {
            "ln": L.init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks, ad, dtype),
            "gate": jnp.zeros((), jnp.float32),   # zero-init cross gate (llama3.2)
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype),
            "mlp_gate": jnp.zeros((), jnp.float32),
        }}
        for j in range(period - 1):
            p[f"self{j}"] = {
                "ln1": L.init_rmsnorm(cfg.d_model),
                "attn": init_attention(ks, ad, dtype),
                "ln2": L.init_rmsnorm(cfg.d_model),
                "mlp": L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype),
            }
        return p

    return {
        "adapter": init_dense(next(ks), d_front, cfg.d_model, dtype),
        "embed": {"table": L.init_vocab_embed(next(ks), vl, cfg.d_model, dtype)},
        "periods": _stack([one_period(i) for i in range(n_periods)]),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"w": init_dense(next(ks), cfg.d_model, vl, dtype)},
    }


def _period_fn(cfg: ModelConfig, pc: ParamCtx, tp: int, memory, attn_impl: str):
    ad = attn_dims(cfg, tp)

    def period(x, pp):
        cp = pp["cross"]
        h = L.sp_gather(pc, L.rmsnorm(pc, "cross/ln", cp["ln"], x, cfg.norm_eps))
        a = cross_attention(pc, "cross/attn", cp["attn"], h, memory, ad)
        x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * a
        h = L.sp_gather(pc, L.rmsnorm(pc, "cross/ln2", cp["ln2"], x, cfg.norm_eps))
        m = L.mlp(pc, "cross/mlp", cp["mlp"], h, cfg.mlp_act)
        x = x + jnp.tanh(cp["mlp_gate"]).astype(x.dtype) * m
        for j in range(cfg.cross_attn_period - 1):
            sp = pp[f"self{j}"]
            h = L.sp_gather(pc, L.rmsnorm(pc, f"self{j}/ln1", sp["ln1"], x, cfg.norm_eps))
            a, _ = self_attention(pc, f"self{j}/attn", sp["attn"], h, ad,
                                  impl=attn_impl)
            x = x + a
            h = L.sp_gather(pc, L.rmsnorm(pc, f"self{j}/ln2", sp["ln2"], x, cfg.norm_eps))
            x = x + L.mlp(pc, f"self{j}/mlp", sp["mlp"], h, cfg.mlp_act)
        return x, ()

    return period


def forward(cfg: ModelConfig, pc: ParamCtx, params, tokens, images,
            *, attn_impl="auto", return_hidden=False):
    """tokens: (B,S); images: (B, n_img, d_frontend) stub patch embeddings."""
    tp = pc.ctx.tp
    vl = padded_vocab_local(cfg, tp)
    memory = L.dense(pc, "adapter", params["adapter"], images.astype(pc.compute_dtype))
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)
    period = _period_fn(cfg, pc, tp, memory, attn_impl)
    if cfg.remat:
        period = jax.checkpoint(period, prevent_cse=False)
    x, _ = jax.lax.scan(period, x, params["periods"])
    x = L.sp_gather(pc, L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps))
    if return_hidden:
        return x
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)


def train_loss(cfg: ModelConfig, pc: ParamCtx, params, batch, *, attn_impl="auto"):
    x = forward(cfg, pc, params, batch["tokens"], batch["images"],
                attn_impl=attn_impl, return_hidden=True)
    vl = padded_vocab_local(cfg, pc.ctx.tp)
    loss = L.fused_vocab_xent(pc, "unembed/w", params["unembed"]["w"], x,
                              batch["labels"], vl)
    return loss, {}


def init_vlm_caches(cfg: ModelConfig, batch: int, s_max: int, tp: int,
                    dtype=jnp.bfloat16, *, page_size=None, pool_pages=None):
    period = cfg.cross_attn_period
    n_periods = cfg.n_layers // period
    ad = attn_dims(cfg, tp)
    caches = {}
    for j in range(period - 1):
        one = (init_paged_kv_cache(batch, s_max, ad, dtype,
                                   page_size=page_size, pool_pages=pool_pages)
               if page_size else init_kv_cache(batch, s_max, ad, dtype))
        caches[f"self{j}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), one)
    # precomputed cross-attention K/V over the image memory (filled by
    # fill_cross_caches at prefill; zeros here are shape stand-ins)
    n_img = cfg.n_image_tokens or 1601
    kv_shape = (n_periods, batch, n_img, ad.kv_local, ad.head_dim)
    caches["cross_k"] = jnp.zeros(kv_shape, dtype)
    caches["cross_v"] = jnp.zeros(kv_shape, dtype)
    return caches


def fill_cross_caches(cfg: ModelConfig, pc, params, images, caches):
    # Prefill step for the cross-attention memory: project once, cache.
    ad = attn_dims(cfg, pc.ctx.tp)
    memory = L.dense(pc, "adapter", params["adapter"], images.astype(pc.compute_dtype))

    def body(_, pp):
        k, v = project_cross_kv(pc, "cross/attn", pp["cross"]["attn"], memory, ad)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["periods"])
    return {**caches, "cross_k": ks.astype(caches["cross_k"].dtype),
            "cross_v": vs.astype(caches["cross_v"].dtype)}


def prefill(cfg: ModelConfig, pc: ParamCtx, params, tokens, images, caches,
            *, attn_impl="auto", prompt_lens=None):
    """Real prefill: project the image memory, fill the per-period cross K/V
    caches, AND run the prompt through the self-attention layers, writing
    their K/V and per-sequence lengths (``prompt_lens`` under bucketed,
    right-padded prompts).  Returns (last logits, caches).

    Mirrors ``decode_step``'s period body (the serving convention: no
    sp_gather — the prefill ParamCtx runs with ``sp=False``, correct at any
    tp); any change to the period math in ``_period_fn`` must land here and
    in ``decode_step`` too."""
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    memory = L.dense(pc, "adapter", params["adapter"], images.astype(pc.compute_dtype))
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)

    def period(x, scanned):
        pp, pcache = scanned
        cp = pp["cross"]
        ck, cv = project_cross_kv(pc, "cross/attn", cp["attn"], memory, ad)
        h = L.rmsnorm(pc, "cross/ln", cp["ln"], x, cfg.norm_eps)
        a = cross_attention_cached(pc, "cross/attn", cp["attn"], h, ck, cv, ad)
        x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * a
        h = L.rmsnorm(pc, "cross/ln2", cp["ln2"], x, cfg.norm_eps)
        m = L.mlp(pc, "cross/mlp", cp["mlp"], h, cfg.mlp_act)
        x = x + jnp.tanh(cp["mlp_gate"]).astype(x.dtype) * m
        new_caches = {"cross_k": ck.astype(pcache["cross_k"].dtype),
                      "cross_v": cv.astype(pcache["cross_v"].dtype)}
        for j in range(cfg.cross_attn_period - 1):
            sp = pp[f"self{j}"]
            h = L.rmsnorm(pc, f"self{j}/ln1", sp["ln1"], x, cfg.norm_eps)
            a, (k, v) = self_attention(pc, f"self{j}/attn", sp["attn"], h, ad,
                                       impl=attn_impl)
            new_caches[f"self{j}"] = prefill_kv_cache(pc, pcache[f"self{j}"],
                                                      k, v, ad, prompt_lens)
            x = x + a
            h = L.rmsnorm(pc, f"self{j}/ln2", sp["ln2"], x, cfg.norm_eps)
            x = x + L.mlp(pc, f"self{j}/mlp", sp["mlp"], h, cfg.mlp_act)
        return x, new_caches

    x, new_caches = jax.lax.scan(period, x, (params["periods"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    logits = last_position_logits(pc, params, x, prompt_lens)
    return logits, new_caches


def decode_step(cfg: ModelConfig, pc: ParamCtx, params, token, caches,
                *, attn_impl="auto"):
    # One token; cross-attention uses the precomputed K/V caches.
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], token, vl)
    x = x.astype(pc.compute_dtype)
    decode_impl = "flash" if attn_impl == "flash" else "ref"

    def period(x, scanned):
        pp, pcache = scanned
        cp = pp["cross"]
        h = L.rmsnorm(pc, "cross/ln", cp["ln"], x, cfg.norm_eps)
        a = cross_attention_cached(pc, "cross/attn", cp["attn"], h,
                                   pcache["cross_k"], pcache["cross_v"], ad)
        x = x + jnp.tanh(cp["gate"]).astype(x.dtype) * a
        h = L.rmsnorm(pc, "cross/ln2", cp["ln2"], x, cfg.norm_eps)
        m = L.mlp(pc, "cross/mlp", cp["mlp"], h, cfg.mlp_act)
        x = x + jnp.tanh(cp["mlp_gate"]).astype(x.dtype) * m
        new_caches = {}
        for j in range(cfg.cross_attn_period - 1):
            sp = pp[f"self{j}"]
            h = L.rmsnorm(pc, f"self{j}/ln1", sp["ln1"], x, cfg.norm_eps)
            a, nc = decode_self_attention(pc, f"self{j}/attn", sp["attn"], h,
                                          pcache[f"self{j}"], ad,
                                          impl=decode_impl)
            new_caches[f"self{j}"] = nc
            x = x + a
            h = L.rmsnorm(pc, f"self{j}/ln2", sp["ln2"], x, cfg.norm_eps)
            x = x + L.mlp(pc, f"self{j}/mlp", sp["mlp"], h, cfg.mlp_act)
        new_caches["cross_k"] = pcache["cross_k"]   # pass-through (static)
        new_caches["cross_v"] = pcache["cross_v"]
        return x, new_caches

    x, new_caches = jax.lax.scan(period, x, (params["periods"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x), new_caches
