"""Decoder-only transformer LM (dense and MoE families).

Assembly notes
--------------
* Layers are **stacked** and traversed with ``lax.scan`` (+ optional remat):
  compile time and HLO size stay O(1) in depth — essential for the 94-100
  layer archs in the dry-run.
* Vocabulary is padded to a multiple of tp; padding rows are ordinary
  never-predicted logits (standard Megatron practice).
* All parameter access goes through ``ParamCtx.use`` — FSDP gather + FWQ
  per-client quantization + dtype cast in one place.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    AttnDims,
    decode_self_attention,
    init_attention,
    init_kv_cache,
    init_paged_kv_cache,
    prefill_kv_cache,
    self_attention,
)
from repro.models.common import ParamCtx, init_dense, key_iter
from repro.models.moe import MoEDims, init_moe, moe_block


def padded_vocab_local(cfg: ModelConfig, tp: int) -> int:
    return -(-cfg.vocab_size // tp)  # ceil


def attn_dims(cfg: ModelConfig, tp: int, causal: bool = True) -> AttnDims:
    return AttnDims(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        d_model=cfg.d_model, tp=tp, causal=causal, rope_theta=cfg.rope_theta,
    )


def moe_dims(cfg: ModelConfig, tp: int) -> MoEDims:
    return MoEDims(
        n_experts=cfg.n_experts, k=cfg.experts_per_token, d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff or cfg.d_ff, tp=tp,
        capacity_factor=cfg.capacity_factor, act=cfg.mlp_act,
    )


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(cfg: ModelConfig, key, tp: int, dtype=jnp.float32) -> dict:
    ks = key_iter(key)
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    is_moe = cfg.family == "moe"
    md = moe_dims(cfg, tp) if is_moe else None

    def one_block(_):
        p = {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks, ad, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model),
        }
        if is_moe:
            p["moe"] = init_moe(ks, md, dtype)
        else:
            p["mlp"] = L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype)
        return p

    return {
        "embed": {"table": L.init_vocab_embed(next(ks), vl, cfg.d_model, dtype)},
        "blocks": _stack([one_block(i) for i in range(cfg.n_layers)]),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"w": init_dense(next(ks), cfg.d_model, vl, dtype)},
    }


def _block_fn(cfg: ModelConfig, pc: ParamCtx, tp: int, attn_impl: str):
    ad = attn_dims(cfg, tp)
    md = moe_dims(cfg, tp) if cfg.family == "moe" else None

    def block(x, lp):
        h = L.sp_gather(pc, L.rmsnorm(pc, "blocks/ln1", lp["ln1"], x, cfg.norm_eps))
        a, _ = self_attention(pc, "blocks/attn", lp["attn"], h, ad, impl=attn_impl)
        x = x + a
        h = L.sp_gather(pc, L.rmsnorm(pc, "blocks/ln2", lp["ln2"], x, cfg.norm_eps))
        if cfg.family == "moe":
            m, _aux = moe_block(pc, "blocks/moe", lp["moe"], h, md)
        else:
            m = L.mlp(pc, "blocks/mlp", lp["mlp"], h, cfg.mlp_act)
        return x + m, ()

    return block


def forward(cfg: ModelConfig, pc: ParamCtx, params, tokens, *, attn_impl="auto", return_hidden=False):
    """tokens: (B, S) -> local logits (B, S, V/tp)."""
    tp = pc.ctx.tp
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)
    block = _block_fn(cfg, pc, tp, attn_impl)
    if cfg.remat:
        block = jax.checkpoint(block, prevent_cse=False)
    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = L.sp_gather(pc, L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps))
    if return_hidden:
        return x
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)


def train_loss(cfg: ModelConfig, pc: ParamCtx, params, batch, *, attn_impl="auto"):
    x = forward(cfg, pc, params, batch["tokens"], attn_impl=attn_impl,
                return_hidden=True)
    vl = padded_vocab_local(cfg, pc.ctx.tp)
    loss = L.fused_vocab_xent(pc, "unembed/w", params["unembed"]["w"], x,
                              batch["labels"], vl)
    return loss, {}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, s_max: int, tp: int,
                dtype=jnp.bfloat16, *, page_size=None, pool_pages=None):
    """Layer-stacked decode caches; ``page_size`` selects the paged layout
    (shared page pool + per-slot page tables) over the contiguous slab."""
    ad = attn_dims(cfg, tp)
    if page_size:
        one = init_paged_kv_cache(batch, s_max, ad, dtype,
                                  page_size=page_size, pool_pages=pool_pages)
    else:
        one = init_kv_cache(batch, s_max, ad, dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def last_position_logits(pc: ParamCtx, params, x, prompt_lens=None):
    """Logits at each slot's true last prompt position.

    Bucketed prefill right-pads prompts, so "last position" is per-slot
    (``prompt_lens - 1``), not ``S_p - 1``; causality guarantees the true
    last position never attended the padding after it.
    """
    if prompt_lens is None:
        x_last = x[:, -1:, :]
    else:
        idx = (prompt_lens.astype(jnp.int32) - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.clip(idx, 0, x.shape[1] - 1),
                                     axis=1)
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x_last)


def prefill(cfg: ModelConfig, pc: ParamCtx, params, tokens, caches,
            *, attn_impl="auto", prompt_lens=None):
    """Parallel prefill: one forward pass over the prompt that also writes
    every layer's self-attention K/V into ``caches`` and stamps per-sequence
    lengths — the step continuous batching runs at admission time.

    tokens: (B, S_p) with S_p <= s_max.  Returns (last-position local logits
    (B, 1, V/tp), filled caches).  ``attn_impl="flash"`` runs the prompt
    through the Pallas flash-attention kernel.  ``prompt_lens`` (B,) gives
    per-slot true lengths when prompts are right-padded to a bucket size.
    """
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    md = moe_dims(cfg, tp) if cfg.family == "moe" else None
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)

    def block(x, scanned):
        lp, cache = scanned
        h = L.rmsnorm(pc, "blocks/ln1", lp["ln1"], x, cfg.norm_eps)
        a, (k, v) = self_attention(pc, "blocks/attn", lp["attn"], h, ad,
                                   impl=attn_impl)
        x = x + a
        h = L.rmsnorm(pc, "blocks/ln2", lp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = moe_block(pc, "blocks/moe", lp["moe"], h, md)
        else:
            m = L.mlp(pc, "blocks/mlp", lp["mlp"], h, cfg.mlp_act)
        return x + m, prefill_kv_cache(pc, cache, k, v, ad, prompt_lens)

    x, new_caches = jax.lax.scan(block, x, (params["blocks"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    logits = last_position_logits(pc, params, x, prompt_lens)
    return logits, new_caches


def decode_step(cfg: ModelConfig, pc: ParamCtx, params, token, caches,
                *, attn_impl="auto"):
    """token: (B, 1) int32 -> (local_logits (B,1,V/tp), new caches).

    ``attn_impl="flash"`` routes paged caches through the batched
    flash-decode Pallas kernel; any other value takes the (bitwise
    slab-equivalent) gather reference path.
    """
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    md = moe_dims(cfg, tp) if cfg.family == "moe" else None
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], token, vl)
    x = x.astype(pc.compute_dtype)
    decode_impl = "flash" if attn_impl == "flash" else "ref"

    def block(x, scanned):
        lp, cache = scanned
        h = L.rmsnorm(pc, "blocks/ln1", lp["ln1"], x, cfg.norm_eps)
        a, new_cache = decode_self_attention(pc, "blocks/attn", lp["attn"], h,
                                             cache, ad, impl=decode_impl)
        x = x + a
        h = L.rmsnorm(pc, "blocks/ln2", lp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = moe_block(pc, "blocks/moe", lp["moe"], h, md)
        else:
            m = L.mlp(pc, "blocks/mlp", lp["mlp"], h, cfg.mlp_act)
        return x + m, new_cache

    x, new_caches = jax.lax.scan(block, x, (params["blocks"], caches))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    logits = L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)
    return logits, new_caches
