"""Building-block layers: norms, rotary embeddings, parallel MLPs, embeddings.

Tensor-parallel conventions (Megatron style, executed inside shard_map):

* activations ``x: (B, S, D)`` are replicated across the ``model`` axis and
  local (per-client) along the batch axes;
* column-parallel weights shard their *output* dim over ``model``;
  row-parallel weights shard their *input* dim and are followed by a
  ``psum`` over the model axis;
* vocab-parallel embedding/unembedding shard the vocabulary.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.ops import as_array, dense_dispatch
from repro.models.common import ParamCtx, QTensor, init_dense, init_embed


# ---------------------------------------------------------------------------
# Sequence parallelism boundaries (Megatron-SP)
# ---------------------------------------------------------------------------


def sp_gather(pc: ParamCtx, x):
    """(B, S/tp, D) -> (B, S, D) at a block input (no-op when sp off/tp==1)."""
    if pc.sp and pc.ctx.model_axis and pc.ctx.tp > 1:
        return pc.ctx.all_gather_model(x, axis=1)
    return x


def sp_out(pc: ParamCtx, y):
    """Block-output combine: reduce-scatter over seq when SP, else all-reduce."""
    if pc.sp and pc.ctx.model_axis and pc.ctx.tp > 1:
        return pc.ctx.psum_scatter_model(y, axis=1)
    return pc.ctx.psum_model(y)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(pc: ParamCtx, path: str, scale, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + pc.use_small(path, scale).astype(jnp.float32))).astype(x.dtype)


def init_rmsnorm(d: int, dtype=jnp.float32):
    return jnp.zeros((d,), dtype)  # stored as (scale - 1): zero-init


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """cos/sin tables, f32.  positions: (...,) int32 -> (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (..., S, n_heads, head_dim); cos/sin: (S, head_dim/2) (broadcast)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Parallel MLP (SwiGLU / GeGLU / GeLU)
# ---------------------------------------------------------------------------


def init_mlp(keys, d: int, d_ff_local: int, act: str, dtype=jnp.float32):
    p = {
        "w_up": init_dense(next(keys), d, d_ff_local, dtype),
        "w_down": init_dense(next(keys), d_ff_local, d, dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = init_dense(next(keys), d, d_ff_local, dtype)
    return p


def mlp(pc: ParamCtx, path: str, p, x, act: str):
    """Column-parallel up/gate, row-parallel down (+psum over model)."""
    up = dense(pc, f"{path}/w_up", p["w_up"], x)
    if act == "swiglu":
        gate = dense(pc, f"{path}/w_gate", p["w_gate"], x)
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        gate = dense(pc, f"{path}/w_gate", p["w_gate"], x)
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    y = dense(pc, f"{path}/w_down", p["w_down"], h)
    return sp_out(pc, y)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / unembedding / cross-entropy
# ---------------------------------------------------------------------------


def init_vocab_embed(key, vocab_local: int, d: int, dtype=jnp.float32):
    return init_embed(key, vocab_local, d, dtype)


def vocab_embed(pc: ParamCtx, path: str, table, ids: jnp.ndarray, vocab_local: int):
    """ids: (B, S) global token ids; table: (V/tp, D) local shard."""
    tp_idx = pc.ctx.tp_index()
    lo = tp_idx * vocab_local
    local = ids - lo
    in_range = (local >= 0) & (local < vocab_local)
    safe = jnp.clip(local, 0, vocab_local - 1)
    t = pc.use(f"{path}/table", table)
    if isinstance(t, QTensor):
        # lazy-quant: gather int8 rows, dequantize only the touched rows
        e = (jnp.take(t.codes, safe, axis=0).astype(jnp.float32)
             * t.scale.astype(jnp.float32)).astype(pc.compute_dtype)
    else:
        e = jnp.take(t, safe, axis=0)
    e = jnp.where(in_range[..., None], e, jnp.zeros_like(e))
    return sp_out(pc, e)


def vocab_logits(pc: ParamCtx, path: str, w_unembed, x):
    """x: (B, S, D) -> local logits (B, S, V/tp)."""
    return dense(pc, f"{path}/w", w_unembed, x)


def vocab_parallel_xent(pc: ParamCtx, local_logits, labels, vocab_local: int,
                        *, ignore_id: int = -1):
    """Cross-entropy over vocab-sharded logits without gathering the vocab.

    Stable log-softmax via pmax/psum over the model axis.  labels: (B, S).
    Returns (mean_loss, n_tokens).
    """
    lg = local_logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1))
    if pc.ctx.model_axis and pc.ctx.tp > 1:
        m = jax.lax.pmax(m, pc.ctx.model_axis)
    z = jnp.exp(lg - m[..., None])
    denom = pc.ctx.psum_model(jnp.sum(z, axis=-1))
    tp_idx = pc.ctx.tp_index()
    lo = tp_idx * vocab_local
    local = labels - lo
    in_range = (local >= 0) & (local < vocab_local)
    safe = jnp.clip(local, 0, vocab_local - 1)
    picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    picked = jnp.where(in_range, picked, 0.0)
    picked = pc.ctx.psum_model(picked)          # the true-class logit
    nll = jnp.log(denom) + m - picked
    valid = labels != ignore_id
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(jnp.where(valid, nll, 0.0)) / n, n


def fused_vocab_xent(pc: ParamCtx, path: str, w_unembed, x, labels,
                     vocab_local: int, *, chunk: int = 512, ignore_id: int = -1):
    """Unembed + vocab-parallel cross-entropy, chunked over the sequence.

    Never materializes the full (B, S, V/tp) logits — each seq chunk's logits
    live only inside a rematerialized scan body (65-500k-seq safe).
    x: (B, S, D) full-seq activations; labels: (B, S).  Returns mean loss.
    """
    w = as_array(pc.use(path, w_unembed), pc.compute_dtype)  # gather once, outside scan
    B, S, D = x.shape
    c = min(chunk, S)
    assert S % c == 0, "sequence must divide the xent chunk"
    tp_idx = pc.ctx.tp_index()
    lo = tp_idx * vocab_local

    def body(carry, i):
        nll_sum, n_valid = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * c, c, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        lg = (xs @ w).astype(jnp.float32)     # (B, c, V/tp)
        m = jax.lax.stop_gradient(jnp.max(lg, axis=-1))
        if pc.ctx.model_axis and pc.ctx.tp > 1:
            m = jax.lax.pmax(m, pc.ctx.model_axis)
        z = jnp.exp(lg - m[..., None])
        denom = pc.ctx.psum_model(jnp.sum(z, axis=-1))
        local = ls - lo
        in_range = (local >= 0) & (local < vocab_local)
        safe = jnp.clip(local, 0, vocab_local - 1)
        picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
        picked = pc.ctx.psum_model(jnp.where(in_range, picked, 0.0))
        nll = jnp.log(denom) + m - picked
        valid = ls != ignore_id
        return (nll_sum + jnp.sum(jnp.where(valid, nll, 0.0)),
                n_valid + jnp.sum(valid)), ()

    body = jax.checkpoint(body, prevent_cse=False)
    (nll_sum, n_valid), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        jnp.arange(S // c))
    return nll_sum / jnp.maximum(n_valid, 1)


# ---------------------------------------------------------------------------
# Generic dense projection (serving path swaps in the quant_matmul kernel)
# ---------------------------------------------------------------------------


def dense(pc: ParamCtx, path: str, w, x):
    """``x @ use(w)`` with leaf-type dispatch: under lazy-quant the packed
    int8 codes go straight to the Pallas ``quant_matmul`` kernel."""
    return dense_dispatch(x, pc.use(path, w))
