"""CIFAR-class CNNs for the paper's own evaluation (§5.1).

The paper trains ResNet-34 and MobileNet on CIFAR-10/100.  These are faithful
reduced-depth analogs in pure JAX (``lax.conv_general_dilated``) sized to run
hundreds of FL rounds on CPU:

* ``resnet(depth=...)``  — post-activation residual blocks, GroupNorm instead
  of BatchNorm (batch statistics don't cross FL client boundaries — the
  standard substitution in FL work; noted in DESIGN.md).
* ``mobilenet()``        — depthwise-separable stacks.

Used by the vmap-based FL simulator (tree-mode FWQ) — these models are plain
param-tree functions, no shard_map machinery.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.common import key_iter


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _init_conv(key, kh, kw, cin, cout, groups=1):
    fan = kh * kw * cin // groups
    return (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin // groups, cout))
            * (2.0 / fan) ** 0.5).astype(jnp.float32)


def _groupnorm(x, scale, bias, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return xn * scale + bias


@dataclasses.dataclass(frozen=True)
class CNNModel:
    init: Callable        # key -> params
    apply: Callable       # (params, images) -> logits
    name: str


def resnet(depth_blocks=(2, 2, 2, 2), width=32, n_classes=10) -> CNNModel:
    """Reduced ResNet (ResNet-34 uses (3,4,6,3) at width 64)."""

    widths = [width * (2**i) for i in range(len(depth_blocks))]

    def init(key):
        ks = key_iter(key)
        p = {"stem": {"w": _init_conv(next(ks), 3, 3, 3, widths[0]),
                      "gn_s": jnp.ones((widths[0],)), "gn_b": jnp.zeros((widths[0],))}}
        cin = widths[0]
        for si, (blocks, cout) in enumerate(zip(depth_blocks, widths)):
            for bi in range(blocks):
                blk = {
                    "conv1": _init_conv(next(ks), 3, 3, cin, cout),
                    "gn1_s": jnp.ones((cout,)), "gn1_b": jnp.zeros((cout,)),
                    "conv2": _init_conv(next(ks), 3, 3, cout, cout),
                    "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
                }
                if cin != cout:
                    blk["proj"] = _init_conv(next(ks), 1, 1, cin, cout)
                p[f"s{si}b{bi}"] = blk
                cin = cout
        p["head"] = {"w": (jax.random.normal(next(ks), (cin, n_classes)) * 0.01),
                     "b": jnp.zeros((n_classes,))}
        return p

    def apply(params, images):
        x = _conv(images, params["stem"]["w"])
        x = jax.nn.relu(_groupnorm(x, params["stem"]["gn_s"], params["stem"]["gn_b"]))
        cin = widths[0]
        for si, (blocks, cout) in enumerate(zip(depth_blocks, widths)):
            for bi in range(blocks):
                blk = params[f"s{si}b{bi}"]
                stride = 2 if (bi == 0 and si > 0) else 1
                h = _conv(x, blk["conv1"], stride)
                h = jax.nn.relu(_groupnorm(h, blk["gn1_s"], blk["gn1_b"]))
                h = _conv(h, blk["conv2"])
                h = _groupnorm(h, blk["gn2_s"], blk["gn2_b"])
                sc = x
                if "proj" in blk:
                    sc = _conv(x, blk["proj"], stride)
                elif stride != 1:
                    sc = x[:, ::stride, ::stride]
                x = jax.nn.relu(h + sc)
                cin = cout
        x = x.mean(axis=(1, 2))
        return x @ params["head"]["w"] + params["head"]["b"]

    return CNNModel(init=init, apply=apply, name=f"resnet{sum(depth_blocks)*2+2}")


def mobilenet(width=24, n_stages=4, n_classes=10) -> CNNModel:
    """Depthwise-separable stack (MobileNetV1 style, reduced)."""

    def init(key):
        ks = key_iter(key)
        p = {"stem": {"w": _init_conv(next(ks), 3, 3, 3, width),
                      "gn_s": jnp.ones((width,)), "gn_b": jnp.zeros((width,))}}
        cin = width
        for i in range(n_stages):
            cout = width * (2 ** (i // 2 + 1))
            p[f"dw{i}"] = {
                "dw": _init_conv(next(ks), 3, 3, cin, cin, groups=cin),
                "gn1_s": jnp.ones((cin,)), "gn1_b": jnp.zeros((cin,)),
                "pw": _init_conv(next(ks), 1, 1, cin, cout),
                "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
            }
            cin = cout
        p["head"] = {"w": (jax.random.normal(next(ks), (cin, n_classes)) * 0.01),
                     "b": jnp.zeros((n_classes,))}
        return p

    def apply(params, images):
        x = _conv(images, params["stem"]["w"])
        x = jax.nn.relu(_groupnorm(x, params["stem"]["gn_s"], params["stem"]["gn_b"]))
        cin = x.shape[-1]
        i = 0
        while f"dw{i}" in params:
            blk = params[f"dw{i}"]
            stride = 2 if i % 2 == 1 else 1
            x = _conv(x, blk["dw"], stride, groups=x.shape[-1])
            x = jax.nn.relu(_groupnorm(x, blk["gn1_s"], blk["gn1_b"]))
            x = _conv(x, blk["pw"])
            x = jax.nn.relu(_groupnorm(x, blk["gn2_s"], blk["gn2_b"]))
            i += 1
        x = x.mean(axis=(1, 2))
        return x @ params["head"]["w"] + params["head"]["b"]

    return CNNModel(init=init, apply=apply, name="mobilenet")


def xent_loss(model: CNNModel):
    def loss_fn(params, batch, rng):
        logits = model.apply(params, batch["x"])
        ls = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(ls, batch["y"][:, None], axis=-1).mean()
        acc = (jnp.argmax(logits, -1) == batch["y"]).mean()
        return nll, {"acc": acc}
    return loss_fn
