"""Shared machinery for the manual-sharding model zoo.

Models are written as **local per-shard code with explicit collectives**
(Megatron-JAX style; see DESIGN.md §4/§7) and run under one ``jax.shard_map``
over the whole mesh.  The two cross-cutting concerns are factored here:

* :class:`ParamCtx` — every weight is *used* through ``pc.use(path, w)``,
  which (1) all-gathers FSDP-sharded storage, (2) applies the active weight
  transform — identity, per-client SR quantization (FWQ Algorithm 1 line 4),
  or int8 dequantization on the serving path — and (3) casts to the compute
  dtype.  Autodiff through the tiled all-gather transposes to a
  reduce-scatter, so FSDP gradients come back sharded for free.
* :class:`QTensor` — packed int8/int16 codes + scale, the real quantized
  storage used by serving (streams 1/4 the HBM bytes of f32).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.collectives import AxisCtx

Transform = Callable[[str, jnp.ndarray], jnp.ndarray]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Quantized parameter storage: ``w ~= codes * scale`` (scale folds delta)."""

    codes: jnp.ndarray
    scale: jnp.ndarray

    def tree_flatten(self):
        return (self.codes, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    @property
    def size(self):
        return self.codes.size

    @property
    def dtype(self):
        return self.codes.dtype


def dequant(q: QTensor, dtype) -> jnp.ndarray:
    return q.codes.astype(jnp.float32).astype(dtype) * q.scale.astype(dtype)


#: Minimum product of the NON-sharded dims for FSDP participation.  This
#: criterion is invariant under sharding of the rule dim, so init-time and
#: use-time decisions agree by construction.
FSDP_MIN_OTHER = 256

#: Path fragments never FSDP-sharded (used via ``use_small`` — no gather).
FSDP_EXCLUDE = ("router", "conv_", "a_log", "dt_bias", "d_skip", "/ln", "norm",
                "gate_scalar")

#: Stack prefixes: leaves under these carry a leading scanned-layer dim.
STACK_PREFIXES = ("blocks/", "periods/", "encoder/", "decoder/")


def fsdp_shard_dim(path: str, ndim: int) -> int:
    """Deterministic FSDP shard dim for a parameter (init & use must agree).

    ``ndim`` is the per-layer view (stack dim already stripped).  Default:
    second-to-last dim (the d_model-like dim, divisible by the fsdp size for
    every assigned arch).  Exceptions shard the last dim where the default is
    not guaranteed divisible: embedding tables (vocab rows padded to tp only)
    and row-parallel ``w_down`` (d_ff/tp rows).
    """
    if path.endswith("/table") or "w_down" in path:
        return ndim - 1
    return ndim - 2


def is_stacked(path: str) -> bool:
    return any(p in path for p in STACK_PREFIXES)


def fsdp_participates(path: str, per_layer_shape: tuple[int, ...], fsdp: int) -> bool:
    """Single source of truth for FSDP participation.

    Works on either the stored (sharded) or global per-layer shape: the
    criterion only reads the dims that sharding does not touch.
    """
    if fsdp <= 1 or len(per_layer_shape) < 2:
        return False
    if any(x in path for x in FSDP_EXCLUDE):
        return False
    dim = fsdp_shard_dim(path, len(per_layer_shape))
    other = 1
    for i, s in enumerate(per_layer_shape):
        if i != dim:
            other *= s
    return other >= FSDP_MIN_OTHER


@dataclasses.dataclass
class ParamCtx:
    """Threads mesh context + weight transform through model code.

    ``sp``: Megatron-style sequence parallelism — activations between blocks
    are sharded over the model axis on the sequence dim; block inputs are
    all-gathered and block outputs reduce-scattered (same wire bytes as the
    all-reduce they replace, but layer residuals are stored 1/tp as large —
    required for the 94-100 layer archs to fit HBM).

    ``gather_dtype``: cast parameters to this dtype BEFORE the FSDP
    all-gather (e.g. bf16 halves gather bytes; §Perf knob).

    ``policy``: a :class:`repro.api.precision.PrecisionPolicy`.  Its ``lazy``
    flag selects the serving fast path: ``use()`` on a :class:`QTensor`
    returns the packed handle itself (codes gathered, NOT dequantized);
    matmul call sites dispatch on leaf type via
    :func:`repro.kernels.ops.dense_dispatch`, so dequantization happens
    tile-by-tile inside the ``quant_matmul`` kernel and the weight stream
    stays int8 all the way from HBM to VMEM.
    """

    ctx: AxisCtx
    transform: Transform | None = None
    compute_dtype: Any = jnp.bfloat16
    sp: bool = False
    gather_dtype: Any = None
    policy: Any = None

    @property
    def lazy(self) -> bool:
        return bool(getattr(self.policy, "lazy", False))

    @classmethod
    def from_policy(cls, ctx: AxisCtx, policy, *, transform=None,
                    compute_dtype=jnp.bfloat16, sp: bool = False,
                    gather_dtype=None) -> "ParamCtx":
        """The policy-driven constructor every launcher goes through."""
        return cls(ctx=ctx, transform=transform, compute_dtype=compute_dtype,
                   sp=sp, gather_dtype=gather_dtype, policy=policy)

    def is_fsdp(self, path: str, w) -> bool:
        """w is the *stored local* leaf (per-layer view inside a scan)."""
        leaf = w.codes if isinstance(w, QTensor) else w
        return fsdp_participates(path, leaf.shape, self.ctx.fsdp)

    def use(self, path: str, w, *, gathered_dim: int | None = None):
        """Gather + transform + cast: the single funnel every weight goes through.

        Returns a dense array, or the packed :class:`QTensor` (codes gathered)
        when ``policy.lazy`` is on — consumers dispatch on the leaf type.
        """
        nd = (w.codes if isinstance(w, QTensor) else w).ndim
        dim = fsdp_shard_dim(path, nd) if gathered_dim is None else gathered_dim
        gather = self.is_fsdp(path, w)
        if isinstance(w, QTensor):
            codes = self.ctx.gather_fsdp(w.codes, axis=dim) if gather else w.codes
            if self.lazy and self.transform is None:
                return QTensor(codes, w.scale)
            full = codes.astype(jnp.float32) * w.scale.astype(jnp.float32)
        else:
            full = w
            if gather:
                if self.gather_dtype is not None:
                    full = full.astype(self.gather_dtype)
                full = self.ctx.gather_fsdp(full, axis=dim)
        if self.transform is not None:
            full = self.transform(path, full)
        return full.astype(self.compute_dtype)

    def use_small(self, path: str, w) -> jnp.ndarray:
        """Replicated small parameters (norm scales, biases): no gather."""
        if self.transform is not None:
            w = self.transform(path, w)
        return w.astype(self.compute_dtype)


def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def tree_paths_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, QTensor))
    paths = ["/".join(_key_name(k) for k in kp) for kp, _ in flat]
    return paths, [v for _, v in flat], treedef


def fsdp_plan(params, fsdp: int, *, check_divisibility: bool = True):
    """Per-leaf FSDP dim (in stored-array coords) or None.  Shared by the
    init-time shard pass, the gradient reduction, and the launcher's
    in_specs builder.

    ``check_divisibility`` must be True only when ``params`` carries the
    UNSHARDED (pre-slice) shapes — stored/sharded trees have the rule dim
    already divided and would trip the check spuriously."""
    paths, leaves, treedef = tree_paths_leaves(params)
    plan = []
    for path, leaf in zip(paths, leaves):
        arr = leaf.codes if isinstance(leaf, QTensor) else leaf
        stacked = is_stacked(path)
        eff_ndim = arr.ndim - 1 if stacked else arr.ndim
        shape = arr.shape[1:] if stacked else arr.shape
        if not fsdp_participates(path, shape, fsdp):
            plan.append(None)
            continue
        dim = fsdp_shard_dim(path, eff_ndim) + (1 if stacked else 0)
        if check_divisibility and arr.shape[dim] % fsdp != 0:
            raise ValueError(
                f"FSDP-eligible param {path} shape {arr.shape} not divisible by "
                f"fsdp={fsdp} on dim {dim}; adjust fsdp_shard_dim rule")
        plan.append(dim)
    return paths, leaves, treedef, plan


def apply_fsdp_sharding(params, pc: "ParamCtx", fsdp: int | None = None):
    """Slice each FSDP-eligible leaf to this shard's portion.

    Runs inside shard_map (dp_index traced) or under eval_shape probes —
    pass ``fsdp`` explicitly in the latter case (axis sizes are invisible
    outside shard_map)."""
    n_fsdp = fsdp if fsdp is not None else pc.ctx.fsdp
    paths, leaves, treedef, plan = fsdp_plan(params, n_fsdp)
    idx = pc.ctx.dp_index()
    out = []
    for leaf, dim in zip(leaves, plan):
        if dim is None:
            out.append(leaf)
            continue
        arr = leaf.codes if isinstance(leaf, QTensor) else leaf
        size = arr.shape[dim] // n_fsdp
        piece = jax.lax.dynamic_slice_in_dim(arr, idx * size, size, axis=dim)
        out.append(QTensor(piece, leaf.scale) if isinstance(leaf, QTensor) else piece)
    return jax.tree_util.tree_unflatten(treedef, out)


def reduce_gradients(grads, params_template, ctx: AxisCtx):
    """Server-side gradient mean (Algorithm 1 line 10) respecting FSDP layout.

    FSDP leaves arrive already *summed* across the fsdp axes (the transpose of
    the tiled all-gather is a reduce-scatter): divide by dp.  Replicated
    leaves need the explicit ``pmean`` over the batch axes.
    """
    paths, leaves, treedef, plan = fsdp_plan(params_template, ctx.fsdp,
                                             check_divisibility=False)
    gleaves = jax.tree_util.tree_leaves(
        grads, is_leaf=lambda x: isinstance(x, QTensor))
    out = []
    for g, dim in zip(gleaves, plan):
        if dim is not None:
            out.append(g / ctx.dp)
        else:
            out.append(jax.lax.pmean(g, tuple(ctx.batch_axes)) if ctx.batch_axes
                       else g)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun)."""
    std = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * std).astype(dtype)


def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# Serving-path packing
# ---------------------------------------------------------------------------


def pack_params_for_policy(params, policy, key, *, exempt=None) -> Any:
    """Pack a param tree per a :class:`~repro.api.precision.PrecisionPolicy`.

    Identity at 32-bit weights; otherwise int8/int16 :class:`QTensor` codes at
    ``policy.serve_bits`` (the uniform serving bit-width the co-design chose).
    """
    if not policy.packed:
        return params
    if exempt is None:
        from repro.core.quantization import default_exempt as exempt
    return pack_params_for_serving(params, policy.serve_bits, key, exempt=exempt)


def pack_params_for_serving(params, bits: int, key, *, exempt) -> Any:
    """Convert matmul weights to :class:`QTensor` int8/int16 storage.

    Deterministic nearest rounding (serving wants reproducibility; the SR
    unbiasedness argument matters for *training* — see paper §2.1).
    """
    from repro.core.quantization import storage_dtype

    paths, leaves, treedef = tree_paths_leaves(params)
    out = []
    for path, leaf in zip(paths, leaves):
        if exempt is not None and exempt(path, leaf):
            out.append(leaf)
            continue
        delta = 1.0 / (2.0**bits - 1.0)
        lim = 2**bits - 1
        wf = leaf.astype(jnp.float32)
        if is_stacked(path) and leaf.ndim >= 2:
            # per-layer scales so scanned stacks slice cleanly (and tighter)
            red = tuple(range(1, leaf.ndim))
            s = jnp.maximum(jnp.max(jnp.abs(wf), axis=red), 1e-12)
            scale = (s * delta).astype(jnp.float32)          # (L,)
            sb = scale.reshape((-1,) + (1,) * (leaf.ndim - 1))
        else:
            s = jnp.maximum(jnp.max(jnp.abs(wf)), 1e-12)
            scale = (s * delta).astype(jnp.float32)          # ()
            sb = scale
        codes = jnp.clip(jnp.round(wf / sb), -lim, lim).astype(storage_dtype(bits))
        out.append(QTensor(codes=codes, scale=scale))
    return jax.tree_util.tree_unflatten(treedef, out)
