"""Encoder-decoder backbone (seamless-m4t class).

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed audio-frame embeddings ``(B, S_src, d_frontend)``; a linear
adapter projects them into the encoder width.  Text decoding is a standard
causal decoder with cross-attention into the encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    cross_attention, cross_attention_cached, decode_self_attention,
    init_attention, init_kv_cache, init_paged_kv_cache, project_cross_kv,
    self_attention,
)
from repro.models.common import ParamCtx, init_dense, key_iter
from repro.models.transformer import attn_dims, padded_vocab_local, _stack


def init_encdec(cfg: ModelConfig, key, tp: int, dtype=jnp.float32) -> dict:
    ks = key_iter(key)
    ad_self = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    d_front = cfg.d_frontend or cfg.d_model

    def enc_layer(_):
        return {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": init_attention(ks, ad_self, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype),
        }

    def dec_layer(_):
        return {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "self": init_attention(ks, ad_self, dtype),
            "ln_x": L.init_rmsnorm(cfg.d_model),
            "cross": init_attention(ks, ad_self, dtype),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(ks, cfg.d_model, cfg.d_ff // tp, cfg.mlp_act, dtype),
        }

    return {
        "adapter": init_dense(next(ks), d_front, cfg.d_model, dtype),
        "encoder": _stack([enc_layer(i) for i in range(cfg.n_encoder_layers)]),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "embed": {"table": L.init_vocab_embed(next(ks), vl, cfg.d_model, dtype)},
        "decoder": _stack([dec_layer(i) for i in range(cfg.n_layers)]),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "unembed": {"w": init_dense(next(ks), cfg.d_model, vl, dtype)},
    }


def encode(cfg: ModelConfig, pc: ParamCtx, params, frames, *, attn_impl="auto"):
    """frames: (B, S_src, d_frontend) stub embeddings -> memory (B,S_src,D)."""
    ad = attn_dims(cfg, tp=pc.ctx.tp, causal=False)
    x = L.dense(pc, "adapter", params["adapter"], frames.astype(pc.compute_dtype))
    x = L.sp_out(pc, x) if (pc.sp and pc.ctx.tp > 1) else x

    def layer(x, lp):
        h = L.sp_gather(pc, L.rmsnorm(pc, "enc/ln1", lp["ln1"], x, cfg.norm_eps))
        a, _ = self_attention(pc, "enc/attn", lp["attn"], h, ad, impl=attn_impl)
        x = x + a
        h = L.sp_gather(pc, L.rmsnorm(pc, "enc/ln2", lp["ln2"], x, cfg.norm_eps))
        return x + L.mlp(pc, "enc/mlp", lp["mlp"], h, cfg.mlp_act), ()

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    x, _ = jax.lax.scan(layer, x, params["encoder"])
    return L.sp_gather(pc, L.rmsnorm(pc, "enc_norm", params["enc_norm"], x, cfg.norm_eps))


def decode_train(cfg: ModelConfig, pc: ParamCtx, params, memory, tokens,
                 *, attn_impl="auto", return_hidden=False):
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], tokens, vl)
    x = x.astype(pc.compute_dtype)

    def layer(x, lp):
        h = L.sp_gather(pc, L.rmsnorm(pc, "dec/ln1", lp["ln1"], x, cfg.norm_eps))
        a, _ = self_attention(pc, "dec/self", lp["self"], h, ad, impl=attn_impl)
        x = x + a
        h = L.sp_gather(pc, L.rmsnorm(pc, "dec/ln_x", lp["ln_x"], x, cfg.norm_eps))
        x = x + cross_attention(pc, "dec/cross", lp["cross"], h, memory, ad)
        h = L.sp_gather(pc, L.rmsnorm(pc, "dec/ln2", lp["ln2"], x, cfg.norm_eps))
        return x + L.mlp(pc, "dec/mlp", lp["mlp"], h, cfg.mlp_act), ()

    if cfg.remat:
        layer = jax.checkpoint(layer, prevent_cse=False)
    x, _ = jax.lax.scan(layer, x, params["decoder"])
    x = L.sp_gather(pc, L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps))
    if return_hidden:
        return x
    return L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)


def train_loss(cfg: ModelConfig, pc: ParamCtx, params, batch, *, attn_impl="auto"):
    memory = encode(cfg, pc, params, batch["frames"], attn_impl=attn_impl)
    x = decode_train(cfg, pc, params, memory, batch["tokens"],
                     attn_impl=attn_impl, return_hidden=True)
    vl = padded_vocab_local(cfg, pc.ctx.tp)
    loss = L.fused_vocab_xent(pc, "unembed/w", params["unembed"]["w"], x,
                              batch["labels"], vl)
    return loss, {}


def init_decoder_caches(cfg: ModelConfig, batch: int, s_max: int, tp: int,
                        dtype=jnp.bfloat16, *, page_size=None,
                        pool_pages=None):
    ad = attn_dims(cfg, tp)
    if page_size:
        # only the per-token-growing SELF cache pages; the cross K/V is a
        # fixed-size memory projection and stays a contiguous slab
        one = init_paged_kv_cache(batch, s_max, ad, dtype,
                                  page_size=page_size, pool_pages=pool_pages)
    else:
        one = init_kv_cache(batch, s_max, ad, dtype)
    self_caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)
    # precomputed cross K/V over the encoder memory (filled at prefill via
    # fill_cross_caches; zeros are shape stand-ins)
    kv_shape = (cfg.n_layers, batch, s_max, ad.kv_local, ad.head_dim)
    return {"self": self_caches,
            "cross_k": jnp.zeros(kv_shape, dtype),
            "cross_v": jnp.zeros(kv_shape, dtype)}


def fill_cross_caches(cfg: ModelConfig, pc, params, memory, caches):
    ad = attn_dims(cfg, pc.ctx.tp)

    def body(_, lp):
        k, v = project_cross_kv(pc, "dec/cross", lp["cross"], memory, ad)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["decoder"])
    return {**caches, "cross_k": ks.astype(caches["cross_k"].dtype),
            "cross_v": vs.astype(caches["cross_v"].dtype)}


def prefill(cfg: ModelConfig, pc: ParamCtx, params, frames, caches,
            *, attn_impl="auto", prompt_lens=None):
    """Real prefill: run the encoder over the source frames and fill the
    cross-attention K/V caches.  Decoder self caches start empty (decode
    begins from BOS), so ``None`` logits tell the driver to seed with BOS.

    ``frames`` must span the cache's memory length (the driver pads to it);
    ``prompt_lens`` is accepted for interface uniformity but ignored — the
    text side has no prompt, so there is nothing to bucket.
    """
    del prompt_lens
    memory = encode(cfg, pc, params, frames, attn_impl=attn_impl)
    return None, fill_cross_caches(cfg, pc, params, memory, caches)


def decode_step(cfg: ModelConfig, pc: ParamCtx, params, token, caches,
                *, attn_impl="auto"):
    """One decoder token against cached self-attn KV + cached cross K/V."""
    tp = pc.ctx.tp
    ad = attn_dims(cfg, tp)
    vl = padded_vocab_local(cfg, tp)
    x = L.vocab_embed(pc, "embed", params["embed"]["table"], token, vl)
    x = x.astype(pc.compute_dtype)
    decode_impl = "flash" if attn_impl == "flash" else "ref"

    def layer(x, scanned):
        lp, cache, ck, cv = scanned
        h = L.rmsnorm(pc, "dec/ln1", lp["ln1"], x, cfg.norm_eps)
        a, nc = decode_self_attention(pc, "dec/self", lp["self"], h, cache, ad,
                                      impl=decode_impl)
        x = x + a
        h = L.rmsnorm(pc, "dec/ln_x", lp["ln_x"], x, cfg.norm_eps)
        x = x + cross_attention_cached(pc, "dec/cross", lp["cross"], h, ck, cv, ad)
        h = L.rmsnorm(pc, "dec/ln2", lp["ln2"], x, cfg.norm_eps)
        return x + L.mlp(pc, "dec/mlp", lp["mlp"], h, cfg.mlp_act), nc

    x, new_self = jax.lax.scan(
        layer, x, (params["decoder"], caches["self"],
                   caches["cross_k"], caches["cross_v"]))
    x = L.rmsnorm(pc, "final_norm", params["final_norm"], x, cfg.norm_eps)
    logits = L.vocab_logits(pc, "unembed", params["unembed"]["w"], x)
    return logits, {"self": new_self, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}
