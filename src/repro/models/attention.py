"""Attention: GQA/MQA/MHA with tensor parallelism over heads.

Three execution paths, all per-shard local code:

* ``full``    — materialized scores; right for short sequences (train_4k smoke).
* ``chunked`` — online-softmax over key/value chunks (flash-style in pure
  jnp, ``lax.scan`` over KV blocks): O(S) memory, used for 32k prefill and
  as the lowering target the Pallas ``flash_attention`` kernel mirrors.
* ``decode``  — one query token against a KV cache.

Head sharding: q heads are split over the model axis; KV heads are split when
``n_kv % tp == 0`` and otherwise fully replicated per shard (cheap: KV
projections are small precisely when n_kv is small).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ParamCtx, init_dense
from repro.models.layers import apply_rope, dense, rope_tables, sp_out


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int
    d_model: int
    tp: int
    causal: bool = True
    rope_theta: float = 1e4
    chunk_q: int = 512
    chunk_kv: int = 1024

    @property
    def heads_local(self) -> int:
        assert self.n_heads % self.tp == 0, "q heads must divide tp"
        return self.n_heads // self.tp

    @property
    def kv_sharded(self) -> bool:
        return self.n_kv % self.tp == 0 and self.n_kv >= self.tp

    @property
    def kv_local(self) -> int:
        return self.n_kv // self.tp if self.kv_sharded else self.n_kv

    @property
    def group(self) -> int:
        """Queries per KV head, in local terms."""
        return self.heads_local // self.kv_local if self.kv_sharded \
            else self.n_heads // self.n_kv


def init_attention(keys, dims: AttnDims, dtype=jnp.float32, cross: bool = False):
    d, hd = dims.d_model, dims.head_dim
    p = {
        "wq": init_dense(next(keys), d, dims.heads_local * hd, dtype),
        "wk": init_dense(next(keys), d, dims.kv_local * hd, dtype),
        "wv": init_dense(next(keys), d, dims.kv_local * hd, dtype),
        "wo": init_dense(next(keys), dims.heads_local * hd, d, dtype),
    }
    return p


def _project_qkv(pc: ParamCtx, path, p, x, x_kv, dims: AttnDims, q_pos, kv_pos):
    B = x.shape[0]
    q = dense(pc, f"{path}/wq", p["wq"], x).reshape(B, -1, dims.heads_local, dims.head_dim)
    k = dense(pc, f"{path}/wk", p["wk"], x_kv).reshape(B, -1, dims.kv_local, dims.head_dim)
    v = dense(pc, f"{path}/wv", p["wv"], x_kv).reshape(B, -1, dims.kv_local, dims.head_dim)
    if q_pos is not None:  # rope (self-attention only)
        cq, sq = rope_tables(q_pos, dims.head_dim, dims.rope_theta)
        ck, sk = rope_tables(kv_pos, dims.head_dim, dims.rope_theta)
        q = apply_rope(q, cq, sq)
        k = apply_rope(k, ck, sk)
    return q, k, v


def _expand_kv(k, dims: AttnDims, tp_idx=None):
    """(B, S, KVl, hd) -> (B, S, Hl, hd): repeat each kv head ``group``x.

    kv-sharded: local kv heads expand to exactly the local q heads.
    kv-replicated (+tp>1): expand to ALL q heads, then slice this shard's
    q-head range (``tp_idx`` required).  With tp==1 the slice is identity.
    """
    e = jnp.repeat(k, dims.group, axis=2)
    if dims.kv_sharded or dims.tp == 1:
        return e
    if tp_idx is None:
        return e  # caller wants full heads (seq-parallel decode)
    return jax.lax.dynamic_slice_in_dim(
        e, tp_idx * dims.heads_local, dims.heads_local, axis=2)


def _full_attention(q, k, v, causal: bool, q_off: int = 0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd) — materialized scores."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        iq = jnp.arange(q.shape[1])[:, None] + q_off
        ik = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(ik <= iq, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _chunked_attention(q, k, v, causal: bool, chunk_kv: int):
    """Online-softmax over KV chunks (flash-style, O(S) memory).

    Mirrors kernels/flash_attention.py; this is the portable jnp lowering.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_chunks = Sk // chunk_kv
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    iq = jnp.arange(Sq)[:, None]

    def body(carry, ci):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ci * chunk_kv, chunk_kv, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ci * chunk_kv, chunk_kv, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, ks.astype(jnp.float32))
        if causal:
            ik = ci * chunk_kv + jnp.arange(chunk_kv)[None, :]
            s = jnp.where(ik <= iq, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B,Sq,H,hd)


def self_attention(pc: ParamCtx, path: str, p, x, dims: AttnDims,
                   *, impl: str = "auto"):
    """Training/prefill self-attention.  Returns (y, (k, v)) with local KV.

    ``impl``: ``full`` (materialized scores), ``chunked`` (online-softmax in
    jnp), ``flash`` (Pallas online-softmax kernel — the prefill fast path),
    or ``auto``.
    """
    S = x.shape[1]
    pos = jnp.arange(S)
    q, k, v = _project_qkv(pc, path, p, x, x, dims, pos, pos)
    tp_idx = pc.ctx.tp_index()
    ke, ve = _expand_kv(k, dims, tp_idx), _expand_kv(v, dims, tp_idx)
    if impl == "auto":
        impl = "chunked" if S > 4096 else "full"
    if impl == "flash":
        # (B,S,H,hd) -> kernel layout (B,H,S,hd) and back
        yt = ops.flash_attention(
            jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(ke, (0, 2, 1, 3)),
            jnp.transpose(ve, (0, 2, 1, 3)), causal=dims.causal)
        y = jnp.transpose(yt, (0, 2, 1, 3))
    elif impl == "chunked":
        y = _chunked_attention(q, ke, ve, dims.causal, min(dims.chunk_kv, S))
    else:
        y = _full_attention(q, ke, ve, dims.causal)
    B = x.shape[0]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    out = dense(pc, f"{path}/wo", p["wo"], y)
    return sp_out(pc, out), (k, v)


def project_cross_kv(pc: ParamCtx, path: str, p, memory, dims: AttnDims):
    """Precompute cross-attention K/V once (prefill); decode reuses them.

    Recomputing the memory projections per decode token is the difference
    between useful-compute ratios of ~0.01 and ~1 for VLM/enc-dec serving
    (EXPERIMENTS.md §Perf cell 3).
    """
    B = memory.shape[0]
    k = dense(pc, f"{path}/wk", p["wk"], memory).reshape(
        B, -1, dims.kv_local, dims.head_dim)
    v = dense(pc, f"{path}/wv", p["wv"], memory).reshape(
        B, -1, dims.kv_local, dims.head_dim)
    return k, v


def cross_attention_cached(pc: ParamCtx, path: str, p, x, k, v, dims: AttnDims):
    """Decode-path cross-attention against precomputed K/V."""
    B = x.shape[0]
    q = dense(pc, f"{path}/wq", p["wq"], x).reshape(
        B, -1, dims.heads_local, dims.head_dim)
    tp_idx = pc.ctx.tp_index()
    y = _full_attention(q, _expand_kv(k.astype(q.dtype), dims, tp_idx),
                        _expand_kv(v.astype(q.dtype), dims, tp_idx),
                        causal=False)
    S = x.shape[1]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    return pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))


def cross_attention(pc: ParamCtx, path: str, p, x, memory, dims: AttnDims):
    """Decoder -> encoder/image-memory attention (no causal mask, no rope)."""
    q, k, v = _project_qkv(pc, path, p, x, memory, dims, None, None)
    tp_idx = pc.ctx.tp_index()
    y = _full_attention(q, _expand_kv(k, dims, tp_idx), _expand_kv(v, dims, tp_idx),
                        causal=False)
    B, S = x.shape[0], x.shape[1]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    return sp_out(pc, dense(pc, f"{path}/wo", p["wo"], y))


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_local, KVl, hd)
    v: jnp.ndarray
    length: jnp.ndarray     # (B,) int32: tokens already cached, per sequence
                            # (continuous batching admits/evicts mid-flight,
                            # so every slot carries its own clock)


def kv_cache_seq_parallel(dims: AttnDims) -> bool:
    """When KV heads are replicated across tp, the cache is sharded over the
    SEQUENCE dim instead (the 'sequence-parallel KV cache'): without it each
    model shard would hold the full 32k cache (tens of GB for the 94L archs).
    """
    return dims.tp > 1 and not dims.kv_sharded


def init_kv_cache(batch: int, s_max: int, dims: AttnDims, dtype=jnp.bfloat16):
    s_local = s_max // dims.tp if kv_cache_seq_parallel(dims) else s_max
    shape = (batch, s_local, dims.kv_local, dims.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def prefill_kv_cache(pc: ParamCtx, cache: KVCache, k, v,
                     dims: AttnDims) -> KVCache:
    """Write a full prompt's K/V (B, S_p, KVl, hd) into a fresh cache.

    Works for both cache layouts: each shard keeps the slice of the prompt
    that falls in its global-position range (the whole prompt when the cache
    is not sequence-parallel).  Lengths are set to S_p for every sequence.
    """
    S_loc, S_p = cache.k.shape[1], k.shape[1]
    base = (pc.ctx.tp_index() * S_loc) if kv_cache_seq_parallel(dims) else 0
    gpos = base + jnp.arange(S_loc)
    idx = jnp.clip(gpos, 0, S_p - 1)
    sel = (gpos < S_p)[None, :, None, None]
    knew = jnp.where(sel, jnp.take(k.astype(cache.k.dtype), idx, axis=1), cache.k)
    vnew = jnp.where(sel, jnp.take(v.astype(cache.v.dtype), idx, axis=1), cache.v)
    return KVCache(knew, vnew, jnp.full((k.shape[0],), S_p, jnp.int32))


def decode_self_attention(pc: ParamCtx, path: str, p, x, cache: KVCache,
                          dims: AttnDims):
    """One-token decode: x (B, 1, D); returns (y, new_cache).

    Per-sequence lengths: slot b's new token writes at ``length[b]`` and
    attends to positions ``<= length[b]`` — sequences admitted at different
    times (continuous batching) coexist in one step.

    Two cache layouts:
    * kv-sharded (n_kv % tp == 0): cache (B, S_max, KV/tp, hd) — classic.
    * sequence-parallel: cache (B, S_max/tp, KV, hd); every shard computes
      partial attention over its sequence slice and the partials merge with a
      distributed online-softmax (pmax + psum) across the model axis.
    """
    seqpar = kv_cache_seq_parallel(dims)
    pos = cache.length[:, None]                      # (B, 1) per-seq positions
    q, k, v = _project_qkv(pc, path, p, x, x, dims, pos, pos)
    S_loc = cache.k.shape[1]
    scale = dims.head_dim ** -0.5

    if seqpar:
        # --- write: only the shard owning global position `length[b]` stores
        tp_idx = pc.ctx.tp_index()
        owner = cache.length // S_loc                               # (B,)
        local_pos = cache.length - owner * S_loc
        wmask = ((jnp.arange(S_loc)[None, :] == local_pos[:, None])
                 & (owner == tp_idx)[:, None])                      # (B,S)
        knew = jnp.where(wmask[:, :, None, None], k.astype(cache.k.dtype), cache.k)
        vnew = jnp.where(wmask[:, :, None, None], v.astype(cache.v.dtype), cache.v)
        # --- partial attention over the local slice ------------------------
        # Every shard needs ALL q heads against its slice: gather q (one
        # token — bytes are negligible next to the cache stream).
        qg = pc.ctx.all_gather_model(q, axis=2)     # (B, 1, H, hd)
        ke = _expand_kv(knew.astype(q.dtype), dims)  # kv replicated -> H heads
        ve = _expand_kv(vnew.astype(q.dtype), dims)
        s = jnp.einsum("bqhd,bkhd->bhqk", qg, ke).astype(jnp.float32) * scale
        gpos = tp_idx * S_loc + jnp.arange(S_loc)
        gmask = gpos[None, :] <= cache.length[:, None]              # (B,S)
        s = jnp.where(gmask[:, None, None, :], s, -1e30)
        ax = dims_model_axis(pc)
        m_loc = jnp.max(s, axis=-1)                                # (B,H,1)
        m_glob = jax.lax.pmax(m_loc, ax) if ax else m_loc
        pexp = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(pexp, axis=-1)
        acc_loc = jnp.einsum("bhqk,bkhd->bhqd", pexp.astype(q.dtype), ve)
        l_glob = jax.lax.psum(l_loc, ax) if ax else l_loc
        acc_glob = jax.lax.psum(acc_loc, ax) if ax else acc_loc
        y = (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None].astype(q.dtype))
        y = jnp.transpose(y, (0, 2, 1, 3))                          # (B,1,H,hd)
        # back to the local q-head slice for the row-parallel wo
        hl = dims.heads_local
        y = jax.lax.dynamic_slice_in_dim(y, tp_idx * hl, hl, axis=2)
    else:
        wmask = (jnp.arange(S_loc)[None, :] == cache.length[:, None])  # (B,S)
        knew = jnp.where(wmask[:, :, None, None], k.astype(cache.k.dtype), cache.k)
        vnew = jnp.where(wmask[:, :, None, None], v.astype(cache.v.dtype), cache.v)
        tp_idx2 = pc.ctx.tp_index()
        ke = _expand_kv(knew.astype(q.dtype), dims, tp_idx2)
        ve = _expand_kv(vnew.astype(q.dtype), dims, tp_idx2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * scale
        att_mask = (jnp.arange(S_loc)[None, :] <= cache.length[:, None])
        s = jnp.where(att_mask[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        y = jnp.einsum("bhqk,bkhd->bqhd", w, ve)

    B = x.shape[0]
    y = y.reshape(B, 1, dims.heads_local * dims.head_dim)
    out = pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))
    return out, KVCache(knew, vnew, cache.length + 1)


def dims_model_axis(pc: ParamCtx):
    return pc.ctx.model_axis
