"""Attention: GQA/MQA/MHA with tensor parallelism over heads.

Three execution paths, all per-shard local code:

* ``full``    — materialized scores; right for short sequences (train_4k smoke).
* ``chunked`` — online-softmax over key/value chunks (flash-style in pure
  jnp, ``lax.scan`` over KV blocks): O(S) memory, used for 32k prefill and
  as the lowering target the Pallas ``flash_attention`` kernel mirrors.
* ``decode``  — one query token against a KV cache: a contiguous
  :class:`KVCache` slab or a :class:`PagedKVCache` (shared page pool +
  per-slot page tables; ``impl="flash"`` walks the tables inside the
  batched flash-decode Pallas kernel).

Head sharding: q heads are split over the model axis; KV heads are split when
``n_kv % tp == 0`` and otherwise fully replicated per shard (cheap: KV
projections are small precisely when n_kv is small).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ParamCtx, init_dense
from repro.models.layers import apply_rope, dense, rope_tables, sp_out


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int
    d_model: int
    tp: int
    causal: bool = True
    rope_theta: float = 1e4
    chunk_q: int = 512
    chunk_kv: int = 1024

    @property
    def heads_local(self) -> int:
        assert self.n_heads % self.tp == 0, "q heads must divide tp"
        return self.n_heads // self.tp

    @property
    def kv_sharded(self) -> bool:
        return self.n_kv % self.tp == 0 and self.n_kv >= self.tp

    @property
    def kv_local(self) -> int:
        return self.n_kv // self.tp if self.kv_sharded else self.n_kv

    @property
    def group(self) -> int:
        """Queries per KV head, in local terms."""
        return self.heads_local // self.kv_local if self.kv_sharded \
            else self.n_heads // self.n_kv


def init_attention(keys, dims: AttnDims, dtype=jnp.float32, cross: bool = False):
    d, hd = dims.d_model, dims.head_dim
    p = {
        "wq": init_dense(next(keys), d, dims.heads_local * hd, dtype),
        "wk": init_dense(next(keys), d, dims.kv_local * hd, dtype),
        "wv": init_dense(next(keys), d, dims.kv_local * hd, dtype),
        "wo": init_dense(next(keys), dims.heads_local * hd, d, dtype),
    }
    return p


def _project_qkv(pc: ParamCtx, path, p, x, x_kv, dims: AttnDims, q_pos, kv_pos):
    B = x.shape[0]
    q = dense(pc, f"{path}/wq", p["wq"], x).reshape(B, -1, dims.heads_local, dims.head_dim)
    k = dense(pc, f"{path}/wk", p["wk"], x_kv).reshape(B, -1, dims.kv_local, dims.head_dim)
    v = dense(pc, f"{path}/wv", p["wv"], x_kv).reshape(B, -1, dims.kv_local, dims.head_dim)
    if q_pos is not None:  # rope (self-attention only)
        cq, sq = rope_tables(q_pos, dims.head_dim, dims.rope_theta)
        ck, sk = rope_tables(kv_pos, dims.head_dim, dims.rope_theta)
        q = apply_rope(q, cq, sq)
        k = apply_rope(k, ck, sk)
    return q, k, v


def _expand_kv(k, dims: AttnDims, tp_idx=None):
    """(B, S, KVl, hd) -> (B, S, Hl, hd): repeat each kv head ``group``x.

    kv-sharded: local kv heads expand to exactly the local q heads.
    kv-replicated (+tp>1): expand to ALL q heads, then slice this shard's
    q-head range (``tp_idx`` required).  With tp==1 the slice is identity.
    """
    e = jnp.repeat(k, dims.group, axis=2)
    if dims.kv_sharded or dims.tp == 1:
        return e
    if tp_idx is None:
        return e  # caller wants full heads (seq-parallel decode)
    return jax.lax.dynamic_slice_in_dim(
        e, tp_idx * dims.heads_local, dims.heads_local, axis=2)


def _full_attention(q, k, v, causal: bool, q_off: int = 0):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd) — materialized scores."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        iq = jnp.arange(q.shape[1])[:, None] + q_off
        ik = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(ik <= iq, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _chunked_attention(q, k, v, causal: bool, chunk_kv: int):
    """Online-softmax over KV chunks (flash-style, O(S) memory).

    Mirrors kernels/flash_attention.py; this is the portable jnp lowering.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    n_chunks = Sk // chunk_kv
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    iq = jnp.arange(Sq)[:, None]

    def body(carry, ci):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ci * chunk_kv, chunk_kv, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ci * chunk_kv, chunk_kv, axis=1)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, ks.astype(jnp.float32))
        if causal:
            ik = ci * chunk_kv + jnp.arange(chunk_kv)[None, :]
            s = jnp.where(ik <= iq, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B,Sq,H,hd)


def self_attention(pc: ParamCtx, path: str, p, x, dims: AttnDims,
                   *, impl: str = "auto"):
    """Training/prefill self-attention.  Returns (y, (k, v)) with local KV.

    ``impl``: ``full`` (materialized scores), ``chunked`` (online-softmax in
    jnp), ``flash`` (Pallas online-softmax kernel — the prefill fast path),
    or ``auto``.
    """
    S = x.shape[1]
    pos = jnp.arange(S)
    q, k, v = _project_qkv(pc, path, p, x, x, dims, pos, pos)
    tp_idx = pc.ctx.tp_index()
    ke, ve = _expand_kv(k, dims, tp_idx), _expand_kv(v, dims, tp_idx)
    if impl == "auto":
        impl = "chunked" if S > 4096 else "full"
    if impl == "flash":
        # (B,S,H,hd) -> kernel layout (B,H,S,hd) and back
        yt = ops.flash_attention(
            jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(ke, (0, 2, 1, 3)),
            jnp.transpose(ve, (0, 2, 1, 3)), causal=dims.causal)
        y = jnp.transpose(yt, (0, 2, 1, 3))
    elif impl == "chunked":
        y = _chunked_attention(q, ke, ve, dims.causal, min(dims.chunk_kv, S))
    else:
        y = _full_attention(q, ke, ve, dims.causal)
    B = x.shape[0]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    out = dense(pc, f"{path}/wo", p["wo"], y)
    return sp_out(pc, out), (k, v)


def project_cross_kv(pc: ParamCtx, path: str, p, memory, dims: AttnDims):
    """Precompute cross-attention K/V once (prefill); decode reuses them.

    Recomputing the memory projections per decode token is the difference
    between useful-compute ratios of ~0.01 and ~1 for VLM/enc-dec serving
    (EXPERIMENTS.md §Perf cell 3).
    """
    B = memory.shape[0]
    k = dense(pc, f"{path}/wk", p["wk"], memory).reshape(
        B, -1, dims.kv_local, dims.head_dim)
    v = dense(pc, f"{path}/wv", p["wv"], memory).reshape(
        B, -1, dims.kv_local, dims.head_dim)
    return k, v


def cross_attention_cached(pc: ParamCtx, path: str, p, x, k, v, dims: AttnDims):
    """Decode-path cross-attention against precomputed K/V."""
    B = x.shape[0]
    q = dense(pc, f"{path}/wq", p["wq"], x).reshape(
        B, -1, dims.heads_local, dims.head_dim)
    tp_idx = pc.ctx.tp_index()
    y = _full_attention(q, _expand_kv(k.astype(q.dtype), dims, tp_idx),
                        _expand_kv(v.astype(q.dtype), dims, tp_idx),
                        causal=False)
    S = x.shape[1]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    return pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))


def cross_attention(pc: ParamCtx, path: str, p, x, memory, dims: AttnDims):
    """Decoder -> encoder/image-memory attention (no causal mask, no rope)."""
    q, k, v = _project_qkv(pc, path, p, x, memory, dims, None, None)
    tp_idx = pc.ctx.tp_index()
    y = _full_attention(q, _expand_kv(k, dims, tp_idx), _expand_kv(v, dims, tp_idx),
                        causal=False)
    B, S = x.shape[0], x.shape[1]
    y = y.reshape(B, S, dims.heads_local * dims.head_dim)
    return sp_out(pc, dense(pc, f"{path}/wo", p["wo"], y))


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_local, KVl, hd)
    v: jnp.ndarray
    length: jnp.ndarray     # (B,) int32: tokens already cached, per sequence
                            # (continuous batching admits/evicts mid-flight,
                            # so every slot carries its own clock)


class PagedKVCache(NamedTuple):
    """Paged decode cache: fixed-size pages allocated from a shared pool.

    ``k_pages``/``v_pages``: ``(N_pool, page, KVl, hd)`` — this shard's page
    pool, shared by every slot, so short and long prompts stop paying the
    same ``s_max`` footprint.  ``page_table``: ``(B, n_pmax)`` int32 — slot
    b's logical page ``j`` lives at pool row ``page_table[b, j]``; ``-1``
    marks an unallocated page (reads of it are masked, writes to it are
    dropped — a capacity overflow can never corrupt another slot's pages).
    ``length``: ``(B,)`` int32 GLOBAL tokens cached per sequence.

    Logical pages cover the SAME per-shard position range as the contiguous
    layout (kv-sharded: all of ``s_max``; sequence-parallel: this shard's
    ``s_max/tp`` slice), so the reference paged decode reconstructs the
    contiguous view exactly and stays bitwise-equal to :class:`KVCache`.
    """

    k_pages: jnp.ndarray
    v_pages: jnp.ndarray
    page_table: jnp.ndarray
    length: jnp.ndarray

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[-3]


def kv_cache_seq_parallel(dims: AttnDims) -> bool:
    """When KV heads are replicated across tp, the cache is sharded over the
    SEQUENCE dim instead (the 'sequence-parallel KV cache'): without it each
    model shard would hold the full 32k cache (tens of GB for the 94L archs).
    """
    return dims.tp > 1 and not dims.kv_sharded


def init_kv_cache(batch: int, s_max: int, dims: AttnDims, dtype=jnp.bfloat16):
    s_local = s_max // dims.tp if kv_cache_seq_parallel(dims) else s_max
    shape = (batch, s_local, dims.kv_local, dims.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32))


def init_paged_kv_cache(batch: int, s_max: int, dims: AttnDims,
                        dtype=jnp.bfloat16, *, page_size: int,
                        pool_pages: int | None = None) -> PagedKVCache:
    """Paged cache with an all-unallocated page table (entries -1).

    ``pool_pages`` is the PER-SHARD pool size; the default matches the
    contiguous footprint (``batch * s_local/page``) — drivers shrink it to
    the actual workload demand, which is where the memory win comes from.
    """
    seqpar = kv_cache_seq_parallel(dims)
    if seqpar and s_max % dims.tp:
        raise ValueError(f"s_max={s_max} must divide tp={dims.tp} for the "
                         "sequence-parallel paged cache")
    s_local = s_max // dims.tp if seqpar else s_max
    if s_local % page_size:
        raise ValueError(f"page_size={page_size} must divide the per-shard "
                         f"sequence capacity {s_local}")
    n_pmax = s_local // page_size
    if pool_pages is None:
        pool_pages = batch * n_pmax
    shape = (pool_pages, page_size, dims.kv_local, dims.head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        jnp.full((batch, n_pmax), -1, jnp.int32),
                        jnp.zeros((batch,), jnp.int32))


def demote_kv_cache(caches, dtype):
    """Cast every KV cache's key/value storage to ``dtype`` mid-run.

    Page tables and per-slot lengths are preserved, so a serving driver can
    demote a pressured f32 pool to bf16 without disturbing admissions —
    the jitted decode step simply retraces on the new cache dtype.
    """
    import jax

    def _one(c):
        if isinstance(c, PagedKVCache):
            return c._replace(k_pages=c.k_pages.astype(dtype),
                              v_pages=c.v_pages.astype(dtype))
        if isinstance(c, KVCache):
            return c._replace(k=c.k.astype(dtype), v=c.v.astype(dtype))
        return c

    return jax.tree_util.tree_map(
        _one, caches,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))


def _check_prompt_fits(S_p: int, S_loc: int, dims: AttnDims) -> None:
    S_glob = S_loc * (dims.tp if kv_cache_seq_parallel(dims) else 1)
    if S_p > S_glob:
        raise ValueError(
            f"prompt length {S_p} exceeds the KV-cache capacity {S_glob} "
            "(s_max); raise s_max or bucket the request — refusing to "
            "silently truncate the prompt")


def prefill_kv_cache(pc: ParamCtx, cache, k, v, dims: AttnDims,
                     prompt_lens=None):
    """Write a full prompt's K/V (B, S_p, KVl, hd) into a fresh cache.

    Works for both cache layouts (each shard keeps the slice of the prompt
    that falls in its global-position range) and both storage layouts
    (contiguous :class:`KVCache` slab or :class:`PagedKVCache` pool).
    ``prompt_lens``: optional (B,) per-slot true lengths when the prompt
    batch is right-padded to a bucket; lengths default to S_p for every
    sequence.  Prompts longer than the cache raise instead of truncating.
    """
    if isinstance(cache, PagedKVCache):
        return _prefill_paged(pc, cache, k, v, dims, prompt_lens)
    S_loc, S_p = cache.k.shape[1], k.shape[1]
    _check_prompt_fits(S_p, S_loc, dims)
    base = (pc.ctx.tp_index() * S_loc) if kv_cache_seq_parallel(dims) else 0
    plens = (jnp.full((k.shape[0],), S_p, jnp.int32) if prompt_lens is None
             else prompt_lens.astype(jnp.int32))
    gpos = base + jnp.arange(S_loc)
    idx = jnp.clip(gpos, 0, S_p - 1)
    sel = (gpos[None, :] < plens[:, None])[:, :, None, None]
    knew = jnp.where(sel, jnp.take(k.astype(cache.k.dtype), idx, axis=1), cache.k)
    vnew = jnp.where(sel, jnp.take(v.astype(cache.v.dtype), idx, axis=1), cache.v)
    return KVCache(knew, vnew, plens)


def _prefill_paged(pc: ParamCtx, cache: PagedKVCache, k, v, dims: AttnDims,
                   prompt_lens=None) -> PagedKVCache:
    B, S_p = k.shape[0], k.shape[1]
    n_pmax = cache.page_table.shape[1]
    page = cache.page_size
    S_loc = n_pmax * page
    _check_prompt_fits(S_p, S_loc, dims)
    base = (pc.ctx.tp_index() * S_loc) if kv_cache_seq_parallel(dims) else 0
    plens = (jnp.full((B,), S_p, jnp.int32) if prompt_lens is None
             else prompt_lens.astype(jnp.int32))
    gpos = base + jnp.arange(S_loc)
    idx = jnp.clip(gpos, 0, S_p - 1)
    sel = gpos[None, :] < plens[:, None]                      # (B, S_loc)
    pids = jnp.maximum(cache.page_table, 0)
    n_pool = cache.k_pages.shape[0]
    tgt = jnp.where(cache.page_table >= 0, cache.page_table, n_pool)

    def write(pages, src):
        src_loc = jnp.take(src.astype(pages.dtype), idx, axis=1)
        src_pg = src_loc.reshape((B, n_pmax, page) + src_loc.shape[2:])
        content = jnp.where(sel.reshape(B, n_pmax, page)[..., None, None],
                            src_pg, pages[pids])
        # unique targets by construction (a page belongs to one slot); the
        # out-of-range id n_pool drops unallocated pages' writes
        return pages.at[tgt].set(content, mode="drop")

    return PagedKVCache(write(cache.k_pages, k), write(cache.v_pages, v),
                        cache.page_table, plens)


def _attend_decode(pc: ParamCtx, q, kview, vview, length, dims: AttnDims,
                   extra_mask=None):
    """One-token decode attention over a local contiguous K/V view.

    ``kview``/``vview``: (B, S_loc, KVl, hd) — a contiguous slab or the
    page-gathered reconstruction of one (identical math either way, so the
    paged path stays bitwise-equal to the contiguous reference).  Positions
    ``<= length[b]`` are attended; ``extra_mask`` (B, S_loc) further
    restricts (paged: unallocated pages).  Sequence-parallel layouts merge
    per-shard partials with a distributed online softmax (pmax + psum).
    Returns y (B, 1, heads_local, hd).
    """
    S_loc = kview.shape[1]
    scale = dims.head_dim ** -0.5
    if kv_cache_seq_parallel(dims):
        tp_idx = pc.ctx.tp_index()
        # Every shard needs ALL q heads against its slice: gather q (one
        # token — bytes are negligible next to the cache stream).
        qg = pc.ctx.all_gather_model(q, axis=2)      # (B, 1, H, hd)
        ke = _expand_kv(kview.astype(q.dtype), dims)  # kv replicated -> H heads
        ve = _expand_kv(vview.astype(q.dtype), dims)
        s = jnp.einsum("bqhd,bkhd->bhqk", qg, ke).astype(jnp.float32) * scale
        gpos = tp_idx * S_loc + jnp.arange(S_loc)
        gmask = gpos[None, :] <= length[:, None]                    # (B,S)
        if extra_mask is not None:
            gmask = jnp.logical_and(gmask, extra_mask)
        s = jnp.where(gmask[:, None, None, :], s, -1e30)
        ax = dims_model_axis(pc)
        m_loc = jnp.max(s, axis=-1)                                # (B,H,1)
        m_glob = jax.lax.pmax(m_loc, ax) if ax else m_loc
        pexp = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(pexp, axis=-1)
        acc_loc = jnp.einsum("bhqk,bkhd->bhqd", pexp.astype(q.dtype), ve)
        l_glob = jax.lax.psum(l_loc, ax) if ax else l_loc
        acc_glob = jax.lax.psum(acc_loc, ax) if ax else acc_loc
        y = (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None].astype(q.dtype))
        y = jnp.transpose(y, (0, 2, 1, 3))                          # (B,1,H,hd)
        # back to the local q-head slice for the row-parallel wo
        hl = dims.heads_local
        return jax.lax.dynamic_slice_in_dim(y, tp_idx * hl, hl, axis=2)
    tp_idx = pc.ctx.tp_index()
    ke = _expand_kv(kview.astype(q.dtype), dims, tp_idx)
    ve = _expand_kv(vview.astype(q.dtype), dims, tp_idx)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * scale
    att_mask = (jnp.arange(S_loc)[None, :] <= length[:, None])
    if extra_mask is not None:
        att_mask = jnp.logical_and(att_mask, extra_mask)
    s = jnp.where(att_mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, ve)


def decode_self_attention(pc: ParamCtx, path: str, p, x, cache,
                          dims: AttnDims, *, impl: str = "ref"):
    """One-token decode: x (B, 1, D); returns (y, new_cache).

    Per-sequence lengths: slot b's new token writes at ``length[b]`` and
    attends to positions ``<= length[b]`` — sequences admitted at different
    times (continuous batching) coexist in one step.

    Storage dispatch on the cache type:
    * :class:`KVCache` — contiguous slab, kv-sharded (B, S_max, KV/tp, hd)
      or sequence-parallel (B, S_max/tp, KV, hd) with a distributed online
      softmax merging the per-shard partials.
    * :class:`PagedKVCache` — shared page pool + per-slot page tables, same
      position ownership per shard.  ``impl="ref"`` gathers pages into the
      contiguous view (bitwise-equal to :class:`KVCache`); ``impl="flash"``
      walks the page table inside the batched flash-decode Pallas kernel
      (no (B, S) materialization; fp-accumulation order differs).
    """
    if isinstance(cache, PagedKVCache):
        return _decode_paged(pc, path, p, x, cache, dims, impl=impl)
    seqpar = kv_cache_seq_parallel(dims)
    pos = cache.length[:, None]                      # (B, 1) per-seq positions
    q, k, v = _project_qkv(pc, path, p, x, x, dims, pos, pos)
    S_loc = cache.k.shape[1]

    if seqpar:
        # write: only the shard owning global position `length[b]` stores
        tp_idx = pc.ctx.tp_index()
        owner = cache.length // S_loc                               # (B,)
        local_pos = cache.length - owner * S_loc
        wmask = ((jnp.arange(S_loc)[None, :] == local_pos[:, None])
                 & (owner == tp_idx)[:, None])                      # (B,S)
    else:
        wmask = (jnp.arange(S_loc)[None, :] == cache.length[:, None])
    knew = jnp.where(wmask[:, :, None, None], k.astype(cache.k.dtype), cache.k)
    vnew = jnp.where(wmask[:, :, None, None], v.astype(cache.v.dtype), cache.v)
    y = _attend_decode(pc, q, knew, vnew, cache.length, dims)

    B = x.shape[0]
    y = y.reshape(B, 1, dims.heads_local * dims.head_dim)
    out = pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))
    return out, KVCache(knew, vnew, cache.length + 1)


def _paged_write_token(cache: PagedKVCache, k_tok, v_tok, dims: AttnDims,
                       tp_idx):
    """Write one token's K/V (B, KVl, hd) at position ``length[b]``.

    The write lands in page ``page_table[b, pos // page]`` at offset
    ``pos % page``; it is DROPPED (not clipped onto a live page) when the
    position falls outside this shard's range or the page is unallocated —
    a slot past its capacity can only lose its own new token, never clobber
    another slot's pages.
    """
    B, n_pmax = cache.page_table.shape
    page = cache.page_size
    n_pool = cache.k_pages.shape[0]
    S_loc = n_pmax * page
    if kv_cache_seq_parallel(dims):
        owner = cache.length // S_loc
        in_range = owner == tp_idx
        lpos = cache.length - owner * S_loc
    else:
        in_range = cache.length < S_loc
        lpos = cache.length
    lpos = jnp.where(in_range, lpos, 0)
    j = lpos // page
    off = lpos % page
    pid = jnp.take_along_axis(cache.page_table, j[:, None], axis=1)[:, 0]
    ok = jnp.logical_and(in_range, pid >= 0)
    tgt = jnp.where(ok, pid, n_pool)                 # n_pool = dropped
    k_pages = cache.k_pages.at[tgt, off].set(
        k_tok.astype(cache.k_pages.dtype), mode="drop")
    v_pages = cache.v_pages.at[tgt, off].set(
        v_tok.astype(cache.v_pages.dtype), mode="drop")
    return k_pages, v_pages


def _decode_paged(pc: ParamCtx, path: str, p, x, cache: PagedKVCache,
                  dims: AttnDims, *, impl: str = "ref"):
    pos = cache.length[:, None]
    q, k, v = _project_qkv(pc, path, p, x, x, dims, pos, pos)
    tp_idx = pc.ctx.tp_index()
    k_pages, v_pages = _paged_write_token(cache, k[:, 0], v[:, 0], dims, tp_idx)
    new_cache = PagedKVCache(k_pages, v_pages, cache.page_table,
                             cache.length + 1)
    B, n_pmax = cache.page_table.shape
    page = cache.page_size
    if impl == "flash":
        y = _paged_flash_attend(pc, q, new_cache, dims, tp_idx)
    else:
        # reference path: gather pages into the contiguous per-shard view and
        # run the exact slab math (bitwise-equal to the KVCache layout)
        pids = jnp.maximum(cache.page_table, 0)
        kview = k_pages[pids].reshape(
            (B, n_pmax * page) + k_pages.shape[2:])
        vview = v_pages[pids].reshape(
            (B, n_pmax * page) + v_pages.shape[2:])
        alloc = jnp.repeat(cache.page_table >= 0, page, axis=1)  # (B, S_loc)
        y = _attend_decode(pc, q, kview, vview, cache.length, dims,
                           extra_mask=alloc)
    y = y.reshape(B, 1, dims.heads_local * dims.head_dim)
    out = pc.ctx.psum_model(dense(pc, f"{path}/wo", p["wo"], y))
    return out, new_cache


def _paged_flash_attend(pc: ParamCtx, q, cache: PagedKVCache, dims: AttnDims,
                        tp_idx):
    """Batched flash-decode over the page pool (Pallas kernel).

    The kernel walks each slot's page table with an online softmax over the
    key dimension and returns unnormalized (acc, m, l) partials; the
    sequence-parallel layout merges them across the model axis exactly like
    the reference distributed softmax.  Returns y (B, 1, heads_local, hd).
    """
    from repro.kernels import ops

    seqpar = kv_cache_seq_parallel(dims)
    B, n_pmax = cache.page_table.shape
    S_loc = n_pmax * cache.page_size
    hd = dims.head_dim
    if seqpar:
        qh = pc.ctx.all_gather_model(q, axis=2)[:, 0]        # (B, H, hd)
        kvh, n_q = dims.kv_local, dims.n_heads
        base = tp_idx * S_loc
    else:
        qh = q[:, 0]                                         # (B, Hl, hd)
        kvh, n_q = dims.kv_local, dims.heads_local
        base = 0
    # group q heads by their kv head (matches _expand_kv's repeat order)
    qr = qh.reshape(B, kvh, n_q // kvh, hd)
    # cache.length was already incremented by the write, so it IS the valid
    # token count (including the just-written token); clip to local coords
    lloc = jnp.clip(cache.length - base, 0, S_loc)
    acc, m, l = ops.flash_paged_decode(qr, cache.k_pages, cache.v_pages,
                                       cache.page_table, lloc)
    ax = dims_model_axis(pc)
    if seqpar and ax:
        m_glob = jax.lax.pmax(m, ax)
        corr = jnp.exp(m - m_glob)
        l = jax.lax.psum(l * corr, ax)
        acc = jax.lax.psum(acc * corr, ax)
    y = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)        # (B,KVh,G,hd)
    y = y.reshape(B, 1, n_q, hd)
    if seqpar:
        hl = dims.heads_local
        y = jax.lax.dynamic_slice_in_dim(y, tp_idx * hl, hl, axis=2)
    return y


def dims_model_axis(pc: ParamCtx):
    return pc.ctx.model_axis


# ---------------------------------------------------------------------------
# Slot-granular cache merges (continuous batching / bucketed prefill)
# ---------------------------------------------------------------------------


def merge_slot_caches(old, new, keep):
    """Per-slot cache merge: ``keep[b]`` selects slot b's state from ``new``.

    Ordinary cache leaves are layer-stacked ``(L, B, ...)`` and merge with a
    masked where on the slot dim.  :class:`PagedKVCache` pools merge at PAGE
    granularity through the page table (a slot's pages live scattered in the
    shared pool, so a slot-dim where cannot apply): kept slots' pages are
    scattered from ``new`` into ``old``, every other pool row is untouched.
    """
    def one(o, n):
        if isinstance(o, PagedKVCache):
            return _merge_paged_stacked(o, n, keep)
        return jnp.where(keep.reshape((1, -1) + (1,) * (o.ndim - 2)), n, o)

    return jax.tree_util.tree_map(
        one, old, new, is_leaf=lambda x: isinstance(x, PagedKVCache))


def _merge_paged_stacked(old: PagedKVCache, new: PagedKVCache, keep):
    """Layer-stacked (L, ...) paged merge; ``keep`` (B,) is layer-invariant."""
    def merge_layer(o: PagedKVCache, n: PagedKVCache):
        n_pool = o.k_pages.shape[0]
        pids = jnp.maximum(n.page_table, 0)
        tgt = jnp.where((n.page_table >= 0) & keep[:, None],
                        n.page_table, n_pool)

        def pool(po, pn):
            return po.at[tgt].set(pn[pids], mode="drop")

        return PagedKVCache(
            pool(o.k_pages, n.k_pages), pool(o.v_pages, n.v_pages),
            jnp.where(keep[:, None], n.page_table, o.page_table),
            jnp.where(keep, n.length, o.length))

    return jax.vmap(merge_layer)(old, new)


def fresh_slot_caches(caches):
    """Zeroed per-slot state for a prefill pass, KEEPING page tables.

    The prefill needs the live tables to place its pages;
    :func:`merge_slot_caches` discards the non-admitted slots' (and any
    untouched) pages afterwards.
    """
    def one(c):
        if isinstance(c, PagedKVCache):
            return PagedKVCache(jnp.zeros_like(c.k_pages),
                                jnp.zeros_like(c.v_pages),
                                c.page_table, jnp.zeros_like(c.length))
        return jax.tree_util.tree_map(jnp.zeros_like, c)

    return jax.tree_util.tree_map(
        one, caches, is_leaf=lambda x: isinstance(x, PagedKVCache))
