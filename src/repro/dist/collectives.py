"""Mesh-axis context and collectives for the shard_map model zoo.

:class:`AxisCtx` is the one object threaded through every layer (via
``ParamCtx.ctx``): it names the mesh axes a computation runs under and turns
them into sizes, indices, and collectives.  All model code is *local
per-shard* code (Megatron-JAX style), so the context is how a layer asks
"which tensor-parallel rank am I" or "all-reduce this over the clients".

Design rules
------------
* **Sizes are static.**  ``ctx.dp`` / ``ctx.tp`` / ``ctx.fsdp`` use the
  constant-folding of ``lax.psum(1, axis)``, which inside ``shard_map``
  returns a Python int.  That staticness is load-bearing: the FSDP
  participation rules in :mod:`repro.models.common` branch on these values
  at trace time.  Outside any mesh context every size is 1 and every index
  is 0, so the same model code runs unsharded (unit tests, ``eval_shape``
  probes) with all collectives degenerating to identities.
* **Flattened batch index.**  Multi-axis data parallelism (``("pod",
  "data")``) is flattened row-major by ``lax.axis_index`` with the axis
  tuple; ``lax.all_gather`` over the same tuple tiles in the identical
  order, so the FSDP slice/gather pair in ``models/common.py`` round-trips
  by construction.
* **Quantized gradient all-reduce.**  :func:`quantized_psum_batch` is the
  paper's Eq. 1 stochastic-rounding quantizer applied to *model updates on
  the wire* (cf. arXiv:2402.12957, arXiv:1911.02417): clients agree on a
  shared grid via a ``pmax`` of the per-client scale, SR-quantize onto
  integer codes, ``psum`` the codes (integers sum exactly — no
  re-quantization error at the server), and dequantize to the mean.
  Unbiased for every bit-width because SR is unbiased per client.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import _jax_compat
from repro.core.quantization import FULL_PRECISION_BITS, _sr_round

_jax_compat.install()


def _axis_size(names: tuple[str, ...]) -> int:
    """Static product of the named axis sizes; 1 when unbound/empty.

    ``lax.psum`` of a Python constant is constant-folded to ``size * x``
    inside shard_map/pmap, so this is a trace-time int, not a tracer.
    """
    if not names:
        return 1
    try:
        return int(jax.lax.psum(1, names if len(names) > 1 else names[0]))
    except NameError:      # outside any mesh context (eval_shape, unit tests)
        return 1


def _axis_index(names: tuple[str, ...]):
    """Flattened (row-major) index over ``names``; 0 when unbound/empty."""
    if not names:
        return 0
    try:
        return jax.lax.axis_index(names if len(names) > 1 else names[0])
    except NameError:
        return 0


@dataclasses.dataclass(frozen=True)
class AxisCtx:
    """Named mesh axes of one launch configuration.

    ``batch_axes``: data-parallel axes — one FL client per group.
    ``model_axis``: tensor-parallel axis (None = no TP).
    ``fsdp_axes``:  axes parameters are fully-sharded over (in practice the
    batch axes: FSDP rides on data parallelism).
    """

    batch_axes: tuple[str, ...]
    model_axis: str | None
    fsdp_axes: tuple[str, ...]

    # --- static sizes ----------------------------------------------------
    @property
    def dp(self) -> int:
        """Number of data-parallel groups (= FL clients) in scope."""
        return _axis_size(tuple(self.batch_axes))

    @property
    def tp(self) -> int:
        return _axis_size((self.model_axis,) if self.model_axis else ())

    @property
    def fsdp(self) -> int:
        return _axis_size(tuple(self.fsdp_axes))

    # --- indices ---------------------------------------------------------
    def dp_index(self):
        """Flattened data-parallel rank (client id); 0 outside a mesh."""
        return _axis_index(tuple(self.batch_axes))

    def tp_index(self):
        return _axis_index((self.model_axis,) if self.model_axis else ())

    # --- model-axis collectives -----------------------------------------
    def psum_model(self, x):
        if self.model_axis is None:
            return x
        return jax.lax.psum(x, self.model_axis)

    def pmean_model(self, x):
        if self.model_axis is None:
            return x
        return jax.lax.pmean(x, self.model_axis)

    def all_gather_model(self, x, *, axis: int):
        if self.model_axis is None:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def psum_scatter_model(self, x, *, axis: int):
        if self.model_axis is None:
            return x
        return jax.lax.psum_scatter(x, self.model_axis,
                                    scatter_dimension=axis, tiled=True)

    # --- batch/FSDP collectives -----------------------------------------
    def psum_batch(self, x):
        if not self.batch_axes:
            return x
        return jax.lax.psum(x, tuple(self.batch_axes))

    def pmean_batch(self, x):
        if not self.batch_axes:
            return x
        return jax.lax.pmean(x, tuple(self.batch_axes))

    def gather_fsdp(self, x, *, axis: int):
        """Tiled all-gather of FSDP-sharded storage along ``axis``.

        The transpose under autodiff is a reduce-scatter, which is what
        makes FSDP gradients come back sharded for free (DESIGN.md §4).
        """
        if self.fsdp == 1:
            return x
        names = tuple(self.fsdp_axes)
        return jax.lax.all_gather(x, names if len(names) > 1 else names[0],
                                  axis=axis, tiled=True)


def code_bound(bits: int) -> int:
    """Largest |code| a ``bits``-wide SR quantizer can emit: ``2^bits - 1``.

    This is the *exactness contract* between the runtime and the static
    analyzer: :func:`quantized_psum_batch` clips its codes to
    ``±code_bound(bits)`` before the integer all-reduce, and both
    :func:`wire_dtype` (runtime) and ``repro.analyze`` (static, via the
    interval interpreter and the analytic per-cell proof) reason from the
    same bound — ``n_clients * code_bound(bits)`` must fit the accumulator.
    """
    return 2 ** int(bits) - 1


def wire_dtype(bits: int, n_clients: int):
    """Narrowest signed integer dtype whose sum of codes is exact.

    Per-client codes lie in ``[-code_bound(bits), code_bound(bits)]``; an
    all-reduce over ``n_clients`` needs the accumulator to hold
    ``n * code_bound(bits)``.  This is the dtype that actually crosses the
    wire, so lower ``comm`` bits shrink the measured all-reduce bytes
    (s8/s16 vs f32 in the HLO) instead of always paying the int32
    accumulator.
    """
    need = n_clients * code_bound(bits)
    if need <= jnp.iinfo(jnp.int8).max:
        return jnp.int8
    if need <= jnp.iinfo(jnp.int16).max:
        return jnp.int16
    if need <= jnp.iinfo(jnp.int32).max:
        return jnp.int32
    # int64 is no escape hatch: without jax_enable_x64 it silently becomes
    # int32 again, so refuse rather than wrap around
    raise ValueError(
        f"comm bits={bits} with {n_clients} clients needs an accumulator "
        f"holding {need} > int32 max; lower the bit-width (<= 16 is always "
        "safe below 32768 clients) or use 32 (uncompressed)")


def envelope_wire_dtype(bits_options, n_clients: int):
    """Widest accumulator ANY bit-width in an adaptive program's comm
    envelope needs, or ``None`` when the whole envelope is uncompressed.

    Calls :func:`wire_dtype` on every compressed member, so it raises if any
    round of any schedule the program can emit would overflow the int32
    accumulator — proving the envelope proves the whole run.
    """
    compressed = [b for b in sorted({int(b) for b in bits_options})
                  if b < FULL_PRECISION_BITS]
    if not compressed:
        return None
    dts = [wire_dtype(b, n_clients) for b in compressed]
    return max(dts, key=lambda d: jnp.dtype(d).itemsize)


def _nonfinite_guard(gf, on_nonfinite: str, ax=()):
    """Keep NaN/Inf gradients out of the wire quantizer.

    A non-finite leaf would poison the shared scale (``pmax`` of Inf/NaN)
    and quantize every client's codes into garbage *silently*.  ``"raise"``
    surfaces it as a runtime error via a host callback whose result is tied
    into the dataflow (so DCE cannot drop the check); ``"saturate"`` maps
    NaN to 0 and clamps ±Inf to the client's largest finite magnitude.

    ``ax`` names the batch axes when called inside a collective: the bad
    count is psum'd over them first so every shard reaches the same
    verdict.  Without this the clean shards enter the scale ``pmax`` while
    the poisoned shards raise in the callback, and the all-reduce
    rendezvous deadlocks waiting for participants that will never arrive.
    """
    if on_nonfinite == "raise":
        bad = jnp.sum(jnp.where(jnp.isfinite(gf), 0, 1))
        if ax:
            bad = jax.lax.psum(bad, tuple(ax))

        def _host_check(nbad):
            if int(nbad):
                raise FloatingPointError(
                    f"quantized_psum_batch: {int(nbad)} non-finite gradient "
                    "values reached the wire quantizer (pass "
                    "on_nonfinite='saturate' to clamp instead)")
            return np.int32(0)

        token = jax.pure_callback(
            _host_check, jax.ShapeDtypeStruct((), jnp.int32), bad)
        # fold the (always-zero) token into the values so the callback is a
        # real dependency of the result, not dead code
        return gf + token.astype(jnp.float32)
    if on_nonfinite == "saturate":
        fmax = jnp.max(jnp.where(jnp.isfinite(gf), jnp.abs(gf), 0.0))
        return jnp.clip(jnp.where(jnp.isnan(gf), 0.0, gf), -fmax, fmax)
    raise ValueError(f"on_nonfinite must be 'raise' or 'saturate', "
                     f"got {on_nonfinite!r}")


def quantized_psum_batch(axes: AxisCtx, grad, rng, bits, *,
                         on_nonfinite: str = "raise"):
    """SR-quantized all-reduce **mean** of ``grad`` over the batch axes.

    Drop-in replacement for ``lax.pmean(grad, batch_axes)`` that moves
    ``bits``-wide integer codes on the wire instead of f32:

    1. shared grid: ``s = pmax_i max|g_i|``, resolution ``delta = 1/(2^b-1)``
       (paper Eq. 1 with the scale agreed across clients so codes are
       summable);
    2. each client stochastically rounds ``g_i / (s*delta)`` to integers
       with an independent key (folded by client id) — unbiased per Eq. 1;
    3. ``psum`` the codes: integer sums are exact, so the only error is the
       per-client SR noise — the server introduces none;
    4. dequantize and divide by the client count -> the mean.

    ``bits >= 32`` bypasses quantization (exact ``pmean``); a 1-group
    context is a no-op.  Returns E[out] == pmean(grad) for every bit-width.

    ``on_nonfinite`` guards the quantizer against NaN/Inf inputs (see
    :func:`_nonfinite_guard`): ``"raise"`` (default) fails loudly at
    runtime, ``"saturate"`` clamps and continues.
    """
    n = axes.dp
    if n == 1:
        return grad                       # single client: nothing to reduce
    ax = tuple(axes.batch_axes)
    if int(bits) >= FULL_PRECISION_BITS:
        return jax.lax.pmean(grad, ax)    # full precision: exact mean

    gf = _nonfinite_guard(grad.astype(jnp.float32), on_nonfinite, ax)
    s = jax.lax.pmax(jnp.max(jnp.abs(gf)), ax)
    s = jnp.where(s > 0, s, 1.0)
    lim = float(code_bound(int(bits)))
    step = s / lim                        # = s * Delta_q, the grid pitch
    ckey = jax.random.fold_in(rng, axes.dp_index())
    codes = _sr_round(gf / step, ckey)
    codes = jnp.clip(codes, -lim, lim)    # numeric guard; |t| <= lim already
    # Integer accumulation is exact as long as the dtype holds
    # n * (2^bits - 1) — wire_dtype picks the narrowest such dtype (s8/s16/
    # s32), so the all-reduce moves bits-scaled bytes instead of a fixed
    # int32 (f32 would round past 2^24: reachable at bits=16, ~257 clients).
    total = jax.lax.psum(codes.astype(wire_dtype(int(bits), n)), ax)
    return ((total.astype(jnp.float32) * step) / n).astype(grad.dtype)
