"""Distribution layer: mesh-axis context, collectives, and sharding rules.

``collectives`` carries :class:`AxisCtx` (the named-axis context threaded
through all model code) and the SR-quantized gradient all-reduce;
``sharding`` maps parameter paths / batches / decode caches to
``PartitionSpec`` layouts for ``shard_map``.
"""

from repro.dist.collectives import (  # noqa: F401
    AxisCtx,
    quantized_psum_batch,
    wire_dtype,
)
from repro.dist.wire import grad_wire_report  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    tp_dim,
    tree_param_specs,
)
