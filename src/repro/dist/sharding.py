"""Sharding-rule table: parameter/batch/cache PartitionSpecs for shard_map.

The model zoo initializes **local-TP** storage (``model.init(key, tp)``
returns each shard's slice) and FSDP slicing happens inside the mapped
function (``models.common.apply_fsdp_sharding``).  This module is the single
place that turns a parameter *path* into the global layout those two steps
imply — the specs handed to ``shard_map``'s ``in_specs``/``out_specs`` and
to the checkpoint/dry-run layers.

Rules are keyed on the leaf name (the path's last segment), mirroring the
Megatron conventions the layers implement:

=============  ====================================  =================
leaf           storage (per layer)                   TP-sharded dim
=============  ====================================  =================
``wq``         (d_model, heads_local*hd)             1 (column)
``wk``/``wv``  (d_model, kv_local*hd)                1 iff KV sharded
``wo``         (heads_local*hd | d_inner_l, d)       0 (row)
``w_up/gate``  mlp (d, d_ff/tp) / moe (e/tp, d, f)   1 / 0 (experts)
``w_down``     mlp (d_ff/tp, d) / moe (e/tp, f, d)   0 / 0 (experts)
``embed/table``(vocab/tp, d)                         0 (vocab rows)
``unembed/w``  (d, vocab/tp)                         1 (vocab cols)
``wx/wz/w_dt`` (d, d_inner_l | heads_l)              1 (column)
``w_bc``       (d, 2N) single-group                  replicated
``conv_x``     (W, d_inner_l)                        1
``conv_bc``    (W, 2N)                               replicated
``norm``       SSD gated norm (d_inner_l,)           0
``a_log`` ...  per-head scalars (heads_l,)           0
``ln*``, router, adapter, gates                      replicated
=============  ====================================  =================

FSDP placement reuses :func:`repro.models.common.fsdp_participates` /
``fsdp_shard_dim`` — the *same* predicate the init-time slicing uses, so
spec and storage cannot disagree.  A dim carrying both TP and FSDP (e.g.
``wo`` row dim) gets a major-to-minor tuple ``(model, *fsdp_axes)``,
matching init-slices-by-tp-then-fsdp storage order.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import AxisCtx

#: leaf-name -> per-layer TP dim for 2-D projections (None = replicated).
_TP_2D = {
    "wq": 1, "wo": 0,
    "w_up": 1, "w_gate": 1, "w_down": 0,
    "wx": 1, "wz": 1, "w_dt": 1,
    "conv_x": 1,
    "table": 0, "w": 1,
}

#: leaf names sharded over the expert dim when 3-D (MoE expert stacks).
_TP_EXPERT = ("w_up", "w_gate", "w_down")

#: 1-D per-head/per-channel leaves that are TP-local.
_TP_1D = ("norm", "a_log", "dt_bias", "d_skip")


def _basename(path: str) -> str:
    return path.rsplit("/", 1)[-1]


def tp_dim(path: str, ndim: int, kv: bool = True) -> int | None:
    """Tensor-parallel sharded dim of a parameter, in per-layer coordinates
    (any scanned-stack dim already stripped), or None if replicated.

    ``kv``: whether KV heads are sharded on this launch (``n_kv % tp == 0``);
    when False, ``wk``/``wv`` are fully replicated per shard.
    """
    base = _basename(path)
    if base in ("wk", "wv"):
        return 1 if kv else None
    if ndim == 3 and base in _TP_EXPERT:
        return 0                       # MoE expert stacks: shard experts
    if ndim == 1:
        return 0 if base in _TP_1D else None
    return _TP_2D.get(base)


def _kv_sharded(path: str, per_layer_shape: tuple[int, ...], cfg) -> bool:
    """Infer from storage whether KV heads were sharded at init: a replicated
    KV projection stores the *full* ``n_kv * head_dim`` output dim."""
    if _basename(path) not in ("wk", "wv") or not cfg.n_kv_heads:
        return True
    return per_layer_shape[-1] != cfg.n_kv_heads * cfg.resolved_head_dim


def _entry(names: tuple[str, ...] | None):
    if not names:
        return None
    return names if len(names) > 1 else names[0]


def _leaf_spec(path: str, arr, cfg, axes: AxisCtx, fsdp: int) -> P:
    from repro.models.common import fsdp_participates, fsdp_shard_dim, is_stacked

    off = 1 if (is_stacked(path) and arr.ndim >= 1) else 0
    nd = arr.ndim - off
    per_shape = tuple(arr.shape[off:])
    entries: list[tuple[str, ...] | None] = [None] * arr.ndim

    td = tp_dim(path, nd, _kv_sharded(path, per_shape, cfg))
    if td is not None and axes.model_axis is not None:
        entries[td + off] = (axes.model_axis,)

    if fsdp > 1 and axes.fsdp_axes and fsdp_participates(path, per_shape, fsdp):
        fd = fsdp_shard_dim(path, nd) + off
        entries[fd] = (entries[fd] or ()) + tuple(axes.fsdp_axes)

    return P(*[_entry(e) for e in entries])


def tree_param_specs(shapes, cfg, axes: AxisCtx, fsdp: int):
    """PartitionSpec tree matching a (local-storage) parameter tree.

    ``shapes``: pytree of arrays / ShapeDtypeStructs / QTensors holding the
    per-shard storage layout (TP applied at init; FSDP slicing may or may
    not have been applied — the rules only read sharding-invariant dims).
    ``fsdp``: total FSDP way-count of the launch (static).
    """
    from repro.models.common import QTensor, tree_paths_leaves

    paths, leaves, treedef = tree_paths_leaves(shapes)
    out = []
    for path, leaf in zip(paths, leaves):
        if isinstance(leaf, QTensor):
            out.append(QTensor(
                codes=_leaf_spec(path, leaf.codes, cfg, axes, fsdp),
                scale=P(*([None] * leaf.scale.ndim))))
        else:
            out.append(_leaf_spec(path, leaf, cfg, axes, fsdp))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch / cache layouts
# ---------------------------------------------------------------------------


def _batch_entry(axes: AxisCtx):
    ba = tuple(axes.batch_axes)
    if not ba:
        return None
    return ba if len(ba) > 1 else ba[0]


def batch_specs(batch_tree, axes: AxisCtx):
    """Shard every batch leaf's leading (global-batch) dim over the batch
    axes; all other dims replicated."""
    lead = _batch_entry(axes)

    def one(leaf):
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_specs(caches, axes: AxisCtx, cfg):
    """PartitionSpecs for decode caches (layer-stacked, batch-local storage).

    Self-attention KV caches follow :func:`repro.models.attention.
    kv_cache_seq_parallel`: KV-sharded launches split the KV-head dim over
    the model axis; KV-replicated launches split the *sequence* dim instead
    (each TP shard owns a slice of the context).  SSM caches split heads /
    channels.  Cross-attention K/V (full-memory, per shard) split the KV
    head dim only when KV is sharded.
    """
    from repro.models.attention import KVCache, PagedKVCache
    from repro.models.ssm import SSMCache

    model = axes.model_axis
    lead = _batch_entry(axes)

    def kv_sharded(n_kv_local: int) -> bool:
        return bool(cfg.n_kv_heads) and n_kv_local != cfg.n_kv_heads

    def self_kv(arr):                       # (L, B, S_local, KV_local, hd)
        if kv_sharded(arr.shape[3]):
            return P(None, lead, None, model, None)
        return P(None, lead, model, None, None)   # sequence-parallel cache

    def paged_kv(c: PagedKVCache) -> PagedKVCache:
        # pools: (L, N_pool, page, KV_local, hd); tables: (L, B, n_pmax).
        # kv-sharded: every shard holds all pages of its KV-head slice and
        # the SAME table.  Sequence-parallel: each shard owns a private pool
        # + table covering its s_max/tp position slice, so pool AND table
        # shard over the model axis.
        if kv_sharded(c.k_pages.shape[3]):
            pool = P(None, None, None, model, None)
            table = P(None, lead, None)
        else:
            pool = P(None, model, None, None, None)
            table = P(None, lead, model)
        return PagedKVCache(k_pages=pool, v_pages=pool, page_table=table,
                            length=P(None, lead))

    def one(c):
        if isinstance(c, PagedKVCache):
            return paged_kv(c)
        if isinstance(c, KVCache):
            # per-sequence lengths: (L, B) — batch-local like the K/V slabs
            return KVCache(k=self_kv(c.k), v=self_kv(c.v),
                           length=P(None, lead))
        if isinstance(c, SSMCache):
            return SSMCache(
                state=P(None, lead, model, None, None),   # (L,B,H_l,N,P)
                conv_x=P(None, lead, None, model),        # (L,B,W-1,d_in_l)
                conv_bc=P(None, lead, None, None))        # (L,B,W-1,2N)
        if c.ndim == 5:                      # cross K/V: (L,B,S_mem,KV_l,hd)
            if kv_sharded(c.shape[3]):
                return P(None, lead, None, model, None)
            return P(None, lead, None, None, None)
        return P(*((None,) if c.ndim == 1 else (None, lead) +
                   (None,) * (c.ndim - 2)))

    return jax.tree_util.tree_map(
        one, caches,
        is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache, SSMCache)))
