"""Gradient wire-byte accounting for the SR-quantized all-reduce.

:func:`quantized_psum_batch <repro.dist.collectives.quantized_psum_batch>`
compresses only the *replicated* gradient leaves — FSDP leaves are already
reduce-scattered (in f32) by the all-gather transpose, and re-compressing
them would double-reduce (see the wire-model note in
``repro/launch/steps.py``).  :func:`grad_wire_report` turns that split into
the bytes-on-wire numbers the sweep reporter publishes: how many gradient
bytes one training round moves at ``comm`` bits versus uncompressed f32.
"""

from __future__ import annotations

import numpy as np

from repro.core.quantization import FULL_PRECISION_BITS
from repro.dist.collectives import wire_dtype  # noqa: F401


def grad_wire_report(params_tree, *, fsdp: int, n_clients: int,
                     comm_bits: int) -> dict:
    """Per-round gradient wire bytes for one device, by reduction path.

    ``params_tree`` is the (local, post-FSDP) parameter tree or its
    ShapeDtypeStructs — the same template ``reduce_gradients`` partitions.
    Replicated leaves cross the wire once per all-reduce at the code dtype
    (plus one f32 scale scalar per leaf for the shared-grid ``pmax``);
    FSDP leaves reduce-scatter in f32 regardless of ``comm``.
    """
    from repro.models.common import QTensor, fsdp_plan

    _, leaves, _, plan = fsdp_plan(params_tree, fsdp,
                                   check_divisibility=False)
    repl_elems = fsdp_elems = n_repl_leaves = 0
    for leaf, dim in zip(leaves, plan):
        arr = leaf.codes if isinstance(leaf, QTensor) else leaf
        size = int(np.prod(arr.shape)) if arr.shape else 1
        if dim is None:
            repl_elems += size
            n_repl_leaves += 1
        else:
            fsdp_elems += size

    if n_clients <= 1:
        # single client: every reduction is a no-op — nothing crosses a wire
        return {
            "n_clients": int(n_clients), "comm_bits": int(comm_bits),
            "wire_dtype": "none", "replicated_elems": int(repl_elems),
            "replicated_leaves": int(n_repl_leaves),
            "fsdp_elems": int(fsdp_elems), "replicated_bytes_f32": 0,
            "replicated_bytes_wire": 0, "fsdp_reduce_scatter_bytes": 0,
            "wire_ratio": 1.0,
        }
    # same gate as quantized_psum_batch's bypass: >= full precision is f32
    compressed = int(comm_bits) < FULL_PRECISION_BITS
    dt = wire_dtype(comm_bits, n_clients) if compressed else np.float32
    itemsize = np.dtype(dt).itemsize
    f32_bytes = repl_elems * 4
    wire_bytes = (repl_elems * itemsize + n_repl_leaves * 4 if compressed
                  else f32_bytes)
    return {
        "n_clients": int(n_clients),
        "comm_bits": int(comm_bits),
        "wire_dtype": np.dtype(dt).name if compressed else "float32",
        "replicated_elems": int(repl_elems),
        "replicated_leaves": int(n_repl_leaves),
        "fsdp_elems": int(fsdp_elems),
        "replicated_bytes_f32": int(f32_bytes),
        "replicated_bytes_wire": int(wire_bytes),
        "fsdp_reduce_scatter_bytes": int(fsdp_elems * 4),
        "wire_ratio": wire_bytes / max(f32_bytes, 1),
    }


def wire_scale(comm_bits: int, n_clients: int) -> float:
    """Fraction of the f32 payload that crosses the wire at ``comm_bits``.

    The SR all-reduce ships codes at :func:`wire_dtype`'s itemsize, so the
    factor is ``itemsize / 4`` (exactly ``1.0`` when uncompressed — callers
    that multiply a static f32 payload by it stay bit-identical).  The
    fault executor bills retransmissions against this scaled payload, which
    is how an adaptive program's comm demotion shows up as measured energy
    savings under packet loss.
    """
    if int(comm_bits) >= FULL_PRECISION_BITS:
        return 1.0
    return np.dtype(wire_dtype(comm_bits, n_clients)).itemsize / 4.0


def grad_wire_rounds(params_tree, *, fsdp: int, n_clients: int,
                     comm_bits_seq) -> list[dict]:
    """Per-round wire rows for a (possibly adaptive) comm-bit schedule.

    One row per round: the round index, its executed ``comm`` bits, and the
    :func:`grad_wire_report` byte accounting at those bits.  Distinct
    bit-widths are computed once and reused, so a K-policy schedule costs K
    tree walks, not R.
    """
    cache: dict[int, dict] = {}
    rows = []
    for r, bits in enumerate(comm_bits_seq):
        bits = int(bits)
        if bits not in cache:
            cache[bits] = grad_wire_report(params_tree, fsdp=fsdp,
                                           n_clients=n_clients,
                                           comm_bits=bits)
        rep = cache[bits]
        rows.append({
            "round": r,
            "comm_bits": bits,
            "wire_dtype": rep["wire_dtype"],
            "replicated_bytes_wire": rep["replicated_bytes_wire"],
            "replicated_bytes_f32": rep["replicated_bytes_f32"],
            "wire_ratio": rep["wire_ratio"],
        })
    return rows
