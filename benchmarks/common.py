"""Shared benchmark scaffolding: co-design instances, CSV emission, and the
one BENCH_<name>.json writer every benchmark reports through."""

from __future__ import annotations

import contextlib
import json
import os
import time

import numpy as np

from repro.core.channel import ChannelModel
from repro.core.convergence import quant_noise
from repro.core.energy import CommParams, alpha_coefficients, heterogeneous_fleet, memory_capacities
from repro.core.master import MasterSpec
from repro.core.primal import PrimalData, _round_tmin


def codesign_instance(n=10, rounds=4, seed=0, b_max=20e6, grad_mb=1.25,
                      group_step_mhz=5.0, t_factor=1.15, frac_8=0.4,
                      cap_lo_frac=0.5, cap_hi_frac=1.5, policy=None):
    """A (PrimalData, MasterSpec, fleet, channel, comm) tuple like the paper's
    simulation setting (§5.1): N0=-174dBm, 2-20dBm tx power, heterogeneous
    fleet in 4 compute groups, non-trivial memory limits.

    ``policy`` (:class:`repro.api.PrecisionPolicy`) supplies the bit lattice
    the master searches; defaults to the paper's (8, 16, 32)."""
    fleet = heterogeneous_fleet(n, seed=seed, group_step_mhz=group_step_mhz)
    ch = ChannelModel(n_devices=n, seed=seed)
    comm = CommParams(b_max_hz=b_max, grad_bytes=grad_mb * 1e6)
    gains = ch.gain_matrix(rounds)
    p_comm = np.array([d.p_comm for d in fleet])
    a1 = np.zeros((rounds, n))
    a2 = np.zeros((rounds, n))
    for r in range(rounds):
        a1[r], a2[r] = alpha_coefficients(gains[r], p_comm, comm)
    beta1 = np.array([d.beta1 for d in fleet])
    beta2 = np.array([d.beta2 for d in fleet])
    p_comp = np.array([d.runtime_power() for d in fleet])
    tmin32 = _round_tmin(a2, beta1 + 32 * beta2, b_max)
    data = PrimalData(alpha1=a1, alpha2=a2, beta1=beta1, beta2=beta2,
                      p_comp=p_comp, b_max=b_max,
                      t_max=float(t_factor * tmin32.sum()))
    caps = memory_capacities(n, lo_mb=grad_mb * cap_lo_frac,
                             hi_mb=grad_mb * cap_hi_frac) * 1e6
    if policy is None:
        from repro.api.precision import PrecisionPolicy

        policy = PrecisionPolicy()
    spec = MasterSpec(bits_options=policy.bit_options, n_devices=n,
                      error_budget=1.0, mem_capacity_bytes=caps,
                      model_bytes_fp=grad_mb * 1e6)
    # Error budget (constraint 23): bind hard enough that only ~frac_8 of the
    # cohort may take the most aggressive bit-width — this is what makes the
    # bit/bandwidth TRADE (paper Fig. 5) non-degenerate.  Stay feasible w.r.t.
    # memory-forced minimum bit-widths.
    allowed = spec.allowed()
    bits = np.asarray(spec.bits_options)
    # minimum ACHIEVABLE error: every device at its largest memory-feasible
    # bit-width — the budget must sit above this to be feasible at all
    best = np.array([bits[np.flatnonzero(allowed[i])[-1]] for i in range(n)])
    floor = float(np.sum(quant_noise(best) ** 2))
    d8 = float(quant_noise([8])[0] ** 2)
    d16 = float(quant_noise([16])[0] ** 2)
    spec.error_budget = max(floor * 1.05,
                            frac_8 * n * d8 + (1 - frac_8) * n * d16 * 1.05)
    return data, spec, fleet, ch, comm


def csv_header():
    print("name,us_per_call,derived")


_ACTIVE_ROWS: list | None = None


def emit(name: str, value_us: float, derived: str = ""):
    """The run.py CSV contract: ``name,us_per_call,derived``.

    Inside a :func:`bench_output` block every emitted line is also recorded
    as a shared-schema row for the section's ``BENCH_<name>.json``.
    """
    print(f"{name},{value_us:.2f},{derived}")
    if _ACTIVE_ROWS is not None:
        _ACTIVE_ROWS.append(bench_row(name, "us_per_call", value_us, "us",
                                      derived=derived))


def bench_row(cell: str, metric: str, value: float, units: str,
              git_sha: str | None = None, **extra) -> dict:
    """One row of the shared benchmark schema.

    ``git_sha`` defaults to the current HEAD; replay paths (benches that
    resume from a sweep store) must pass the *stored* record's sha so the
    row says which commit produced the measurement, not which one reread it.
    """
    return {"cell": cell, "metric": metric, "value": float(value),
            "units": units, "git_sha": git_sha or _git_sha(), **extra}


_SHA_CACHE: list[str] = []


def _git_sha() -> str:
    if not _SHA_CACHE:
        from repro.sweep.runner import git_sha

        _SHA_CACHE.append(git_sha())
    return _SHA_CACHE[0]


def write_bench(name: str, rows: list[dict], out_dir: str = "results") -> str:
    """Write ``BENCH_<name>.json`` (the machine-readable benchmark output)."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


@contextlib.contextmanager
def bench_output(name: str, out_dir: str = "results"):
    """Collect every :func:`emit` inside the block into BENCH_<name>.json.

    Yields the row list so a section can append non-CSV rows
    (:func:`bench_row`) alongside the emitted ones.
    """
    global _ACTIVE_ROWS
    prev, _ACTIVE_ROWS = _ACTIVE_ROWS, []
    try:
        yield _ACTIVE_ROWS
        write_bench(name, _ACTIVE_ROWS, out_dir)
    finally:
        _ACTIVE_ROWS = prev


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeats * 1e6, out
