"""Theorem 1 / Corollary 1 check: empirical average grad-norm vs the bound,
and the quantization error floor as bit-widths shrink."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_output, emit
from repro.core.convergence import (ProblemConstants, corollary1_bound,
                                    estimate_constants_from_trace, quant_noise)
from repro.data import ClientBatcher, SyntheticImages, dirichlet_partition
from repro.fed import FLSimulation, SimConfig
from repro.models.cnn import mobilenet, xent_loss


def main(rounds=25, n_clients=6):
    with bench_output("bound"):
        model = mobilenet(width=8, n_stages=2)
        loss = xent_loss(model)
        imgs, labels = SyntheticImages(n=1024, hw=16).generate()
        parts = dirichlet_partition(labels, n_clients, alpha=0.5)
        batcher = ClientBatcher(imgs, labels, parts, batch=16)

        results = {}
        for bits in (32, 8, 4, 2):
            sim = FLSimulation(loss, model.init, SimConfig(n_clients=n_clients, lr=0.05))
            for r in range(rounds):
                x, y = batcher.sample_round(r, np.arange(n_clients))
                sim.run_round({"x": jnp.asarray(x), "y": jnp.asarray(y)},
                              np.full(n_clients, bits))
            gsq = [h["grad_norm_sq"] for h in sim.history]
            results[bits] = float(np.mean(gsq))

        # empirical floors should be ordered by delta^2 (Cor. 1 quantization term)
        d2 = {b: float(quant_noise([b])[0] ** 2) for b in results}
        emit("bound_grad_norms", 0.0,
             ";".join(f"q{b}={results[b]:.4f}" for b in results))
        emit("bound_floor_ordering", 0.0,
             f"q2>=q32:{results[2] >= results[32] * 0.8};"
             f"delta_sq_q2={d2[2]:.2e};delta_sq_q8={d2[8]:.2e}")

        # theory curve anchored on the fp trace
        losses = [h["loss"] for h in sim.history]
        consts = estimate_constants_from_trace(gsq, losses, d=1 << 14,
                                               M=16, N=n_clients)
        bound = corollary1_bound(consts, rounds, quant_noise([8] * n_clients))
        emit("bound_corollary1", 0.0,
             f"empirical_q8={results[8]:.4f};bound={bound:.4f};"
             f"holds={results[8] <= bound * 1.5}")
    return results


if __name__ == "__main__":
    main()
