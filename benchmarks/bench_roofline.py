"""§Roofline table — reads the ``roofline-all-archs`` sweep store
(``results/sweep_roofline-all-archs.jsonl``), falling back to the legacy
dry-run JSON artifacts.  Populate with ``repro-sweep run roofline-all-archs``.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import bench_output, bench_row, emit

LEGACY = ("results/dryrun_single.json", "results/dryrun_multi.json")


def load_rows():
    """Cell metric dicts, each tagged with the git_sha that measured it."""
    from repro.sweep import ResultsStore, get_preset

    sweep = get_preset("roofline-all-archs")
    store = ResultsStore.for_sweep(sweep, "results")
    rows = [dict(r["metrics"], git_sha=r.get("git_sha"))
            for r in store.rows() if r.get("status") == "ok"]
    if not rows:                       # legacy artifacts are a fallback only
        for path in LEGACY:
            if os.path.exists(path):
                rows.extend(json.load(open(path)))
    return rows


def main():
    with bench_output("roofline") as jrows:
        rows = load_rows()
        if not rows:
            emit("roofline_missing", 0.0,
                 "run `repro-sweep run roofline-all-archs` first")
            return []
        ok = [r for r in rows if r.get("status") == "ok"]
        for r in ok:
            step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            frac = r["compute_s"] / step_s if step_s else 0.0
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                 step_s * 1e6,
                 f"dom={r['dominant']};compute={r['compute_s']:.2e};"
                 f"mem={r['memory_s']:.2e};coll={r['collective_s']:.2e};"
                 f"flops_frac={frac:.2f};useful={r['useful_flops_ratio']:.3f}")
            jrows.append(bench_row(
                f"{r['arch']}_{r['shape']}_{r['mesh']}", "roofline_step",
                step_s, "s", git_sha=r.get("git_sha"),
                dominant=r["dominant"]))
        n_fail = len(rows) - len(ok)
        emit("roofline_summary", 0.0, f"cells_ok={len(ok)};cells_fail={n_fail}")
    return ok


if __name__ == "__main__":
    main()
