"""§Roofline table from the dry-run JSON artifacts (results/dryrun_*.json)."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = ("results/dryrun_single.json", "results/dryrun_multi.json")


def load_rows():
    rows = []
    for path in RESULTS:
        if os.path.exists(path):
            rows.extend(json.load(open(path)))
    return rows


def main():
    rows = load_rows()
    if not rows:
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return []
    ok = [r for r in rows if r.get("status") == "ok"]
    for r in ok:
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / step_s if step_s else 0.0
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             step_s * 1e6,
             f"dom={r['dominant']};compute={r['compute_s']:.2e};"
             f"mem={r['memory_s']:.2e};coll={r['collective_s']:.2e};"
             f"flops_frac={frac:.2f};useful={r['useful_flops_ratio']:.3f}")
    n_fail = len(rows) - len(ok)
    emit("roofline_summary", 0.0, f"cells_ok={len(ok)};cells_fail={n_fail}")
    return ok


if __name__ == "__main__":
    main()
