"""GBD solver quality: UB/LB gap trace + optimality vs exhaustive search."""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_output, codesign_instance, emit, timed
from repro.core.gbd import exhaustive_best, run_gbd


def main():
    with bench_output("gbd"):
        # gap trace on a mid-size instance
        data, spec, *_ = codesign_instance(n=10, rounds=3, seed=2)
        us, res = timed(lambda: run_gbd(data, spec, max_rounds=30), repeats=1)
        emit("gbd_n10", us, f"iters={res.iterations};gap={res.gap:.2e};"
             f"energy={res.energy:.3f}J;converged={res.converged}")

        # exactness on a brute-forceable instance
        data, spec, *_ = codesign_instance(n=4, rounds=2, seed=1)
        res = run_gbd(data, spec, max_rounds=30)
        q_star, v_star = exhaustive_best(data, spec)
        emit("gbd_vs_exhaustive_n4", 0.0,
             f"gbd={res.energy:.5f}J;exhaustive={v_star:.5f}J;"
             f"rel_err={(res.energy - v_star)/v_star:.2e}")
    return res


if __name__ == "__main__":
    main()
