"""Paper Fig. 5: optimal bit-width selection vs total bandwidth.

Devices sit in 4 channel-gain groups g1<=g2<=g3<=g4.  When bandwidth is
scarce, the weak-channel group is forced to the smallest bit-widths ("talk"
dominates); as B_max grows, compute-limited devices compress instead."""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import bench_output, codesign_instance, emit
from repro.core.gbd import run_gbd


def bits_vs_bandwidth(b_maxes=(4e6, 8e6, 20e6, 38e6), n=12, seed=0):
    rows = []
    for b in b_maxes:
        # NOTE: pushing the deadline into the binding regime (t_factor < 1)
        # collides with the bandwidth feasibility cliff at small B_max — see
        # EXPERIMENTS.md Fig. 5 notes; we run at the feasibility boundary.
        data, spec, fleet, ch, comm = codesign_instance(n=n, rounds=3, seed=seed,
                                                        b_max=b, grad_mb=2.5,
                                                        t_factor=1.0)
        res = run_gbd(data, spec, max_rounds=25)
        groups = ch.group_of()
        by_group = {f"g{g+1}": float(np.mean(res.q[groups == g]))
                    for g in range(4)}
        comm_frac = float(np.sum(data.alpha1 / res.bandwidth)
                          / max(res.energy, 1e-12))
        rows.append({"b_max_mhz": b / 1e6, "mean_bits_by_group": by_group,
                     "comm_energy_frac": comm_frac, "energy": res.energy})
    return rows


def main(out_json=""):
    with bench_output("fig5_bandwidth"):
        rows = bits_vs_bandwidth()
        for r in rows:
            g = r["mean_bits_by_group"]
            emit(f"fig5_B{int(r['b_max_mhz'])}MHz", r["energy"] * 1e6,
                 f"g1={g['g1']:.1f};g2={g['g2']:.1f};g3={g['g3']:.1f};"
                 f"g4={g['g4']:.1f};comm_frac={r['comm_energy_frac']:.2f}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
