"""Kernel micro-benchmarks: us/call + derived GB/s (interpret mode on CPU —
the numbers validate plumbing/shape behavior; real rates need a TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_output, emit, timed
from repro.kernels import ops


def main():
    with bench_output("kernels"):
        key = jax.random.PRNGKey(0)

        w = jax.random.normal(key, (1024, 1024), jnp.float32)
        us, _ = timed(lambda: jax.block_until_ready(ops.sr_quantize_fused(w, key, 7)),
                      repeats=3)
        emit("kernel_sr_quant_1024x1024", us, f"GBps={w.nbytes*2/us/1e3:.2f}")

        x = jax.random.normal(key, (256, 2048), jnp.bfloat16)
        codes = jax.random.randint(key, (2048, 1024), -127, 128, jnp.int8)
        scale = jnp.float32(0.01)
        us, _ = timed(lambda: jax.block_until_ready(ops.quant_matmul(x, codes, scale)),
                      repeats=3)
        flops = 2 * 256 * 2048 * 1024
        emit("kernel_quant_matmul_256x2048x1024", us, f"GFLOPs={flops/us/1e3:.2f}")

        # decode-shaped: a handful of rows (adaptive bm keeps the grid tight)
        xd = jax.random.normal(key, (4, 2048), jnp.float32)
        us, _ = timed(lambda: jax.block_until_ready(ops.quant_matmul(xd, codes, scale)),
                      repeats=3)
        emit("kernel_quant_matmul_decode_4x2048x1024", us,
             f"GBps_weights={codes.nbytes/us/1e3:.2f}")

        # ragged / non-128-aligned (padding + masking path)
        xr = jax.random.normal(key, (300, 700), jnp.float32)
        cr = jax.random.randint(key, (700, 200), -127, 128, jnp.int8)
        us, _ = timed(lambda: jax.block_until_ready(ops.quant_matmul(xr, cr, scale)),
                      repeats=3)
        emit("kernel_quant_matmul_ragged_300x700x200", us, "non_aligned=True")

        q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32)
        us, _ = timed(lambda: jax.block_until_ready(ops.flash_attention(q, q, q)),
                      repeats=2)
        emit("kernel_flash_attention_4h_1024", us, "interpret_mode=True")


if __name__ == "__main__":
    main()
