"""Paper Fig. 4: energy vs device heterogeneity.

10 devices in 4 groups with core clocks C, C+5L, C+15L, C+20L MHz
(C=1400); L sweeps 0..10.  Heterogeneity raises total energy; FWQ's
per-device bit-widths absorb part of it."""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import bench_output, codesign_instance, emit
from repro.core import baselines
from repro.core.gbd import run_gbd


def energy_vs_hetero(Ls=(0, 2, 4, 6, 8, 10), n=10, seed=0):
    rows = []
    for L in Ls:
        data, spec, *_ = codesign_instance(n=n, rounds=3, seed=seed,
                                           group_step_mhz=float(L))
        out = {"L": L}
        out["fwq"] = run_gbd(data, spec, max_rounds=20).energy
        out["full_precision"] = baselines.full_precision(data, spec).energy
        out["unified_q"] = baselines.unified_q(data, spec).energy
        out["rand_q"] = baselines.rand_q(data, spec, seed=seed).energy
        out["q_spread"] = int(len(np.unique(run_gbd(data, spec, max_rounds=10).q)))
        rows.append(out)
    return rows


def main(out_json=""):
    with bench_output("fig4_hetero"):
        rows = energy_vs_hetero()
        for r in rows:
            emit(f"fig4_L{r['L']}", r["fwq"] * 1e6,
                 f"fwq={r['fwq']:.3f}J;fp={r['full_precision']:.3f}J;"
                 f"uq={r['unified_q']:.3f}J;q_spread={r['q_spread']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
