"""Benchmark runner: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see benchmarks/common.emit);
every section also writes a shared-schema ``results/BENCH_<name>.json``
(benchmarks/common.bench_output).  Sections with an experiment grid
(fig2_convergence, serving, roofline) are thin wrappers over
``repro.sweep`` presets and resume from the sweep's results store.

    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run --only fig2,kernels
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import csv_header

SECTIONS = {
    "fig2_convergence": "benchmarks.bench_convergence",
    "fig3_users": "benchmarks.bench_users",
    "fig4_hetero": "benchmarks.bench_hetero",
    "fig5_bandwidth": "benchmarks.bench_bandwidth",
    "gbd": "benchmarks.bench_gbd",
    "bound": "benchmarks.bench_bound",
    "kernels": "benchmarks.bench_kernels",
    "roofline": "benchmarks.bench_roofline",
    "serving": "benchmarks.bench_serving",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated section filter")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    csv_header()
    failures = []
    for name, mod_name in SECTIONS.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception as e:  # pragma: no cover
            traceback.print_exc()
            failures.append((name, str(e)))
            print(f"{name}_FAILED,0,{e}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# {len(failures)} section(s) failed", file=sys.stderr)
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
